//! Property tests over the coordinator invariants (routing/topology
//! state, batching of ring swaps, membership-state machine), using the
//! in-tree prop framework (seeded, replayable).

use dgro::graph::{apsp, components, diameter, eval::EvalPool, ring::Ring, Graph};
use dgro::latency::Model;
use dgro::membership::list::{MemberState, MembershipList};
use dgro::prop::{ensure, ensure_close, forall, Config as PropConfig};
use dgro::topology::{kring, paper_k, random_ring, shortest_ring};
use dgro::util::rng::Rng;

fn random_model(rng: &mut Rng) -> Model {
    Model::ALL[rng.index(Model::ALL.len())]
}

#[test]
fn prop_rings_are_hamiltonian_cycles() {
    forall("ring structure", PropConfig::default().cases(64), |rng| {
        let n = 3 + rng.index(120);
        let w = random_model(rng).sample(n, rng);
        let ring = if rng.chance(0.5) {
            random_ring(n, rng)
        } else {
            shortest_ring(&w, rng.index(n))
        };
        ring.validate().map_err(|e| e.to_string())?;
        let g = ring.to_graph(&w);
        ensure(g.m() == n, format!("{} edges for n={n}", g.m()))?;
        for u in 0..n {
            ensure(g.degree(u) == 2, format!("degree {} at {u}", g.degree(u)))?;
        }
        ensure(components::is_connected(&g), "ring must be connected")
    });
}

#[test]
fn prop_kring_degree_bounded_and_connected() {
    forall("kring invariants", PropConfig::default().cases(40), |rng| {
        let n = 8 + rng.index(100);
        let k = 1 + rng.index(paper_k(n));
        let m_random = rng.index(k + 1);
        let w = random_model(rng).sample(n, rng);
        let kr = kring::hybrid_krings(&w, k, m_random, rng);
        let g = kr.to_graph(&w);
        ensure(
            g.max_degree() <= 2 * k,
            format!("degree {} > 2K={}", g.max_degree(), 2 * k),
        )?;
        ensure(components::is_connected(&g), "K-ring must be connected")
    });
}

#[test]
fn prop_diameter_monotone_under_edge_addition() {
    forall("diameter monotonicity", PropConfig::default().cases(40), |rng| {
        let n = 6 + rng.index(40);
        let w = Model::Uniform.sample(n, rng);
        let r = random_ring(n, rng);
        let g1 = r.to_graph(&w);
        let d1 = diameter::diameter(&g1);
        // Add another ring: diameter must not increase.
        let g2 = g1.union(&random_ring(n, rng).to_graph(&w));
        let d2 = diameter::diameter(&g2);
        ensure(d2 <= d1 + 1e-4, format!("{d1} -> {d2} after adding edges"))
    });
}

#[test]
fn prop_parallel_eval_matches_serial_across_thread_counts() {
    // The EvalPool entry points (apsp_par / diameter_par /
    // diameter_with_seeds / diameter_batch) must return the serial
    // values on random K-ring overlays for every pool width —
    // parallelism changes the schedule, never the result.
    forall(
        "parallel eval equivalence",
        PropConfig::default().cases(10),
        |rng| {
            let n = 8 + rng.index(56);
            let w = random_model(rng).sample(n, rng);
            let k = paper_k(n);
            let g = kring::random_krings(n, k, rng).to_graph(&w);
            let dm = apsp::apsp(&g);
            let d_serial = diameter::diameter(&g) as f64;
            let cands: Vec<Graph> = (0..3)
                .map(|_| kring::random_krings(n, k, rng).to_graph(&w))
                .collect();
            let serial_batch: Vec<f32> =
                cands.iter().map(diameter::diameter).collect();
            let seeds: Vec<u32> =
                (0..3).map(|_| rng.index(n) as u32).collect();
            // The fixed sweep schedule makes the bounding diameter a
            // pure function of (graph, seeds): one-worker reference
            // values, which wider pools must reproduce bit-for-bit.
            let d_ref = EvalPool::new(1).diameter_par(&g);
            let ds_ref = EvalPool::new(1).diameter_with_seeds(&g, &seeds);
            for &threads in &[1usize, 2, 8] {
                let pool = EvalPool::new(threads);
                let pm = pool.apsp_par(&g);
                for i in 0..n * n {
                    let (x, y) = (dm.d[i], pm.d[i]);
                    ensure(
                        x.to_bits() == y.to_bits(),
                        format!("apsp[{i}]: {x} vs {y} T={threads}"),
                    )?;
                }
                let tol = 1e-3 * d_serial.max(1.0);
                let dp = pool.diameter_par(&g);
                ensure_close(dp as f64, d_serial, tol)?;
                ensure(
                    dp.to_bits() == d_ref.to_bits(),
                    format!("diameter_par {dp} vs {d_ref} T={threads}"),
                )?;
                let (ds, landmarks) = pool.diameter_with_seeds(&g, &seeds);
                ensure_close(ds as f64, d_serial, tol)?;
                ensure(
                    ds.to_bits() == ds_ref.0.to_bits()
                        && landmarks == ds_ref.1,
                    format!("warm certification drifted at T={threads}"),
                )?;
                ensure(
                    !landmarks.is_empty(),
                    "connected overlay must yield landmarks",
                )?;
                let (dw, _) = pool.diameter_with_seeds(&g, &landmarks);
                ensure_close(dw as f64, d_serial, tol)?;
                let pb = pool.diameter_batch(&cands);
                for (a, b) in serial_batch.iter().zip(&pb) {
                    ensure(
                        a.to_bits() == b.to_bits(),
                        format!("batch: {a} vs {b} T={threads}"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_diameter_est_brackets_exact_across_budgets_and_threads() {
    // The certified estimator's interval must contain the exact
    // Takes–Kosters diameter at every landmark budget, and be a pure
    // function of (graph, seeds, budget): pool width changes the
    // schedule, never the certified bounds.
    forall(
        "diameter_est bracketing",
        PropConfig::default().cases(8).seed(0xD1A),
        |rng| {
            let n = 16 + rng.index(1009); // up to 1024 nodes
            let w = random_model(rng).sample(n, rng);
            let g = kring::random_krings(n, paper_k(n), rng).to_graph(&w);
            let exact = diameter::diameter(&g) as f64;
            let tol = 1e-3 * exact.max(1.0);
            for &budget in &[4usize, 16, 64] {
                let reference =
                    EvalPool::new(1).diameter_est(&g, &[], budget);
                for &threads in &[2usize, 8] {
                    let est = EvalPool::new(threads)
                        .diameter_est(&g, &[], budget);
                    let a = (
                        est.lower.to_bits(),
                        est.upper.to_bits(),
                        &est.landmarks,
                        est.sweeps,
                    );
                    let b = (
                        reference.lower.to_bits(),
                        reference.upper.to_bits(),
                        &reference.landmarks,
                        reference.sweeps,
                    );
                    ensure(
                        a == b,
                        format!("T={threads} b={budget} drifted"),
                    )?;
                }
                ensure(
                    f64::from(reference.lower) <= exact + tol,
                    format!(
                        "b={budget}: lower {} > exact {exact}",
                        reference.lower
                    ),
                )?;
                ensure(
                    exact <= f64::from(reference.upper) + tol,
                    format!(
                        "b={budget}: exact {exact} > upper {}",
                        reference.upper
                    ),
                )?;
                ensure(
                    reference.sweeps <= budget,
                    "estimator overspent its sweep budget",
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_apsp_triangle_inequality_and_symmetry() {
    forall("apsp metric axioms", PropConfig::default().cases(25), |rng| {
        let n = 5 + rng.index(30);
        let w = random_model(rng).sample(n, rng);
        let k = paper_k(n);
        let g = kring::random_krings(n, k, rng).to_graph(&w);
        let dm = apsp::apsp(&g);
        for _ in 0..50 {
            let (i, j, l) = (rng.index(n), rng.index(n), rng.index(n));
            let (dij, dji) = (dm.get(i, j), dm.get(j, i));
            ensure_close(dij as f64, dji as f64, 1e-3)?;
            let (dil, dlj) = (dm.get(i, l), dm.get(l, j));
            if dil.is_finite() && dlj.is_finite() {
                ensure(
                    dij <= dil + dlj + 1e-3,
                    format!("triangle violated: d({i},{j})={dij} > {dil}+{dlj}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_partitions_preserve_membership() {
    forall("partition stitching", PropConfig::default().cases(40), |rng| {
        let n = 6 + rng.index(200);
        let m = 1 + rng.index(n.min(64));
        let base = random_ring(n, rng);
        let parts = dgro::dgro::parallel::partition(base.order(), m);
        ensure(parts.len() == m, "exactly M partitions")?;
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let (mn, mx) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        ensure(mx - mn <= 1, format!("unbalanced: {mn}..{mx}"))?;
        let mut all: Vec<u32> = parts.concat();
        all.sort_unstable();
        ensure(
            all == (0..n as u32).collect::<Vec<_>>(),
            "partitions must cover every node exactly once",
        )
    });
}

#[test]
fn prop_parallel_ring_always_valid() {
    forall("parallel ring validity", PropConfig::default().cases(25), |rng| {
        let n = 6 + rng.index(80);
        let m = 1 + rng.index(n / 2);
        let w = random_model(rng).sample(n, rng);
        let ring = dgro::dgro::parallel::parallel_ring_greedy(
            &w,
            dgro::dgro::parallel::ParallelConfig::new(m),
            rng,
        )
        .map_err(|e| e.to_string())?;
        ring.validate().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_membership_merge_is_monotone() {
    // The SWIM merge rule: records never regress to lower incarnation,
    // and at equal incarnation precedence only moves Alive->Suspect->
    // Faulty. Applying a random update stream in any order converges.
    forall("membership monotonicity", PropConfig::default().cases(40), |rng| {
        let n = 4 + rng.index(20);
        let mut list = MembershipList::full(n);
        let states = [
            MemberState::Alive,
            MemberState::Suspect,
            MemberState::Faulty,
        ];
        let mut max_inc = vec![0u64; n];
        for step in 0..100 {
            let id = rng.index(n) as u32;
            let st = states[rng.index(3)];
            let inc = rng.below(4);
            list.apply(id, st, inc, step as f64);
            let rec = list.get(id).unwrap();
            max_inc[id as usize] = max_inc[id as usize].max(inc);
            ensure(
                rec.incarnation >= max_inc[id as usize].min(rec.incarnation),
                "incarnation regressed",
            )?;
        }
        // A final fresh-incarnation Alive must always win.
        list.apply(0, MemberState::Alive, 100, 200.0);
        ensure(
            list.get(0).unwrap().state == MemberState::Alive,
            "fresh Alive must refute anything older",
        )
    });
}

#[test]
fn prop_ring_canonicalization_is_rotation_reflection_invariant() {
    forall("ring canonical form", PropConfig::default().cases(40), |rng| {
        let n = 4 + rng.index(30);
        let ring = random_ring(n, rng);
        let order = ring.order().to_vec();
        // Random rotation.
        let shift = rng.index(n);
        let rotated: Vec<u32> = (0..n)
            .map(|i| order[(i + shift) % n])
            .collect();
        // Random reflection.
        let mut reflected = rotated.clone();
        if rng.chance(0.5) {
            reflected.reverse();
        }
        let a = ring.canonical();
        let b = Ring::new(reflected).unwrap().canonical();
        ensure(a == b, "canonical form must kill rotation/reflection")
    });
}

#[test]
fn prop_gossip_rho_in_unit_interval() {
    forall("rho is a ratio", PropConfig::default().cases(25), |rng| {
        let n = 6 + rng.index(60);
        let w = random_model(rng).sample(n, rng);
        let g = kring::random_krings(n, paper_k(n).max(1), rng).to_graph(&w);
        let stats = dgro::gossip::measure::measure(
            &w,
            &g,
            dgro::gossip::measure::MeasureConfig::default(),
            rng,
        );
        let rho = stats.rho();
        ensure((0.0..=1.0).contains(&rho), format!("rho {rho} out of [0,1]"))
    });
}

#[test]
fn prop_gossip_measurement_converges_to_exact_averages() {
    // Algorithm 3's gossiped (local, global, min) triple must approach
    // the exact network averages once the sample count and round count
    // are large, across random seeds, latency models and topologies.
    use dgro::gossip::measure::{exact_stats, measure, MeasureConfig};
    forall(
        "gossip convergence",
        PropConfig::default().cases(12).seed(0x60551),
        |rng| {
            let n = 24 + rng.index(60);
            let w = random_model(rng).sample(n, rng);
            let g = if rng.chance(0.5) {
                kring::random_krings(n, paper_k(n), rng).to_graph(&w)
            } else {
                shortest_ring(&w, rng.index(n)).to_graph(&w)
            };
            let est = measure(
                &w,
                &g,
                MeasureConfig {
                    samples: 24,
                    rounds: 80,
                },
                rng,
            );
            let exact = exact_stats(&w, &g);
            ensure(
                est.messages == 80 * n,
                format!("{} messages for n={n}", est.messages),
            )?;
            let rel = |a: f64, b: f64| (a - b).abs() / b.max(1e-9);
            ensure(
                rel(est.local, exact.local) < 0.3,
                format!("local {} vs exact {}", est.local, exact.local),
            )?;
            ensure(
                rel(est.global, exact.global) < 0.3,
                format!("global {} vs exact {}", est.global, exact.global),
            )?;
            // Per-node minimums average below per-node means, and gossip
            // mixing (a convex combination of phase-1 values) preserves
            // that ordering.
            ensure(
                est.min <= est.global + 1e-9,
                format!("min {} > global {}", est.min, est.global),
            )
        },
    );
}

// ---------------------------------------------------------------------
// Traffic-plane routing invariants (PR 8): greedy routing over
// arbitrary connected alive overlays, with shrinking to a minimal
// counterexample on failure (docs/TRAFFIC.md §routing).

#[test]
fn prop_greedy_routing_terminates_avoids_dead_nodes_and_bounds_stretch() {
    use dgro::prop::{forall_shrunk, OverlayCase};
    use dgro::traffic::{greedy_route, RouteScratch};
    forall_shrunk(
        "greedy routing invariants",
        PropConfig::default().cases(48).seed(0x7AFF_2026),
        |rng| OverlayCase::arbitrary(rng, 512),
        |c| c.shrinks(),
        |c| {
            let (g, w) = c.graph();
            let mut scratch = RouteScratch::new(g.n());
            let mut path = Vec::new();
            // A deterministic batch of (src, dst) pairs per case.
            let mut pick = Rng::new(c.seed ^ 0x51AC_ED);
            for _ in 0..8 {
                let src = c.alive[pick.index(c.alive.len())];
                let dst = c.alive[pick.index(c.alive.len())];
                let s = greedy_route(
                    &g,
                    &w,
                    src,
                    dst,
                    &mut scratch,
                    Some(&mut path),
                );
                // Termination: each hop claims an unvisited node, so a
                // route can never take more hops than there are alive
                // nodes.
                ensure(
                    (s.hops as usize) <= c.alive.len(),
                    format!("{} hops > {} alive", s.hops, c.alive.len()),
                )?;
                // The path stays on the alive overlay: every node is
                // alive, every step is a real edge of the alive graph.
                for &v in &path {
                    ensure(
                        c.alive.binary_search(&v).is_ok(),
                        format!("dead node {v} on path"),
                    )?;
                }
                let mut walked = 0.0_f64;
                for hop in path.windows(2) {
                    ensure(
                        g.has_edge(hop[0] as usize, hop[1] as usize),
                        format!("phantom edge {}-{}", hop[0], hop[1]),
                    )?;
                    walked += f64::from(
                        w.get(hop[0] as usize, hop[1] as usize),
                    );
                }
                ensure_close(walked, s.latency_ms, 1e-3)?;
                if s.delivered {
                    ensure(
                        path.last() == Some(&dst),
                        "delivered route must end at dst",
                    )?;
                    // Stretch >= 1: the greedy path is a path, so its
                    // latency is bounded below by the shortest one.
                    let dist = f64::from(
                        apsp::dijkstra(&g, src as usize)[dst as usize],
                    );
                    ensure(
                        s.latency_ms + 1e-3 >= dist,
                        format!(
                            "greedy {} below shortest {dist}",
                            s.latency_ms
                        ),
                    )?;
                }
                if src != dst && g.has_edge(src as usize, dst as usize) {
                    // Direct neighbors deliver in one hop, and in the
                    // metric embedding that edge IS a shortest path:
                    // stretch == 1 exactly.
                    ensure(
                        s.delivered && s.hops == 1,
                        format!("direct {src}->{dst} took {} hops", s.hops),
                    )?;
                    let dist = f64::from(
                        apsp::dijkstra(&g, src as usize)[dst as usize],
                    );
                    ensure_close(s.latency_ms, dist, 1e-3)?;
                }
            }
            Ok(())
        },
    );
}
