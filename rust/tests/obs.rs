//! Integration pins for the observability subsystem (`dgro::obs`):
//!
//! * the sim-transport flight-recorder timeline exports
//!   **byte-identically** across repeated runs of the same
//!   (spec, seed) — the determinism contract `--obs-out` relies on;
//! * sharded runs export the same timeline and counter snapshot for
//!   every worker thread count (wall-time instruments live only in
//!   registry histograms, which the deterministic exports exclude);
//! * the loss-hardening counters (`net.stale_frames`,
//!   `net.dup_frames`, `net.probe_retx`, `net.frames_lost`) flow
//!   end-to-end from a seeded [`LossyTransport`]-backed replay into
//!   both the registry and the synced [`Metrics`] view;
//! * the combined artifact set (timeline + causal traces + request
//!   traces + health digest) of sharded × traffic and lossy
//!   traced-transport × traffic runs is byte-identical across repeats
//!   and worker thread counts, with zero trace orphans and a
//!   reproducible critical path.

#![allow(clippy::field_reassign_with_default)] // config-mutation idiom

use dgro::net::TransportKind;
use dgro::obs::{health_json, trace};
use dgro::scenario::{
    ChurnSpec, ScenarioEngine, ScenarioReport, ScenarioSpec, Topology,
};
use dgro::traffic::TrafficConfig;

fn obs_spec(horizon: f64) -> ScenarioSpec {
    ScenarioSpec {
        name: "obs-pin".into(),
        about: "observability determinism workload".into(),
        nodes: 24,
        initial_alive: 24,
        model: "fabric".into(),
        horizon,
        churn: vec![ChurnSpec::Poisson { rate: 0.002 }],
        latency: vec![],
    }
}

fn sim_run(seed: u64) -> ScenarioReport {
    let mut engine = ScenarioEngine::new(obs_spec(1000.0), seed).unwrap();
    engine.opts.transport = Some(TransportKind::Sim);
    engine.opts.obs_record = true;
    engine.run(Topology::Dgro).unwrap()
}

#[test]
fn sim_timeline_jsonl_is_byte_identical_across_runs() {
    let a = sim_run(0);
    let b = sim_run(0);
    let ja = a.obs.as_ref().unwrap().rec.export_jsonl(true).unwrap();
    let jb = b.obs.as_ref().unwrap().rec.export_jsonl(true).unwrap();
    assert!(!ja.is_empty(), "a recording run must capture spans");
    assert_eq!(ja, jb, "sim timelines must be byte-identical");
    // The adaptive loop's span vocabulary is present...
    for kind in ["period", "measure", "gossip", "decide"] {
        assert!(
            ja.contains(&format!("\"kind\": \"{kind}\"")),
            "missing {kind} spans in:\n{ja}"
        );
    }
    // ...and the deterministic export carries no wall-clock field.
    assert!(
        !ja.contains("wall_ms"),
        "sim-only export must omit wall_ms"
    );
    // A different seed records a different timeline (the pin is not
    // comparing empty or constant strings).
    let c = sim_run(1);
    let jc = c.obs.as_ref().unwrap().rec.export_jsonl(true).unwrap();
    assert_ne!(ja, jc, "seeds 0 and 1 produced identical timelines");
}

#[test]
fn sharded_obs_exports_are_thread_count_invariant() {
    let run = |threads: usize| {
        let mut engine =
            ScenarioEngine::new(obs_spec(2000.0), 3).unwrap();
        engine.opts.shards = 4;
        engine.opts.threads = threads;
        engine.opts.obs_record = true;
        let rep = engine.run(Topology::DgroSharded).unwrap();
        let obs = rep.obs.as_ref().unwrap();
        (
            obs.rec.export_jsonl(true).unwrap(),
            obs.reg.counters_snapshot(),
            rep.render(),
        )
    };
    let (t1, c1, r1) = run(1);
    for threads in [2usize, 8] {
        let (t, c, r) = run(threads);
        assert_eq!(t1, t, "timeline differs at T={threads}");
        assert_eq!(c1, c, "counter snapshot differs at T={threads}");
        assert_eq!(r1, r, "rendered report differs at T={threads}");
    }
}

#[test]
fn lossy_replay_counters_reach_registry_and_synced_metrics() {
    // Loss forces probe retransmits, duplication forces the per-phase
    // dedup filter, and straggling copies past a phase barrier are
    // rejected as stale. Individual counters are seed-dependent, so
    // each is asserted over a small seed union while the
    // registry-vs-metrics agreement is asserted per run.
    let mut stale = 0u64;
    let mut dup = 0u64;
    let mut retx = 0u64;
    let mut lost = 0u64;
    for seed in 0..3u64 {
        let mut engine =
            ScenarioEngine::new(obs_spec(2000.0), seed).unwrap();
        engine.opts.transport = Some(TransportKind::Sim);
        engine.opts.loss_rate = 0.08;
        engine.opts.dup_rate = 0.25;
        engine.opts.reorder_rate = 0.25;
        let rep = engine.run(Topology::Dgro).unwrap();
        let obs = rep.obs.as_ref().unwrap();
        for name in [
            "net.stale_frames",
            "net.dup_frames",
            "net.probe_retx",
            "net.frames_lost",
            "net.frames_sent",
        ] {
            assert_eq!(
                obs.reg.get(name),
                rep.metrics.counter(name),
                "seed {seed}: {name} diverged between the registry \
                 and the synced metrics view"
            );
        }
        stale += obs.reg.get("net.stale_frames");
        dup += obs.reg.get("net.dup_frames");
        retx += obs.reg.get("net.probe_retx");
        lost += obs.reg.get("net.frames_lost");
        assert!(
            obs.reg.counter_vec("net.peer.injected_drops", 1).total()
                > 0,
            "seed {seed}: the loss decorator recorded no drops"
        );
    }
    assert!(lost > 0, "8% loss wrote no frames off");
    assert!(retx > 0, "lost probes must be retransmitted");
    assert!(dup > 0, "25% duplication tripped no dedup filter");
    assert!(stale > 0, "no straggler was rejected by its epoch tag");
}

// The deterministic artifact surface of one run: the sim timeline, the
// plain counters, the sampled request traces and the SLO-aware health
// digest. snapshot.json / metrics.prom are deliberately absent — their
// histograms carry wall-clock instruments (period wall time, decode
// µs) that no two processes reproduce.
type ArtifactSet =
    (String, Vec<(String, u64)>, String, String);

#[test]
fn sharded_traffic_combined_artifacts_are_thread_invariant() {
    let run = |threads: usize| -> ArtifactSet {
        let mut engine =
            ScenarioEngine::new(obs_spec(2000.0), 3).unwrap();
        engine.opts.shards = 4;
        engine.opts.threads = threads;
        engine.opts.obs_record = true;
        let mut tcfg = TrafficConfig::default();
        tcfg.rate = 20_000.0;
        tcfg.trace_sample = 5;
        let (rep, traffic, tobs) = engine
            .run_traffic(Topology::DgroSharded, tcfg)
            .unwrap();
        let obs = rep.obs.as_ref().unwrap();
        (
            obs.rec.export_jsonl(true).unwrap(),
            obs.reg.counters_snapshot(),
            traffic.traces_jsonl(),
            health_json(&tobs.reg.to_json(), Some(&traffic.slo()))
                .to_string(),
        )
    };
    let base = run(1);
    assert!(!base.2.is_empty(), "sampling must record request traces");
    assert!(base.3.contains("\"checks\""), "health digest is empty");
    assert_eq!(base, run(1), "repeat run diverged");
    for threads in [2usize, 8] {
        assert_eq!(base, run(threads), "artifacts differ at T={threads}");
    }
}

#[test]
fn traced_lossy_traffic_run_is_reproducible_and_orphan_free() {
    // The PR's acceptance scenario: seeded sim transport with 5% loss,
    // full causal tracing, sampled request traces. Every artifact and
    // the extracted critical path must be byte-identical across
    // repeats and worker thread counts, and the assembled causal
    // forest must contain no orphan spans.
    let run = |threads: usize| -> (ArtifactSet, String) {
        let mut engine =
            ScenarioEngine::new(obs_spec(1000.0), 5).unwrap();
        engine.opts.threads = threads;
        engine.opts.transport = Some(TransportKind::Sim);
        engine.opts.loss_rate = 0.05;
        engine.opts.obs_record = true;
        engine.opts.trace_sample = 1;
        let mut tcfg = TrafficConfig::default();
        tcfg.rate = 20_000.0;
        tcfg.trace_sample = 3;
        let (rep, traffic, tobs) =
            engine.run_traffic(Topology::Dgro, tcfg).unwrap();
        let obs = rep.obs.as_ref().unwrap();
        let timeline = obs.rec.export_jsonl(true).unwrap();
        let spans = trace::parse_jsonl(&timeline).unwrap();
        let forest = trace::assemble(&spans);
        assert_eq!(forest.traces.len(), 4, "one trace per period");
        let mut critical = String::new();
        for t in &forest.traces {
            assert!(
                t.orphans.is_empty(),
                "orphan spans at T={threads}: {:?}",
                t.orphans
            );
            assert!(
                t.spans.iter().any(|s| s.kind == "deliver"),
                "no cross-node deliver span was captured"
            );
            let (chain, ms) = t.critical_chain();
            assert!(chain.contains(" -> "), "degenerate chain {chain}");
            critical.push_str(&format!("{chain} {ms:.3}\n"));
        }
        let set = (
            timeline,
            obs.reg.counters_snapshot(),
            traffic.traces_jsonl(),
            health_json(&tobs.reg.to_json(), Some(&traffic.slo()))
                .to_string(),
        );
        (set, critical)
    };
    let base = run(1);
    assert!(!base.0 .2.is_empty(), "no request traces were sampled");
    assert_eq!(base, run(1), "repeat run diverged");
    for threads in [2usize, 8] {
        assert_eq!(
            base,
            run(threads),
            "artifacts or critical path differ at T={threads}"
        );
    }
}
