//! Smoke-run every figure harness in quick mode: each must produce
//! non-empty tables with the documented column structure, and the
//! paper-shape assertions that are cheap enough for CI live here.

use dgro::bench_harness::{run_figure, ALL_FIGURES};

#[test]
fn every_figure_regenerates_in_quick_mode() {
    for fig in ALL_FIGURES {
        if fig == 9 {
            continue; // artifact passthrough; covered below
        }
        let tables = run_figure(fig, true)
            .unwrap_or_else(|e| panic!("figure {fig}: {e}"));
        assert!(!tables.is_empty(), "figure {fig} produced no tables");
        for t in &tables {
            assert!(
                !t.rows.is_empty(),
                "figure {fig} table '{}' is empty",
                t.title
            );
            for row in &t.rows {
                assert_eq!(row.len(), t.header.len());
                assert!(
                    row.iter().all(|x| x.is_finite()),
                    "figure {fig}: non-finite cell in '{}'",
                    t.title
                );
            }
        }
    }
}

#[test]
fn fig9_passthrough_when_artifacts_exist() {
    match run_figure(9, true) {
        Ok(tables) => {
            let t = &tables[0];
            assert_eq!(t.header[0], "episode");
            assert!(t.rows.len() >= 2, "training curve too short");
            // Training must improve the test diameter over the run.
            let first = t.rows.first().unwrap()[3];
            let min_d = t
                .rows
                .iter()
                .map(|r| r[3])
                .fold(f64::INFINITY, f64::min);
            assert!(
                min_d <= first,
                "best test diameter {min_d} vs first {first}"
            );
        }
        Err(e) => {
            eprintln!("SKIP fig9 (no artifacts): {e}");
        }
    }
}

#[test]
fn fig5_shape_shortest_base_ring_helps_chord_on_clustered_latency() {
    let tables = run_figure(5, true).unwrap();
    // Table [1] is FABRIC; mean over rows must favor the shortest ring.
    let t = &tables[1];
    let (mut base, mut swapped) = (0.0, 0.0);
    for row in &t.rows {
        base += row[1];
        swapped += row[2];
    }
    assert!(
        swapped < base,
        "paper Fig 5 shape violated: chord+shortest {swapped} vs chord {base}"
    );
}

#[test]
fn fig13_shape_dgro_competitive_with_best_baseline() {
    let tables = run_figure(13, true).unwrap();
    for t in &tables {
        for row in &t.rows {
            let dgro = *row.last().unwrap();
            let best_baseline = row[1..row.len() - 1]
                .iter()
                .cloned()
                .fold(f64::INFINITY, f64::min);
            assert!(
                dgro <= best_baseline * 1.5,
                "{}: dgro {dgro} vs best baseline {best_baseline}",
                t.title
            );
        }
    }
}
