//! Integration tests for the scenario engine: every catalog scenario
//! runs end-to-end, the acceptance workload is byte-deterministic, and
//! `compare` produces the DGRO-vs-baselines diameter-under-churn table.

use dgro::graph::eval::{CertifyConfig, CertifyMode};
use dgro::scenario::compare::compare;
use dgro::scenario::dynamics::LatencyEffect;
use dgro::scenario::engine::{ScenarioEngine, ScenarioReport, Topology};
use dgro::scenario::spec::{catalog, find, ChurnSpec, ScenarioSpec};

fn run(name: &str, topology: Topology, seed: u64) -> ScenarioReport {
    let engine = ScenarioEngine::new(find(name).unwrap(), seed).unwrap();
    engine.run(topology).unwrap()
}

/// Shared sanity: full period coverage, finite diameters, a live
/// population within the universe bounds.
fn check_invariants(rep: &ScenarioReport, nodes: usize, horizon: f64) {
    let expect_periods = (horizon / 250.0).ceil() as usize;
    assert_eq!(
        rep.rows.len(),
        expect_periods,
        "{}: period coverage",
        rep.scenario
    );
    for r in &rep.rows {
        assert!(
            r.diameter.is_finite() && r.diameter >= 0.0,
            "{}: diameter {} at t={}",
            rep.scenario,
            r.diameter,
            r.t
        );
        assert!(
            (3..=nodes).contains(&r.alive),
            "{}: alive {} at t={}",
            rep.scenario,
            r.alive,
            r.t
        );
        assert!((0.0..=1.0).contains(&r.rho));
    }
}

#[test]
fn every_catalog_scenario_runs_on_the_adaptive_coordinator() {
    for spec in catalog() {
        let engine = ScenarioEngine::new(spec.clone(), 42).unwrap();
        let rep = engine.run(Topology::Dgro).unwrap();
        check_invariants(&rep, spec.nodes, spec.horizon);
    }
}

#[test]
fn every_catalog_scenario_runs_on_a_static_baseline() {
    for spec in catalog() {
        let engine = ScenarioEngine::new(spec.clone(), 42).unwrap();
        let rep = engine.run(Topology::Chord).unwrap();
        check_invariants(&rep, spec.nodes, spec.horizon);
        assert_eq!(rep.total_swaps(), 0, "{}: static swap", spec.name);
    }
}

#[test]
fn acceptance_flash_crowd_dgro_seed7_is_byte_deterministic() {
    // `dgro scenario run --name flash-crowd --topology dgro --seed 7`
    // must emit byte-identical reports across runs.
    let a = run("flash-crowd", Topology::Dgro, 7);
    let b = run("flash-crowd", Topology::Dgro, 7);
    assert_eq!(a.render(), b.render());
    assert_eq!(a.table().to_csv(), b.table().to_csv());
    // A different seed draws different churn.
    let c = run("flash-crowd", Topology::Dgro, 8);
    assert_ne!(a.render(), c.render());
}

#[test]
fn flash_crowd_grows_the_overlay() {
    let rep = run("flash-crowd", Topology::Dgro, 7);
    let first = rep.rows.first().unwrap();
    let last = rep.rows.last().unwrap();
    assert!(
        first.alive <= 60 && first.alive >= 45,
        "starts near the initial population, got {}",
        first.alive
    );
    assert!(
        last.alive >= 70,
        "flash crowd must have joined, got {}",
        last.alive
    );
    // The burst lands inside [1500, 2000): alive jumps across it.
    let before: usize = rep
        .rows
        .iter()
        .filter(|r| r.t <= 1500.0)
        .map(|r| r.alive)
        .max()
        .unwrap();
    let after: usize = rep
        .rows
        .iter()
        .filter(|r| r.t >= 2250.0)
        .map(|r| r.alive)
        .min()
        .unwrap();
    assert!(after > before, "alive {before} -> {after} across the burst");
}

#[test]
fn rack_failure_drops_the_block() {
    let rep = run("rack-failure", Topology::Chord, 7);
    let early_alive = rep.rows.first().unwrap().alive;
    assert!(early_alive >= 80, "pre-crash population {early_alive}");
    let min_alive =
        rep.rows.iter().map(|r| r.alive).min().unwrap();
    // 15 nodes crash together around t=2000 (background churn may add
    // or return a few).
    assert!(
        min_alive <= 85 - 12,
        "correlated crash not visible: min alive {min_alive}"
    );
}

#[test]
fn wan_partition_inflates_diameter_then_recovers() {
    let rep = run("wan-partition", Topology::Chord, 7);
    let mean = |lo: f64, hi: f64| -> f64 {
        let sel: Vec<f64> = rep
            .rows
            .iter()
            .filter(|r| r.t > lo && r.t <= hi)
            .map(|r| r.diameter)
            .collect();
        assert!(!sel.is_empty(), "no rows in ({lo}, {hi}]");
        sel.iter().sum::<f64>() / sel.len() as f64
    };
    let before = mean(0.0, 1250.0);
    let during = mean(1500.0, 2750.0);
    let after = mean(3000.0, 4500.0);
    assert!(
        during > before * 1.3,
        "partition must inflate the diameter: {before} -> {during}"
    );
    assert!(
        after < during,
        "healing must recover: during {during}, after {after}"
    );
}

#[test]
fn diurnal_drift_makes_the_diameter_breathe() {
    let rep = run("diurnal-drift", Topology::Chord, 7);
    let max = rep.peak_diameter();
    let min = rep
        .rows
        .iter()
        .map(|r| r.diameter)
        .fold(f64::INFINITY, f64::min);
    assert!(
        max > 1.5 * min,
        "amplitude-0.6 drift must move the diameter: {min}..{max}"
    );
}

#[test]
fn link_degradation_keeps_population_and_stays_finite() {
    let spec = find("link-degradation").unwrap();
    let rep = run("link-degradation", Topology::Dgro, 7);
    // No churn in this scenario: the population never moves.
    for r in &rep.rows {
        assert_eq!(r.alive, spec.nodes);
        assert!(r.diameter.is_finite() && r.diameter > 0.0);
    }
    assert_eq!(rep.metrics.counter("membership.joins"), 0);
}

#[test]
fn steady_state_adaptive_coordinator_improves_or_holds() {
    let rep = run("steady-state", Topology::Dgro, 7);
    let first = rep.rows.first().unwrap().diameter;
    let last = rep.rows.last().unwrap().diameter;
    // On clustered FABRIC latencies the ρ rule swaps toward shortest
    // rings; with only background churn the diameter must not blow up.
    assert!(
        last <= first * 1.1,
        "steady-state regressed: {first} -> {last}"
    );
    assert!(rep.total_swaps() >= 1, "expected at least one ring swap");
}

#[test]
fn compare_tabulates_dgro_vs_baselines_across_the_catalog() {
    let specs = catalog();
    assert!(specs.len() >= 6);
    let topologies = [Topology::Dgro, Topology::Chord, Topology::Rapid];
    let rep = compare(&specs, &topologies, 11, 250.0, 1).unwrap();
    assert_eq!(rep.summary.rows.len(), specs.len());
    assert_eq!(rep.summary.header.len(), 1 + topologies.len());
    assert_eq!(rep.timelines.len(), specs.len());
    for (i, row) in rep.summary.rows.iter().enumerate() {
        assert_eq!(row[0], i as f64);
        for cell in &row[1..] {
            assert!(cell.is_finite() && *cell > 0.0);
        }
    }
    let rendered = rep.render();
    for spec in &specs {
        assert!(rendered.contains(&spec.name), "missing {}", spec.name);
    }
    // Byte-identical on a re-run (the acceptance determinism bar) —
    // including when the cross product fans out across threads.
    let again = compare(&specs, &topologies, 11, 250.0, 4).unwrap();
    assert_eq!(rendered, again.render());
}

#[test]
fn hybrid_compare_reproduces_the_exact_mode_ranking() {
    // Regression pin for the compare-path certification gate: compare
    // used to reject --certify hybrid|sketch outright. Now the panel
    // accepts them (the centralized DGRO column is forced back to
    // exact — its adaptive loop steers on true diameters). At
    // oracle_every = 1 every hybrid evaluation reports the oracle's
    // exact value after the bracket check, so the catalog ranking —
    // and the mean-diameter cells themselves — must match exact mode
    // bit for bit.
    use dgro::scenario::compare::{compare_opts, CompareOpts};
    let specs = catalog();
    let topologies =
        [Topology::Dgro, Topology::Chord, Topology::Circulant];
    let exact = compare_opts(
        &specs,
        &topologies,
        11,
        CompareOpts {
            threads: 4,
            ..CompareOpts::default()
        },
    )
    .unwrap();
    let hybrid = compare_opts(
        &specs,
        &topologies,
        11,
        CompareOpts {
            threads: 4,
            certify: CertifyConfig {
                mode: CertifyMode::Hybrid,
                budget: 8,
                oracle_every: 1,
            },
            ..CompareOpts::default()
        },
    )
    .unwrap();
    let rank = |row: &[f64]| -> Vec<usize> {
        let mut idx: Vec<usize> = (1..row.len()).collect();
        idx.sort_by(|&a, &b| {
            row[a].partial_cmp(&row[b]).unwrap().then(a.cmp(&b))
        });
        idx
    };
    assert_eq!(exact.summary.rows.len(), specs.len());
    for (i, (e, h)) in exact
        .summary
        .rows
        .iter()
        .zip(&hybrid.summary.rows)
        .enumerate()
    {
        assert_eq!(
            rank(e),
            rank(h),
            "{}: hybrid flipped the topology ranking",
            specs[i].name
        );
        for (j, (ec, hc)) in e.iter().zip(h.iter()).enumerate() {
            assert!(
                (ec - hc).abs() < 1e-9,
                "{}: column {j} drifted ({ec} vs {hc})",
                specs[i].name
            );
        }
    }
}

#[test]
fn hybrid_oracle_brackets_the_catalog_on_static_and_sharded_paths() {
    // With oracle_every = 1 every diameter evaluation is re-checked
    // against the exact value and the run bails on any bracket
    // violation — so a clean pass over the catalog IS the acceptance
    // proof that the certified interval always contains the truth.
    let hybrid = CertifyConfig {
        mode: CertifyMode::Hybrid,
        budget: 4,
        oracle_every: 1,
    };
    for spec in catalog() {
        let mut engine = ScenarioEngine::new(spec.clone(), 5).unwrap();
        engine.opts.certify = hybrid;
        let rep = engine.run(Topology::Chord).unwrap();
        check_invariants(&rep, spec.nodes, spec.horizon);
        assert!(
            rep.metrics.counter("eval.oracle_checks") > 0,
            "{}: the oracle never ran",
            spec.name
        );
    }
    // One sharded pass rides along (the K-sweep parity runs live in
    // sharded.rs).
    let spec = find("anchor-storm").unwrap();
    let (nodes, horizon) = (spec.nodes, spec.horizon);
    let mut engine = ScenarioEngine::new(spec, 5).unwrap();
    engine.opts.shards = 4;
    engine.opts.certify = hybrid;
    let rep = engine.run(Topology::DgroSharded).unwrap();
    check_invariants(&rep, nodes, horizon);
    assert!(rep.metrics.counter("eval.oracle_checks") > 0);
}

#[test]
fn incremental_static_engine_matches_from_scratch_rebuild() {
    // Churn-heavy: membership moves nearly every period, a flash crowd
    // lands mid-run, and a degrade window forces latency rebuilds — the
    // worst case for the incremental path's change tracking.
    let spec = ScenarioSpec {
        name: "churn-heavy-equality".into(),
        about: "incremental vs rebuild regression".into(),
        nodes: 40,
        initial_alive: 36,
        model: "uniform".into(),
        horizon: 2000.0,
        churn: vec![
            ChurnSpec::Poisson { rate: 0.004 },
            ChurnSpec::FlashCrowd {
                first: 36,
                count: 4,
                at: 600.0,
                over: 200.0,
            },
        ],
        latency: vec![LatencyEffect::Degrade {
            node: 3,
            factor: 4.0,
            start: 500.0,
            end: 1200.0,
        }],
    };
    for &threads in &[1usize, 4] {
        let mut inc = ScenarioEngine::new(spec.clone(), 13).unwrap();
        inc.opts.threads = threads;
        let mut scratch = ScenarioEngine::new(spec.clone(), 13).unwrap();
        scratch.opts.incremental = false;
        for topo in [Topology::Chord, Topology::RandomKRing] {
            let a = inc.run(topo).unwrap();
            let b = scratch.run(topo).unwrap();
            assert_eq!(a.rows.len(), b.rows.len());
            for (x, y) in a.rows.iter().zip(&b.rows) {
                assert_eq!(x.t, y.t);
                assert_eq!(x.alive, y.alive, "t={}", x.t);
                // Bit-equal ρ proves the rng stream did not drift.
                assert_eq!(x.rho, y.rho, "t={}", x.t);
                assert!(
                    (x.diameter - y.diameter).abs()
                        <= 1e-3 * y.diameter.max(1.0),
                    "t={} threads={threads}: incremental {} vs \
                     rebuild {}",
                    x.t,
                    x.diameter,
                    y.diameter
                );
            }
        }
    }
}
