//! PJRT round-trip: the AOT HLO artifact (Pallas kernels lowered by
//! python/compile/aot.py) must agree with the pure-Rust Q-net mirror on
//! the *trained* weights for every size bucket, including padded
//! execution. This is the L1 <-> L3 contract test.
//!
//! Requires `make artifacts` to have run (skips gracefully otherwise so
//! plain `cargo test` works on a fresh checkout).

use dgro::dgro::construct::{self, GreedyScorer};
use dgro::graph::diameter;
use dgro::latency::{synthetic, Model};
use dgro::qnet::native::NativeQnet;
use dgro::qnet::state::State;
use dgro::qnet::QScorer;
use dgro::runtime::{ArtifactStore, PjrtQnet};
use dgro::util::rng::Rng;

fn store() -> Option<ArtifactStore> {
    match ArtifactStore::discover(ArtifactStore::default_dir()) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

/// The PJRT backend is feature-gated (`--features pjrt`); without it the
/// stub constructor fails and these round-trip tests skip. With the
/// feature compiled in, a constructor failure is a real regression and
/// must fail loudly, not skip.
fn pjrt_backend(store: ArtifactStore) -> Option<PjrtQnet> {
    match PjrtQnet::new(store) {
        Ok(p) => Some(p),
        Err(e) if cfg!(not(feature = "pjrt")) => {
            eprintln!("SKIP (pjrt backend not compiled in): {e}");
            None
        }
        Err(e) => panic!("pjrt backend failed to initialize: {e}"),
    }
}

#[test]
fn pjrt_matches_native_on_trained_weights() {
    let Some(store) = store() else { return };
    let params = store.load_params().unwrap();
    let mut native = NativeQnet::new(params);
    let Some(mut pjrt) = pjrt_backend(store) else { return };

    let mut rng = Rng::new(20240711);
    for n in [16usize, 20, 32, 60, 120] {
        let w = synthetic::uniform(n, &mut rng);
        let mut st = State::new(&w, 0);
        // Walk a few construction steps so A/deg are non-trivial.
        for step in 0..(n / 3) {
            let next = (step * 7 + 3) % n;
            if !st.visited[next] {
                st.step(next);
            }
        }
        let q_native = native.score(&st).unwrap();
        let q_pjrt = pjrt.score(&st).unwrap();
        assert_eq!(q_native.len(), n);
        assert_eq!(q_pjrt.len(), n);
        for (i, (a, b)) in q_native.iter().zip(&q_pjrt).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 * (1.0 + a.abs().max(b.abs())),
                "N={n} candidate {i}: native {a} vs pjrt {b}"
            );
        }
    }
}

#[test]
fn pjrt_padding_equivalence() {
    // N=20 pads into the 32-bucket; the padded run's Q-values for real
    // nodes must match the native (unpadded) forward — the
    // wscale-as-parameter contract.
    let Some(store) = store() else { return };
    let params = store.load_params().unwrap();
    let mut native = NativeQnet::new(params);
    let Some(mut pjrt) = pjrt_backend(store) else { return };

    let mut rng = Rng::new(7);
    let w = synthetic::uniform(20, &mut rng);
    let mut st = State::new(&w, 3);
    st.step(8);
    st.step(15);
    let q_native = native.score(&st).unwrap();
    let q_pjrt = pjrt.score(&st).unwrap(); // padded to 32 internally
    for (i, (a, b)) in q_native.iter().zip(&q_pjrt).enumerate() {
        assert!(
            (a - b).abs() <= 1e-3 * (1.0 + a.abs()),
            "candidate {i}: native {a} vs padded-pjrt {b}"
        );
    }
}

#[test]
fn pjrt_ring_construction_end_to_end() {
    // Full Algorithm 1 through the PJRT scorer: valid ring, and the
    // same ring the native scorer builds (identical Q ranking).
    let Some(store) = store() else { return };
    let params = store.load_params().unwrap();
    let mut native = NativeQnet::new(params);
    let Some(mut pjrt) = pjrt_backend(store) else { return };

    let mut rng = Rng::new(99);
    let w = synthetic::uniform(24, &mut rng);
    let ring_native = construct::build_ring(&mut native, &w, 0).unwrap();
    let ring_pjrt = construct::build_ring(&mut pjrt, &w, 0).unwrap();
    ring_pjrt.validate().unwrap();
    assert_eq!(
        ring_native.order(),
        ring_pjrt.order(),
        "identical weights must produce identical construction"
    );
}

#[test]
fn trained_qnet_beats_or_matches_random_ring() {
    // Sanity on training quality: the learned constructor (best of 4
    // starts) should do no worse than the mean random ring on the
    // training distribution. (Fig 10's full comparison incl. GA lives in
    // the bench harness.)
    let Some(store) = store() else { return };
    let params = store.load_params().unwrap();
    let mut native = NativeQnet::new(params);

    let mut rng = Rng::new(1234);
    let mut qnet_sum = 0.0f32;
    let mut rand_sum = 0.0f32;
    let trials = 5;
    for _ in 0..trials {
        let w = synthetic::uniform(20, &mut rng);
        let (_, _, d) =
            construct::best_of_starts(&mut native, &w, 1, 4, &mut rng)
                .unwrap();
        qnet_sum += d;
        let rr = dgro::topology::random_ring(20, &mut rng);
        rand_sum += diameter::diameter(&rr.to_graph(&w));
    }
    assert!(
        qnet_sum <= rand_sum * 1.05,
        "qnet mean {} vs random mean {}",
        qnet_sum / trials as f32,
        rand_sum / trials as f32
    );
}

#[test]
fn bucket_error_message_for_oversized_graph() {
    let Some(store) = store() else { return };
    let Some(mut pjrt) = pjrt_backend(store) else { return };
    let mut rng = Rng::new(5);
    let w = Model::Uniform.sample(300, &mut rng);
    let st = State::new(&w, 0);
    let err = pjrt.score(&st).unwrap_err().to_string();
    assert!(err.contains("bucket"), "got: {err}");
}

#[test]
fn greedy_scorer_unaffected_by_artifacts() {
    // Control: the non-ML path must work without any artifact.
    let mut rng = Rng::new(6);
    let w = synthetic::uniform(12, &mut rng);
    let ring = construct::build_ring(&mut GreedyScorer, &w, 0).unwrap();
    ring.validate().unwrap();
}
