//! Integration tests for the coordinator-free decentralized runner:
//!
//! * the ring-strand property — concurrent epoch-tagged two-phase
//!   swaps under a seeded [`LossyTransport`] (5–10% drop, plus dup and
//!   reorder) never tear a ring: after quiescence every up peer holds
//!   valid full-universe permutations, peers that adopted a slot's
//!   winning version hold byte-identical orders, and every peer's
//!   overlay stays connected over the actually-alive set;
//! * determinism pins — the sim-backed decentralized scenario run is
//!   byte-deterministic and invariant across evaluation-pool widths
//!   T ∈ {1, 2, 8};
//! * the acceptance pin — mean alive-overlay diameter across the
//!   scenario catalog stays within 15% of the centralized coordinator
//!   under identical specs, seeds and (trimmed) horizons;
//! * the anchor-storm cell — the catalog's adversarial anchor storm
//!   completes under 10% injected loss with zero ring strands.

#![allow(clippy::field_reassign_with_default)] // config-mutation idiom

use std::collections::HashSet;

use dgro::config::Config;
use dgro::coordinator::{AdaptiveRunner, DecentralizedRunner, RunOptions};
use dgro::graph::ring::Ring;
use dgro::latency::{LatencyMatrix, Model};
use dgro::membership::events::{EventTrace, MembershipEvent};
use dgro::net::{LossyConfig, LossyTransport, SimTransport, Transport};
use dgro::prop::{ensure, forall, Config as PropConfig};
use dgro::scenario::{catalog, find, ScenarioEngine, Topology};
use dgro::util::rng::Rng;

/// Swap-version ordering (mirrors the runner's commit rule): a higher
/// period wins; within a period the *lowest* proposer id wins.
fn ver_newer(a: (u32, u32), b: (u32, u32)) -> bool {
    a.0 > b.0 || (a.0 == b.0 && a.1 < b.1)
}

/// Whether the alive-restricted overlay of one peer's K-ring view is
/// connected: consecutive alive members along each ring (dead nodes
/// skipped, ends wrapped) must link the whole alive set.
fn alive_overlay_connected(
    rings: &[Vec<u32>],
    alive: &HashSet<u32>,
) -> bool {
    if alive.len() <= 1 {
        return true;
    }
    let mut adj: Vec<Vec<u32>> = Vec::new();
    let ids: Vec<u32> = {
        let mut v: Vec<u32> = alive.iter().copied().collect();
        v.sort_unstable();
        v
    };
    let index =
        |id: u32| ids.binary_search(&id).expect("alive id indexed");
    adj.resize(ids.len(), Vec::new());
    for order in rings {
        let walk: Vec<u32> = order
            .iter()
            .copied()
            .filter(|id| alive.contains(id))
            .collect();
        if walk.len() < 2 {
            continue;
        }
        for i in 0..walk.len() {
            let u = walk[i];
            let v = walk[(i + 1) % walk.len()];
            if u != v {
                adj[index(u)].push(v);
                adj[index(v)].push(u);
            }
        }
    }
    let mut seen = vec![false; ids.len()];
    let mut stack = vec![0usize];
    seen[0] = true;
    let mut reached = 1usize;
    while let Some(u) = stack.pop() {
        for &v in &adj[u] {
            let vi = index(v);
            if !seen[vi] {
                seen[vi] = true;
                reached += 1;
                stack.push(vi);
            }
        }
    }
    reached == ids.len()
}

/// The no-strand invariant over a finished runner: every up peer's
/// every ring is a valid full-universe permutation, every slot's
/// winning swap version maps to exactly one order across its adopters,
/// and every up peer's own overlay view connects the alive set.
fn assert_no_strand<T: Transport>(
    co: &DecentralizedRunner<T>,
) -> Result<(), String> {
    let ups = co.up_nodes();
    let alive: HashSet<u32> = ups.iter().copied().collect();
    let views = co.ring_views();
    let versions = co.ring_versions();
    let k = versions[0].len();
    for &u in &ups {
        for (slot, order) in views[u as usize].iter().enumerate() {
            Ring::new(order.clone())
                .and_then(|r| r.validate().map(|_| r))
                .map_err(|e| {
                    format!("peer {u} slot {slot}: torn ring: {e}")
                })?;
        }
        ensure(
            alive_overlay_connected(&views[u as usize], &alive),
            format!("peer {u}: alive overlay disconnected"),
        )?;
    }
    for slot in 0..k {
        let best = ups
            .iter()
            .map(|&u| versions[u as usize][slot])
            .fold((0, 0), |acc, v| if ver_newer(v, acc) { v } else { acc });
        let mut winner: Option<&Vec<u32>> = None;
        for &u in &ups {
            if versions[u as usize][slot] != best {
                continue;
            }
            let order = &views[u as usize][slot];
            match winner {
                None => winner = Some(order),
                Some(w) => ensure(
                    w == order,
                    format!(
                        "slot {slot}: split-brain at version \
                         {best:?} (peer {u} disagrees)"
                    ),
                )?,
            }
        }
    }
    Ok(())
}

fn fabric_world(n: usize, seed: u64) -> LatencyMatrix {
    Model::Fabric.sample(n, &mut Rng::new(seed))
}

fn small_cfg(n: usize, seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.nodes = n;
    cfg.k = 2;
    cfg.seed = seed;
    cfg.model = "fabric".into();
    cfg.gossip_rounds = 6;
    cfg.gossip_samples = 2;
    cfg.adapt_period_ms = 250.0;
    cfg
}

// ---------------------------------------------------------------------
// Ring-strand property under seeded loss/dup/reorder.
// ---------------------------------------------------------------------

#[test]
fn prop_lossy_concurrent_swaps_never_strand_the_ring() {
    forall(
        "lossy two-phase swaps keep rings whole",
        PropConfig::default().cases(6).seed(0xDECE_57A8),
        |rng| {
            let n = 8 + rng.index(9); // 8..=16
            let seed = 1 + rng.next_u64() % 0xFFFF;
            let cfg = small_cfg(n, seed);
            let w = fabric_world(n, seed ^ 0x5EED);
            let fault = LossyConfig {
                drop_rate: rng.uniform(0.05, 0.10),
                dup_rate: rng.uniform(0.0, 0.05),
                reorder_rate: rng.uniform(0.0, 0.05),
                seed: rng.next_u64(),
            };
            let lossy = LossyTransport::new(
                SimTransport::new(w.clone()),
                fault,
            );
            // Churn burst in the first kilosecond, then three quiet
            // periods so the anti-entropy tail has room to settle.
            let mut trace = EventTrace::default();
            let crashed = rng.index(3.min(n - 4)) + 1;
            for i in 0..crashed {
                let node = (1 + i * 2) as u32;
                let at = rng.uniform(200.0, 700.0);
                trace.events.push(MembershipEvent::Crash {
                    time: at,
                    node,
                });
                if rng.chance(0.5) {
                    trace.events.push(MembershipEvent::Join {
                        time: at + rng.uniform(100.0, 250.0),
                        node,
                    });
                }
            }
            trace.events.sort_by(|a, b| {
                a.time().total_cmp(&b.time())
            });
            let mut co = DecentralizedRunner::new(cfg, w, lossy)
                .map_err(|e| e.to_string())?;
            co.run_with(&trace, 1750.0, RunOptions::new())
                .map_err(|e| e.to_string())?;
            assert_no_strand(&co)
        },
    );
}

// ---------------------------------------------------------------------
// Determinism and thread-invariance pins.
// ---------------------------------------------------------------------

fn mini_engine(threads: usize) -> ScenarioEngine {
    let mut spec = find("flash-crowd").expect("catalog entry");
    spec.nodes = 24;
    spec.initial_alive = 16;
    spec.horizon = 1250.0;
    spec.churn = vec![dgro::scenario::ChurnSpec::FlashCrowd {
        first: 16,
        count: 8,
        at: 400.0,
        over: 300.0,
    }];
    let mut engine = ScenarioEngine::new(spec, 11).expect("engine");
    engine.opts.threads = threads;
    engine
}

#[test]
fn decentralized_scenario_is_byte_deterministic() {
    let r1 = mini_engine(1).run(Topology::Decentralized).unwrap();
    let r2 = mini_engine(1).run(Topology::Decentralized).unwrap();
    assert_eq!(r1.render(), r2.render());
    assert!(!r1.rows.is_empty());
    for row in &r1.rows {
        assert!(row.diameter.is_finite() && row.diameter > 0.0);
    }
}

#[test]
fn decentralized_scenario_is_thread_invariant() {
    let base = mini_engine(1).run(Topology::Decentralized).unwrap();
    for threads in [2usize, 8] {
        let rep =
            mini_engine(threads).run(Topology::Decentralized).unwrap();
        assert_eq!(
            base.render(),
            rep.render(),
            "T={threads} must reproduce the T=1 report"
        );
    }
}

// ---------------------------------------------------------------------
// Diameter-gap acceptance pin vs the centralized coordinator.
// ---------------------------------------------------------------------

#[test]
fn decentralized_diameter_tracks_centralized_within_bound() {
    let mut central_sum = 0.0;
    let mut dec_sum = 0.0;
    for mut spec in catalog() {
        spec.horizon = spec.horizon.min(1500.0);
        let name = spec.name.clone();
        let mean = |topology: Topology| -> f64 {
            let engine =
                ScenarioEngine::new(spec.clone(), 7).expect("engine");
            let rep = engine.run(topology).expect("run");
            assert!(!rep.rows.is_empty(), "{name}: empty report");
            rep.rows.iter().map(|r| r.diameter).sum::<f64>()
                / rep.rows.len() as f64
        };
        let central = mean(Topology::Dgro);
        let dec = mean(Topology::Decentralized);
        assert!(
            central > 0.0 && dec > 0.0,
            "{name}: degenerate diameters ({central}, {dec})"
        );
        // Per-scenario guard: the coordinator-free loop may trail the
        // centralized one on any single adversarial spec, but never
        // catastrophically.
        assert!(
            dec <= central * 1.5,
            "{name}: decentralized mean alive-diameter {dec:.3} vs \
             centralized {central:.3} exceeds the 1.5x guard"
        );
        central_sum += central;
        dec_sum += dec;
    }
    // Catalog-level acceptance: within 15% of centralized overall.
    assert!(
        dec_sum <= central_sum * 1.15,
        "catalog mean alive-diameter gap too large: decentralized \
         {dec_sum:.3} vs centralized {central_sum:.3}"
    );
}

// ---------------------------------------------------------------------
// Anchor-storm under 10% loss: completes, zero ring strands.
// ---------------------------------------------------------------------

#[test]
fn anchor_storm_under_loss_leaves_no_strands() {
    let n = 24;
    let seed = 23;
    let cfg = small_cfg(n, seed);
    let w = fabric_world(n, seed);
    let lossy = LossyTransport::new(
        SimTransport::new(w.clone()),
        LossyConfig {
            drop_rate: 0.10,
            dup_rate: 0.03,
            reorder_rate: 0.03,
            seed: 0xA5C0,
        },
    );
    // Three storm waves against fixed "anchor" ids with rejoins —
    // the catalog shape, sized for a message-granularity run.
    let mut trace = EventTrace::default();
    for wave in 0..3u32 {
        let at = 400.0 + 400.0 * wave as f64;
        for a in 0..3u32 {
            let node = 1 + a * 4;
            trace.events.push(MembershipEvent::Crash {
                time: at + a as f64,
                node,
            });
            trace.events.push(MembershipEvent::Join {
                time: at + 250.0 + a as f64,
                node,
            });
        }
    }
    trace
        .events
        .sort_by(|a, b| a.time().total_cmp(&b.time()));
    let mut co =
        DecentralizedRunner::new(cfg, w, lossy).expect("runner");
    let rep = co.run_with(&trace, 2000.0, RunOptions::new()).unwrap();
    assert_eq!(rep.alive, n, "every anchor rejoined");
    assert!(rep.final_diameter.is_finite() && rep.final_diameter > 0.0);
    assert_no_strand(&co).unwrap();
}

#[test]
fn anchor_storm_engine_cell_completes_under_loss() {
    let mut spec = find("anchor-storm").expect("catalog entry");
    spec.horizon = 1500.0;
    let mut engine = ScenarioEngine::new(spec, 7).expect("engine");
    engine.opts.loss_rate = 0.10;
    let rep = engine.run(Topology::Decentralized).expect("run");
    assert!(!rep.rows.is_empty());
    for row in &rep.rows {
        assert!(row.diameter.is_finite() && row.diameter > 0.0);
    }
}
