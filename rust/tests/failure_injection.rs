//! Failure-injection and adversarial-input tests: the coordinator and
//! substrates must behave sanely under partitions, mass failures,
//! degenerate metrics, and missing/corrupt artifacts.

#![allow(clippy::field_reassign_with_default)] // config-mutation idiom

use dgro::config::Config;
use dgro::coordinator::Coordinator;
use dgro::graph::{components, diameter, Graph};
use dgro::latency::LatencyMatrix;
use dgro::membership::events::{EventTrace, MembershipEvent};
use dgro::membership::list::MemberState;
use dgro::qnet::params::QnetParams;
use dgro::sim::broadcast::broadcast_times;
use dgro::util::rng::Rng;

fn cfg(nodes: usize) -> Config {
    let mut c = Config::default();
    c.nodes = nodes;
    c.model = "fabric".into();
    c.scorer = "greedy".into();
    c.adapt_period_ms = 100.0;
    c
}

#[test]
fn mass_crash_half_the_overlay() {
    // Crash 50% of members mid-run; the coordinator must keep adapting
    // and its full-id overlay stays connected (rings span all ids; the
    // alive-restricted overlay may fragment, which is the protocol's
    // real-world failure mode, not a crash of the coordinator).
    let mut co = Coordinator::new(cfg(40)).unwrap();
    let mut trace = EventTrace::default();
    for (i, node) in (0..20u32).enumerate() {
        trace.events.push(MembershipEvent::Crash {
            time: 50.0 + i as f64,
            node,
        });
    }
    let rep = co.run(&trace, 1000.0).unwrap();
    assert_eq!(rep.alive, 20);
    assert!(components::is_connected(&co.overlay()));
    assert!(rep.final_diameter > 0.0);
}

#[test]
fn broadcast_from_partitioned_source_reaches_only_its_side() {
    // Two cliques joined by nothing: a broadcast covers exactly the
    // source's side; completion reflects the reachable set only.
    let mut g = Graph::empty(8);
    for u in 0..4 {
        for v in (u + 1)..4 {
            g.add_edge(u, v, 1.0);
            g.add_edge(u + 4, v + 4, 1.0);
        }
    }
    let rep = broadcast_times(&g, 0, &vec![0.0; 8]);
    assert!(rep.arrival[..4].iter().all(|t| t.is_finite()));
    assert!(rep.arrival[4..].iter().all(|t| t.is_infinite()));
    assert_eq!(rep.completion, 1.0);
}

#[test]
fn degenerate_all_equal_latency_matrix() {
    // Constant metric: every topology has the same edge weights; the
    // adaptive rule must land on Keep (rho sentinel 0.5) and never churn
    // rings pointlessly.
    let w = LatencyMatrix::from_fn(24, |_, _| 7.0);
    let mut rng = Rng::new(1);
    let g = dgro::topology::random_ring(24, &mut rng).to_graph(&w);
    let stats = dgro::gossip::measure::measure(
        &w,
        &g,
        dgro::gossip::measure::MeasureConfig::default(),
        &mut rng,
    );
    let choice = dgro::dgro::select::decide(
        &stats,
        dgro::dgro::select::SelectConfig::default(),
    );
    assert_eq!(choice, dgro::dgro::select::RingChoice::Keep);
}

#[test]
fn corrupt_weight_artifacts_are_rejected_not_trusted() {
    // Truncated data, NaNs, and wrong shapes must all fail loudly.
    let good = QnetParams::synthetic(4, 8, 1);
    assert!(good.validate().is_ok());

    let mut nan = QnetParams::synthetic(4, 8, 1);
    nan.thetas[2].data[0] = f32::NAN;
    assert!(nan.validate().is_err());

    let mut misshapen = QnetParams::synthetic(4, 8, 1);
    misshapen.thetas[7].shape = vec![8, 99];
    assert!(misshapen.validate().is_err());

    assert!(QnetParams::parse("{\"format\": \"dgro-qnet-v1\"}").is_err());
    assert!(QnetParams::parse("not json at all").is_err());
}

#[test]
fn leave_then_rejoin_bumps_incarnation() {
    let mut co = Coordinator::new(cfg(10)).unwrap();
    co.apply_event(&MembershipEvent::Leave { time: 1.0, node: 3 });
    assert_eq!(
        co.membership.get(3).unwrap().state,
        MemberState::Left
    );
    co.apply_event(&MembershipEvent::Join { time: 2.0, node: 3 });
    let m = co.membership.get(3).unwrap();
    assert_eq!(m.state, MemberState::Alive);
    assert!(m.incarnation >= 1, "rejoin must outrank the Left record");
}

#[test]
fn zero_churn_long_run_reaches_stable_keep_state() {
    // With no churn the adaptive loop must converge: after the swaps
    // settle, diameter stays flat (no oscillation thrash).
    let mut co = Coordinator::new(cfg(51)).unwrap();
    let rep = co.run(&EventTrace::default(), 3000.0).unwrap();
    let tail: Vec<f32> = rep
        .timeline
        .iter()
        .rev()
        .take(5)
        .map(|&(_, _, d)| d)
        .collect();
    let spread = tail.iter().cloned().fold(f32::MIN, f32::max)
        - tail.iter().cloned().fold(f32::MAX, f32::min);
    assert!(
        spread <= rep.initial_diameter * 0.35,
        "diameter still oscillating at the end: {tail:?}"
    );
}

#[test]
fn single_node_and_tiny_graphs_do_not_panic() {
    // Graph substrate edge cases.
    let g1 = Graph::empty(1);
    assert_eq!(diameter::diameter(&g1), 0.0);
    let g0 = Graph::empty(0);
    assert_eq!(diameter::diameter(&g0), 0.0);
    let mut g2 = Graph::empty(2);
    g2.add_edge(0, 1, 3.5);
    assert_eq!(diameter::diameter(&g2), 3.5);
}

#[test]
fn oversized_partition_request_is_rejected() {
    let w = LatencyMatrix::from_fn(8, |u, v| (u + v) as f32 + 1.0);
    let mut rng = Rng::new(2);
    let res = std::panic::catch_unwind(move || {
        let mut r = Rng::new(3);
        dgro::dgro::parallel::parallel_ring_greedy(
            &w,
            dgro::dgro::parallel::ParallelConfig::new(100),
            &mut r,
        )
    });
    assert!(res.is_err(), "M > N must be rejected");
    let _ = rng.next_u64();
}
