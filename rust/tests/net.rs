//! Integration tests for the real-socket transport subsystem:
//!
//! * wire-protocol properties — encode/decode identity for every
//!   message variant, unknown-version rejection, truncation rejection;
//! * the loopback smoke test — 8 UDP nodes converge to the same
//!   membership view as the sim transport under seed 0;
//! * the acceptance pin — `dgro scenario run --transport sim|udp` on
//!   the same spec + seed shows per-period alive-diameter parity within
//!   tolerance (figure 21 records the same replay).

use dgro::config::Config;
use dgro::latency::Model;
use dgro::membership::events::{EventTrace, MembershipEvent};
use dgro::net::{
    Message, NetCoordinator, SimTransport, TransportKind, UdpTransport,
    WIRE_VERSION,
};
use dgro::prop::{ensure, forall, Config as PropConfig};
use dgro::scenario::{
    ChurnSpec, ScenarioEngine, ScenarioReport, ScenarioSpec, Topology,
};
use dgro::util::rng::Rng;

// ---------------------------------------------------------------------
// Wire-protocol properties.
// ---------------------------------------------------------------------

fn random_message(rng: &mut Rng) -> Message {
    match rng.index(6) {
        0 => Message::Ping {
            seq: rng.next_u64() as u32,
        },
        1 => Message::Pong {
            seq: rng.next_u64() as u32,
            hold_ms: rng.f64() * 10.0,
        },
        2 => Message::GossipPush {
            local: rng.f64() * 100.0,
            global: rng.f64() * 100.0,
            min: rng.f64(),
            m: rng.f64(),
            ml: rng.f64(),
        },
        3 => {
            let node = rng.index(1 << 20) as u32;
            let time = rng.f64() * 1e6;
            let event = match rng.index(3) {
                0 => MembershipEvent::Join { time, node },
                1 => MembershipEvent::Leave { time, node },
                _ => MembershipEvent::Crash { time, node },
            };
            Message::Membership { event }
        }
        4 => {
            let n = 3 + rng.index(64);
            Message::RingSwap {
                slot: rng.index(8) as u32,
                order: rng.permutation(n),
            }
        }
        _ => Message::Report {
            period: rng.index(1000) as u32,
            t_ms: rng.f64() * 1e5,
            rho: rng.f64(),
            diameter: rng.f64() * 100.0,
            alive: rng.index(1000) as u32,
            swaps: rng.index(100) as u32,
        },
    }
}

#[test]
fn prop_every_message_variant_round_trips() {
    forall(
        "wire encode/decode identity",
        PropConfig::default().cases(256),
        |rng| {
            let msg = random_message(rng);
            let bytes = msg.encode();
            let back =
                Message::decode(&bytes).map_err(|e| e.to_string())?;
            ensure(back == msg, format!("{msg:?} != {back:?}"))
        },
    );
}

#[test]
fn prop_unknown_wire_versions_are_rejected() {
    forall(
        "unknown version rejected",
        PropConfig::default().cases(64),
        |rng| {
            let msg = random_message(rng);
            let mut bytes = msg.encode();
            // Any version byte other than the spoken one must fail.
            bytes[0] = WIRE_VERSION.wrapping_add(1 + rng.index(254) as u8);
            ensure(
                Message::decode(&bytes).is_err(),
                format!("version {} accepted", bytes[0]),
            )
        },
    );
}

#[test]
fn prop_truncated_frames_are_rejected() {
    forall(
        "truncation rejected",
        PropConfig::default().cases(128),
        |rng| {
            let msg = random_message(rng);
            let bytes = msg.encode();
            let cut = rng.index(bytes.len());
            ensure(
                Message::decode(&bytes[..cut]).is_err(),
                format!("{cut}-byte prefix of {msg:?} accepted"),
            )
        },
    );
}

// ---------------------------------------------------------------------
// Loopback smoke: 8 UDP nodes vs the sim transport, seed 0.
// ---------------------------------------------------------------------

fn net_config(nodes: usize, seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.nodes = nodes;
    cfg.model = "fabric".to_string();
    cfg.scorer = "greedy".to_string();
    cfg.adapt_period_ms = 250.0;
    cfg.seed = seed;
    cfg
}

#[test]
fn eight_udp_nodes_converge_to_the_sim_membership_view() {
    let nodes = 8;
    let cfg = net_config(nodes, 0);
    let mut rng = Rng::new(0);
    let w = Model::Fabric.sample(nodes, &mut rng);
    let mut trng = Rng::new(0);
    let trace = EventTrace::churn(nodes, 1000.0, 0.002, &mut trng);

    let mut sim = NetCoordinator::new(
        cfg.clone(),
        w.clone(),
        SimTransport::new(w.clone()),
    )
    .unwrap();
    sim.run(&trace, 1000.0).unwrap();

    let mut udp = NetCoordinator::new(
        cfg,
        w.clone(),
        UdpTransport::bind(w, UdpTransport::DEFAULT_TIME_SCALE).unwrap(),
    )
    .unwrap();
    udp.run(&trace, 1000.0).unwrap();

    let sim_views = sim.node_views();
    let udp_views = udp.node_views();
    assert_eq!(sim_views.len(), nodes);
    assert_eq!(udp_views.len(), nodes);
    // Every UDP node's view matches its sim twin — and everyone agrees
    // with the coordinator's global table (full dissemination).
    let global = sim.membership.snapshot();
    for (i, (s, u)) in sim_views.iter().zip(&udp_views).enumerate() {
        assert_eq!(s, u, "node {i}: udp view diverged from sim");
        assert_eq!(s, &global, "node {i}: view diverged from global");
    }
    // Both transports actually moved frames.
    assert!(sim.frames_sent() > 0);
    assert!(udp.frames_sent() > 0);
}

// ---------------------------------------------------------------------
// Acceptance pin: trace-replay parity, sim vs udp, one seed.
// ---------------------------------------------------------------------

fn parity_spec() -> ScenarioSpec {
    ScenarioSpec {
        name: "net-parity".into(),
        about: "sim-vs-udp acceptance replay".into(),
        nodes: 24,
        initial_alive: 24,
        model: "fabric".into(),
        horizon: 1000.0,
        churn: vec![ChurnSpec::Poisson { rate: 0.002 }],
        latency: vec![],
    }
}

fn replay(kind: TransportKind) -> ScenarioReport {
    let mut engine = ScenarioEngine::new(parity_spec(), 0).unwrap();
    engine.transport = Some(kind);
    engine.run(Topology::Dgro).unwrap()
}

#[test]
fn scenario_replay_sim_vs_udp_has_alive_diameter_parity() {
    let sim = replay(TransportKind::Sim);
    let udp = replay(TransportKind::Udp);
    assert_eq!(sim.rows.len(), 4, "horizon 1000 / period 250");
    assert_eq!(sim.rows.len(), udp.rows.len());
    for (a, b) in sim.rows.iter().zip(&udp.rows) {
        assert_eq!(a.t, b.t);
        // The membership trace is seed-derived and disseminated on both
        // transports identically: alive counts must agree exactly.
        assert_eq!(a.alive, b.alive, "t={}", a.t);
        assert!(a.diameter.is_finite() && a.diameter > 0.0);
        assert!(b.diameter.is_finite() && b.diameter > 0.0);
        // ρ comes from measured RTTs — exact on sim, jittered on udp —
        // so decisions (and hence diameters) may drift, but per-period
        // alive diameter must stay within tolerance.
        let tol = 0.35 * a.diameter.max(1.0);
        assert!(
            (a.diameter - b.diameter).abs() <= tol,
            "t={}: sim {} vs udp {} (tol {tol})",
            a.t,
            a.diameter,
            b.diameter
        );
    }
    let (ms, mu) = (sim.mean_diameter(), udp.mean_diameter());
    assert!(
        (ms - mu).abs() <= 0.25 * ms.max(1.0),
        "mean alive diameter drifted: sim {ms} vs udp {mu}"
    );
}

#[test]
fn sim_transport_replay_is_byte_deterministic() {
    let a = replay(TransportKind::Sim);
    let b = replay(TransportKind::Sim);
    assert_eq!(a.render(), b.render());
}
