//! Integration tests for the real-socket transport subsystem:
//!
//! * wire-protocol properties — encode/decode identity for every
//!   message variant, unknown-version rejection, truncation rejection,
//!   and cross-epoch replay rejection (wire v2);
//! * the loopback smoke tests — 8 UDP (and TCP) nodes converge to the
//!   same membership view as the sim transport under seed 0;
//! * the acceptance pins — `dgro scenario run --transport sim|udp|tcp`
//!   on the same spec + seed shows per-period alive-diameter parity
//!   within tolerance (figure 21 records the same replay), seeded loss
//!   injection replays byte-identically, measurement drift under 5–10%
//!   injected loss stays inside the pinned bound, and the catalog's
//!   `anchor-storm` completes over tcp and lossy udp.

#![allow(clippy::field_reassign_with_default)] // config-mutation idiom

use dgro::config::Config;
use dgro::latency::Model;
use dgro::membership::events::{EventTrace, MembershipEvent};
use dgro::net::{
    Message, NetCoordinator, SimTransport, TcpTransport, Transport,
    TransportKind, UdpTransport, WIRE_VERSION,
};
use dgro::prop::{ensure, forall, Config as PropConfig};
use dgro::scenario::{
    find, ChurnSpec, ScenarioEngine, ScenarioReport, ScenarioSpec,
    Topology,
};
use dgro::util::rng::Rng;

// ---------------------------------------------------------------------
// Wire-protocol properties.
// ---------------------------------------------------------------------

fn random_message(rng: &mut Rng) -> Message {
    match rng.index(6) {
        0 => Message::Ping {
            seq: rng.next_u64() as u32,
        },
        1 => Message::Pong {
            seq: rng.next_u64() as u32,
            hold_ms: rng.f64() * 10.0,
        },
        2 => Message::GossipPush {
            local: rng.f64() * 100.0,
            global: rng.f64() * 100.0,
            min: rng.f64(),
            m: rng.f64(),
            ml: rng.f64(),
        },
        3 => {
            let node = rng.index(1 << 20) as u32;
            let time = rng.f64() * 1e6;
            let event = match rng.index(3) {
                0 => MembershipEvent::Join { time, node },
                1 => MembershipEvent::Leave { time, node },
                _ => MembershipEvent::Crash { time, node },
            };
            Message::Membership { event }
        }
        4 => {
            let n = 3 + rng.index(64);
            Message::RingSwap {
                slot: rng.index(8) as u32,
                order: rng.permutation(n),
            }
        }
        _ => Message::Report {
            period: rng.index(1000) as u32,
            t_ms: rng.f64() * 1e5,
            rho: rng.f64(),
            diameter: rng.f64() * 100.0,
            alive: rng.index(1000) as u32,
            swaps: rng.index(100) as u32,
        },
    }
}

#[test]
fn prop_every_message_variant_round_trips() {
    forall(
        "wire encode/decode identity",
        PropConfig::default().cases(256),
        |rng| {
            let msg = random_message(rng);
            let epoch = rng.next_u64() as u32;
            let bytes = msg.encode(epoch);
            let (e, back) =
                Message::decode(&bytes).map_err(|e| e.to_string())?;
            ensure(
                e == epoch && back == msg,
                format!("{msg:?}@{epoch} != {back:?}@{e}"),
            )
        },
    );
}

#[test]
fn prop_unknown_wire_versions_are_rejected() {
    forall(
        "unknown version rejected",
        PropConfig::default().cases(64),
        |rng| {
            let msg = random_message(rng);
            let mut bytes = msg.encode(0);
            // Any version byte other than the spoken one must fail.
            bytes[0] = WIRE_VERSION.wrapping_add(1 + rng.index(254) as u8);
            ensure(
                Message::decode(&bytes).is_err(),
                format!("version {} accepted", bytes[0]),
            )
        },
    );
}

#[test]
fn prop_truncated_frames_are_rejected() {
    forall(
        "truncation rejected",
        PropConfig::default().cases(128),
        |rng| {
            let msg = random_message(rng);
            let bytes = msg.encode(rng.next_u64() as u32);
            let cut = rng.index(bytes.len());
            ensure(
                Message::decode(&bytes[..cut]).is_err(),
                format!("{cut}-byte prefix of {msg:?} accepted"),
            )
        },
    );
}

#[test]
fn prop_cross_epoch_replays_are_rejected() {
    // A frame captured in one collection phase and replayed (or simply
    // delivered late) in another must fail the strict decode — whatever
    // the message type, whatever the epoch distance.
    forall(
        "cross-epoch replay rejected",
        PropConfig::default().cases(128),
        |rng| {
            let msg = random_message(rng);
            let sent_in = rng.next_u64() as u32;
            let offset = 1 + rng.index(u32::MAX as usize) as u32;
            let arrives_in = sent_in.wrapping_add(offset);
            let bytes = msg.encode(sent_in);
            if Message::decode_expect(&bytes, sent_in).is_err() {
                return ensure(false, "same-epoch decode must succeed");
            }
            ensure(
                Message::decode_expect(&bytes, arrives_in).is_err(),
                format!(
                    "{msg:?} sent in epoch {sent_in} accepted in \
                     epoch {arrives_in}"
                ),
            )
        },
    );
}

// ---------------------------------------------------------------------
// Loopback smoke: 8 UDP nodes vs the sim transport, seed 0.
// ---------------------------------------------------------------------

fn net_config(nodes: usize, seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.nodes = nodes;
    cfg.model = "fabric".to_string();
    cfg.scorer = "greedy".to_string();
    cfg.adapt_period_ms = 250.0;
    cfg.seed = seed;
    cfg
}

type Views = Vec<Vec<(u32, dgro::membership::list::MemberState, u64)>>;

/// Run the seed-0 churn trace over `transport` and return every
/// actor's membership view, the coordinator's global (oracle) table,
/// and the frames moved.
fn converged_views<T: Transport>(
    transport: T,
    nodes: usize,
) -> (Views, Vec<(u32, dgro::membership::list::MemberState, u64)>, u64)
{
    let cfg = net_config(nodes, 0);
    let mut trng = Rng::new(0);
    let trace = EventTrace::churn(nodes, 1000.0, 0.002, &mut trng);
    let mut rng = Rng::new(0);
    let w = Model::Fabric.sample(nodes, &mut rng);
    let mut co = NetCoordinator::new(cfg, w, transport).unwrap();
    co.run(&trace, 1000.0).unwrap();
    (co.node_views(), co.membership.snapshot(), co.frames_sent())
}

#[test]
fn udp_and_tcp_nodes_converge_to_the_sim_membership_view() {
    let nodes = 8;
    let mut rng = Rng::new(0);
    let w = Model::Fabric.sample(nodes, &mut rng);

    let (sim_views, global, sim_frames) =
        converged_views(SimTransport::new(w.clone()), nodes);
    let (udp_views, _, udp_frames) = converged_views(
        UdpTransport::bind(w.clone(), UdpTransport::DEFAULT_TIME_SCALE)
            .unwrap(),
        nodes,
    );
    let (tcp_views, _, tcp_frames) = converged_views(
        TcpTransport::bind(w, UdpTransport::DEFAULT_TIME_SCALE).unwrap(),
        nodes,
    );
    assert_eq!(sim_views.len(), nodes);
    // Every node's view matches its sim twin — and everyone agrees
    // with the coordinator's global table (full dissemination; a
    // transport-independent dissemination bug cannot hide behind a
    // transport-vs-transport comparison).
    for (i, s) in sim_views.iter().enumerate() {
        assert_eq!(s, &global, "node {i}: view diverged from global");
        assert_eq!(s, &udp_views[i], "node {i}: udp diverged from sim");
        assert_eq!(s, &tcp_views[i], "node {i}: tcp diverged from sim");
    }
    // Every transport actually moved frames.
    assert!(sim_frames > 0);
    assert!(udp_frames > 0);
    assert!(tcp_frames > 0);
}

// ---------------------------------------------------------------------
// Acceptance pin: trace-replay parity, sim vs udp, one seed.
// ---------------------------------------------------------------------

fn parity_spec() -> ScenarioSpec {
    ScenarioSpec {
        name: "net-parity".into(),
        about: "sim-vs-udp acceptance replay".into(),
        nodes: 24,
        initial_alive: 24,
        model: "fabric".into(),
        horizon: 1000.0,
        churn: vec![ChurnSpec::Poisson { rate: 0.002 }],
        latency: vec![],
    }
}

fn replay(kind: TransportKind) -> ScenarioReport {
    replay_with(kind, 0.0)
}

fn replay_with(kind: TransportKind, loss: f64) -> ScenarioReport {
    let mut engine = ScenarioEngine::new(parity_spec(), 0).unwrap();
    engine.opts.transport = Some(kind);
    engine.opts.loss_rate = loss;
    engine.run(Topology::Dgro).unwrap()
}

/// Shared parity assertion: per-period alive counts agree exactly (the
/// trace is oracle-driven on every transport) and alive diameters stay
/// within the pinned relative tolerances.
fn assert_parity(
    sim: &ScenarioReport,
    other: &ScenarioReport,
    label: &str,
    per_period_tol: f64,
    mean_tol: f64,
) {
    assert_eq!(sim.rows.len(), other.rows.len(), "{label}");
    for (a, b) in sim.rows.iter().zip(&other.rows) {
        assert_eq!(a.t, b.t, "{label}");
        assert_eq!(a.alive, b.alive, "{label} t={}", a.t);
        assert!(a.diameter.is_finite() && a.diameter > 0.0, "{label}");
        assert!(b.diameter.is_finite() && b.diameter > 0.0, "{label}");
        let tol = per_period_tol * a.diameter.max(1.0);
        assert!(
            (a.diameter - b.diameter).abs() <= tol,
            "{label} t={}: sim {} vs {} (tol {tol})",
            a.t,
            a.diameter,
            b.diameter
        );
    }
    let (ms, mo) = (sim.mean_diameter(), other.mean_diameter());
    assert!(
        (ms - mo).abs() <= mean_tol * ms.max(1.0),
        "{label}: mean alive diameter drifted: sim {ms} vs {mo}"
    );
}

#[test]
fn scenario_replay_sim_vs_udp_has_alive_diameter_parity() {
    let sim = replay(TransportKind::Sim);
    let udp = replay(TransportKind::Udp);
    assert_eq!(sim.rows.len(), 4, "horizon 1000 / period 250");
    // ρ comes from measured RTTs — exact on sim, jittered on udp — so
    // decisions (and hence diameters) may drift within tolerance.
    assert_parity(&sim, &udp, "udp", 0.35, 0.25);
}

#[test]
fn scenario_replay_sim_vs_tcp_has_alive_diameter_parity() {
    let sim = replay(TransportKind::Sim);
    let tcp = replay(TransportKind::Tcp);
    assert_eq!(sim.rows.len(), tcp.rows.len());
    assert_parity(&sim, &tcp, "tcp", 0.35, 0.25);
}

#[test]
fn sim_transport_replay_is_byte_deterministic() {
    let a = replay(TransportKind::Sim);
    let b = replay(TransportKind::Sim);
    assert_eq!(a.render(), b.render());
}

// ---------------------------------------------------------------------
// Loss hardening: seeded determinism + pinned drift bounds.
// ---------------------------------------------------------------------

#[test]
fn lossy_replay_is_byte_deterministic_per_seed() {
    // Same seed ⇒ the LossyTransport drops the same frames ⇒ the whole
    // CoordinatorReport (rendered) is byte-identical.
    let a = replay_with(TransportKind::Sim, 0.08);
    let b = replay_with(TransportKind::Sim, 0.08);
    assert_eq!(a.render(), b.render());
    // And the fault injection actually did something.
    assert!(
        a.metrics.counter("net.frames_lost") > 0,
        "8% loss over a full replay must write frames off"
    );
}

#[test]
fn injected_loss_keeps_measurement_drift_bounded() {
    let clean = replay_with(TransportKind::Sim, 0.0);
    for loss in [0.05, 0.10] {
        let lossy = replay_with(TransportKind::Sim, loss);
        // Membership is oracle-driven: alive counts agree exactly even
        // under loss; only the ρ inputs (and hence swap decisions)
        // drift. The per-period bound is loose (a one-period decision
        // flip legitimately moves the diameter a lot) but pinned — a
        // disconnection-style explosion fails it — and the mean bound
        // caps the sustained drift.
        assert_parity(
            &clean,
            &lossy,
            &format!("loss={loss}"),
            1.0,
            0.40,
        );
    }
}

// ---------------------------------------------------------------------
// Acceptance: the catalog's anchor-storm over tcp and lossy udp.
// ---------------------------------------------------------------------

fn anchor_replay(kind: TransportKind, loss: f64) -> ScenarioReport {
    let spec = find("anchor-storm").unwrap();
    let mut engine = ScenarioEngine::new(spec, 0).unwrap();
    engine.opts.transport = Some(kind);
    engine.opts.loss_rate = loss;
    // Compress wall time so the real-socket replays fit the CI
    // net-smoke budget.
    engine.opts.time_scale = 0.01;
    engine.run(Topology::Dgro).unwrap()
}

#[test]
fn anchor_storm_completes_on_tcp_and_lossy_udp_within_drift_bound() {
    let sim = anchor_replay(TransportKind::Sim, 0.0);
    assert_eq!(sim.rows.len(), 16, "horizon 4000 / period 250");
    let tcp = anchor_replay(TransportKind::Tcp, 0.0);
    let udp = anchor_replay(TransportKind::Udp, 0.05);
    for (label, rep) in [("tcp", &tcp), ("udp+5%loss", &udp)] {
        assert_eq!(rep.rows.len(), sim.rows.len(), "{label}");
        for (a, b) in sim.rows.iter().zip(&rep.rows) {
            assert_eq!(a.alive, b.alive, "{label} t={}", a.t);
            assert!(
                b.diameter.is_finite() && b.diameter > 0.0,
                "{label} t={}",
                a.t
            );
        }
        let (ms, mr) = (sim.mean_diameter(), rep.mean_diameter());
        assert!(
            (ms - mr).abs() <= 0.35 * ms.max(1.0),
            "{label}: mean alive diameter drift sim {ms} vs {mr}"
        );
    }
}
