//! Cross-module integration tests: coordinator + gossip + membership +
//! simulator working together over realistic latency models.

#![allow(clippy::field_reassign_with_default)] // config-mutation idiom

use dgro::config::Config;
use dgro::coordinator::Coordinator;
use dgro::dgro::select::adaptive_krings;
use dgro::graph::{components, diameter};
use dgro::latency::Model;
use dgro::membership::events::EventTrace;
use dgro::membership::swim::{SwimConfig, SwimSim};
use dgro::sim::broadcast::broadcast_times;
use dgro::topology::{chord::Chord, paper_k, rapid::Rapid};
use dgro::util::rng::Rng;

fn cfg(model: &str, nodes: usize) -> Config {
    let mut c = Config::default();
    c.model = model.to_string();
    c.nodes = nodes;
    c.scorer = "greedy".to_string();
    c.adapt_period_ms = 100.0;
    c
}

#[test]
fn adaptive_coordinator_beats_chord_and_rapid_on_fabric() {
    // The paper's headline at system level: after adaptation, the
    // coordinator's overlay has a smaller diameter than the latency-
    // oblivious baselines on the same matrix.
    let mut co = Coordinator::new(cfg("fabric", 102)).unwrap();
    let w = co.w.clone();
    let rep = co.run(&EventTrace::default(), 1500.0).unwrap();

    let mut rng = Rng::new(1);
    let d_chord =
        diameter::diameter(&Chord::build(102, &mut rng).to_graph(&w));
    let d_rapid =
        diameter::diameter(&Rapid::build(102, &mut rng).to_graph(&w));
    assert!(
        rep.final_diameter < d_chord && rep.final_diameter < d_rapid,
        "dgro {} vs chord {} rapid {}",
        rep.final_diameter,
        d_chord,
        d_rapid
    );
}

#[test]
fn adaptation_converges_rho_into_the_band() {
    let mut co = Coordinator::new(cfg("fabric", 85)).unwrap();
    let rep = co.run(&EventTrace::default(), 2000.0).unwrap();
    let last_rho = rep.timeline.last().unwrap().1;
    // After swaps the ρ statistic must sit inside (or hug) the Keep band.
    assert!(
        last_rho > 0.05 && last_rho < 0.95,
        "rho {last_rho} should converge toward the band"
    );
}

#[test]
fn coordinator_survives_heavy_churn_and_stays_connected() {
    let mut co = Coordinator::new(cfg("bitnode", 60)).unwrap();
    let mut rng = Rng::new(3);
    let trace = EventTrace::churn(60, 2000.0, 0.004, &mut rng);
    assert!(trace.len() > 10, "want a heavy trace, got {}", trace.len());
    let rep = co.run(&trace, 2000.0).unwrap();
    assert!(rep.alive >= 3);
    // Full-membership overlay stays connected (rings span all ids).
    assert!(components::is_connected(&co.overlay()));
}

#[test]
fn broadcast_completion_bounded_by_diameter_plus_processing() {
    let mut rng = Rng::new(5);
    let w = Model::Fabric.sample(68, &mut rng);
    let g = adaptive_krings(&w, paper_k(68), &mut rng).to_graph(&w);
    let d = diameter::diameter(&g) as f64;
    let proc = vec![1.0; 68];
    for src in [0usize, 10, 33] {
        let rep = broadcast_times(&g, src, &proc);
        assert!(rep.completion > 0.0);
        assert!(
            rep.completion <= d + 68.0, // diameter + total proc bound
            "completion {} vs diameter {d}",
            rep.completion
        );
    }
}

#[test]
fn swim_dissemination_faster_on_adapted_overlay() {
    // Crash dissemination (diameter-bound) must be no slower on the
    // DGRO overlay than on a single random ring.
    let mut rng = Rng::new(7);
    let w = Model::Fabric.sample(68, &mut rng);
    let dgro_g = adaptive_krings(&w, paper_k(68), &mut rng).to_graph(&w);
    let ring_g = dgro::topology::random_ring(68, &mut rng).to_graph(&w);
    let proc = vec![1.0; 68];

    let mut mean_diss = |g: &dgro::graph::Graph| {
        let mut swim = SwimSim::new(g, SwimConfig::default());
        let mut total = 0.0;
        for v in [5usize, 25, 55] {
            total +=
                swim.crash_and_measure(v, &proc, &mut rng).dissemination;
        }
        total / 3.0
    };
    let d_dgro = mean_diss(&dgro_g);
    let d_ring = mean_diss(&ring_g);
    assert!(
        d_dgro < d_ring,
        "dgro dissemination {d_dgro} vs ring {d_ring}"
    );
}

#[test]
fn config_end_to_end_roundtrip_into_coordinator() {
    let text = r#"{"nodes": 40, "model": "gaussian", "scorer": "native",
                   "epsilon": 0.2, "adapt_period_ms": 50}"#;
    let cfg = Config::parse(text).unwrap();
    let mut co = Coordinator::new(cfg).unwrap();
    let rep = co.run(&EventTrace::default(), 200.0).unwrap();
    assert_eq!(rep.timeline.len(), 4); // 200 / 50
}

#[test]
fn all_latency_models_drive_the_full_stack() {
    for model in Model::ALL {
        let mut co = Coordinator::new(cfg(model.name(), 51)).unwrap();
        let rep = co.run(&EventTrace::default(), 300.0).unwrap();
        assert!(
            rep.final_diameter > 0.0,
            "{}: zero diameter",
            model.name()
        );
        assert!(
            rep.final_diameter <= rep.initial_diameter * 1.3,
            "{}: adaptation made things much worse ({} -> {})",
            model.name(),
            rep.initial_diameter,
            rep.final_diameter
        );
    }
}
