//! Integration pins for the traffic plane (docs/TRAFFIC.md): the
//! workload rides the scenario engine's overlay timeline, and the
//! report is byte-deterministic — a pure function of
//! `(scenario, topology, seed, config)` — across repeated runs and
//! worker thread counts, on the in-process coordinator and on the
//! lossy sim transport alike.

use dgro::graph::eval::{CertifyConfig, CertifyMode};
use dgro::net::TransportKind;
use dgro::scenario::engine::{ScenarioEngine, Topology};
use dgro::scenario::spec::{ChurnSpec, ScenarioSpec};
use dgro::traffic::{TrafficConfig, TrafficReport};

fn mini_spec() -> ScenarioSpec {
    ScenarioSpec {
        name: "traffic-mini".into(),
        about: "small churny world for the traffic determinism pins".into(),
        nodes: 24,
        initial_alive: 22,
        model: "uniform".into(),
        horizon: 750.0,
        churn: vec![ChurnSpec::Poisson { rate: 0.004 }],
        latency: vec![],
    }
}

fn tcfg() -> TrafficConfig {
    let mut c = TrafficConfig::default();
    // ~10k requests per 250 ms period on a 24-node world: enough to
    // exercise queueing and the parallel routing fan-out, small enough
    // to keep the suite fast.
    c.rate = 40_000.0;
    c
}

/// One full run; returns the pair of deterministic renderings plus the
/// traffic report for structural checks.
fn run(
    topology: Topology,
    threads: usize,
    lossy: bool,
) -> (String, String, TrafficReport) {
    let mut engine = ScenarioEngine::new(mini_spec(), 21).unwrap();
    engine.opts.threads = threads;
    if topology == Topology::DgroSharded {
        engine.opts.shards = 2;
    }
    if lossy {
        engine.opts.transport = Some(TransportKind::Sim);
        engine.opts.loss_rate = 0.05;
    }
    let (rep, traffic, _obs) =
        engine.run_traffic(topology, tcfg()).unwrap();
    (rep.render(), traffic.render(), traffic)
}

#[test]
fn traffic_rides_the_timeline_and_aligns_periods() {
    let mut engine = ScenarioEngine::new(mini_spec(), 21).unwrap();
    engine.opts.threads = 2;
    let (rep, traffic, obs) =
        engine.run_traffic(Topology::Dgro, tcfg()).unwrap();
    assert_eq!(
        traffic.periods.len(),
        rep.rows.len(),
        "one traffic row per adaptation period"
    );
    for (tp, pr) in traffic.periods.iter().zip(&rep.rows) {
        assert_eq!(tp.t, pr.t, "traffic rows align with scenario rows");
    }
    assert!(traffic.offered > 0);
    assert!(traffic.success_rate() > 0.5, "{}", traffic.success_rate());
    assert!(traffic.mean_stretch >= 1.0, "{}", traffic.mean_stretch);
    assert!(traffic.max_stretch >= traffic.mean_stretch);
    assert_eq!(traffic.node_load.len(), 24);
    assert_eq!(
        traffic.node_load.iter().sum::<u64>(),
        traffic.delivered
    );
    // The obs surface carries the same totals.
    assert_eq!(obs.reg.get("traffic.offered"), traffic.offered);
    assert_eq!(obs.reg.get("traffic.delivered"), traffic.delivered);
    assert_eq!(
        obs.reg.counter_vec("traffic.node_load", 24).total(),
        traffic.delivered
    );
}

#[test]
fn same_seed_produces_byte_identical_reports() {
    let (sa, ta, ra) = run(Topology::Dgro, 2, false);
    let (sb, tb, rb) = run(Topology::Dgro, 2, false);
    assert_eq!(sa, sb);
    assert_eq!(ta, tb);
    assert_eq!(ra.to_json().to_string(), rb.to_json().to_string());
    assert_eq!(ra.table().to_csv(), rb.table().to_csv());
}

#[test]
fn worker_thread_count_is_invisible_in_the_report() {
    let (s1, t1, _) = run(Topology::Dgro, 1, false);
    for threads in [2usize, 8] {
        let (s, t, _) = run(Topology::Dgro, threads, false);
        assert_eq!(s1, s, "scenario report drifted at T={threads}");
        assert_eq!(t1, t, "traffic report drifted at T={threads}");
    }
}

#[test]
fn sharded_coordinator_carries_traffic_deterministically() {
    let (s1, t1, rep) = run(Topology::DgroSharded, 1, false);
    assert!(rep.offered > 0);
    assert!(rep.success_rate() > 0.5, "{}", rep.success_rate());
    for threads in [2usize, 8] {
        let (s, t, _) = run(Topology::DgroSharded, threads, false);
        assert_eq!(s1, s, "sharded scenario drifted at T={threads}");
        assert_eq!(t1, t, "sharded traffic drifted at T={threads}");
    }
}

#[test]
fn lossy_sim_transport_stays_byte_deterministic() {
    // 5% seeded frame loss on the sim transport: the overlay timeline
    // differs from the in-process run, but it is still a pure function
    // of the seed — and so is the traffic report riding on it.
    let (s1, t1, rep) = run(Topology::Dgro, 1, true);
    assert!(rep.offered > 0);
    for threads in [1usize, 2, 8] {
        let (s, t, _) = run(Topology::Dgro, threads, true);
        assert_eq!(s1, s, "lossy scenario drifted at T={threads}");
        assert_eq!(t1, t, "lossy traffic drifted at T={threads}");
    }
}

#[test]
fn hybrid_certification_composes_with_traffic() {
    let mut engine = ScenarioEngine::new(mini_spec(), 21).unwrap();
    engine.opts.threads = 2;
    engine.opts.certify = CertifyConfig {
        mode: CertifyMode::Hybrid,
        budget: 8,
        oracle_every: 4,
    };
    let (rep, traffic, _obs) =
        engine.run_traffic(Topology::Chord, tcfg()).unwrap();
    assert_eq!(traffic.periods.len(), rep.rows.len());
    assert!(traffic.offered > 0);
    assert!(traffic.mean_stretch >= 1.0);
}
