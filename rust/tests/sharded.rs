//! Sharded-coordinator integration: diameter parity against the
//! centralized coordinator at K ∈ {1, 4, 8} on a seeded scenario,
//! thread-count determinism, and the stitching property — re-anchoring
//! never strands a partition (the global overlay stays connected).

#![allow(clippy::field_reassign_with_default)] // config-mutation idiom

use dgro::config::Config;
use dgro::coordinator::{ShardedConfig, ShardedCoordinator};
use dgro::graph::eval::{CertifyConfig, CertifyMode};
use dgro::graph::{components, Graph};
use dgro::membership::events::MembershipEvent;
use dgro::prop::{ensure, forall, Config as PropConfig};
use dgro::scenario::{
    ChurnSpec, ScenarioEngine, ScenarioReport, ScenarioSpec, Topology,
};

/// The seeded parity workload: clustered FABRIC latencies (where ring
/// choice actually matters) plus background churn.
fn parity_spec() -> ScenarioSpec {
    ScenarioSpec {
        name: "sharded-parity".into(),
        about: "sharded-vs-centralized diameter parity".into(),
        nodes: 80,
        initial_alive: 80,
        model: "fabric".into(),
        horizon: 2000.0,
        churn: vec![ChurnSpec::Poisson { rate: 0.0005 }],
        latency: vec![],
    }
}

fn run_sharded(shards: usize, seed: u64, threads: usize) -> ScenarioReport {
    let mut engine = ScenarioEngine::new(parity_spec(), seed).unwrap();
    engine.opts.shards = shards;
    engine.opts.threads = threads;
    engine.run(Topology::DgroSharded).unwrap()
}

#[test]
fn sharded_diameter_parity_at_k_1_4_8() {
    let engine = ScenarioEngine::new(parity_spec(), 11).unwrap();
    let central = engine.run(Topology::Dgro).unwrap();
    let central_mean = central.mean_diameter();
    assert!(central_mean > 0.0);
    for k in [1usize, 4, 8] {
        let rep = run_sharded(k, 11, 1);
        assert_eq!(
            rep.rows.len(),
            central.rows.len(),
            "K={k}: period coverage"
        );
        for r in &rep.rows {
            assert!(
                r.diameter.is_finite() && r.diameter > 0.0,
                "K={k}: diameter {} at t={}",
                r.diameter,
                r.t
            );
            assert!(r.alive >= 3 && r.alive <= 80);
        }
        // The paper's §VI parity claim at system level: partition-local
        // ownership must stay in the centralized diameter ballpark
        // (fig 20 measures the exact curve; this is the regression
        // floor).
        let ratio = rep.mean_diameter() / central_mean;
        assert!(
            ratio <= 2.5,
            "K={k}: sharded mean diameter {} vs centralized {} \
             (ratio {ratio})",
            rep.mean_diameter(),
            central_mean
        );
    }
}

#[test]
fn sharded_runs_are_deterministic_and_thread_invariant() {
    let a = run_sharded(4, 7, 1);
    let b = run_sharded(4, 7, 1);
    assert_eq!(a.render(), b.render(), "same-seed runs differ");
    let c = run_sharded(4, 7, 4);
    assert_eq!(a.render(), c.render(), "thread count changed the run");
    // A different seed draws different churn.
    let d = run_sharded(4, 8, 1);
    assert_ne!(a.render(), d.render());
}

fn run_certified(
    shards: usize,
    threads: usize,
    certify: CertifyConfig,
) -> ScenarioReport {
    let spec = dgro::scenario::find("anchor-storm").unwrap();
    let mut engine = ScenarioEngine::new(spec, 11).unwrap();
    engine.opts.shards = shards;
    engine.opts.threads = threads;
    engine.opts.certify = certify;
    engine.run(Topology::DgroSharded).unwrap()
}

#[test]
fn hybrid_certification_preserves_swap_decisions_on_anchor_storm() {
    // Ring-swap decisions never consult a diameter, so sketch-certified
    // runs must reproduce the exact-mode swap sequence bit-for-bit at
    // every K — the acceptance pin behind `--certify hybrid`.
    let hybrid = CertifyConfig {
        mode: CertifyMode::Hybrid,
        budget: 8,
        oracle_every: 4,
    };
    for k in [1usize, 4, 8] {
        let exact = run_certified(k, 1, CertifyConfig::exact());
        let est = run_certified(k, 1, hybrid);
        assert_eq!(exact.rows.len(), est.rows.len(), "K={k}");
        for (a, b) in exact.rows.iter().zip(&est.rows) {
            assert_eq!(a.swaps, b.swaps, "K={k} t={}", a.t);
            assert_eq!(a.alive, b.alive, "K={k} t={}", a.t);
            // Hybrid reports the certified upper envelope (or the
            // oracle value), which never undercuts the exact diameter
            // by more than the convergence tolerance.
            assert!(
                b.diameter >= a.diameter - 1e-3 * a.diameter.max(1.0),
                "K={k} t={}: hybrid {} under exact {}",
                a.t,
                b.diameter,
                a.diameter
            );
        }
    }
}

#[test]
fn hybrid_sharded_runs_are_thread_invariant() {
    let hybrid = CertifyConfig {
        mode: CertifyMode::Hybrid,
        budget: 8,
        oracle_every: 4,
    };
    let a = run_certified(4, 1, hybrid);
    let b = run_certified(4, 4, hybrid);
    assert_eq!(a.render(), b.render(), "thread count changed the run");
    let c = run_certified(4, 1, hybrid);
    assert_eq!(a.render(), c.render(), "same-seed runs differ");
}

#[test]
fn prop_stitching_never_strands_a_partition() {
    forall(
        "shard stitching connectivity",
        PropConfig::default().cases(24),
        |rng| {
            let n = 24 + rng.index(73); // 24..=96
            let max_k = (n / 3).min(8);
            let k = 2 + rng.index(max_k - 1); // 2..=max_k
            let mut cfg = Config::default();
            cfg.nodes = n;
            cfg.model = "uniform".to_string();
            cfg.scorer = "greedy".to_string();
            cfg.seed = rng.next_u64();
            let mut co =
                ShardedCoordinator::new(cfg, ShardedConfig::new(k))
                    .map_err(|e| e.to_string())?;
            // Kill a random subset (up to half the universe), then
            // re-stitch.
            let kills = rng.index(n / 2 + 1);
            for _ in 0..kills {
                let node = rng.index(n) as u32;
                co.apply_event(&MembershipEvent::Crash {
                    time: 1.0,
                    node,
                });
            }
            co.re_anchor();
            // 1) No stranded partition: the full stitched overlay is
            //    one component whatever died.
            ensure(
                components::is_connected(&co.overlay()),
                format!("full overlay disconnected (n={n} K={k})"),
            )?;
            // 2) The anchor links alone connect every shard.
            let mut sg = Graph::empty(k);
            for &(u, v) in co.anchors() {
                let su = co.shard_of(u).expect("anchor in universe");
                let sv = co.shard_of(v).expect("anchor in universe");
                ensure(su != sv, "anchor within one shard")?;
                sg.add_edge(su, sv, 1.0);
            }
            ensure(
                components::is_connected(&sg),
                format!("shard graph disconnected (n={n} K={k})"),
            )
        },
    );
}

#[test]
fn compare_with_sharded_column_runs_end_to_end() {
    // The acceptance path behind `dgro scenario compare --shards 8`,
    // shrunk to one scenario so it stays CI-sized.
    let specs = vec![parity_spec()];
    let topologies = [
        Topology::Dgro,
        Topology::Chord,
        Topology::DgroSharded,
    ];
    let rep = dgro::scenario::compare_opts(
        &specs,
        &topologies,
        11,
        dgro::scenario::CompareOpts {
            period: 250.0,
            threads: 1,
            shards: 8,
        },
    )
    .unwrap();
    assert_eq!(rep.summary.rows.len(), 1);
    assert_eq!(rep.summary.header.len(), 4);
    let row = &rep.summary.rows[0];
    for cell in &row[1..] {
        assert!(cell.is_finite() && *cell > 0.0);
    }
    // Parity in the compare table itself: sharded vs centralized DGRO.
    let (dgro_mean, sharded_mean) = (row[1], row[3]);
    assert!(
        sharded_mean <= dgro_mean * 2.5,
        "compare table: sharded {sharded_mean} vs dgro {dgro_mean}"
    );
    assert!(rep.render().contains("sharded"));
}
