//! Discrete-event network simulator implementing the paper's §III system
//! model: per-link constant latency δ(u,v), per-node processing delay
//! Δ_v, immediate sequential relay of membership messages.

pub mod broadcast;
pub mod engine;

pub use broadcast::{broadcast_times, BroadcastReport};
pub use engine::{Engine, Event, EventKind};
