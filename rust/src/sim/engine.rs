//! Discrete-event core: a time-ordered queue of events delivered to a
//! handler. The membership runtime (SWIM probes, gossip dissemination)
//! and the broadcast analysis both run on this engine.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What an event does. Payloads are small and explicit rather than boxed
/// closures so the engine stays inspectable and deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// A message arriving at `dst`, sent by `src` (payload tag).
    Deliver { src: u32, dst: u32, tag: u64 },
    /// A timer firing at a node.
    Timer { node: u32, tag: u64 },
}

/// A scheduled event.
#[derive(Clone, Debug)]
pub struct Event {
    /// Delivery time (must be finite; `schedule` rejects NaN/inf).
    pub time: f64,
    /// Tie-break so equal-time events are FIFO-deterministic.
    pub seq: u64,
    /// What to deliver.
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap by (time, seq): reverse the natural comparison.
        // `schedule` rejects non-finite times, so total_cmp agrees with
        // the numeric order here (a NaN would otherwise silently corrupt
        // the heap invariant and deliver events out of order).
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The event queue + clock.
pub struct Engine {
    queue: BinaryHeap<Event>,
    now: f64,
    seq: u64,
    delivered: u64,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    /// An empty engine at t = 0.
    pub fn new() -> Engine {
        Engine {
            queue: BinaryHeap::new(),
            now: 0.0,
            seq: 0,
            delivered: 0,
        }
    }

    /// Current sim-time (last delivered event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Events delivered so far.
    pub fn delivered(&self) -> u64 {
        self.delivered
    }

    /// Events still queued.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule an event at absolute time `time` (>= now, finite).
    /// Panics on a non-finite time: a NaN would poison the heap ordering
    /// for every event, so it is rejected at the boundary instead.
    pub fn schedule(&mut self, time: f64, kind: EventKind) {
        assert!(
            time.is_finite(),
            "non-finite event time {time} for {kind:?}"
        );
        debug_assert!(time >= self.now, "cannot schedule into the past");
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Event { time, seq, kind });
    }

    /// Schedule relative to the current clock.
    pub fn schedule_in(&mut self, delay: f64, kind: EventKind) {
        self.schedule(self.now + delay.max(0.0), kind);
    }

    /// Time of the earliest queued event without delivering it. The
    /// transport layer uses this to honor receive deadlines: it only
    /// consumes events whose time is within the caller's timeout window.
    pub fn peek_time(&self) -> Option<f64> {
        self.queue.peek().map(|e| e.time)
    }

    /// Advance the clock to `t` without delivering anything — the
    /// "nothing arrived before the timeout" case of a blocking receive.
    /// Clamped to the next queued event's time so no event is ever
    /// skipped past or delivered late.
    pub fn advance_to(&mut self, t: f64) {
        assert!(t.is_finite(), "non-finite advance target {t}");
        let bound = match self.peek_time() {
            Some(next) => t.min(next),
            None => t,
        };
        self.now = self.now.max(bound);
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<Event> {
        let ev = self.queue.pop()?;
        self.now = ev.time;
        self.delivered += 1;
        Some(ev)
    }

    /// Run until the queue drains or `until` is reached, calling
    /// `handler(engine, event)` for each event (the handler may schedule
    /// more). Returns the number of events processed.
    pub fn run_until(
        &mut self,
        until: f64,
        mut handler: impl FnMut(&mut Engine, Event),
    ) -> u64 {
        let mut processed = 0;
        while let Some(ev) = self.queue.peek() {
            if ev.time > until {
                break;
            }
            let ev = self.next().unwrap();
            handler(self, ev);
            processed += 1;
        }
        self.now = self.now.max(until.min(self.now + 0.0));
        processed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_deliver_in_time_order() {
        let mut e = Engine::new();
        e.schedule(3.0, EventKind::Timer { node: 3, tag: 0 });
        e.schedule(1.0, EventKind::Timer { node: 1, tag: 0 });
        e.schedule(2.0, EventKind::Timer { node: 2, tag: 0 });
        let mut seen = Vec::new();
        while let Some(ev) = e.next() {
            if let EventKind::Timer { node, .. } = ev.kind {
                seen.push(node);
            }
        }
        assert_eq!(seen, vec![1, 2, 3]);
        assert_eq!(e.now(), 3.0);
    }

    #[test]
    fn equal_times_are_fifo() {
        let mut e = Engine::new();
        for i in 0..5 {
            e.schedule(1.0, EventKind::Timer { node: i, tag: 0 });
        }
        let mut seen = Vec::new();
        while let Some(ev) = e.next() {
            if let EventKind::Timer { node, .. } = ev.kind {
                seen.push(node);
            }
        }
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn handler_can_chain_events() {
        let mut e = Engine::new();
        e.schedule(0.0, EventKind::Timer { node: 0, tag: 0 });
        let mut count = 0;
        e.run_until(10.0, |eng, ev| {
            count += 1;
            if let EventKind::Timer { node, tag } = ev.kind {
                if tag < 3 {
                    eng.schedule_in(
                        1.0,
                        EventKind::Timer {
                            node,
                            tag: tag + 1,
                        },
                    );
                }
            }
        });
        assert_eq!(count, 4); // tags 0,1,2,3
        assert_eq!(e.now(), 3.0);
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_time_is_rejected_at_schedule() {
        let mut e = Engine::new();
        e.schedule(f64::NAN, EventKind::Timer { node: 0, tag: 0 });
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_time_is_rejected_at_schedule() {
        let mut e = Engine::new();
        e.schedule(f64::INFINITY, EventKind::Timer { node: 0, tag: 0 });
    }

    #[test]
    fn run_until_stops_at_horizon() {
        let mut e = Engine::new();
        e.schedule(1.0, EventKind::Timer { node: 0, tag: 0 });
        e.schedule(100.0, EventKind::Timer { node: 0, tag: 1 });
        let n = e.run_until(10.0, |_, _| {});
        assert_eq!(n, 1);
        assert_eq!(e.pending(), 1);
    }
}
