//! Membership-update broadcast over an overlay (paper §III-A): when a
//! node initiates or receives an update it relays to all neighbors;
//! delivery over (u, v) takes δ(u, v) plus the receiver's processing
//! delay Δ_v. The completion time of a broadcast from the worst-case
//! source is the *latency realization* of the topology's diameter — the
//! quantity the whole paper optimizes.

use super::engine::{Engine, EventKind};
use crate::graph::Graph;

/// Result of one broadcast simulation.
#[derive(Clone, Debug)]
pub struct BroadcastReport {
    /// First-arrival time per node (f64::INFINITY if unreachable).
    pub arrival: Vec<f64>,
    /// Time the last reachable node heard the update.
    pub completion: f64,
    /// Messages sent (every relay counts — gossip cost accounting).
    pub messages: u64,
}

/// Simulate a broadcast from `src` over `g`, with per-node processing
/// delays `proc` (Δ_v; may be all-zero).
pub fn broadcast_times(g: &Graph, src: usize, proc: &[f64]) -> BroadcastReport {
    let n = g.n();
    assert_eq!(proc.len(), n);
    let mut engine = Engine::new();
    let mut arrival = vec![f64::INFINITY; n];
    let mut messages = 0u64;

    arrival[src] = 0.0;
    // Source relays immediately to every neighbor.
    for &(v, w) in g.neighbors(src) {
        engine.schedule(
            w as f64 + proc[v as usize],
            EventKind::Deliver {
                src: src as u32,
                dst: v,
                tag: 0,
            },
        );
        messages += 1;
    }

    while let Some(ev) = engine.next() {
        if let EventKind::Deliver { dst, .. } = ev.kind {
            let u = dst as usize;
            if arrival[u].is_finite() {
                continue; // duplicate — already relayed
            }
            arrival[u] = ev.time;
            for &(v, w) in g.neighbors(u) {
                if arrival[v as usize].is_finite() {
                    continue;
                }
                engine.schedule_in(
                    w as f64 + proc[v as usize],
                    EventKind::Deliver {
                        src: dst,
                        dst: v,
                        tag: 0,
                    },
                );
                messages += 1;
            }
        }
    }

    let completion = arrival
        .iter()
        .copied()
        .filter(|t| t.is_finite())
        .fold(0.0, f64::max);
    BroadcastReport {
        arrival,
        completion,
        messages,
    }
}

/// Worst-case broadcast completion over all sources — the simulated
/// counterpart of the graph diameter (with Δ_v = 0 and no duplicate
/// suppression they coincide exactly; the test asserts it).
pub fn worst_case_completion(g: &Graph, proc: &[f64]) -> f64 {
    (0..g.n())
        .map(|s| broadcast_times(g, s, proc).completion)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{apsp, diameter, Graph};
    use crate::latency::synthetic;
    use crate::topology::random_ring;
    use crate::util::rng::Rng;

    #[test]
    fn arrival_equals_shortest_path_when_no_processing() {
        let mut rng = Rng::new(1);
        let w = synthetic::uniform(20, &mut rng);
        let g = random_ring(20, &mut rng).to_graph(&w);
        let rep = broadcast_times(&g, 0, &vec![0.0; 20]);
        let d = apsp::dijkstra(&g, 0);
        for v in 0..20 {
            assert!(
                (rep.arrival[v] - d[v] as f64).abs() < 1e-4,
                "node {v}: sim {} vs dijkstra {}",
                rep.arrival[v],
                d[v]
            );
        }
    }

    #[test]
    fn worst_case_completion_is_diameter() {
        let mut rng = Rng::new(2);
        let w = synthetic::uniform(16, &mut rng);
        let g = random_ring(16, &mut rng).to_graph(&w);
        let d = diameter::diameter(&g) as f64;
        let wc = worst_case_completion(&g, &vec![0.0; 16]);
        assert!((wc - d).abs() < 1e-3, "sim {wc} vs diameter {d}");
    }

    #[test]
    fn processing_delay_slows_completion() {
        let mut rng = Rng::new(3);
        let w = synthetic::uniform(14, &mut rng);
        let g = random_ring(14, &mut rng).to_graph(&w);
        let fast = broadcast_times(&g, 0, &vec![0.0; 14]).completion;
        let slow = broadcast_times(&g, 0, &vec![1.0; 14]).completion;
        assert!(slow > fast);
    }

    #[test]
    fn unreachable_nodes_are_inf_and_ignored() {
        let mut g = Graph::empty(4);
        g.add_edge(0, 1, 2.0);
        // Nodes 2, 3 isolated.
        let rep = broadcast_times(&g, 0, &vec![0.0; 4]);
        assert_eq!(rep.arrival[1], 2.0);
        assert!(rep.arrival[2].is_infinite());
        assert_eq!(rep.completion, 2.0);
    }

    #[test]
    fn message_count_bounded_by_relays() {
        let mut rng = Rng::new(4);
        let w = synthetic::uniform(12, &mut rng);
        let g = random_ring(12, &mut rng).to_graph(&w);
        let rep = broadcast_times(&g, 0, &vec![0.0; 12]);
        // Every node relays to <= deg neighbors once.
        let max_msgs: u64 =
            (0..12).map(|u| g.degree(u) as u64).sum();
        assert!(rep.messages <= max_msgs);
        assert!(rep.messages >= 11); // at least a spanning relay
    }
}
