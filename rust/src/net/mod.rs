//! Real-socket transport subsystem: run the DGRO coordinator over
//! message-level transports (docs/TRANSPORT.md).
//!
//! Five layers:
//!
//! * [`transport`] — the [`Transport`](transport::Transport) trait
//!   (framed datagrams, peer addressing, clock, per-link delay shaping)
//!   with [`SimTransport`](transport::SimTransport) over the
//!   discrete-event engine and [`UdpTransport`](transport::UdpTransport)
//!   over `std::net::UdpSocket` loopback with a deterministic
//!   delay-injection shim driven by the same
//!   [`LatencyMatrix`](crate::latency::LatencyMatrix) the simulator
//!   uses.
//! * [`tcp`] — [`TcpTransport`](tcp::TcpTransport): length-prefixed
//!   framed streams over per-peer loopback TCP connections with
//!   on-demand dialing and reconnect/backoff, sharing the delay shim.
//! * [`lossy`] — [`LossyTransport`](lossy::LossyTransport): a seeded
//!   drop/duplicate/reorder decorator over any backend, so loss
//!   scenarios replay deterministically (`--loss-rate`, `--dup-rate`,
//!   `--reorder-rate`).
//! * [`wire`] — the versioned, **epoch-tagged** binary wire protocol:
//!   gossip probes, membership events, ring-swap announcements,
//!   coordinator reports. Since wire v2 every frame carries the
//!   collection-phase epoch so cross-phase stragglers are rejected.
//! * [`runner`] — the [`NetCoordinator`](runner::NetCoordinator): N
//!   in-process node actors over the chosen transport, Algorithm-3
//!   measurement from real message RTTs with bounded probe retransmit
//!   and loss-weighted push-sum aggregation, ρ-guided ring swaps, the
//!   same [`CoordinatorReport`](crate::coordinator::CoordinatorReport)
//!   stream as the in-process coordinator.
//!
//! `dgro scenario run --transport sim|udp|tcp [--loss-rate R]` replays
//! any scenario trace over any transport; `rust/tests/net.rs` pins the
//! cross-transport per-period alive-diameter parity (exact trace
//! parity, bounded drift under injected loss) and figure 21 records it.

pub mod lossy;
pub mod runner;
pub mod tcp;
pub mod transport;
pub mod wire;

use anyhow::{bail, Result};

pub use lossy::{LossyConfig, LossyTransport};
pub use runner::NetCoordinator;
pub use tcp::TcpTransport;
pub use transport::{Delivery, SimTransport, Transport, UdpTransport};
pub use wire::{Message, WIRE_VERSION};

/// Which transport backs a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// [`SimTransport`]: discrete-event engine, exact delays.
    Sim,
    /// [`UdpTransport`]: UDP loopback processes with the delay shim.
    Udp,
    /// [`TcpTransport`]: framed loopback streams with reconnect and
    /// the same delay shim.
    Tcp,
}

impl TransportKind {
    /// Parse a CLI transport name.
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Ok(TransportKind::Sim),
            "udp" => Ok(TransportKind::Udp),
            "tcp" => Ok(TransportKind::Tcp),
            other => bail!("unknown transport '{other}' (sim|udp|tcp)"),
        }
    }

    /// Stable display/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Sim => "sim",
            TransportKind::Udp => "udp",
            TransportKind::Tcp => "tcp",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_round_trips() {
        for k in
            [TransportKind::Sim, TransportKind::Udp, TransportKind::Tcp]
        {
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
    }
}
