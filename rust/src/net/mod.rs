//! Real-socket transport subsystem: run the DGRO coordinator over
//! message-level transports (docs/TRANSPORT.md).
//!
//! Three layers:
//!
//! * [`transport`] — the [`Transport`](transport::Transport) trait
//!   (framed datagrams, peer addressing, clock, per-link delay shaping)
//!   with [`SimTransport`](transport::SimTransport) over the
//!   discrete-event engine and [`UdpTransport`](transport::UdpTransport)
//!   over `std::net::UdpSocket` loopback with a deterministic
//!   delay-injection shim driven by the same
//!   [`LatencyMatrix`](crate::latency::LatencyMatrix) the simulator
//!   uses.
//! * [`wire`] — the versioned binary wire protocol: gossip probes,
//!   membership events, ring-swap announcements, coordinator reports.
//! * [`runner`] — the [`NetCoordinator`](runner::NetCoordinator): N
//!   in-process node actors over the chosen transport, Algorithm-3
//!   measurement from real message RTTs, ρ-guided ring swaps, the same
//!   [`CoordinatorReport`](crate::coordinator::CoordinatorReport)
//!   stream as the in-process coordinator.
//!
//! `dgro scenario run --transport sim|udp` replays any scenario trace
//! over either transport; `rust/tests/net.rs` pins the sim-vs-udp
//! per-period alive-diameter parity and figure 21 records it.

pub mod runner;
pub mod transport;
pub mod wire;

use anyhow::{bail, Result};

pub use runner::NetCoordinator;
pub use transport::{Delivery, SimTransport, Transport, UdpTransport};
pub use wire::{Message, WIRE_VERSION};

/// Which transport backs a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// [`SimTransport`]: discrete-event engine, exact delays.
    Sim,
    /// [`UdpTransport`]: UDP loopback processes with the delay shim.
    Udp,
}

impl TransportKind {
    /// Parse a CLI transport name.
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Ok(TransportKind::Sim),
            "udp" => Ok(TransportKind::Udp),
            other => bail!("unknown transport '{other}' (sim|udp)"),
        }
    }

    /// Stable display/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Sim => "sim",
            TransportKind::Udp => "udp",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_round_trips() {
        for k in [TransportKind::Sim, TransportKind::Udp] {
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
        }
        assert!(TransportKind::parse("tcp").is_err());
    }
}
