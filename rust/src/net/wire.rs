//! Wire protocol for the message-level transport: compact binary
//! framing for gossip probes, membership events, ring-swap announcements
//! and coordinator reports (docs/TRANSPORT.md has the byte-level table).
//!
//! Every frame starts with a version byte ([`WIRE_VERSION`]), a 32-bit
//! **epoch tag**, a flags byte and a tag byte; integers are
//! little-endian, floats are IEEE-754 bit patterns. Decoding is strict:
//! unknown versions, unknown flags, unknown tags, truncated frames and
//! trailing bytes are all hard errors — a membership protocol that
//! silently mis-parses a frame corrupts views on every node downstream,
//! so the boundary rejects instead.
//!
//! The epoch is the loss-hardening half of the contract (wire v2): the
//! coordinator stamps every frame with the collection phase it belongs
//! to, and a receiver that has moved on to a later phase drops the
//! straggler outright ([`Message::decode_expect`]) instead of folding it
//! into the next barrier. Without it, a datagram written off as lost and
//! then delivered late would perturb a *later* phase's delivery count —
//! the cascade documented (and previously only documented) in
//! docs/TRANSPORT.md.
//!
//! Wire v3 adds the flags byte and, when [`FLAG_TRACE`] is set, a
//! 16-byte trace context ([`TraceCtx`]: trace id + parent span id,
//! both u64 LE) between the flags and tag bytes — how a causal trace
//! stitches sender → delivery → reply spans across nodes (see
//! [`crate::obs::trace`]). Untraced frames pay exactly one extra byte
//! over v2. v1/v2 frames are rejected with a distinct "legacy" error
//! so mixed-version fleets fail diagnosably.

use anyhow::{bail, Result};

use crate::membership::events::MembershipEvent;
use crate::membership::list::MemberState;
use crate::obs::trace::TraceCtx;

/// Current wire version. Bump on any incompatible layout change; peers
/// reject frames whose version byte differs. v2 added the 32-bit epoch
/// tag between the version and tag bytes; v3 added the flags byte and
/// the optional trace context.
pub const WIRE_VERSION: u8 = 3;

/// Byte length of the minimal frame header: version, epoch, flags, tag
/// (a [`FLAG_TRACE`] frame carries [`TRACE_CTX_LEN`] more).
pub const HEADER_LEN: usize = 1 + 4 + 1 + 1;

/// Flags bit: the header carries a 16-byte trace context between the
/// flags and tag bytes.
pub const FLAG_TRACE: u8 = 1;

/// Byte length of the optional trace context (trace id + parent span).
pub const TRACE_CTX_LEN: usize = 8 + 8;

/// One protocol message. The transport moves opaque frames; this enum is
/// the typed layer on top.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// RTT probe request (Algorithm-3 sampling). `seq` matches the
    /// probe to its [`Message::Pong`].
    Ping {
        /// Prober-local sequence number echoed by the reply.
        seq: u32,
    },
    /// RTT probe reply: echoes the ping's `seq`, carrying the
    /// responder's processing delay (NTP-style) so the prober can
    /// subtract it — without this, receiver-side scheduling slop would
    /// systematically inflate every RTT measured over real sockets.
    Pong {
        /// The echoed [`Message::Ping`] sequence number.
        seq: u32,
        /// Time the responder held the ping before replying
        /// (transport-clock ms); the prober subtracts it from the
        /// measured round trip.
        hold_ms: f64,
    },
    /// One push-sum gossip step: half of the sender's accumulated
    /// (local, global, min) latency triple plus the push-sum weights
    /// (`m` = node-count mass, `ml` = mass of nodes that contributed a
    /// local sample).
    GossipPush {
        /// Accumulated mean-neighbor-latency mass.
        local: f64,
        /// Accumulated mean-random-latency mass.
        global: f64,
        /// Accumulated min-sampled-latency mass.
        min: f64,
        /// Push-sum node-count weight.
        m: f64,
        /// Push-sum weight of local-sample contributors.
        ml: f64,
    },
    /// A membership change disseminated to every node (join / leave /
    /// crash with its trace timestamp).
    Membership {
        /// The event being disseminated.
        event: MembershipEvent,
    },
    /// Ring-swap announcement: ring `slot` of the K-ring overlay is
    /// replaced by the given visit order.
    RingSwap {
        /// Which ring slot is replaced.
        slot: u32,
        /// The new ring's visit order (a permutation of `0..n`).
        order: Vec<u32>,
    },
    /// Per-period coordinator report broadcast to the membership — the
    /// same numbers the in-process
    /// [`CoordinatorReport`](crate::coordinator::CoordinatorReport)
    /// timeline carries.
    Report {
        /// Adaptation period index.
        period: u32,
        /// Sim-time at the end of the period (ms).
        t_ms: f64,
        /// ρ statistic for the period.
        rho: f64,
        /// Full-overlay diameter.
        diameter: f64,
        /// Alive members.
        alive: u32,
        /// Cumulative ring swaps.
        swaps: u32,
    },
    /// One SWIM membership record, flooded peer-to-peer by the
    /// decentralized runner (docs/DECENTRALIZED.md): receivers fold it
    /// through [`MembershipList::apply`](crate::membership::list::MembershipList::apply)
    /// and re-forward only when the merge actually advanced their view,
    /// so the flood self-quenches.
    MemberUpdate {
        /// The member the record is about.
        node: u32,
        /// Reported lifecycle state.
        state: MemberState,
        /// SWIM incarnation (higher wins; ties break on state rank).
        incarnation: u64,
        /// Sim-time the record was produced.
        time: f64,
    },
    /// Phase 1 of the decentralized two-phase ring swap: the proposer
    /// asks the affected ring neighbors to lock the period's single
    /// swap grant for `seq` before it may commit `order` into `slot`.
    SwapPropose {
        /// Ring slot the proposal would replace.
        slot: u32,
        /// Proposer-local sequence number echoed by the ack.
        seq: u32,
        /// The candidate ring's visit order (a permutation of `0..n`).
        order: Vec<u32>,
    },
    /// Phase 1 reply: grant (or refuse) the proposal carrying `seq`.
    /// A node grants at most one proposal per adaptation period.
    SwapAck {
        /// The echoed [`Message::SwapPropose`] sequence number.
        seq: u32,
        /// Whether the responder granted its period lock.
        accept: bool,
    },
    /// Phase 2: a fully granted swap, flooded to the membership. The
    /// `(period, proposer)` pair is the slot's version — receivers
    /// apply the commit only when it is newer than what they hold
    /// (higher period wins; ties break toward the lower proposer id),
    /// so any subset of commits applied in any order converges.
    SwapCommit {
        /// Ring slot being replaced.
        slot: u32,
        /// Adaptation period the swap was granted in.
        period: u32,
        /// Node id that proposed (and won) the swap.
        proposer: u32,
        /// The committed ring's visit order (a permutation of `0..n`).
        order: Vec<u32>,
    },
    /// Anti-entropy digest: the sender's per-slot ring versions
    /// (`(period, proposer)` per K-ring slot, slot index implicit).
    /// A receiver holding a newer version for any slot pushes the
    /// corresponding [`Message::SwapCommit`] back, repairing peers
    /// that missed a commit under loss.
    RingDigest {
        /// One `(period, proposer)` version per ring slot.
        versions: Vec<(u32, u32)>,
    },
}

const TAG_PING: u8 = 0;
const TAG_PONG: u8 = 1;
const TAG_GOSSIP: u8 = 2;
const TAG_MEMBERSHIP: u8 = 3;
const TAG_RINGSWAP: u8 = 4;
const TAG_REPORT: u8 = 5;
const TAG_MEMBER_UPDATE: u8 = 6;
const TAG_SWAP_PROPOSE: u8 = 7;
const TAG_SWAP_ACK: u8 = 8;
const TAG_SWAP_COMMIT: u8 = 9;
const TAG_RING_DIGEST: u8 = 10;

const EV_JOIN: u8 = 0;
const EV_LEAVE: u8 = 1;
const EV_CRASH: u8 = 2;

const ST_ALIVE: u8 = 0;
const ST_SUSPECT: u8 = 1;
const ST_FAULTY: u8 = 2;
const ST_LEFT: u8 = 3;

fn state_byte(s: MemberState) -> u8 {
    match s {
        MemberState::Alive => ST_ALIVE,
        MemberState::Suspect => ST_SUSPECT,
        MemberState::Faulty => ST_FAULTY,
        MemberState::Left => ST_LEFT,
    }
}

fn byte_state(b: u8) -> Result<MemberState> {
    Ok(match b {
        ST_ALIVE => MemberState::Alive,
        ST_SUSPECT => MemberState::Suspect,
        ST_FAULTY => MemberState::Faulty,
        ST_LEFT => MemberState::Left,
        other => bail!("unknown member state {other}"),
    })
}

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, x: f64) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Strict little-endian reader over a frame body.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated frame: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Length-prefixed u32 sequence (ring visit orders). Bounds the
    /// declared length before allocating: a corrupt length must not
    /// drive an OOM allocation; the body can hold at most `len` u32s
    /// anyway.
    fn read_order(&mut self) -> Result<Vec<u32>> {
        let len = self.u32()? as usize;
        if len > self.buf.len() / 4 + 1 {
            bail!("ring order length {len} exceeds frame");
        }
        let mut order = Vec::with_capacity(len);
        for _ in 0..len {
            order.push(self.u32()?);
        }
        Ok(order)
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "trailing garbage: {} bytes past the message end",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

impl Message {
    /// Encode into a framed byte vector without trace context
    /// (version + epoch + flags + tag + payload).
    pub fn encode(&self, epoch: u32) -> Vec<u8> {
        self.encode_traced(epoch, None)
    }

    /// Encode into a framed byte vector, optionally carrying a trace
    /// context (version + epoch + flags \[+ trace ctx\] + tag +
    /// payload).
    pub fn encode_traced(
        &self,
        epoch: u32,
        ctx: Option<TraceCtx>,
    ) -> Vec<u8> {
        let mut out = Vec::with_capacity(24 + TRACE_CTX_LEN);
        out.push(WIRE_VERSION);
        out.extend_from_slice(&epoch.to_le_bytes());
        match ctx {
            Some(c) => {
                out.push(FLAG_TRACE);
                out.extend_from_slice(&c.trace.to_le_bytes());
                out.extend_from_slice(&c.parent.to_le_bytes());
            }
            None => out.push(0),
        }
        self.write_body(&mut out);
        out
    }

    /// Append the tag byte and payload.
    fn write_body(&self, out: &mut Vec<u8>) {
        match self {
            Message::Ping { seq } => {
                out.push(TAG_PING);
                put_u32(out, *seq);
            }
            Message::Pong { seq, hold_ms } => {
                out.push(TAG_PONG);
                put_u32(out, *seq);
                put_f64(out, *hold_ms);
            }
            Message::GossipPush {
                local,
                global,
                min,
                m,
                ml,
            } => {
                out.push(TAG_GOSSIP);
                for x in [local, global, min, m, ml] {
                    put_f64(out, *x);
                }
            }
            Message::Membership { event } => {
                out.push(TAG_MEMBERSHIP);
                let (kind, time, node) = match *event {
                    MembershipEvent::Join { time, node } => {
                        (EV_JOIN, time, node)
                    }
                    MembershipEvent::Leave { time, node } => {
                        (EV_LEAVE, time, node)
                    }
                    MembershipEvent::Crash { time, node } => {
                        (EV_CRASH, time, node)
                    }
                };
                out.push(kind);
                put_f64(out, time);
                put_u32(out, node);
            }
            Message::RingSwap { slot, order } => {
                out.push(TAG_RINGSWAP);
                put_u32(out, *slot);
                put_u32(out, order.len() as u32);
                for &v in order {
                    put_u32(out, v);
                }
            }
            Message::Report {
                period,
                t_ms,
                rho,
                diameter,
                alive,
                swaps,
            } => {
                out.push(TAG_REPORT);
                put_u32(out, *period);
                put_f64(out, *t_ms);
                put_f64(out, *rho);
                put_f64(out, *diameter);
                put_u32(out, *alive);
                put_u32(out, *swaps);
            }
            Message::MemberUpdate {
                node,
                state,
                incarnation,
                time,
            } => {
                out.push(TAG_MEMBER_UPDATE);
                put_u32(out, *node);
                out.push(state_byte(*state));
                put_u64(out, *incarnation);
                put_f64(out, *time);
            }
            Message::SwapPropose { slot, seq, order } => {
                out.push(TAG_SWAP_PROPOSE);
                put_u32(out, *slot);
                put_u32(out, *seq);
                put_u32(out, order.len() as u32);
                for &v in order {
                    put_u32(out, v);
                }
            }
            Message::SwapAck { seq, accept } => {
                out.push(TAG_SWAP_ACK);
                put_u32(out, *seq);
                out.push(u8::from(*accept));
            }
            Message::SwapCommit {
                slot,
                period,
                proposer,
                order,
            } => {
                out.push(TAG_SWAP_COMMIT);
                put_u32(out, *slot);
                put_u32(out, *period);
                put_u32(out, *proposer);
                put_u32(out, order.len() as u32);
                for &v in order {
                    put_u32(out, v);
                }
            }
            Message::RingDigest { versions } => {
                out.push(TAG_RING_DIGEST);
                put_u32(out, versions.len() as u32);
                for &(period, proposer) in versions {
                    put_u32(out, period);
                    put_u32(out, proposer);
                }
            }
        }
    }

    /// Decode the tag byte and payload from `r`.
    fn read_body(tag: u8, r: &mut Reader<'_>) -> Result<Message> {
        let msg = match tag {
            TAG_PING => Message::Ping { seq: r.u32()? },
            TAG_PONG => Message::Pong {
                seq: r.u32()?,
                hold_ms: r.f64()?,
            },
            TAG_GOSSIP => Message::GossipPush {
                local: r.f64()?,
                global: r.f64()?,
                min: r.f64()?,
                m: r.f64()?,
                ml: r.f64()?,
            },
            TAG_MEMBERSHIP => {
                let kind = r.u8()?;
                let time = r.f64()?;
                let node = r.u32()?;
                let event = match kind {
                    EV_JOIN => MembershipEvent::Join { time, node },
                    EV_LEAVE => MembershipEvent::Leave { time, node },
                    EV_CRASH => MembershipEvent::Crash { time, node },
                    other => bail!("unknown membership kind {other}"),
                };
                Message::Membership { event }
            }
            TAG_RINGSWAP => {
                let slot = r.u32()?;
                let order = r.read_order()?;
                Message::RingSwap { slot, order }
            }
            TAG_REPORT => Message::Report {
                period: r.u32()?,
                t_ms: r.f64()?,
                rho: r.f64()?,
                diameter: r.f64()?,
                alive: r.u32()?,
                swaps: r.u32()?,
            },
            TAG_MEMBER_UPDATE => Message::MemberUpdate {
                node: r.u32()?,
                state: byte_state(r.u8()?)?,
                incarnation: r.u64()?,
                time: r.f64()?,
            },
            TAG_SWAP_PROPOSE => {
                let slot = r.u32()?;
                let seq = r.u32()?;
                let order = r.read_order()?;
                Message::SwapPropose { slot, seq, order }
            }
            TAG_SWAP_ACK => {
                let seq = r.u32()?;
                let accept = match r.u8()? {
                    0 => false,
                    1 => true,
                    other => bail!("bad swap-ack flag {other}"),
                };
                Message::SwapAck { seq, accept }
            }
            TAG_SWAP_COMMIT => {
                let slot = r.u32()?;
                let period = r.u32()?;
                let proposer = r.u32()?;
                let order = r.read_order()?;
                Message::SwapCommit {
                    slot,
                    period,
                    proposer,
                    order,
                }
            }
            TAG_RING_DIGEST => {
                let len = r.u32()? as usize;
                // Same pre-allocation bound as the ring orders: the
                // body can hold at most len (u32, u32) pairs.
                if len > r.buf.len() / 8 + 1 {
                    bail!("ring-digest length {len} exceeds frame");
                }
                let mut versions = Vec::with_capacity(len);
                for _ in 0..len {
                    versions.push((r.u32()?, r.u32()?));
                }
                Message::RingDigest { versions }
            }
            other => bail!("unknown message tag {other}"),
        };
        Ok(msg)
    }

    /// Decode a framed byte vector into `(epoch, message)`, dropping
    /// any trace context. Rejects unknown versions, flags and tags,
    /// truncated frames and trailing bytes; the caller decides what to
    /// do with the epoch (the coordinator drops cross-epoch stragglers
    /// — see [`Message::decode_expect`]).
    pub fn decode(frame: &[u8]) -> Result<(u32, Message)> {
        let (epoch, _ctx, msg) = Message::decode_traced(frame)?;
        Ok((epoch, msg))
    }

    /// Decode a framed byte vector into `(epoch, trace context,
    /// message)`. Same strictness as [`Message::decode`]; legacy
    /// (v1/v2) frames, unknown flag bits and a declared-but-truncated
    /// trace context each get a distinct error.
    pub fn decode_traced(
        frame: &[u8],
    ) -> Result<(u32, Option<TraceCtx>, Message)> {
        if frame.len() < HEADER_LEN {
            bail!("frame too short ({} bytes)", frame.len());
        }
        let version = frame[0];
        if version != WIRE_VERSION {
            if (1..WIRE_VERSION).contains(&version) {
                bail!(
                    "legacy wire version {version} (speaking \
                     {WIRE_VERSION}); upgrade the peer"
                );
            }
            bail!(
                "unknown wire version {version} (speaking {})",
                WIRE_VERSION
            );
        }
        let epoch = u32::from_le_bytes(frame[1..5].try_into().unwrap());
        let flags = frame[5];
        if flags & !FLAG_TRACE != 0 {
            bail!("unknown header flags {flags:#04x}");
        }
        let (ctx, tag_at) = if flags & FLAG_TRACE != 0 {
            if frame.len() < HEADER_LEN + TRACE_CTX_LEN {
                bail!(
                    "truncated trace context: need {TRACE_CTX_LEN} \
                     bytes, have {}",
                    frame.len() - HEADER_LEN
                );
            }
            let trace =
                u64::from_le_bytes(frame[6..14].try_into().unwrap());
            let parent =
                u64::from_le_bytes(frame[14..22].try_into().unwrap());
            (Some(TraceCtx { trace, parent }), 6 + TRACE_CTX_LEN)
        } else {
            (None, 6)
        };
        let tag = frame[tag_at];
        let mut r = Reader {
            buf: &frame[tag_at + 1..],
            pos: 0,
        };
        let msg = Message::read_body(tag, &mut r)?;
        r.done()?;
        Ok((epoch, ctx, msg))
    }

    /// Strict epoch-checked decode: like [`Message::decode`], but a
    /// frame whose epoch differs from `expect` is a hard error — the
    /// loss-tolerant protocol's rule that a straggler from a written-off
    /// collection phase must never mutate state in a later one.
    pub fn decode_expect(frame: &[u8], expect: u32) -> Result<Message> {
        let (epoch, msg) = Message::decode(frame)?;
        if epoch != expect {
            bail!("stale frame epoch {epoch} (current epoch {expect})");
        }
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, Config};
    use crate::util::rng::Rng;

    fn samples() -> Vec<Message> {
        vec![
            Message::Ping { seq: 0 },
            Message::Ping { seq: u32::MAX },
            Message::Pong {
                seq: 7,
                hold_ms: 1.5,
            },
            Message::GossipPush {
                local: 1.25,
                global: -0.5,
                min: f64::MIN_POSITIVE,
                m: 0.5,
                ml: 0.0,
            },
            Message::Membership {
                event: MembershipEvent::Join {
                    time: 125.5,
                    node: 3,
                },
            },
            Message::Membership {
                event: MembershipEvent::Crash {
                    time: 0.0,
                    node: u32::MAX,
                },
            },
            Message::RingSwap {
                slot: 2,
                order: vec![0, 3, 1, 2],
            },
            Message::RingSwap {
                slot: 0,
                order: vec![],
            },
            Message::Report {
                period: 4,
                t_ms: 1000.0,
                rho: 0.75,
                diameter: 88.25,
                alive: 96,
                swaps: 3,
            },
            Message::MemberUpdate {
                node: 12,
                state: MemberState::Suspect,
                incarnation: u64::MAX,
                time: 750.25,
            },
            Message::MemberUpdate {
                node: 0,
                state: MemberState::Left,
                incarnation: 0,
                time: 0.0,
            },
            Message::SwapPropose {
                slot: 1,
                seq: 9,
                order: vec![2, 0, 3, 1],
            },
            Message::SwapAck {
                seq: 9,
                accept: true,
            },
            Message::SwapAck {
                seq: u32::MAX,
                accept: false,
            },
            Message::SwapCommit {
                slot: 0,
                period: 17,
                proposer: 5,
                order: vec![1, 3, 0, 2],
            },
            Message::RingDigest {
                versions: vec![(17, 5), (0, 0), (u32::MAX, 3)],
            },
            Message::RingDigest { versions: vec![] },
        ]
    }

    fn sample_ctxs() -> Vec<Option<TraceCtx>> {
        vec![
            None,
            Some(TraceCtx {
                trace: 1,
                parent: 1,
            }),
            Some(TraceCtx {
                trace: 0xDEAD_BEEF_CAFE_F00D,
                parent: u64::MAX,
            }),
        ]
    }

    #[test]
    fn every_variant_round_trips() {
        for msg in samples() {
            for epoch in [0u32, 7, u32::MAX] {
                let bytes = msg.encode(epoch);
                assert_eq!(bytes[0], WIRE_VERSION);
                let (e, back) = Message::decode(&bytes)
                    .unwrap_or_else(|e| panic!("{msg:?}: {e}"));
                assert_eq!(e, epoch);
                assert_eq!(back, msg);
            }
        }
    }

    #[test]
    fn traced_variants_round_trip_and_plain_decode_ignores_ctx() {
        for msg in samples() {
            for ctx in sample_ctxs() {
                let bytes = msg.encode_traced(9, ctx);
                let (e, back_ctx, back) =
                    Message::decode_traced(&bytes)
                        .unwrap_or_else(|e| panic!("{msg:?}: {e}"));
                assert_eq!(e, 9);
                assert_eq!(back_ctx, ctx);
                assert_eq!(back, msg);
                // The ctx-agnostic decode accepts the same frame.
                let (e2, back2) = Message::decode(&bytes).unwrap();
                assert_eq!((e2, back2), (9, msg.clone()));
                // Untraced encode is the v3 frame with flags 0.
                if ctx.is_none() {
                    assert_eq!(bytes, msg.encode(9));
                    assert_eq!(bytes[5], 0);
                } else {
                    assert_eq!(bytes[5], FLAG_TRACE);
                    assert_eq!(
                        bytes.len(),
                        msg.encode(9).len() + TRACE_CTX_LEN
                    );
                }
            }
        }
    }

    #[test]
    fn unknown_version_is_rejected() {
        let mut bytes = Message::Ping { seq: 1 }.encode(0);
        bytes[0] = WIRE_VERSION + 1;
        let err = Message::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("unknown wire version"), "{err}");
    }

    #[test]
    fn legacy_versions_get_a_distinct_error() {
        // A well-formed v2 frame: version, epoch, tag, ping payload.
        let mut v2 = vec![2u8];
        v2.extend_from_slice(&7u32.to_le_bytes());
        v2.push(0); // TAG_PING
        v2.extend_from_slice(&1u32.to_le_bytes());
        let err = Message::decode(&v2).unwrap_err().to_string();
        assert!(err.contains("legacy wire version 2"), "{err}");
        let mut v1 = v2.clone();
        v1[0] = 1;
        let err = Message::decode(&v1).unwrap_err().to_string();
        assert!(err.contains("legacy wire version 1"), "{err}");
        // Version 0 and future versions are "unknown", not legacy.
        let mut v0 = v2.clone();
        v0[0] = 0;
        let err = Message::decode(&v0).unwrap_err().to_string();
        assert!(err.contains("unknown wire version"), "{err}");
    }

    #[test]
    fn unknown_flags_are_rejected() {
        let mut bytes = Message::Ping { seq: 1 }.encode(0);
        bytes[5] = 0x02;
        let err = Message::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("unknown header flags"), "{err}");
        bytes[5] = 0xFF;
        let err = Message::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("unknown header flags"), "{err}");
    }

    #[test]
    fn truncated_trace_context_is_a_distinct_error() {
        let ctx = Some(TraceCtx {
            trace: 42,
            parent: 43,
        });
        let bytes = Message::Ping { seq: 5 }.encode_traced(1, ctx);
        assert_eq!(bytes.len(), HEADER_LEN + TRACE_CTX_LEN + 4);
        for cut in HEADER_LEN..HEADER_LEN + TRACE_CTX_LEN {
            let err = Message::decode(&bytes[..cut])
                .unwrap_err()
                .to_string();
            assert!(
                err.contains("truncated trace context"),
                "cut {cut}: {err}"
            );
        }
        // Shorter still is a plain short-frame error...
        let err =
            Message::decode(&bytes[..3]).unwrap_err().to_string();
        assert!(err.contains("frame too short"), "{err}");
        // ...and cutting into the payload is a body truncation.
        let cut = HEADER_LEN + TRACE_CTX_LEN + 2;
        let err = Message::decode(&bytes[..cut])
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated frame"), "{err}");
    }

    #[test]
    fn unknown_tag_is_rejected() {
        let mut bytes = vec![WIRE_VERSION];
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.push(0); // flags
        bytes.push(200); // tag
        bytes.extend_from_slice(&[0, 0, 0, 0]);
        let err = Message::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("tag"), "{err}");
    }

    #[test]
    fn cross_epoch_frames_are_rejected_by_strict_decode() {
        let bytes = Message::Ping { seq: 9 }.encode(4);
        assert_eq!(
            Message::decode_expect(&bytes, 4).unwrap(),
            Message::Ping { seq: 9 }
        );
        let err =
            Message::decode_expect(&bytes, 5).unwrap_err().to_string();
        assert!(err.contains("epoch"), "{err}");
    }

    #[test]
    fn truncation_and_trailing_bytes_are_rejected() {
        let bytes = Message::Report {
            period: 1,
            t_ms: 2.0,
            rho: 0.5,
            diameter: 3.0,
            alive: 4,
            swaps: 5,
        }
        .encode(3);
        for cut in 0..bytes.len() {
            assert!(
                Message::decode(&bytes[..cut]).is_err(),
                "accepted a {cut}-byte prefix"
            );
        }
        let mut extended = bytes;
        extended.push(0);
        let err = Message::decode(&extended).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn corrupt_ring_length_does_not_allocate() {
        let mut bytes = Message::RingSwap {
            slot: 1,
            order: vec![5, 6],
        }
        .encode(0);
        // Overwrite the length field (header, then the u32 slot) with a
        // huge value.
        let at = HEADER_LEN + 4;
        bytes[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Message::decode(&bytes).is_err());
    }

    #[test]
    fn corrupt_digest_and_commit_lengths_do_not_allocate() {
        let mut commit = Message::SwapCommit {
            slot: 0,
            period: 3,
            proposer: 1,
            order: vec![0, 1, 2],
        }
        .encode(0);
        // Length sits past the header and the slot/period/proposer u32s.
        let at = HEADER_LEN + 12;
        commit[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Message::decode(&commit).is_err());

        let mut digest = Message::RingDigest {
            versions: vec![(1, 2)],
        }
        .encode(0);
        let at = HEADER_LEN;
        digest[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Message::decode(&digest).is_err());
    }

    #[test]
    fn bad_member_state_and_ack_flag_are_rejected() {
        let mut upd = Message::MemberUpdate {
            node: 1,
            state: MemberState::Alive,
            incarnation: 2,
            time: 3.0,
        }
        .encode(0);
        // State byte sits past the header and the node u32.
        upd[HEADER_LEN + 4] = 9;
        let err = Message::decode(&upd).unwrap_err().to_string();
        assert!(err.contains("unknown member state"), "{err}");

        let mut ack = Message::SwapAck {
            seq: 1,
            accept: true,
        }
        .encode(0);
        *ack.last_mut().unwrap() = 2;
        let err = Message::decode(&ack).unwrap_err().to_string();
        assert!(err.contains("bad swap-ack flag"), "{err}");
    }

    fn arbitrary_message(rng: &mut Rng) -> Message {
        match rng.index(11) {
            0 => Message::Ping {
                seq: rng.next_u64() as u32,
            },
            1 => Message::Pong {
                seq: rng.next_u64() as u32,
                hold_ms: rng.uniform(0.0, 1e6),
            },
            2 => Message::GossipPush {
                local: rng.uniform(-1e9, 1e9),
                global: rng.uniform(-1e9, 1e9),
                min: rng.uniform(0.0, 1e9),
                m: rng.uniform(0.0, 2.0),
                ml: rng.uniform(0.0, 2.0),
            },
            3 => {
                let time = rng.uniform(0.0, 1e7);
                let node = rng.next_u64() as u32;
                let event = match rng.index(3) {
                    0 => MembershipEvent::Join { time, node },
                    1 => MembershipEvent::Leave { time, node },
                    _ => MembershipEvent::Crash { time, node },
                };
                Message::Membership { event }
            }
            4 => {
                let n = rng.index(33);
                Message::RingSwap {
                    slot: rng.index(8) as u32,
                    order: (0..n)
                        .map(|_| rng.next_u64() as u32)
                        .collect(),
                }
            }
            5 => Message::Report {
                period: rng.next_u64() as u32,
                t_ms: rng.uniform(0.0, 1e7),
                rho: rng.f64(),
                diameter: rng.uniform(0.0, 1e4),
                alive: rng.next_u64() as u32,
                swaps: rng.next_u64() as u32,
            },
            6 => Message::MemberUpdate {
                node: rng.next_u64() as u32,
                state: match rng.index(4) {
                    0 => MemberState::Alive,
                    1 => MemberState::Suspect,
                    2 => MemberState::Faulty,
                    _ => MemberState::Left,
                },
                incarnation: rng.next_u64(),
                time: rng.uniform(0.0, 1e7),
            },
            7 => {
                let n = rng.index(33);
                Message::SwapPropose {
                    slot: rng.index(8) as u32,
                    seq: rng.next_u64() as u32,
                    order: (0..n)
                        .map(|_| rng.next_u64() as u32)
                        .collect(),
                }
            }
            8 => Message::SwapAck {
                seq: rng.next_u64() as u32,
                accept: rng.chance(0.5),
            },
            9 => {
                let n = rng.index(33);
                Message::SwapCommit {
                    slot: rng.index(8) as u32,
                    period: rng.next_u64() as u32,
                    proposer: rng.next_u64() as u32,
                    order: (0..n)
                        .map(|_| rng.next_u64() as u32)
                        .collect(),
                }
            }
            _ => {
                let n = rng.index(9);
                Message::RingDigest {
                    versions: (0..n)
                        .map(|_| {
                            (
                                rng.next_u64() as u32,
                                rng.next_u64() as u32,
                            )
                        })
                        .collect(),
                }
            }
        }
    }

    fn arbitrary_ctx(rng: &mut Rng) -> Option<TraceCtx> {
        if rng.chance(0.5) {
            Some(TraceCtx {
                trace: rng.next_u64() | 1,
                parent: rng.next_u64() | 1,
            })
        } else {
            None
        }
    }

    #[test]
    fn prop_arbitrary_frames_round_trip_both_paths() {
        forall(
            "wire v3 round trip",
            Config::default().cases(256).seed(0x31E0),
            |rng| {
                let msg = arbitrary_message(rng);
                let epoch = rng.next_u64() as u32;
                let ctx = arbitrary_ctx(rng);
                let bytes = msg.encode_traced(epoch, ctx);
                let (e, c, back) = Message::decode_traced(&bytes)
                    .map_err(|e| e.to_string())?;
                if (e, c, &back) != (epoch, ctx, &msg) {
                    return Err(format!(
                        "round trip mismatch: {msg:?} -> {back:?}"
                    ));
                }
                let m2 = Message::decode_expect(&bytes, epoch)
                    .map_err(|e| e.to_string())?;
                if m2 != msg {
                    return Err("decode_expect mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_every_strict_prefix_is_rejected() {
        forall(
            "wire v3 prefixes fail",
            Config::default().cases(128).seed(0x31E1),
            |rng| {
                let msg = arbitrary_message(rng);
                let ctx = arbitrary_ctx(rng);
                let bytes =
                    msg.encode_traced(rng.next_u64() as u32, ctx);
                for cut in 0..bytes.len() {
                    if Message::decode(&bytes[..cut]).is_ok() {
                        return Err(format!(
                            "accepted a {cut}-byte prefix of {msg:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_decode_never_panics_on_random_bytes() {
        forall(
            "wire v3 fuzz decode is total",
            Config::default().cases(512).seed(0x31E2),
            |rng| {
                let n = rng.index(64);
                let mut bytes: Vec<u8> =
                    (0..n).map(|_| rng.next_u64() as u8).collect();
                // Half the cases keep a valid version byte so the
                // deeper header/body paths get fuzzed too.
                if !bytes.is_empty() && rng.chance(0.5) {
                    bytes[0] = WIRE_VERSION;
                }
                let _ = Message::decode_traced(&bytes);
                Ok(())
            },
        );
    }
}
