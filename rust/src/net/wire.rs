//! Wire protocol for the message-level transport: compact binary
//! framing for gossip probes, membership events, ring-swap announcements
//! and coordinator reports (docs/TRANSPORT.md has the byte-level table).
//!
//! Every frame starts with a version byte ([`WIRE_VERSION`]), a 32-bit
//! **epoch tag** and a tag byte; integers are little-endian, floats are
//! IEEE-754 bit patterns. Decoding is strict: unknown versions, unknown
//! tags, truncated frames and trailing bytes are all hard errors — a
//! membership protocol that silently mis-parses a frame corrupts views
//! on every node downstream, so the boundary rejects instead.
//!
//! The epoch is the loss-hardening half of the contract (wire v2): the
//! coordinator stamps every frame with the collection phase it belongs
//! to, and a receiver that has moved on to a later phase drops the
//! straggler outright ([`Message::decode_expect`]) instead of folding it
//! into the next barrier. Without it, a datagram written off as lost and
//! then delivered late would perturb a *later* phase's delivery count —
//! the cascade documented (and previously only documented) in
//! docs/TRANSPORT.md.

use anyhow::{bail, Result};

use crate::membership::events::MembershipEvent;

/// Current wire version. Bump on any incompatible layout change; peers
/// reject frames whose version byte differs. v2 added the 32-bit epoch
/// tag between the version and tag bytes.
pub const WIRE_VERSION: u8 = 2;

/// Byte length of the frame header: version, epoch, tag.
pub const HEADER_LEN: usize = 1 + 4 + 1;

/// One protocol message. The transport moves opaque frames; this enum is
/// the typed layer on top.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// RTT probe request (Algorithm-3 sampling). `seq` matches the
    /// probe to its [`Message::Pong`].
    Ping {
        /// Prober-local sequence number echoed by the reply.
        seq: u32,
    },
    /// RTT probe reply: echoes the ping's `seq`, carrying the
    /// responder's processing delay (NTP-style) so the prober can
    /// subtract it — without this, receiver-side scheduling slop would
    /// systematically inflate every RTT measured over real sockets.
    Pong {
        /// The echoed [`Message::Ping`] sequence number.
        seq: u32,
        /// Time the responder held the ping before replying
        /// (transport-clock ms); the prober subtracts it from the
        /// measured round trip.
        hold_ms: f64,
    },
    /// One push-sum gossip step: half of the sender's accumulated
    /// (local, global, min) latency triple plus the push-sum weights
    /// (`m` = node-count mass, `ml` = mass of nodes that contributed a
    /// local sample).
    GossipPush {
        /// Accumulated mean-neighbor-latency mass.
        local: f64,
        /// Accumulated mean-random-latency mass.
        global: f64,
        /// Accumulated min-sampled-latency mass.
        min: f64,
        /// Push-sum node-count weight.
        m: f64,
        /// Push-sum weight of local-sample contributors.
        ml: f64,
    },
    /// A membership change disseminated to every node (join / leave /
    /// crash with its trace timestamp).
    Membership {
        /// The event being disseminated.
        event: MembershipEvent,
    },
    /// Ring-swap announcement: ring `slot` of the K-ring overlay is
    /// replaced by the given visit order.
    RingSwap {
        /// Which ring slot is replaced.
        slot: u32,
        /// The new ring's visit order (a permutation of `0..n`).
        order: Vec<u32>,
    },
    /// Per-period coordinator report broadcast to the membership — the
    /// same numbers the in-process
    /// [`CoordinatorReport`](crate::coordinator::CoordinatorReport)
    /// timeline carries.
    Report {
        /// Adaptation period index.
        period: u32,
        /// Sim-time at the end of the period (ms).
        t_ms: f64,
        /// ρ statistic for the period.
        rho: f64,
        /// Full-overlay diameter.
        diameter: f64,
        /// Alive members.
        alive: u32,
        /// Cumulative ring swaps.
        swaps: u32,
    },
}

const TAG_PING: u8 = 0;
const TAG_PONG: u8 = 1;
const TAG_GOSSIP: u8 = 2;
const TAG_MEMBERSHIP: u8 = 3;
const TAG_RINGSWAP: u8 = 4;
const TAG_REPORT: u8 = 5;

const EV_JOIN: u8 = 0;
const EV_LEAVE: u8 = 1;
const EV_CRASH: u8 = 2;

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, x: f64) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Strict little-endian reader over a frame body.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated frame: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "trailing garbage: {} bytes past the message end",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

impl Message {
    /// Encode into a framed byte vector
    /// (version + epoch + tag + payload).
    pub fn encode(&self, epoch: u32) -> Vec<u8> {
        let mut out = Vec::with_capacity(24);
        out.push(WIRE_VERSION);
        out.extend_from_slice(&epoch.to_le_bytes());
        match self {
            Message::Ping { seq } => {
                out.push(TAG_PING);
                put_u32(&mut out, *seq);
            }
            Message::Pong { seq, hold_ms } => {
                out.push(TAG_PONG);
                put_u32(&mut out, *seq);
                put_f64(&mut out, *hold_ms);
            }
            Message::GossipPush {
                local,
                global,
                min,
                m,
                ml,
            } => {
                out.push(TAG_GOSSIP);
                for x in [local, global, min, m, ml] {
                    put_f64(&mut out, *x);
                }
            }
            Message::Membership { event } => {
                out.push(TAG_MEMBERSHIP);
                let (kind, time, node) = match *event {
                    MembershipEvent::Join { time, node } => {
                        (EV_JOIN, time, node)
                    }
                    MembershipEvent::Leave { time, node } => {
                        (EV_LEAVE, time, node)
                    }
                    MembershipEvent::Crash { time, node } => {
                        (EV_CRASH, time, node)
                    }
                };
                out.push(kind);
                put_f64(&mut out, time);
                put_u32(&mut out, node);
            }
            Message::RingSwap { slot, order } => {
                out.push(TAG_RINGSWAP);
                put_u32(&mut out, *slot);
                put_u32(&mut out, order.len() as u32);
                for &v in order {
                    put_u32(&mut out, v);
                }
            }
            Message::Report {
                period,
                t_ms,
                rho,
                diameter,
                alive,
                swaps,
            } => {
                out.push(TAG_REPORT);
                put_u32(&mut out, *period);
                put_f64(&mut out, *t_ms);
                put_f64(&mut out, *rho);
                put_f64(&mut out, *diameter);
                put_u32(&mut out, *alive);
                put_u32(&mut out, *swaps);
            }
        }
        out
    }

    /// Decode a framed byte vector into `(epoch, message)`. Rejects
    /// unknown versions and tags, truncated frames and trailing bytes;
    /// the caller decides what to do with the epoch (the coordinator
    /// drops cross-epoch stragglers — see [`Message::decode_expect`]).
    pub fn decode(frame: &[u8]) -> Result<(u32, Message)> {
        if frame.len() < HEADER_LEN {
            bail!("frame too short ({} bytes)", frame.len());
        }
        if frame[0] != WIRE_VERSION {
            bail!(
                "unknown wire version {} (speaking {})",
                frame[0],
                WIRE_VERSION
            );
        }
        let epoch = u32::from_le_bytes(frame[1..5].try_into().unwrap());
        let tag = frame[5];
        let mut r = Reader {
            buf: &frame[HEADER_LEN..],
            pos: 0,
        };
        let msg = match tag {
            TAG_PING => Message::Ping { seq: r.u32()? },
            TAG_PONG => Message::Pong {
                seq: r.u32()?,
                hold_ms: r.f64()?,
            },
            TAG_GOSSIP => Message::GossipPush {
                local: r.f64()?,
                global: r.f64()?,
                min: r.f64()?,
                m: r.f64()?,
                ml: r.f64()?,
            },
            TAG_MEMBERSHIP => {
                let kind = r.u8()?;
                let time = r.f64()?;
                let node = r.u32()?;
                let event = match kind {
                    EV_JOIN => MembershipEvent::Join { time, node },
                    EV_LEAVE => MembershipEvent::Leave { time, node },
                    EV_CRASH => MembershipEvent::Crash { time, node },
                    other => bail!("unknown membership kind {other}"),
                };
                Message::Membership { event }
            }
            TAG_RINGSWAP => {
                let slot = r.u32()?;
                let len = r.u32()? as usize;
                // Bound before allocating: a corrupt length must not
                // drive an OOM allocation; the body can hold at most
                // len u32s anyway.
                if len > r.buf.len() / 4 + 1 {
                    bail!("ring-swap length {len} exceeds frame");
                }
                let mut order = Vec::with_capacity(len);
                for _ in 0..len {
                    order.push(r.u32()?);
                }
                Message::RingSwap { slot, order }
            }
            TAG_REPORT => Message::Report {
                period: r.u32()?,
                t_ms: r.f64()?,
                rho: r.f64()?,
                diameter: r.f64()?,
                alive: r.u32()?,
                swaps: r.u32()?,
            },
            other => bail!("unknown message tag {other}"),
        };
        r.done()?;
        Ok((epoch, msg))
    }

    /// Strict epoch-checked decode: like [`Message::decode`], but a
    /// frame whose epoch differs from `expect` is a hard error — the
    /// loss-tolerant protocol's rule that a straggler from a written-off
    /// collection phase must never mutate state in a later one.
    pub fn decode_expect(frame: &[u8], expect: u32) -> Result<Message> {
        let (epoch, msg) = Message::decode(frame)?;
        if epoch != expect {
            bail!("stale frame epoch {epoch} (current epoch {expect})");
        }
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<Message> {
        vec![
            Message::Ping { seq: 0 },
            Message::Ping { seq: u32::MAX },
            Message::Pong {
                seq: 7,
                hold_ms: 1.5,
            },
            Message::GossipPush {
                local: 1.25,
                global: -0.5,
                min: f64::MIN_POSITIVE,
                m: 0.5,
                ml: 0.0,
            },
            Message::Membership {
                event: MembershipEvent::Join {
                    time: 125.5,
                    node: 3,
                },
            },
            Message::Membership {
                event: MembershipEvent::Crash {
                    time: 0.0,
                    node: u32::MAX,
                },
            },
            Message::RingSwap {
                slot: 2,
                order: vec![0, 3, 1, 2],
            },
            Message::RingSwap {
                slot: 0,
                order: vec![],
            },
            Message::Report {
                period: 4,
                t_ms: 1000.0,
                rho: 0.75,
                diameter: 88.25,
                alive: 96,
                swaps: 3,
            },
        ]
    }

    #[test]
    fn every_variant_round_trips() {
        for msg in samples() {
            for epoch in [0u32, 7, u32::MAX] {
                let bytes = msg.encode(epoch);
                assert_eq!(bytes[0], WIRE_VERSION);
                let (e, back) = Message::decode(&bytes)
                    .unwrap_or_else(|e| panic!("{msg:?}: {e}"));
                assert_eq!(e, epoch);
                assert_eq!(back, msg);
            }
        }
    }

    #[test]
    fn unknown_version_is_rejected() {
        let mut bytes = Message::Ping { seq: 1 }.encode(0);
        bytes[0] = WIRE_VERSION + 1;
        let err = Message::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn unknown_tag_is_rejected() {
        let bytes = vec![WIRE_VERSION, 0, 0, 0, 0, 200, 0, 0, 0, 0];
        let err = Message::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("tag"), "{err}");
    }

    #[test]
    fn cross_epoch_frames_are_rejected_by_strict_decode() {
        let bytes = Message::Ping { seq: 9 }.encode(4);
        assert_eq!(
            Message::decode_expect(&bytes, 4).unwrap(),
            Message::Ping { seq: 9 }
        );
        let err =
            Message::decode_expect(&bytes, 5).unwrap_err().to_string();
        assert!(err.contains("epoch"), "{err}");
    }

    #[test]
    fn truncation_and_trailing_bytes_are_rejected() {
        let bytes = Message::Report {
            period: 1,
            t_ms: 2.0,
            rho: 0.5,
            diameter: 3.0,
            alive: 4,
            swaps: 5,
        }
        .encode(3);
        for cut in 0..bytes.len() {
            assert!(
                Message::decode(&bytes[..cut]).is_err(),
                "accepted a {cut}-byte prefix"
            );
        }
        let mut extended = bytes;
        extended.push(0);
        let err = Message::decode(&extended).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn corrupt_ring_length_does_not_allocate() {
        let mut bytes = Message::RingSwap {
            slot: 1,
            order: vec![5, 6],
        }
        .encode(0);
        // Overwrite the length field (header, then the u32 slot) with a
        // huge value.
        let at = HEADER_LEN + 4;
        bytes[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Message::decode(&bytes).is_err());
    }
}
