//! The [`NetCoordinator`]: the DGRO adaptation loop driven over a real
//! message-level [`Transport`] instead of matrix lookups.
//!
//! It spawns one in-process **node actor** per member. Each actor owns a
//! deterministic RNG stream, its own membership view and its own copy of
//! the K-ring overlay (updated by [`Message::RingSwap`] announcements,
//! never read from the coordinator's state). Per adaptation period the
//! coordinator:
//!
//! 1. disseminates the period's membership events to every node
//!    ([`Message::Membership`], barriered on delivery),
//! 2. runs the message-level Algorithm-3 measurement: every alive node
//!    probes sampled neighbors and random alive peers with
//!    [`Message::Ping`]/[`Message::Pong`] pairs — latency estimates come
//!    from **measured RTTs on the transport clock**, not from the
//!    matrix — then aggregates the per-node triples through
//!    [`Message::GossipPush`] push-sum rounds over the overlay,
//! 3. applies the §V ρ decision (with the churn guard of
//!    [`Config::churn_guard`]) and, on a swap, broadcasts the new ring
//!    as a [`Message::RingSwap`],
//! 4. records the same metric series as the in-process
//!    [`Coordinator`](crate::coordinator::Coordinator) and broadcasts a
//!    [`Message::Report`] so every member sees the period summary.
//!
//! **Loss hardening (wire v2).** Every collection phase above runs
//! under its own frame **epoch**: frames are stamped at send, and a
//! frame whose epoch is not the current one — a straggler from a phase
//! that was already written off — is dropped and counted
//! (`net.stale_frames`) instead of perturbing a later phase's barrier.
//! Duplicate deliveries are de-duplicated per phase (`net.dup_frames`).
//! Lost RTT probes are retransmitted with fresh sequence numbers for up
//! to [`PROBE_RETX`] extra rounds (`net.probe_retx`), so ping/pong
//! samples are never ambiguous (a reply always names the transmission
//! it answers). Lost push-sum frames need no retransmit: each node's
//! estimate is read out as a mass-weighted ratio, so dropped mass
//! widens the variance but never biases the weighted average — nodes
//! whose mass was lost entirely are excluded from the readout. On a
//! transport that declares an expected loss rate
//! ([`Transport::loss_hint`]), write-off switches from the
//! conservative idle cap to a deadline two shaped link delays past the
//! phase start, keeping lossy runs fast.
//!
//! **Causal tracing (wire v3).** With a non-zero
//! [`NetCoordinator::trace_sample`] every period gets a deterministic
//! trace id derived from `(seed, period)` (see [`crate::obs::trace`]);
//! frames carry a trace context (`trace`, `parent` span) and the
//! flight recorder captures the cross-node causal chain: the period
//! root span, the measurement span, one span per probe transmission
//! (`probe` for first tries, `retx` for retransmissions — recorded
//! even when the transmission times out, so a retry's parent always
//! resolves), the gossip span, swap/report barriers, and — on nodes
//! whose id is a multiple of the sampling stride — `deliver` spans
//! stitching receipt back to the sender's span. Ping replies echo the
//! incoming context (parented under the ping's delivery span when one
//! was recorded), so a pong's delivery closes the loop
//! sender → delivery → reply. All ids are derived from seed + period +
//! site, never from wall clocks: seeded sim runs export byte-identical
//! `traces.jsonl` at any thread count.
//!
//! Reported diameters are evaluated against the coordinator's oracle
//! latency view (exactly like the sim path) so transports are comparable
//! — what the transport changes is the *measured* inputs to ρ and hence
//! the adaptation decisions. With
//! [`SimTransport`](crate::net::transport::SimTransport) RTTs are exact
//! (2·δ(u,v)); with [`UdpTransport`](crate::net::transport::UdpTransport)
//! they carry real scheduler jitter, and the parity test in
//! rust/tests/net.rs pins how far that is allowed to push the per-period
//! alive diameter.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::Config;
use crate::coordinator::service::{
    alive_overlay_graph, execute_swap, record_period,
};
use crate::coordinator::runner::{AdaptiveRunner, RunOptions};
use crate::coordinator::CoordinatorReport;
use crate::dgro::select::{decide, RingChoice, SelectConfig};
use crate::gossip::measure::GossipStats;
use crate::graph::{diameter, Graph};
use crate::latency::LatencyMatrix;
use crate::membership::events::{EventTrace, MembershipEvent};
use crate::membership::list::{MemberState, MembershipList};
use crate::metrics::Metrics;
use crate::net::transport::{Delivery, Transport};
use crate::net::wire::Message;
use crate::obs::trace::{span_id, trace_id, TraceCtx};
use crate::obs::{Histogram, Obs, Registry};
use crate::topology::kring::KRing;
use crate::topology::random_ring;
use crate::util::rng::Rng;

/// Receive-poll granularity (sim-ms). Each empty poll advances the
/// transport clock by this much; small enough to keep UDP wall time low,
/// large enough that the sim path converges in few sweeps.
pub(crate) const POLL_MS: f64 = 10.0;

/// Consecutive all-idle sweeps before a collection phase declares the
/// outstanding frames lost on a *faithful* transport (spurious UDP
/// drops; never reached on sim). Transports with a declared loss rate
/// use the deadline-based write-off instead (see [`NetCoordinator`]).
pub(crate) const MAX_IDLE_SWEEPS: usize = 50;

/// Extra transmission rounds granted to unanswered RTT probes before
/// the sample is abandoned (each round is its own frame epoch, so a
/// late reply to an earlier transmission can never be mistaken for the
/// retry's answer).
pub const PROBE_RETX: usize = 2;

/// Pre-resolved [`Registry`] handles for the runner's hot-path
/// instruments: the delivery loop must not take the registry's
/// name-map lock per frame.
pub(crate) struct ObsHandles {
    pub(crate) decode_errors: Arc<AtomicU64>,
    pub(crate) stale_frames: Arc<AtomicU64>,
    pub(crate) dup_frames: Arc<AtomicU64>,
    pub(crate) probe_retx: Arc<AtomicU64>,
    pub(crate) frames_lost: Arc<AtomicU64>,
    pub(crate) rings_swapped: Arc<AtomicU64>,
    pub(crate) rtt_err: Arc<Histogram>,
    pub(crate) period_wall: Arc<Histogram>,
    pub(crate) decode_us: Arc<Histogram>,
}

impl ObsHandles {
    pub(crate) fn new(reg: &Registry) -> ObsHandles {
        ObsHandles {
            decode_errors: reg.counter("net.decode_errors"),
            stale_frames: reg.counter("net.stale_frames"),
            dup_frames: reg.counter("net.dup_frames"),
            probe_retx: reg.counter("net.probe_retx"),
            frames_lost: reg.counter("net.frames_lost"),
            rings_swapped: reg.counter("rings.swapped"),
            rtt_err: reg.histogram("net.rtt_abs_error_ms"),
            period_wall: reg.histogram("net.period_wall_ms"),
            decode_us: reg.histogram("net.frame_decode_us"),
        }
    }
}

/// An in-flight RTT probe awaiting its pong.
pub(crate) struct PendingProbe {
    pub(crate) target: u32,
    pub(crate) sent_at_ms: f64,
    pub(crate) global: bool,
    /// This transmission's causal span id (0 when tracing is off).
    pub(crate) span: u64,
    /// Span the transmission hangs under: the measurement span for
    /// first tries, the prior transmission's span for retries.
    pub(crate) parent: u64,
    /// Transmission round (0 = first try, ≥ 1 = retransmission).
    pub(crate) attempt: u32,
}

/// FNV-1a over (src, dst, frame bytes): the per-phase key duplicate
/// deliveries are detected by. Within one epoch the protocol never
/// legitimately sends two byte-identical frames on the same link
/// (probes carry fresh sequence numbers, push-sum sends one frame per
/// round per link, control frames are distinct events).
pub(crate) fn frame_key(src: u32, dst: u32, frame: &[u8]) -> u64 {
    const PRIME: u64 = 0x100_0000_01b3;
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in src
        .to_le_bytes()
        .into_iter()
        .chain(dst.to_le_bytes())
        .chain(frame.iter().copied())
    {
        h = (h ^ b as u64).wrapping_mul(PRIME);
    }
    h
}

/// Largest per-link shaped delay of `w` (sim-ms) — the unit the lossy
/// write-off deadline is measured in.
pub(crate) fn max_delay_ms(w: &LatencyMatrix) -> f64 {
    w.data().iter().fold(0.0f32, |a, &x| a.max(x)) as f64
}

/// Per-measurement accumulator of one node's probe samples.
#[derive(Default)]
pub(crate) struct ProbeAccum {
    pub(crate) local_sum: f64,
    pub(crate) local_cnt: usize,
    pub(crate) global_sum: f64,
    pub(crate) global_cnt: usize,
    pub(crate) min: f64,
}

/// One node's protocol state: everything it knows, it learned from its
/// boot configuration or from frames on the transport.
struct NodeActor {
    id: u32,
    rng: Rng,
    membership: MembershipList,
    /// Local copy of the K ring visit orders.
    rings: Vec<Vec<u32>>,
    next_seq: u32,
    pending: HashMap<u32, PendingProbe>,
    probe: ProbeAccum,
    /// Push-sum accumulator: local, global, min, m, ml.
    acc: [f64; 5],
    /// Incoming pushes for the current gossip round, keyed by sender.
    gossip_in: Vec<(u32, [f64; 5])>,
    /// The last coordinator report this node received.
    last_report: Option<(u32, f64, f64, f64)>,
}

impl NodeActor {
    /// This node's overlay neighbors per its own ring view (sorted,
    /// deduplicated — deterministic across transports).
    fn neighbors(&self) -> Vec<u32> {
        let mut out = Vec::new();
        for ring in &self.rings {
            let n = ring.len();
            for (i, &v) in ring.iter().enumerate() {
                if v == self.id {
                    out.push(ring[(i + n - 1) % n]);
                    out.push(ring[(i + 1) % n]);
                    break;
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    fn fresh_seq(&mut self) -> u32 {
        let s = self.next_seq;
        self.next_seq = self.next_seq.wrapping_add(1);
        s
    }
}

/// The coordinator event loop over a [`Transport`]. Mirrors
/// [`Coordinator`](crate::coordinator::Coordinator)'s interface:
/// construct, then [`NetCoordinator::run_dynamic`] over a trace, read
/// the [`CoordinatorReport`] and [`Metrics`].
pub struct NetCoordinator<T: Transport> {
    /// Shared runtime configuration (nodes, ε, gossip knobs,
    /// churn guard, adaptation period).
    pub cfg: Config,
    /// Oracle latency view: shapes the transport's per-link delays and
    /// evaluates reported diameters. Never consulted for ρ.
    pub w: LatencyMatrix,
    /// The coordinator's copy of the K-ring overlay.
    pub krings: KRing,
    /// The coordinator's global membership table (fed by the trace).
    pub membership: MembershipList,
    /// Counters + per-period series (same names as the sim coordinator).
    /// Event counters accumulate in [`NetCoordinator::obs`] during the
    /// run and are folded back in here at the end of
    /// [`NetCoordinator::run_dynamic`].
    pub metrics: Metrics,
    /// This run's observability surface: lock-free counters +
    /// histograms and the span flight recorder (disabled by default).
    pub obs: Obs,
    hot: ObsHandles,
    rng: Rng,
    nodes: Vec<NodeActor>,
    transport: T,
    in_flight: usize,
    alive_cache: HashSet<u32>,
    /// Current collection-phase epoch: every frame sent is stamped with
    /// it, every frame received is checked against it.
    epoch: u32,
    /// Per-phase duplicate-delivery filter ([`frame_key`] values).
    seen: HashSet<u64>,
    /// Largest shaped link delay of the current latency view (sim-ms),
    /// the unit of the lossy write-off deadline.
    max_w_ms: f64,
    /// Causal-trace sampling stride: 0 disables tracing (frames carry
    /// no context, byte-compatible with untraced runs); `s ≥ 1` traces
    /// every period and additionally records `deliver` spans on nodes
    /// whose id is a multiple of `s`.
    pub trace_sample: usize,
    /// Current period's trace id (0 while untraced).
    trace: u64,
    /// Current period's root span id.
    span_period: u64,
    /// Current period's measurement span id.
    span_measure: u64,
    /// Trace context stamped on every outgoing frame by
    /// [`Self::send`] (`None` = send untraced).
    tctx: Option<TraceCtx>,
}

impl<T: Transport> NetCoordinator<T> {
    /// Spawn `cfg.nodes` node actors over `transport`. The transport
    /// must already be shaped by `w` (same node count); ring state boots
    /// identically on every node, like a deployment config.
    pub fn new(cfg: Config, w: LatencyMatrix, transport: T) -> Result<Self> {
        let mut transport = transport;
        cfg.validate()?;
        if w.n() != cfg.nodes {
            bail!(
                "latency matrix has {} nodes but cfg.nodes = {}",
                w.n(),
                cfg.nodes
            );
        }
        if transport.n() != cfg.nodes {
            bail!(
                "transport has {} endpoints but cfg.nodes = {}",
                transport.n(),
                cfg.nodes
            );
        }
        let k = cfg.effective_k();
        let mut rng = Rng::new(cfg.seed);
        let krings = KRing::new(
            (0..k).map(|_| random_ring(cfg.nodes, &mut rng)).collect(),
        );
        let boot_rings: Vec<Vec<u32>> = krings
            .rings
            .iter()
            .map(|r| r.order().to_vec())
            .collect();
        let nodes = (0..cfg.nodes as u32)
            .map(|id| NodeActor {
                id,
                rng: rng.fork(0x4E0D_E000 + id as u64),
                membership: MembershipList::full(cfg.nodes),
                rings: boot_rings.clone(),
                next_seq: 0,
                pending: HashMap::new(),
                probe: ProbeAccum::default(),
                acc: [0.0; 5],
                gossip_in: Vec::new(),
                last_report: None,
            })
            .collect();
        let obs = Obs::new();
        transport.attach_obs(&obs);
        let hot = ObsHandles::new(&obs.reg);
        Ok(NetCoordinator {
            membership: MembershipList::full(cfg.nodes),
            metrics: Metrics::new(),
            obs,
            hot,
            alive_cache: (0..cfg.nodes as u32).collect(),
            nodes,
            transport,
            in_flight: 0,
            epoch: 0,
            seen: HashSet::new(),
            max_w_ms: max_delay_ms(&w),
            trace_sample: 0,
            trace: 0,
            span_period: 0,
            span_measure: 0,
            tctx: None,
            rng,
            krings,
            w,
            cfg,
        })
    }

    /// Open a new collection phase: bump the frame epoch and reset the
    /// per-phase duplicate filter. Any frame still in flight from the
    /// previous phase becomes a straggler that [`Self::on_delivery`]
    /// will reject by its stale epoch tag.
    fn begin_phase(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        self.seen.clear();
        self.in_flight = 0;
    }

    /// The underlying transport's name ("sim" / "udp").
    pub fn transport_name(&self) -> &'static str {
        self.transport.name()
    }

    /// Peer address of `node` on the underlying transport.
    pub fn addr(&self, node: u32) -> String {
        self.transport.addr(node)
    }

    /// Total frames the transport carried so far.
    pub fn frames_sent(&self) -> u64 {
        self.transport.frames_sent()
    }

    /// Per-node membership snapshots (`(id, state, incarnation)` rows,
    /// ascending) — what each actor *believes*, for convergence tests.
    pub fn node_views(&self) -> Vec<Vec<(u32, MemberState, u64)>> {
        self.nodes.iter().map(|a| a.membership.snapshot()).collect()
    }

    /// The last [`Message::Report`] each node received, as
    /// `(period, t_ms, rho, diameter)`.
    pub fn node_reports(&self) -> Vec<Option<(u32, f64, f64, f64)>> {
        self.nodes.iter().map(|a| a.last_report).collect()
    }

    /// Whether causal tracing is on for this run.
    fn tracing(&self) -> bool {
        self.trace_sample > 0
    }

    fn send(&mut self, src: u32, dst: u32, msg: &Message) -> Result<()> {
        self.transport
            .send(src, dst, &msg.encode_traced(self.epoch, self.tctx))?;
        self.in_flight += 1;
        Ok(())
    }

    /// Broadcast a control message from the coordinator seat (node 0):
    /// sent on the wire to every other node, applied locally on node 0.
    fn broadcast(&mut self, msg: &Message) -> Result<()> {
        self.apply_control(0, msg);
        for dst in 1..self.cfg.nodes as u32 {
            self.send(0, dst, msg)?;
        }
        Ok(())
    }

    /// Apply a control message to one actor's state.
    fn apply_control(&mut self, node: u32, msg: &Message) {
        let actor = &mut self.nodes[node as usize];
        match msg {
            Message::Membership { event } => {
                actor.membership.apply_trace_event(event);
            }
            Message::RingSwap { slot, order } => {
                let slot = *slot as usize;
                if slot < actor.rings.len()
                    && order.len() == actor.rings[slot].len()
                {
                    actor.rings[slot] = order.clone();
                }
            }
            Message::Report {
                period,
                t_ms,
                rho,
                diameter,
                ..
            } => {
                actor.last_report =
                    Some((*period, *t_ms, *rho, *diameter));
            }
            _ => {}
        }
    }

    /// Handle one delivered frame at `node`. Decodes, checks the frame
    /// epoch, filters duplicates, dispatches, and answers pings.
    /// Undecodable frames (corrupt or stray datagrams on the
    /// real-socket path) are counted and dropped rather than aborting
    /// the run; so are cross-epoch stragglers and duplicate deliveries
    /// — none of them may consume a barrier slot or mutate actor state.
    fn on_delivery(&mut self, node: u32, d: Delivery) -> Result<()> {
        // The src field came off the wire: validate it before using it
        // as a reply address or an actor index — a stray datagram must
        // be dropped, not abort the run (self-sends are transport
        // errors, so a src equal to the receiver is equally bogus).
        if d.src as usize >= self.cfg.nodes || d.src == node {
            self.hot.decode_errors.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        // Decode wall time is a wall-clock quantity, so it is only
        // sampled while the flight recorder is on — the always-on
        // counter path must stay free of clock reads.
        let decode_t0 = self
            .obs
            .rec
            .is_enabled()
            .then(std::time::Instant::now);
        let decoded = Message::decode_traced(&d.frame);
        if let Some(t0) = decode_t0 {
            self.hot
                .decode_us
                .observe(t0.elapsed().as_secs_f64() * 1e6);
        }
        let (epoch, ctx, msg) = match decoded {
            Ok(x) => x,
            Err(_) => {
                self.hot.decode_errors.fetch_add(1, Ordering::Relaxed);
                return Ok(());
            }
        };
        if epoch != self.epoch {
            // A straggler from a phase that was already written off:
            // reject it whole instead of folding it into this phase's
            // barrier (the cascade wire v1 was vulnerable to).
            self.hot.stale_frames.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        let key = frame_key(d.src, node, &d.frame);
        if !self.seen.insert(key) {
            // Duplicate delivery: the first copy already consumed the
            // barrier slot and mutated state.
            self.hot.dup_frames.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        self.in_flight = self.in_flight.saturating_sub(1);
        // A sampled receive: stitch this delivery under the sender's
        // span. The frame key salts the span id — it hashes the whole
        // frame (sender, receiver, epoch, context, payload), which
        // within a phase is unique per accepted delivery.
        let mut deliver_span = 0u64;
        if let Some(c) = ctx {
            if self.obs.rec.is_enabled()
                && self.trace_sample > 0
                && node as usize % self.trace_sample == 0
            {
                deliver_span =
                    span_id(c.trace, "deliver", node as u64, key);
                self.obs.rec.record_traced(
                    "deliver",
                    node as u64,
                    d.at_ms,
                    0.0,
                    0.0,
                    c.trace,
                    deliver_span,
                    c.parent,
                );
            }
        }
        match msg {
            Message::Ping { seq } => {
                if self.alive_cache.contains(&node) {
                    // NTP-style: report how long this ping sat between
                    // its delivery and our reply, so the prober can
                    // subtract receiver-side scheduling slop from the
                    // measured round trip.
                    let hold_ms =
                        (self.transport.now_ms() - d.at_ms).max(0.0);
                    // The pong echoes the ping's trace, parented under
                    // this delivery when one was recorded (falling
                    // back to the ping's own span otherwise), so the
                    // prober sees sender → delivery → reply.
                    let saved = self.tctx;
                    self.tctx = ctx.map(|c| TraceCtx {
                        trace: c.trace,
                        parent: if deliver_span != 0 {
                            deliver_span
                        } else {
                            c.parent
                        },
                    });
                    let sent = self.send(
                        node,
                        d.src,
                        &Message::Pong { seq, hold_ms },
                    );
                    self.tctx = saved;
                    sent?;
                }
            }
            Message::Pong { seq, hold_ms } => {
                let at_ms = d.at_ms;
                let actor = &mut self.nodes[node as usize];
                if let Some(p) = actor.pending.remove(&seq) {
                    if p.span != 0 {
                        self.obs.rec.record_traced(
                            if p.attempt == 0 { "probe" } else { "retx" },
                            p.target as u64,
                            p.sent_at_ms,
                            (at_ms - p.sent_at_ms).max(0.0),
                            0.0,
                            self.trace,
                            p.span,
                            p.parent,
                        );
                    }
                    let one_way =
                        ((at_ms - p.sent_at_ms - hold_ms) / 2.0).max(0.0);
                    let truth =
                        self.w.get(node as usize, p.target as usize) as f64;
                    self.hot.rtt_err.observe((one_way - truth).abs());
                    if p.global {
                        actor.probe.global_sum += one_way;
                        actor.probe.global_cnt += 1;
                        if actor.probe.global_cnt == 1
                            || one_way < actor.probe.min
                        {
                            actor.probe.min = one_way;
                        }
                    } else {
                        actor.probe.local_sum += one_way;
                        actor.probe.local_cnt += 1;
                    }
                }
            }
            Message::GossipPush {
                local,
                global,
                min,
                m,
                ml,
            } => {
                self.nodes[node as usize]
                    .gossip_in
                    .push((d.src, [local, global, min, m, ml]));
            }
            control => self.apply_control(node, &control),
        }
        Ok(())
    }

    /// Pump deliveries round-robin until every in-flight frame landed
    /// or the write-off policy fires. Returns frames written off.
    ///
    /// Two write-off policies: a faithful transport uses the
    /// conservative [`MAX_IDLE_SWEEPS`] idle cap (a spurious loopback
    /// drop is rare, so waiting long is cheap in expectation); a
    /// transport that *declares* loss ([`Transport::loss_hint`]) uses a
    /// deadline two shaped link delays past the phase start — with
    /// epoch tagging, writing a frame off early is safe (a late
    /// arrival is rejected as stale, never mis-barriered), so lossy
    /// runs don't stall on every dropped frame.
    fn collect(&mut self) -> Result<u64> {
        let n = self.cfg.nodes as u32;
        let lossy = self.transport.loss_hint() > 0.0;
        let start_ms = self.transport.now_ms();
        let budget_ms = 2.0 * self.max_w_ms + 8.0 * POLL_MS;
        let mut idle = 0usize;
        while self.in_flight > 0 {
            let mut any = false;
            for node in 0..n {
                while let Some(d) = self.transport.recv(node, POLL_MS) {
                    any = true;
                    self.on_delivery(node, d)?;
                }
            }
            if any {
                idle = 0;
                continue;
            }
            idle += 1;
            if lossy {
                if self.transport.now_ms() - start_ms > budget_ms {
                    break;
                }
            } else if idle >= MAX_IDLE_SWEEPS {
                break;
            }
        }
        let lost = self.in_flight as u64;
        if lost > 0 {
            self.hot.frames_lost.fetch_add(lost, Ordering::Relaxed);
            self.in_flight = 0;
        }
        Ok(lost)
    }

    /// Message-level Algorithm 3: probe RTTs, then push-sum gossip
    /// aggregation, all over the transport. Returns the network stats
    /// the ρ rule consumes.
    fn measure_net(&mut self) -> Result<GossipStats> {
        let alive: Vec<u32> = self.membership.alive().collect();
        self.alive_cache = alive.iter().copied().collect();
        let frames0 = self.transport.frames_sent();
        let k = self.cfg.gossip_samples.max(1);
        let n = self.cfg.nodes;
        if alive.len() < 2 {
            return Ok(GossipStats {
                local: 0.0,
                global: 0.0,
                min: 0.0,
                messages: 0,
            });
        }

        // Rings and membership are frozen for the whole measurement, so
        // each alive node's alive-filtered neighbor list is computed
        // once here and reused by the probe phase and every gossip
        // round (it would otherwise be recomputed rounds × alive
        // times).
        let neigh_alive: Vec<Vec<u32>> = (0..n as u32)
            .map(|u| {
                if !self.alive_cache.contains(&u) {
                    return Vec::new();
                }
                self.nodes[u as usize]
                    .neighbors()
                    .into_iter()
                    .filter(|v| self.alive_cache.contains(v))
                    .collect()
            })
            .collect();

        // Phase 1 — RTT probes. Sampling draws come from each node's own
        // RNG stream in a fixed order, so the initial probe plan is
        // identical on every transport; only the measured RTTs (and any
        // loss-driven retransmits) differ.
        // The third plan field is the span the next transmission hangs
        // under: the measurement span for first tries, the prior
        // attempt's span once a probe is retried.
        let mut plans: Vec<Vec<(u32, bool, u64)>> = vec![Vec::new(); n];
        for &u in &alive {
            self.nodes[u as usize].probe = ProbeAccum::default();
            self.nodes[u as usize].pending.clear();
            let neigh = &neigh_alive[u as usize];
            let parent = self.span_measure;
            let actor = &mut self.nodes[u as usize];
            let mut plan: Vec<(u32, bool, u64)> =
                Vec::with_capacity(2 * k);
            for _ in 0..k {
                if neigh.is_empty() {
                    break;
                }
                plan.push((
                    neigh[actor.rng.index(neigh.len())],
                    false,
                    parent,
                ));
            }
            for _ in 0..k {
                let tgt = loop {
                    let v = actor.rng.index(n) as u32;
                    if v != u {
                        break v;
                    }
                };
                if !self.alive_cache.contains(&tgt) {
                    continue; // dead peers cannot answer probes
                }
                plan.push((tgt, true, parent));
            }
            plans[u as usize] = plan;
        }
        // Each transmission round is its own epoch, and a retried probe
        // gets a fresh sequence number — so a pong always names the
        // exact transmission it answers and retransmitted samples stay
        // as unbiased as first-try ones (no Karn ambiguity).
        for attempt in 0..=PROBE_RETX {
            if plans.iter().all(|p| p.is_empty()) {
                break;
            }
            if attempt > 0 {
                let outstanding: u64 =
                    plans.iter().map(|p| p.len() as u64).sum();
                self.hot
                    .probe_retx
                    .fetch_add(outstanding, Ordering::Relaxed);
            }
            self.begin_phase();
            for &u in &alive {
                let plan = std::mem::take(&mut plans[u as usize]);
                for (tgt, global, parent) in plan {
                    let seq = self.nodes[u as usize].fresh_seq();
                    let sent_at_ms = self.transport.now_ms();
                    // Sequence numbers never repeat on a node, so the
                    // (prober, seq) salt gives every transmission —
                    // retries included — its own span id.
                    let span = if self.tracing() {
                        span_id(
                            self.trace,
                            "probe",
                            tgt as u64,
                            ((u as u64) << 32) | seq as u64,
                        )
                    } else {
                        0
                    };
                    self.nodes[u as usize].pending.insert(
                        seq,
                        PendingProbe {
                            target: tgt,
                            sent_at_ms,
                            global,
                            span,
                            parent,
                            attempt: attempt as u32,
                        },
                    );
                    self.tctx = (span != 0).then_some(TraceCtx {
                        trace: self.trace,
                        parent: span,
                    });
                    self.send(u, tgt, &Message::Ping { seq })?;
                }
            }
            self.tctx = None;
            self.collect()?;
            // Whatever is still pending lost its ping or its pong:
            // queue it for the next transmission round (the drain order
            // is keyed by sequence number so retries are deterministic
            // for a deterministic fault pattern).
            let drain_ms = self.transport.now_ms();
            for &u in &alive {
                if self.nodes[u as usize].pending.is_empty() {
                    continue;
                }
                let mut retry: Vec<(u32, PendingProbe)> = self.nodes
                    [u as usize]
                    .pending
                    .drain()
                    .collect();
                retry.sort_by_key(|&(seq, _)| seq);
                plans[u as usize] = retry
                    .into_iter()
                    .map(|(_, p)| {
                        // A timed-out transmission still records its
                        // span (its duration is the write-off wait),
                        // so the retry it parents never dangles.
                        if p.span != 0 {
                            self.obs.rec.record_traced(
                                if p.attempt == 0 {
                                    "probe"
                                } else {
                                    "retx"
                                },
                                p.target as u64,
                                p.sent_at_ms,
                                (drain_ms - p.sent_at_ms).max(0.0),
                                0.0,
                                self.trace,
                                p.span,
                                p.parent,
                            );
                        }
                        let parent = if p.span != 0 {
                            p.span
                        } else {
                            self.span_measure
                        };
                        (p.target, p.global, parent)
                    })
                    .collect();
            }
        }
        // Probes still unanswered after the budget are abandoned: their
        // node simply contributes less (or zero) mass below.

        // Seed the push-sum accumulators from the probe results. Both
        // weights follow the same rule: a node that contributed no
        // sample of a kind carries zero mass for that kind (`m` for
        // global/min, `ml` for local), so nodes whose probes all hit
        // dead peers or got lost cannot drag the network averages
        // toward zero during storms.
        for &u in &alive {
            let actor = &mut self.nodes[u as usize];
            let p = &actor.probe;
            let has_local = p.local_cnt > 0;
            let has_global = p.global_cnt > 0;
            actor.acc = [
                if has_local {
                    p.local_sum / p.local_cnt as f64
                } else {
                    0.0
                },
                if has_global {
                    p.global_sum / p.global_cnt as f64
                } else {
                    0.0
                },
                if has_global { p.min } else { 0.0 },
                if has_global { 1.0 } else { 0.0 },
                if has_local { 1.0 } else { 0.0 },
            ];
        }

        // Phase 2 — push-sum rounds. Each round is barriered under its
        // own epoch and every node merges its incoming pushes in
        // ascending sender order, so the float arithmetic is
        // order-identical across transports. Lost pushes are *not*
        // retransmitted: push-sum reads out as the mass-weighted ratio
        // below, so lost mass widens variance without biasing the
        // weighted average (loss-weighted merging).
        let g_sid = if self.tracing() {
            span_id(self.trace, "gossip", self.epoch as u64, 0)
        } else {
            0
        };
        let g_span = self
            .obs
            .rec
            .start("gossip", self.epoch as u64, self.transport.now_ms())
            .traced(self.trace, g_sid, self.span_measure);
        for _ in 0..self.cfg.gossip_rounds {
            self.begin_phase();
            self.tctx = (g_sid != 0).then_some(TraceCtx {
                trace: self.trace,
                parent: g_sid,
            });
            for &u in &alive {
                let neigh = &neigh_alive[u as usize];
                if neigh.is_empty() {
                    continue;
                }
                let actor = &mut self.nodes[u as usize];
                let v = neigh[actor.rng.index(neigh.len())];
                let mut half = [0.0; 5];
                for (h, a) in half.iter_mut().zip(actor.acc.iter_mut()) {
                    *a /= 2.0;
                    *h = *a;
                }
                self.send(
                    u,
                    v,
                    &Message::GossipPush {
                        local: half[0],
                        global: half[1],
                        min: half[2],
                        m: half[3],
                        ml: half[4],
                    },
                )?;
            }
            self.tctx = None;
            self.collect()?;
            for &u in &alive {
                let actor = &mut self.nodes[u as usize];
                let mut incoming = std::mem::take(&mut actor.gossip_in);
                incoming.sort_by_key(|&(src, _)| src);
                for (_, vals) in incoming {
                    for (a, x) in actor.acc.iter_mut().zip(vals.iter()) {
                        *a += x;
                    }
                }
            }
        }
        g_span.finish(&self.obs.rec, self.transport.now_ms());

        // Readout — same weighted averaging as the in-process
        // Algorithm 3 (isolated nodes do not dilute the local average).
        let mut l = 0.0;
        let mut cnt_l = 0usize;
        let mut gl = 0.0;
        let mut mn = 0.0;
        let mut cnt = 0usize;
        for &u in &alive {
            let a = &self.nodes[u as usize].acc;
            if a[3] > 1e-9 {
                gl += a[1] / a[3];
                mn += a[2] / a[3];
                cnt += 1;
            }
            if a[4] > 1e-9 {
                l += a[0] / a[4];
                cnt_l += 1;
            }
        }
        let messages =
            (self.transport.frames_sent() - frames0) as usize;
        Ok(GossipStats {
            local: l / cnt_l.max(1) as f64,
            global: gl / cnt.max(1) as f64,
            min: mn / cnt.max(1) as f64,
            messages,
        })
    }

    /// Overlay graph over the full node set (oracle weights).
    pub fn overlay(&self) -> Graph {
        self.krings.to_graph(&self.w)
    }

    /// Overlay restricted to alive members (the same alive filter the
    /// in-process coordinator applies).
    pub fn alive_overlay(&self) -> Graph {
        alive_overlay_graph(&self.krings, &self.w, &self.membership)
    }

    /// Run over a membership trace with a time-varying latency view —
    /// the transport-backed counterpart of the centralized
    /// coordinator's deprecated ladder, recording the same per-period
    /// series.
    #[deprecated(
        since = "0.10.0",
        note = "use AdaptiveRunner::run_with with RunOptions::latency"
    )]
    pub fn run_dynamic(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
        latency_at: impl FnMut(f64) -> Option<LatencyMatrix>,
    ) -> Result<CoordinatorReport> {
        self.run_with(
            trace,
            horizon,
            RunOptions::new().latency(latency_at),
        )
    }

    /// Deprecated spelling of `run_with(..., RunOptions::new()
    /// .latency(latency_at).maybe_observer(observer))`.
    #[deprecated(
        since = "0.10.0",
        note = "use AdaptiveRunner::run_with with \
                RunOptions::latency + RunOptions::observer"
    )]
    pub fn run_dynamic_observed(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
        latency_at: impl FnMut(f64) -> Option<LatencyMatrix>,
        observer: Option<crate::traffic::OverlayObserver<'_>>,
    ) -> Result<CoordinatorReport> {
        self.run_with(
            trace,
            horizon,
            RunOptions::new()
                .latency(latency_at)
                .maybe_observer(observer),
        )
    }

    /// Run over a static latency view (no dynamic effects). Equivalent
    /// to [`AdaptiveRunner::run_with`] under default [`RunOptions`].
    pub fn run(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
    ) -> Result<CoordinatorReport> {
        self.run_with(trace, horizon, RunOptions::new())
    }
}

impl<T: Transport> AdaptiveRunner for NetCoordinator<T> {
    fn kind(&self) -> &'static str {
        "net"
    }

    /// The message-level event loop: per period, disseminate membership
    /// events (barriered), measure over the wire, decide, maybe swap
    /// (broadcast + barrier), record the shared per-period series and
    /// broadcast the period report. The observer sees the coordinator's
    /// oracle view of the alive overlay, so traffic reports stay
    /// byte-deterministic even when the transport injects loss.
    /// [`RunOptions::trace_sample`] and [`RunOptions::record`] drive
    /// the causal tracing plane; a non-exact [`RunOptions::certify`]
    /// override is rejected.
    fn run_with(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
        mut opts: RunOptions<'_>,
    ) -> Result<CoordinatorReport> {
        crate::coordinator::runner::reject_non_exact_certify(
            self.kind(),
            opts.certify,
        )?;
        if let Some(g) = opts.churn_guard {
            self.cfg.churn_guard = g;
        }
        if opts.record {
            self.obs.rec.set_enabled(true);
        }
        if opts.trace_sample > 0 {
            self.trace_sample = opts.trace_sample;
        }
        let mut latency_at = opts.take_latency();
        let mut observer = opts.observer;
        let initial_diameter = diameter::diameter(&self.overlay());
        let mut timeline = Vec::new();
        let frames_start = self.transport.frames_sent();
        let initial_swaps = self.hot.rings_swapped.load(Ordering::Relaxed);
        let mut swaps0 = initial_swaps;
        let mut t = 0.0;
        let mut ev_idx = 0;
        let mut period = 0u32;
        while t < horizon {
            t += self.cfg.adapt_period_ms;
            period += 1;
            if self.tracing() {
                self.trace = trace_id(self.cfg.seed, period as usize);
                self.span_period =
                    span_id(self.trace, "period", period as u64, 0);
            }
            let period_wall0 = std::time::Instant::now();
            let p_span = self
                .obs
                .rec
                .start("period", period as u64, self.transport.now_ms())
                .traced(self.trace, self.span_period, 0);
            if let Some(w) = latency_at(t) {
                if w.n() != self.w.n() {
                    bail!(
                        "latency update has {} nodes, overlay has {}",
                        w.n(),
                        self.w.n()
                    );
                }
                self.transport.set_latency(&w)?;
                self.max_w_ms = max_delay_ms(&w);
                self.w = w;
                self.obs.reg.incr("latency.updates", 1);
            }
            // Disseminate this period's membership events, barriered so
            // every node's view is current before it measures (its own
            // collection phase: stragglers must not leak into the
            // measurement barrier).
            self.begin_phase();
            self.tctx = self.tracing().then_some(TraceCtx {
                trace: self.trace,
                parent: self.span_period,
            });
            let mut applied = 0u64;
            while ev_idx < trace.events.len()
                && trace.events[ev_idx].time() <= t
            {
                let ev = trace.events[ev_idx];
                let counter = match ev {
                    MembershipEvent::Join { .. } => "membership.joins",
                    MembershipEvent::Leave { .. } => "membership.leaves",
                    MembershipEvent::Crash { .. } => "membership.crashes",
                };
                self.membership.apply_trace_event(&ev);
                self.obs.reg.incr(counter, 1);
                self.broadcast(&Message::Membership { event: ev })?;
                ev_idx += 1;
                applied += 1;
            }
            self.tctx = None;
            self.collect()?;

            // Measure over the wire, decide, maybe swap.
            if self.tracing() {
                self.span_measure =
                    span_id(self.trace, "measure", period as u64, 0);
            }
            let m_span = self
                .obs
                .rec
                .start("measure", period as u64, self.transport.now_ms())
                .traced(self.trace, self.span_measure, self.span_period);
            let stats = self.measure_net()?;
            m_span.finish(&self.obs.rec, self.transport.now_ms());
            self.obs
                .reg
                .incr("gossip.messages", stats.messages as u64);
            let rho = stats.rho();
            let d_sid = if self.tracing() {
                span_id(self.trace, "decide", period as u64, 0)
            } else {
                0
            };
            let d_span = self
                .obs
                .rec
                .start("decide", period as u64, self.transport.now_ms())
                .traced(self.trace, d_sid, self.span_period);
            let choice = decide(
                &stats,
                SelectConfig {
                    epsilon: self.cfg.epsilon,
                },
            );
            let guard = self.cfg.churn_guard > 0
                && applied > self.cfg.churn_guard;
            d_span.finish(&self.obs.rec, self.transport.now_ms());
            match choice {
                RingChoice::Keep => {}
                _ if guard => {
                    self.obs.reg.incr("rings.guard_skips", 1);
                }
                choice => {
                    if let Some((slot, order)) = execute_swap(
                        &mut self.krings,
                        &self.w,
                        choice,
                        &mut self.rng,
                    ) {
                        let sw_sid = if self.tracing() {
                            span_id(self.trace, "swap", period as u64, 0)
                        } else {
                            0
                        };
                        let s_span = self
                            .obs
                            .rec
                            .start(
                                "swap",
                                period as u64,
                                self.transport.now_ms(),
                            )
                            .traced(self.trace, sw_sid, self.span_period);
                        self.hot
                            .rings_swapped
                            .fetch_add(1, Ordering::Relaxed);
                        self.begin_phase();
                        self.tctx = (sw_sid != 0).then_some(TraceCtx {
                            trace: self.trace,
                            parent: sw_sid,
                        });
                        self.broadcast(&Message::RingSwap {
                            slot: slot as u32,
                            order,
                        })?;
                        self.tctx = None;
                        self.collect()?;
                        s_span
                            .finish(&self.obs.rec, self.transport.now_ms());
                    }
                }
            }

            // Record the period — same series as the sim coordinator.
            let d = diameter::diameter(&self.overlay());
            let alive_cnt = self.membership.count_state(MemberState::Alive);
            let alive_d = if alive_cnt == self.membership.len() {
                d
            } else {
                diameter::diameter(&self.alive_overlay())
            };
            let swaps_now =
                self.hot.rings_swapped.load(Ordering::Relaxed);
            record_period(
                &mut self.metrics,
                d,
                rho,
                alive_cnt,
                alive_d,
                swaps_now - swaps0,
                applied,
            );
            swaps0 = swaps_now;
            timeline.push((t, rho, d));
            if let Some(f) = observer.as_mut() {
                let ga = self.alive_overlay();
                let mut alive: Vec<u32> =
                    self.membership.alive().collect();
                alive.sort_unstable();
                f(t, &ga, &self.w, &alive);
            }

            // Close the loop: every member hears the period summary.
            self.begin_phase();
            self.tctx = self.tracing().then_some(TraceCtx {
                trace: self.trace,
                parent: self.span_period,
            });
            self.broadcast(&Message::Report {
                period,
                t_ms: t,
                rho,
                diameter: d as f64,
                alive: alive_cnt as u32,
                swaps: (swaps_now - initial_swaps) as u32,
            })?;
            self.tctx = None;
            self.collect()?;
            self.hot
                .period_wall
                .observe(period_wall0.elapsed().as_secs_f64() * 1e3);
            p_span.finish(&self.obs.rec, self.transport.now_ms());
        }
        self.obs.reg.incr(
            "net.frames_sent",
            self.transport.frames_sent() - frames_start,
        );
        // Fold the registry's event counters back into the owned
        // [`Metrics`] so reports and their byte-determinism pins keep
        // reading the names they always did.
        crate::obs::sync_counters(&self.obs.reg, &mut self.metrics);
        Ok(CoordinatorReport {
            final_diameter: timeline
                .last()
                .map(|&(_, _, d)| d)
                .unwrap_or(initial_diameter),
            initial_diameter,
            swaps: (swaps0 - initial_swaps) as usize,
            alive: self.membership.count_state(MemberState::Alive),
            timeline,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::Model;
    use crate::net::transport::SimTransport;

    fn cfg(nodes: usize) -> Config {
        let mut c = Config::default();
        c.nodes = nodes;
        c.model = "fabric".to_string();
        c.scorer = "greedy".to_string();
        c.adapt_period_ms = 250.0;
        c.seed = 7;
        c
    }

    fn sample(nodes: usize, seed: u64) -> LatencyMatrix {
        let mut rng = Rng::new(seed);
        Model::Fabric.sample(nodes, &mut rng)
    }

    #[test]
    fn net_coordinator_adapts_over_sim_transport() {
        let w = sample(34, 7);
        let mut co = NetCoordinator::new(
            cfg(34),
            w.clone(),
            SimTransport::new(w),
        )
        .unwrap();
        let rep = co.run(&EventTrace::default(), 1000.0).unwrap();
        assert_eq!(rep.timeline.len(), 4);
        // Clustered fabric latencies + random boot rings: ρ is high, the
        // coordinator must swap toward shortest rings and improve.
        assert!(rep.swaps >= 1, "expected at least one swap");
        assert!(
            rep.final_diameter <= rep.initial_diameter,
            "diameter {} -> {}",
            rep.initial_diameter,
            rep.final_diameter
        );
        // Every period's ρ flowed from measured RTTs; on sim they are
        // exact, so the probe error histogram must be ~0.
        let err = co.obs.reg.histogram("net.rtt_abs_error_ms");
        assert!(err.count() > 0, "probes must have been measured");
        let max_err = err.max();
        assert!(max_err < 1e-6, "sim RTTs must be exact, got {max_err}");
        assert_eq!(co.metrics.counter("net.frames_lost"), 0);
        // Ring-swap announcements kept every actor's view in sync with
        // the coordinator's rings.
        for actor in &co.nodes {
            for (slot, ring) in co.krings.rings.iter().enumerate() {
                assert_eq!(actor.rings[slot].as_slice(), ring.order());
            }
        }
    }

    #[test]
    fn membership_events_reach_every_actor() {
        let w = sample(12, 3);
        let mut co = NetCoordinator::new(
            cfg(12),
            w.clone(),
            SimTransport::new(w),
        )
        .unwrap();
        let trace = EventTrace {
            events: vec![
                MembershipEvent::Crash {
                    time: 100.0,
                    node: 3,
                },
                MembershipEvent::Leave {
                    time: 300.0,
                    node: 5,
                },
            ],
        };
        co.run(&trace, 500.0).unwrap();
        let global = co.membership.snapshot();
        for (i, view) in co.node_views().iter().enumerate() {
            assert_eq!(view, &global, "node {i} diverged");
        }
        // And every node heard the final report.
        for rep in co.node_reports() {
            let (period, ..) = rep.expect("report received");
            assert_eq!(period, 2);
        }
    }

    #[test]
    fn stale_and_duplicate_frames_never_mutate_state() {
        let w = sample(8, 1);
        let mut co = NetCoordinator::new(
            cfg(8),
            w.clone(),
            SimTransport::new(w),
        )
        .unwrap();
        co.begin_phase(); // epoch 1 (the "written-off" phase)
        co.begin_phase(); // epoch 2 (current)
        let before = co.node_views();

        // A membership straggler stamped with the written-off epoch:
        // rejected whole, views untouched.
        let stale = Message::Membership {
            event: MembershipEvent::Crash {
                time: 5.0,
                node: 1,
            },
        }
        .encode(1);
        co.transport.send(0, 2, &stale).unwrap();
        let d = co.transport.recv(2, 100.0).expect("delivered");
        co.on_delivery(2, d).unwrap();
        assert_eq!(co.node_views(), before, "stale frame mutated a view");
        assert_eq!(co.obs.reg.get("net.stale_frames"), 1);

        // A current-epoch Join delivered twice: Join is *not*
        // idempotent (it bumps the incarnation), so the duplicate
        // filter is what keeps the view correct.
        let join = Message::Membership {
            event: MembershipEvent::Join {
                time: 6.0,
                node: 3,
            },
        }
        .encode(2);
        co.transport.send(0, 2, &join).unwrap();
        co.transport.send(0, 2, &join).unwrap();
        for _ in 0..2 {
            let d = co.transport.recv(2, 100.0).expect("delivered");
            co.on_delivery(2, d).unwrap();
        }
        assert_eq!(co.obs.reg.get("net.dup_frames"), 1);
        let inc = co.nodes[2]
            .membership
            .snapshot()
            .into_iter()
            .find(|&(id, ..)| id == 3)
            .map(|(_, _, inc)| inc)
            .expect("node 3 in view");
        assert_eq!(inc, 1, "duplicate Join must apply exactly once");

        // Truncated garbage is a decode error, not a state change.
        let ping = Message::Ping { seq: 1 }.encode(2);
        co.transport.send(0, 2, &ping[..3]).unwrap();
        let d = co.transport.recv(2, 100.0).expect("delivered");
        co.on_delivery(2, d).unwrap();
        assert_eq!(co.obs.reg.get("net.decode_errors"), 1);
    }

    #[test]
    fn lossy_sim_run_retransmits_probes_and_completes() {
        use crate::net::lossy::{LossyConfig, LossyTransport};
        let w = sample(24, 9);
        let transport = LossyTransport::new(
            SimTransport::new(w.clone()),
            LossyConfig::drops(0.15, 42),
        );
        let mut co = NetCoordinator::new(cfg(24), w, transport).unwrap();
        let rep = co.run(&EventTrace::default(), 1000.0).unwrap();
        assert_eq!(rep.timeline.len(), 4, "lossy run must still cover \
                    every period");
        assert!(rep.final_diameter.is_finite());
        // 15% injected loss over thousands of frames: probes were
        // retransmitted and some frames written off.
        assert!(co.metrics.counter("net.probe_retx") > 0);
        assert!(co.metrics.counter("net.frames_lost") > 0);
        // The loss-weighted readout kept ρ inputs sane: every period
        // still produced a finite ρ in [0, 1].
        for &(_, rho, _) in &rep.timeline {
            assert!((0.0..=1.0).contains(&rho), "rho {rho}");
        }
    }

    #[test]
    fn churn_guard_suppresses_swaps_on_net_path() {
        let w = sample(20, 5);
        let mut c = cfg(20);
        c.churn_guard = 1;
        // A nearly-degenerate Keep band so the period reaches a swap
        // decision for sure — the guard, not indecision, must stop it.
        c.epsilon = 0.45;
        let mut co = NetCoordinator::new(
            c,
            w.clone(),
            SimTransport::new(w),
        )
        .unwrap();
        // 4 crashes in period 1 exceed the guard threshold of 1.
        let trace = EventTrace {
            events: (0..4)
                .map(|i| MembershipEvent::Crash {
                    time: 10.0 * (i + 1) as f64,
                    node: i,
                })
                .collect(),
        };
        let rep = co.run(&trace, 250.0).unwrap();
        assert_eq!(rep.swaps, 0, "guarded period must not swap");
        assert_eq!(co.metrics.counter("rings.guard_skips"), 1);
    }

    #[test]
    fn traced_lossy_run_exports_an_orphan_free_causal_forest() {
        use crate::net::lossy::{LossyConfig, LossyTransport};
        use crate::obs::trace;

        let run = || {
            let w = sample(24, 9);
            let transport = LossyTransport::new(
                SimTransport::new(w.clone()),
                LossyConfig::drops(0.15, 42),
            );
            let mut co =
                NetCoordinator::new(cfg(24), w, transport).unwrap();
            co.trace_sample = 1;
            co.obs.rec.set_enabled(true);
            co.run(&EventTrace::default(), 1000.0).unwrap();
            co.obs.rec.export_jsonl(true).unwrap()
        };
        let timeline = run();
        assert_eq!(timeline, run(), "traced timeline must be stable");

        let spans = trace::parse_jsonl(&timeline).unwrap();
        let forest = trace::assemble(&spans);
        assert_eq!(forest.traces.len(), 4, "one trace per period");
        let mut kinds: HashSet<String> = HashSet::new();
        for tr in &forest.traces {
            // The acceptance bar: every probe/gossip/swap/deliver span
            // hangs off a recorded parent — nothing dangles, even with
            // 15% frame loss forcing retransmissions.
            assert!(
                tr.orphans.is_empty(),
                "period {:?} has orphans:\n{}",
                tr.period(),
                tr.render_tree()
            );
            assert_eq!(tr.roots.len(), 1, "one period root per trace");
            assert!(tr.period().is_some());
            let (chain, ms) = tr.critical_chain();
            assert!(chain.starts_with("period["), "{chain}");
            assert!(chain.contains(" -> "), "{chain}");
            assert!(ms > 0.0, "critical path has sim-time extent");
            for s in &tr.spans {
                kinds.insert(s.kind.clone());
            }
        }
        for k in ["period", "measure", "probe", "gossip", "deliver"] {
            assert!(kinds.contains(k), "missing span kind {k}");
        }
        // Loss over thousands of frames: some probes were retried, and
        // their retx spans chained back to the timed-out attempt
        // (otherwise they would have shown up as orphans above).
        assert!(kinds.contains("retx"), "lossy run must record retx");
    }

    #[test]
    fn untraced_runs_stamp_no_trace_context() {
        let w = sample(12, 3);
        let mut co = NetCoordinator::new(
            cfg(12),
            w.clone(),
            SimTransport::new(w),
        )
        .unwrap();
        co.obs.rec.set_enabled(true);
        co.run(&EventTrace::default(), 250.0).unwrap();
        let timeline = co.obs.rec.export_jsonl(true).unwrap();
        assert!(!timeline.is_empty());
        assert!(
            !timeline.contains("\"trace\""),
            "trace_sample = 0 must leave spans untraced"
        );
        assert!(
            !timeline.contains("\"deliver\""),
            "deliver spans only exist under tracing"
        );
    }
}
