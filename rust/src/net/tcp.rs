//! [`TcpTransport`]: the stream-based real-socket transport —
//! length-prefixed frames over per-peer loopback TCP connections with
//! on-demand dialing, reconnect with exponential backoff, and the same
//! receiver-side delay shim as the UDP path (docs/TRANSPORT.md).
//!
//! Topology: one `TcpListener` per node endpoint. The first frame
//! toward a destination dials its listener and the stream is cached
//! **per destination** — the sender id travels in every frame header,
//! so the in-process senders share one stream per peer and the
//! steady-state footprint is at most `n` outbound connections (plus a
//! `CONN_CAP` FIFO bound as a defensive ceiling for huge overlays). A
//! broken or evicted connection is re-dialed on the next send, with
//! `CONNECT_RETRIES` backoff rounds before the send is given up as a
//! transport error.
//!
//! Stream framing: `[len u32][deliver_at_us u64][src u32][frame]`,
//! little-endian. TCP gives in-order reliable delivery per connection;
//! the shim header still carries the delivery deadline so per-link
//! latency is shaped from the same [`LatencyMatrix`] the simulator
//! uses, exactly like the UDP datagram header.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::latency::LatencyMatrix;
use crate::net::transport::{Delivery, HeldMsg, ShimRx, Transport};

/// Stream-frame header carried inside the length prefix: delivery
/// deadline (µs since the transport epoch) + sender id.
const STREAM_HEADER: usize = 8 + 4;

/// Largest frame a reader accepts; a corrupt length prefix must not
/// drive an OOM allocation.
const MAX_FRAME: usize = 1 << 20;

/// Defensive ceiling on cached outbound connections (each cached
/// stream also pins one accepted socket and one reader thread on the
/// receiving side, so the file-descriptor footprint is ~2× this plus
/// one listener per node). With the per-destination cache the working
/// set is exactly the peer count, so eviction only ever fires on
/// overlays larger than this.
const CONN_CAP: usize = 192;

/// Dial attempts per send before the connection is declared down.
const CONNECT_RETRIES: u32 = 3;

/// Backoff before dial attempt `k` (k = 1 is the first retry).
fn backoff(attempt: u32) -> Duration {
    Duration::from_millis(1u64 << (2 * attempt.min(3)))
}

/// Stream transport over per-node loopback `TcpListener`s with the
/// delay-injection shim (see the module docs). `time_scale` compresses
/// sim-ms into real-ms like [`crate::net::UdpTransport`].
pub struct TcpTransport {
    addrs: Vec<SocketAddr>,
    shims: Vec<ShimRx>,
    /// Cached outbound streams, keyed by destination (the sender id is
    /// in the frame header); `order` tracks insertion for FIFO
    /// eviction at the defensive `CONN_CAP` ceiling.
    conns: HashMap<u32, TcpStream>,
    order: Vec<u32>,
    epoch: Instant,
    scale: f64,
    w: LatencyMatrix,
    stop: Arc<AtomicBool>,
    acceptors: Vec<std::thread::JoinHandle<()>>,
    readers: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    sent: u64,
    reconnects: u64,
    obs: Option<crate::obs::Obs>,
    obs_tx: Option<Arc<crate::obs::CounterVec>>,
    obs_rx: Option<Arc<crate::obs::CounterVec>>,
}

impl TcpTransport {
    /// Bind `w.n()` loopback listeners and start their acceptor
    /// threads. Outbound connections are dialed lazily on first send.
    pub fn bind(w: LatencyMatrix, time_scale: f64) -> Result<TcpTransport> {
        if !(time_scale > 0.0) {
            bail!("time_scale must be > 0, got {time_scale}");
        }
        let n = w.n();
        let stop = Arc::new(AtomicBool::new(false));
        let epoch = Instant::now();
        let readers: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let mut addrs = Vec::with_capacity(n);
        let mut shims = Vec::with_capacity(n);
        let mut acceptors = Vec::with_capacity(n);
        for node in 0..n {
            let listener = TcpListener::bind("127.0.0.1:0")
                .with_context(|| format!("binding node {node}"))?;
            listener.set_nonblocking(true)?;
            addrs.push(listener.local_addr()?);
            let (tx, rxq) = std::sync::mpsc::channel();
            acceptors.push(spawn_acceptor(
                listener,
                tx,
                epoch,
                Arc::clone(&stop),
                Arc::clone(&readers),
            ));
            shims.push(ShimRx::new(rxq));
        }
        Ok(TcpTransport {
            addrs,
            shims,
            conns: HashMap::new(),
            order: Vec::new(),
            epoch,
            scale: time_scale,
            w,
            stop,
            acceptors,
            readers,
            sent: 0,
            reconnects: 0,
            obs: None,
            obs_tx: None,
            obs_rx: None,
        })
    }

    /// Connections re-dialed after a broken or evicted stream (the
    /// reconnect/backoff path's activity counter).
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Dial `dst` with bounded backoff, recording a `dial` span and a
    /// `net.tcp.dials` count when observability is attached.
    fn dial(&self, dst: u32) -> Result<TcpStream> {
        let addr = self.addrs[dst as usize];
        let timer = self
            .obs
            .as_ref()
            .map(|o| (o, o.rec.start("dial", dst as u64, self.now_ms())));
        let mut last: Option<std::io::Error> = None;
        for attempt in 0..CONNECT_RETRIES {
            if attempt > 0 {
                std::thread::sleep(backoff(attempt));
            }
            match TcpStream::connect(addr) {
                Ok(s) => {
                    s.set_nodelay(true)?;
                    if let Some((o, t)) = timer {
                        o.reg.incr("net.tcp.dials", 1);
                        t.finish(&o.rec, self.now_ms());
                    }
                    return Ok(s);
                }
                Err(e) => last = Some(e),
            }
        }
        if let Some(o) = &self.obs {
            o.reg.incr("net.tcp.dial_failures", 1);
        }
        bail!(
            "dialing node {dst} at {addr} failed after \
             {CONNECT_RETRIES} attempts: {}",
            last.expect("at least one attempt ran")
        );
    }

    /// Evict the oldest cached connection once the cache is full; the
    /// closed stream EOFs its reader on the receiving side, freeing
    /// both descriptors.
    fn make_room(&mut self) {
        while self.conns.len() >= CONN_CAP && !self.order.is_empty() {
            let key = self.order.remove(0);
            self.conns.remove(&key);
        }
    }

    /// Write one framed message on the cached (or freshly dialed)
    /// stream to `dst`, reconnecting once if the cached stream broke.
    fn write_frame(
        &mut self,
        src: u32,
        dst: u32,
        buf: &[u8],
    ) -> Result<()> {
        if !self.conns.contains_key(&dst) {
            self.make_room();
            let s = self.dial(dst)?;
            self.conns.insert(dst, s);
            self.order.push(dst);
        }
        let broken = {
            let s = self.conns.get_mut(&dst).expect("just inserted");
            s.write_all(buf).is_err()
        };
        if !broken {
            return Ok(());
        }
        // The peer (or an eviction race) closed the stream under us:
        // re-dial with backoff and retry the write once.
        self.conns.remove(&dst);
        self.order.retain(|k| *k != dst);
        self.reconnects += 1;
        if let Some(o) = &self.obs {
            o.reg.incr("net.tcp.reconnects", 1);
        }
        let mut s = self.dial(dst)?;
        s.write_all(buf)
            .with_context(|| format!("tcp resend {src} -> {dst}"))?;
        self.make_room();
        self.conns.insert(dst, s);
        self.order.push(dst);
        Ok(())
    }
}

/// Join reader threads that already hit EOF (their sender was evicted
/// or closed), so a long run's connection churn cannot accumulate
/// unbounded zombie threads.
fn reap_finished(
    readers: &Mutex<Vec<std::thread::JoinHandle<()>>>,
) {
    let mut done = Vec::new();
    {
        let mut reg = readers.lock().expect("reader registry");
        let mut i = 0;
        while i < reg.len() {
            if reg[i].is_finished() {
                done.push(reg.swap_remove(i));
            } else {
                i += 1;
            }
        }
    }
    for h in done {
        let _ = h.join();
    }
}

fn spawn_acceptor(
    listener: TcpListener,
    tx: Sender<HeldMsg>,
    epoch: Instant,
    stop: Arc<AtomicBool>,
    readers: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let seq = Arc::new(AtomicU64::new(0));
        while !stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let handle = spawn_stream_reader(
                        stream,
                        tx.clone(),
                        epoch,
                        Arc::clone(&seq),
                    );
                    readers.lock().expect("reader registry").push(handle);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    reap_finished(&readers);
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(_) => {
                    // Transient accept errors (ECONNABORTED, EMFILE
                    // under descriptor pressure, ...) must not kill
                    // the acceptor — a deaf node would silently turn
                    // every frame toward it into a write-off. Reap,
                    // back off briefly, retry; shutdown still exits
                    // via the stop flag.
                    reap_finished(&readers);
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
    })
}

fn spawn_stream_reader(
    mut stream: TcpStream,
    tx: Sender<HeldMsg>,
    epoch: Instant,
    seq: Arc<AtomicU64>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut len_buf = [0u8; 4];
        loop {
            // Blocking reads; the sender closing its end (drop, evict,
            // transport shutdown) EOFs us out of the loop.
            if stream.read_exact(&mut len_buf).is_err() {
                break;
            }
            let len = u32::from_le_bytes(len_buf) as usize;
            if len < STREAM_HEADER || len > MAX_FRAME {
                break; // framing lost: abandon the connection
            }
            let mut payload = vec![0u8; len];
            if stream.read_exact(&mut payload).is_err() {
                break;
            }
            let deliver_at_us =
                u64::from_le_bytes(payload[..8].try_into().unwrap());
            let src =
                u32::from_le_bytes(payload[8..12].try_into().unwrap());
            let msg = HeldMsg {
                deliver_at_us,
                arrival_us: epoch.elapsed().as_micros() as u64,
                seq: seq.fetch_add(1, Ordering::Relaxed),
                src,
                frame: payload[STREAM_HEADER..].to_vec(),
            };
            if tx.send(msg).is_err() {
                break; // transport dropped
            }
        }
    })
}

impl Transport for TcpTransport {
    fn n(&self) -> usize {
        self.w.n()
    }

    fn now_ms(&self) -> f64 {
        self.now_us() as f64 / 1e3 / self.scale
    }

    fn send(&mut self, src: u32, dst: u32, frame: &[u8]) -> Result<()> {
        if src == dst {
            bail!("self-send {src} -> {dst}");
        }
        if dst as usize >= self.w.n() {
            bail!("destination {dst} out of range");
        }
        let delay_us = (self.w.get(src as usize, dst as usize) as f64
            * self.scale
            * 1e3) as u64;
        let deliver_at = self.now_us() + delay_us;
        let len = (STREAM_HEADER + frame.len()) as u32;
        let mut buf = Vec::with_capacity(4 + len as usize);
        buf.extend_from_slice(&len.to_le_bytes());
        buf.extend_from_slice(&deliver_at.to_le_bytes());
        buf.extend_from_slice(&src.to_le_bytes());
        buf.extend_from_slice(frame);
        self.write_frame(src, dst, &buf)
            .with_context(|| format!("tcp send {src} -> {dst}"))?;
        self.sent += 1;
        if let Some(tx) = &self.obs_tx {
            tx.incr(src as usize, 1);
        }
        Ok(())
    }

    fn recv(&mut self, dst: u32, timeout_ms: f64) -> Option<Delivery> {
        let d =
            self.shims[dst as usize].recv(self.epoch, self.scale, timeout_ms);
        if d.is_some() {
            if let Some(rx) = &self.obs_rx {
                rx.incr(dst as usize, 1);
            }
        }
        d
    }

    fn set_latency(&mut self, w: &LatencyMatrix) -> Result<()> {
        if w.n() != self.w.n() {
            bail!("latency update size {} != {}", w.n(), self.w.n());
        }
        self.w = w.clone();
        Ok(())
    }

    fn addr(&self, node: u32) -> String {
        format!("tcp://{}", self.addrs[node as usize])
    }

    fn frames_sent(&self) -> u64 {
        self.sent
    }

    fn name(&self) -> &'static str {
        "tcp"
    }

    fn attach_obs(&mut self, obs: &crate::obs::Obs) {
        let n = self.w.n();
        self.obs_tx = Some(obs.reg.counter_vec("net.peer.tx", n));
        self.obs_rx = Some(obs.reg.counter_vec("net.peer.rx", n));
        self.obs = Some(obs.clone());
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Closing every outbound stream EOFs the corresponding reader
        // threads; the acceptors exit on the stop flag.
        self.conns.clear();
        for a in self.acceptors.drain(..) {
            let _ = a.join();
        }
        let handles: Vec<_> = self
            .readers
            .lock()
            .expect("reader registry")
            .drain(..)
            .collect();
        for r in handles {
            let _ = r.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w3() -> LatencyMatrix {
        LatencyMatrix::from_fn(3, |u, v| 10.0 * (u + v) as f32)
    }

    #[test]
    fn tcp_transport_round_trips_and_shapes_delay() {
        // Generous scale so the shaped delay dominates scheduler noise.
        let mut t = TcpTransport::bind(w3(), 0.5).unwrap();
        let t0 = t.now_ms();
        t.send(0, 1, b"hello").unwrap();
        let d = t.recv(1, 1000.0).expect("loopback delivery");
        assert_eq!(d.frame, b"hello");
        assert_eq!(d.src, 0);
        // Link 0-1 is 10 sim-ms: the shim must hold it at least that
        // long on the transport clock.
        assert!(
            d.at_ms - t0 >= 9.0,
            "shim held {} sim-ms, expected ~10",
            d.at_ms - t0
        );
        assert!(t.addr(1).starts_with("tcp://127.0.0.1:"));
        assert_eq!(t.name(), "tcp");
        assert_eq!(t.frames_sent(), 1);
    }

    #[test]
    fn tcp_transport_reuses_and_reorders_by_deadline() {
        let mut t = TcpTransport::bind(w3(), 0.2).unwrap();
        // Two frames on the same stream: both land, in deadline order.
        t.send(0, 2, b"first").unwrap(); // link 0-2: 20 sim-ms
        t.send(0, 2, b"second").unwrap();
        let a = t.recv(2, 1000.0).expect("first delivery");
        let b = t.recv(2, 1000.0).expect("second delivery");
        assert_eq!(a.frame, b"first");
        assert_eq!(b.frame, b"second");
        assert!(b.at_ms >= a.at_ms);
        assert_eq!(t.reconnects(), 0, "cached stream must be reused");
    }

    #[test]
    fn tcp_transport_rejects_self_send_and_size_mismatch() {
        let mut t = TcpTransport::bind(w3(), 0.05).unwrap();
        assert!(t.send(1, 1, b"loop").is_err());
        assert!(t.send(0, 9, b"oob").is_err());
        let bad = LatencyMatrix::from_fn(5, |_, _| 1.0);
        assert!(t.set_latency(&bad).is_err());
        assert!(t.set_latency(&w3()).is_ok());
    }

    #[test]
    fn tcp_recv_times_out_when_idle() {
        let mut t = TcpTransport::bind(w3(), 0.05).unwrap();
        let start = Instant::now();
        assert!(t.recv(0, 50.0).is_none());
        assert!(start.elapsed() < Duration::from_secs(5));
    }
}
