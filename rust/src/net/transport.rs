//! The [`Transport`] abstraction: framed-datagram exchange between the
//! N node endpoints of one overlay, with per-link latency shaping and a
//! transport clock.
//!
//! Implementations:
//!
//! * [`SimTransport`] — wraps the existing discrete-event engine
//!   ([`crate::sim::Engine`]): a send schedules a `Deliver` event at
//!   `now + w(src, dst)`, receives pump the queue, and the clock is sim
//!   time. Exact and fully deterministic — the pre-transport coordinator
//!   behavior is this transport's special case.
//! * [`UdpTransport`] — one `std::net::UdpSocket` per node on loopback
//!   with a reader thread each, plus a **delay-injection shim**: the
//!   sender stamps each datagram with a delivery deadline
//!   `now + w(src, dst) · time_scale` and the receiver holds it until
//!   the deadline passes, so the wall-clock link latencies are shaped by
//!   the *same* [`LatencyMatrix`] the simulator uses (compressed by
//!   `time_scale` real-ms per sim-ms). Clock and delivery timestamps are
//!   reported in sim-ms units (wall / scale), so measurement code is
//!   transport-agnostic.
//! * [`TcpTransport`](crate::net::tcp::TcpTransport) — length-prefixed
//!   framed streams with per-peer reconnect/backoff, sharing the same
//!   delay shim (its receive side is the crate-private `ShimRx` defined
//!   here).
//! * [`LossyTransport`](crate::net::lossy::LossyTransport) — a seeded
//!   drop/duplicate/reorder decorator over any of the above, for
//!   replayable loss-injection scenarios.
//!
//! Determinism caveats for the real-socket path live in
//! docs/TRANSPORT.md: delivery *order* can differ by scheduler jitter
//! and datagrams can be dropped, so protocol layers above must either
//! barrier on expected message counts or tolerate loss — since wire v2,
//! [`NetCoordinator`](crate::net::runner::NetCoordinator) does both
//! (epoch-tagged phases, probe retransmit, loss-weighted push-sum).

use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::net::{SocketAddr, UdpSocket};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::latency::LatencyMatrix;
use crate::sim::engine::{Engine, EventKind};

/// One delivered frame: who sent it, when the transport handed it over
/// (transport clock, sim-ms units) and the raw bytes.
#[derive(Clone, Debug)]
pub struct Delivery {
    /// Sending node id.
    pub src: u32,
    /// Delivery time on the transport clock (sim-ms units).
    pub at_ms: f64,
    /// The framed message bytes (see [`crate::net::wire`]).
    pub frame: Vec<u8>,
}

/// Message-level transport between the `n` node endpoints of one
/// overlay. All methods take the node id view — addressing, sockets and
/// clocks are the implementation's business.
pub trait Transport {
    /// Number of node endpoints.
    fn n(&self) -> usize;

    /// Current transport clock in sim-ms units (sim time for
    /// [`SimTransport`], scaled wall time for [`UdpTransport`]).
    fn now_ms(&self) -> f64;

    /// Send one framed datagram from `src` to `dst`. Delivery is
    /// delayed by the shaped per-link latency; `dst == src` is an error.
    fn send(&mut self, src: u32, dst: u32, frame: &[u8]) -> Result<()>;

    /// Receive the next frame addressed to `dst`, waiting at most
    /// `timeout_ms` (sim-ms units) past the current clock. `None` on
    /// timeout.
    fn recv(&mut self, dst: u32, timeout_ms: f64) -> Option<Delivery>;

    /// Swap in an updated latency matrix: subsequent sends are shaped
    /// by the new per-link delays (dynamic-latency scenarios).
    fn set_latency(&mut self, w: &LatencyMatrix) -> Result<()>;

    /// Peer address of `node` — a socket address for real transports, a
    /// stable synthetic name for simulated ones.
    fn addr(&self, node: u32) -> String;

    /// Frames sent so far (cost accounting).
    fn frames_sent(&self) -> u64;

    /// Short transport name for reports ("sim" / "udp" / "tcp").
    fn name(&self) -> &'static str;

    /// Expected frame-loss probability, if the transport is known to
    /// lose frames on purpose (the
    /// [`LossyTransport`](crate::net::lossy::LossyTransport) decorator
    /// overrides this with its drop rate). Protocol layers use it to
    /// pick the aggressive, deadline-based write-off policy instead of
    /// the conservative idle cap. 0.0 for faithful transports.
    fn loss_hint(&self) -> f64 {
        0.0
    }

    /// Attach a run's observability sinks. Transports that implement
    /// this record per-peer tx/rx counters (and, for TCP, dial spans
    /// and reconnect counts) into the registry; the default is a
    /// no-op so synthetic test transports need not care. Called by
    /// [`NetCoordinator`](crate::net::runner::NetCoordinator) before
    /// the first send.
    fn attach_obs(&mut self, obs: &crate::obs::Obs) {
        let _ = obs;
    }
}

impl Transport for Box<dyn Transport> {
    fn n(&self) -> usize {
        (**self).n()
    }

    fn now_ms(&self) -> f64 {
        (**self).now_ms()
    }

    fn send(&mut self, src: u32, dst: u32, frame: &[u8]) -> Result<()> {
        (**self).send(src, dst, frame)
    }

    fn recv(&mut self, dst: u32, timeout_ms: f64) -> Option<Delivery> {
        (**self).recv(dst, timeout_ms)
    }

    fn set_latency(&mut self, w: &LatencyMatrix) -> Result<()> {
        (**self).set_latency(w)
    }

    fn addr(&self, node: u32) -> String {
        (**self).addr(node)
    }

    fn frames_sent(&self) -> u64 {
        (**self).frames_sent()
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn loss_hint(&self) -> f64 {
        (**self).loss_hint()
    }

    fn attach_obs(&mut self, obs: &crate::obs::Obs) {
        (**self).attach_obs(obs)
    }
}

// ---------------------------------------------------------------------
// SimTransport
// ---------------------------------------------------------------------

/// Simulated transport over the discrete-event engine: exact per-link
/// delays from the latency matrix, deterministic FIFO tie-breaking,
/// zero real time.
pub struct SimTransport {
    engine: Engine,
    w: LatencyMatrix,
    inbox: Vec<VecDeque<Delivery>>,
    store: HashMap<u64, Vec<u8>>,
    next_tag: u64,
    sent: u64,
    obs_tx: Option<Arc<crate::obs::CounterVec>>,
    obs_rx: Option<Arc<crate::obs::CounterVec>>,
}

impl SimTransport {
    /// A transport over `w.n()` endpoints with per-link delays from `w`.
    pub fn new(w: LatencyMatrix) -> SimTransport {
        let n = w.n();
        SimTransport {
            engine: Engine::new(),
            w,
            inbox: (0..n).map(|_| VecDeque::new()).collect(),
            store: HashMap::new(),
            next_tag: 0,
            sent: 0,
            obs_tx: None,
            obs_rx: None,
        }
    }

    /// Deliver one pending engine event into its inbox. Returns false
    /// when the queue is empty or the next event is past `deadline`.
    fn pump_one(&mut self, deadline: f64) -> bool {
        match self.engine.peek_time() {
            Some(t) if t <= deadline => {
                let ev = self.engine.next().expect("peeked event exists");
                if let EventKind::Deliver { src, dst, tag } = ev.kind {
                    let frame = self
                        .store
                        .remove(&tag)
                        .expect("frame stored at send");
                    self.inbox[dst as usize].push_back(Delivery {
                        src,
                        at_ms: ev.time,
                        frame,
                    });
                }
                true
            }
            _ => false,
        }
    }
}

impl Transport for SimTransport {
    fn n(&self) -> usize {
        self.w.n()
    }

    fn now_ms(&self) -> f64 {
        self.engine.now()
    }

    fn send(&mut self, src: u32, dst: u32, frame: &[u8]) -> Result<()> {
        if src == dst {
            bail!("self-send {src} -> {dst}");
        }
        if dst as usize >= self.w.n() {
            bail!("destination {dst} out of range");
        }
        let tag = self.next_tag;
        self.next_tag += 1;
        self.store.insert(tag, frame.to_vec());
        let delay = self.w.get(src as usize, dst as usize) as f64;
        self.engine
            .schedule_in(delay, EventKind::Deliver { src, dst, tag });
        self.sent += 1;
        if let Some(tx) = &self.obs_tx {
            tx.incr(src as usize, 1);
        }
        Ok(())
    }

    fn recv(&mut self, dst: u32, timeout_ms: f64) -> Option<Delivery> {
        let deadline = self.engine.now() + timeout_ms;
        loop {
            if let Some(d) = self.inbox[dst as usize].pop_front() {
                if let Some(rx) = &self.obs_rx {
                    rx.incr(dst as usize, 1);
                }
                return Some(d);
            }
            if !self.pump_one(deadline) {
                // Nothing arrives before the deadline: the blocking
                // receive "waited it out", so the sim clock advances —
                // without this, empty polls would never make progress
                // toward future deliveries.
                self.engine.advance_to(deadline);
                return None;
            }
        }
    }

    fn set_latency(&mut self, w: &LatencyMatrix) -> Result<()> {
        if w.n() != self.w.n() {
            bail!("latency update size {} != {}", w.n(), self.w.n());
        }
        self.w = w.clone();
        Ok(())
    }

    fn addr(&self, node: u32) -> String {
        format!("sim://node/{node}")
    }

    fn frames_sent(&self) -> u64 {
        self.sent
    }

    fn name(&self) -> &'static str {
        "sim"
    }

    fn attach_obs(&mut self, obs: &crate::obs::Obs) {
        let n = self.w.n();
        self.obs_tx = Some(obs.reg.counter_vec("net.peer.tx", n));
        self.obs_rx = Some(obs.reg.counter_vec("net.peer.rx", n));
    }
}

// ---------------------------------------------------------------------
// UdpTransport
// ---------------------------------------------------------------------

/// Datagram header: delivery deadline in µs since the transport epoch,
/// then the sender id, then the frame.
const UDP_HEADER: usize = 8 + 4;

/// One shim-held message on the receive side of a real-socket
/// transport (UDP and TCP share this representation).
pub(crate) struct HeldMsg {
    pub(crate) deliver_at_us: u64,
    pub(crate) arrival_us: u64,
    pub(crate) seq: u64,
    pub(crate) src: u32,
    pub(crate) frame: Vec<u8>,
}

impl PartialEq for HeldMsg {
    fn eq(&self, other: &Self) -> bool {
        self.deliver_at_us == other.deliver_at_us && self.seq == other.seq
    }
}
impl Eq for HeldMsg {}
impl Ord for HeldMsg {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap by (deadline, arrival seq): reverse the natural order.
        other
            .deliver_at_us
            .cmp(&self.deliver_at_us)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for HeldMsg {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Receive side of the delay-injection shim, shared by the real-socket
/// transports: a channel fed by reader threads plus the deadline-ordered
/// hold buffer. [`ShimRx::recv`] is the blocking receive-with-hold loop
/// both [`UdpTransport`] and
/// [`TcpTransport`](crate::net::tcp::TcpTransport) delegate to.
pub(crate) struct ShimRx {
    rx: Receiver<HeldMsg>,
    held: BinaryHeap<HeldMsg>,
}

impl ShimRx {
    /// Wrap the reader-thread channel of one node endpoint.
    pub(crate) fn new(rx: Receiver<HeldMsg>) -> ShimRx {
        ShimRx {
            rx,
            held: BinaryHeap::new(),
        }
    }

    /// Drain everything the reader threads have queued into the
    /// deadline-ordered hold buffer.
    fn drain(&mut self) {
        while let Ok(msg) = self.rx.try_recv() {
            self.held.push(msg);
        }
    }

    /// Blocking receive against the shim: release the earliest held
    /// message whose deadline has passed, waiting at most `timeout_ms`
    /// (sim-ms units) of scaled wall time. `epoch` is the transport's
    /// shared clock origin, `scale` its real-ms-per-sim-ms compression.
    pub(crate) fn recv(
        &mut self,
        epoch: Instant,
        scale: f64,
        timeout_ms: f64,
    ) -> Option<Delivery> {
        let now_us = || epoch.elapsed().as_micros() as u64;
        let deadline_us = now_us() + (timeout_ms * scale * 1e3) as u64;
        loop {
            self.drain();
            let now = now_us();
            match self.held.peek().map(|m| m.deliver_at_us) {
                Some(at) if at <= now => {
                    let msg = self.held.pop().expect("peeked");
                    // Report the shim deadline, not the (jittery) wall
                    // arrival, unless the message genuinely arrived
                    // late — keeps RTT measurements tight.
                    let at_us = msg.deliver_at_us.max(msg.arrival_us);
                    return Some(Delivery {
                        src: msg.src,
                        at_ms: at_us as f64 / 1e3 / scale,
                        frame: msg.frame,
                    });
                }
                Some(at) => {
                    if now >= deadline_us && at > deadline_us {
                        return None; // held mail matures past the timeout
                    }
                    // Sleep until the earliest hold deadline (or the
                    // timeout, whichever comes first); fresh arrivals
                    // wake the channel early.
                    let wake = at.min(deadline_us).max(now + 1);
                    match self
                        .rx
                        .recv_timeout(Duration::from_micros(wake - now))
                    {
                        Ok(m) => self.held.push(m),
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => {
                            return None;
                        }
                    }
                }
                None => {
                    if now >= deadline_us {
                        return None;
                    }
                    match self.rx.recv_timeout(Duration::from_micros(
                        deadline_us - now,
                    )) {
                        Ok(m) => self.held.push(m),
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => {
                            return None;
                        }
                    }
                }
            }
        }
    }
}

/// Real-socket transport: N UDP sockets on 127.0.0.1 with one reader
/// thread per node and receiver-side delay shaping (see the module
/// docs). `time_scale` compresses sim-ms into real-ms so multi-second
/// scenario horizons replay in tens of milliseconds of wall time.
pub struct UdpTransport {
    sockets: Vec<UdpSocket>,
    addrs: Vec<SocketAddr>,
    shims: Vec<ShimRx>,
    epoch: Instant,
    scale: f64,
    w: LatencyMatrix,
    stop: Arc<AtomicBool>,
    readers: Vec<std::thread::JoinHandle<()>>,
    sent: u64,
    obs_tx: Option<Arc<crate::obs::CounterVec>>,
    obs_rx: Option<Arc<crate::obs::CounterVec>>,
}

impl UdpTransport {
    /// Default wall-time compression: 0.05 real-ms per sim-ms (a 4 s
    /// scenario horizon replays in ~200 ms of shaped delay).
    pub const DEFAULT_TIME_SCALE: f64 = 0.05;

    /// Bind `w.n()` loopback sockets and start their reader threads.
    pub fn bind(w: LatencyMatrix, time_scale: f64) -> Result<UdpTransport> {
        if !(time_scale > 0.0) {
            bail!("time_scale must be > 0, got {time_scale}");
        }
        let n = w.n();
        let stop = Arc::new(AtomicBool::new(false));
        // One epoch shared by senders, receivers and reader threads:
        // arrival timestamps and shim deadlines must come off the same
        // clock, or skew between them misclassifies on-time datagrams
        // as late.
        let epoch = Instant::now();
        let mut sockets = Vec::with_capacity(n);
        let mut addrs = Vec::with_capacity(n);
        let mut shims = Vec::with_capacity(n);
        let mut readers = Vec::with_capacity(n);
        for node in 0..n {
            let sock = UdpSocket::bind("127.0.0.1:0")
                .with_context(|| format!("binding node {node}"))?;
            sock.set_read_timeout(Some(Duration::from_millis(20)))?;
            addrs.push(sock.local_addr()?);
            let reader = sock
                .try_clone()
                .with_context(|| format!("cloning node {node} socket"))?;
            let (tx, rxq) = std::sync::mpsc::channel();
            readers.push(spawn_reader(reader, tx, epoch, Arc::clone(&stop)));
            shims.push(ShimRx::new(rxq));
            sockets.push(sock);
        }
        Ok(UdpTransport {
            sockets,
            addrs,
            shims,
            epoch,
            scale: time_scale,
            w,
            stop,
            readers,
            sent: 0,
            obs_tx: None,
            obs_rx: None,
        })
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

fn spawn_reader(
    sock: UdpSocket,
    tx: Sender<HeldMsg>,
    epoch: Instant,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut buf = [0u8; 65_536];
        let mut seq = 0u64;
        while !stop.load(Ordering::Relaxed) {
            match sock.recv_from(&mut buf) {
                Ok((len, _)) if len >= UDP_HEADER => {
                    let deliver_at_us =
                        u64::from_le_bytes(buf[..8].try_into().unwrap());
                    let src =
                        u32::from_le_bytes(buf[8..12].try_into().unwrap());
                    let msg = HeldMsg {
                        deliver_at_us,
                        arrival_us: epoch.elapsed().as_micros() as u64,
                        seq,
                        src,
                        frame: buf[UDP_HEADER..len].to_vec(),
                    };
                    seq += 1;
                    if tx.send(msg).is_err() {
                        break; // transport dropped
                    }
                }
                Ok(_) => {} // runt datagram: drop
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut => {}
                Err(_) => break,
            }
        }
    })
}

impl Transport for UdpTransport {
    fn n(&self) -> usize {
        self.w.n()
    }

    fn now_ms(&self) -> f64 {
        self.now_us() as f64 / 1e3 / self.scale
    }

    fn send(&mut self, src: u32, dst: u32, frame: &[u8]) -> Result<()> {
        if src == dst {
            bail!("self-send {src} -> {dst}");
        }
        if dst as usize >= self.w.n() {
            bail!("destination {dst} out of range");
        }
        let delay_us = (self.w.get(src as usize, dst as usize) as f64
            * self.scale
            * 1e3) as u64;
        let deliver_at = self.now_us() + delay_us;
        let mut buf = Vec::with_capacity(UDP_HEADER + frame.len());
        buf.extend_from_slice(&deliver_at.to_le_bytes());
        buf.extend_from_slice(&src.to_le_bytes());
        buf.extend_from_slice(frame);
        self.sockets[src as usize]
            .send_to(&buf, self.addrs[dst as usize])
            .with_context(|| format!("udp send {src} -> {dst}"))?;
        self.sent += 1;
        if let Some(tx) = &self.obs_tx {
            tx.incr(src as usize, 1);
        }
        Ok(())
    }

    fn recv(&mut self, dst: u32, timeout_ms: f64) -> Option<Delivery> {
        let d =
            self.shims[dst as usize].recv(self.epoch, self.scale, timeout_ms);
        if d.is_some() {
            if let Some(rx) = &self.obs_rx {
                rx.incr(dst as usize, 1);
            }
        }
        d
    }

    fn set_latency(&mut self, w: &LatencyMatrix) -> Result<()> {
        if w.n() != self.w.n() {
            bail!("latency update size {} != {}", w.n(), self.w.n());
        }
        self.w = w.clone();
        Ok(())
    }

    fn addr(&self, node: u32) -> String {
        format!("udp://{}", self.addrs[node as usize])
    }

    fn frames_sent(&self) -> u64 {
        self.sent
    }

    fn name(&self) -> &'static str {
        "udp"
    }

    fn attach_obs(&mut self, obs: &crate::obs::Obs) {
        let n = self.w.n();
        self.obs_tx = Some(obs.reg.counter_vec("net.peer.tx", n));
        self.obs_rx = Some(obs.reg.counter_vec("net.peer.rx", n));
    }
}

impl Drop for UdpTransport {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w3() -> LatencyMatrix {
        LatencyMatrix::from_fn(3, |u, v| 10.0 * (u + v) as f32)
    }

    #[test]
    fn sim_transport_delays_by_latency_and_orders_deliveries() {
        let mut t = SimTransport::new(w3());
        t.send(0, 2, b"far").unwrap(); // delay 20
        t.send(0, 1, b"near").unwrap(); // delay 10
        let d = t.recv(1, 100.0).unwrap();
        assert_eq!(d.frame, b"near");
        assert_eq!(d.src, 0);
        assert!((d.at_ms - 10.0).abs() < 1e-9);
        let d = t.recv(2, 100.0).unwrap();
        assert_eq!(d.frame, b"far");
        assert!((d.at_ms - 20.0).abs() < 1e-9);
        assert_eq!(t.frames_sent(), 2);
        assert!(t.recv(1, 5.0).is_none(), "no further traffic");
    }

    #[test]
    fn sim_transport_timeout_does_not_consume_late_events() {
        let mut t = SimTransport::new(w3());
        t.send(0, 1, b"x").unwrap(); // arrives at t = 10
        assert!(t.recv(1, 3.0).is_none(), "before the delay elapses");
        assert!(t.recv(1, 100.0).is_some(), "still delivered later");
    }

    #[test]
    fn sim_transport_rejects_self_send_and_size_mismatch() {
        let mut t = SimTransport::new(w3());
        assert!(t.send(1, 1, b"loop").is_err());
        assert!(t.send(0, 9, b"oob").is_err());
        let bad = LatencyMatrix::from_fn(5, |_, _| 1.0);
        assert!(t.set_latency(&bad).is_err());
        assert!(t.set_latency(&w3()).is_ok());
        assert_eq!(t.name(), "sim");
        assert!(t.addr(2).contains("sim"));
    }

    #[test]
    fn udp_transport_round_trips_and_shapes_delay() {
        // Generous scale so the shaped delay dominates scheduler noise.
        let mut t = UdpTransport::bind(w3(), 0.5).unwrap();
        let t0 = t.now_ms();
        t.send(0, 1, b"hello").unwrap();
        let d = t.recv(1, 1000.0).expect("loopback delivery");
        assert_eq!(d.frame, b"hello");
        assert_eq!(d.src, 0);
        // Link 0-1 is 10 sim-ms: the shim must hold it at least that
        // long on the transport clock.
        assert!(
            d.at_ms - t0 >= 9.0,
            "shim held {} sim-ms, expected ~10",
            d.at_ms - t0
        );
        assert!(t.addr(1).starts_with("udp://127.0.0.1:"));
        assert_eq!(t.name(), "udp");
    }

    #[test]
    fn udp_recv_times_out_when_idle() {
        let mut t = UdpTransport::bind(w3(), 0.05).unwrap();
        let start = Instant::now();
        assert!(t.recv(0, 50.0).is_none());
        // 50 sim-ms at scale 0.05 = 2.5 real ms; allow slack but prove
        // it did not hang.
        assert!(start.elapsed() < Duration::from_secs(5));
    }
}
