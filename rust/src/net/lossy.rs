//! [`LossyTransport`]: a deterministic loss-injection decorator over
//! any [`Transport`] backend.
//!
//! Real networks drop and duplicate datagrams; scheduler jitter makes
//! those events unreproducible on real sockets. This wrapper moves the
//! fault injection to the *sender* side, driven by a seeded RNG, so a
//! loss scenario replays byte-identically over the sim transport (and
//! statistically identically over UDP/TCP): frame `i` of a run is
//! dropped, duplicated or reordered purely as a function of
//! `(seed, i)`.
//!
//! `dgro scenario run --transport sim|udp|tcp --loss-rate R
//! --dup-rate D --reorder-rate Q` wraps the chosen backend in this
//! decorator;
//! `rust/tests/net.rs` pins that two runs with the same seed produce
//! byte-identical coordinator reports and that measurement drift under
//! 5–10% injected loss stays inside the documented bound.

use anyhow::Result;

use crate::latency::LatencyMatrix;
use crate::net::transport::{Delivery, Transport};
use crate::util::rng::Rng;

/// Fault model of a [`LossyTransport`]: per-frame drop, duplicate and
/// reorder probabilities plus the RNG seed the injection stream
/// derives from.
#[derive(Clone, Copy, Debug)]
pub struct LossyConfig {
    /// Probability a sent frame is silently dropped.
    pub drop_rate: f64,
    /// Probability a delivered frame is sent twice (duplicate
    /// delivery at the receiver).
    pub dup_rate: f64,
    /// Probability a sent frame is held back and released *after* the
    /// sender's next frame, swapping their wire order (a held frame is
    /// flushed at the next receive, so it can never outlive its
    /// collection phase).
    pub reorder_rate: f64,
    /// Seed of the injection stream (same seed ⇒ same fault pattern).
    pub seed: u64,
}

impl LossyConfig {
    /// A fault model with the given drop rate only.
    pub fn drops(drop_rate: f64, seed: u64) -> LossyConfig {
        LossyConfig {
            drop_rate,
            dup_rate: 0.0,
            reorder_rate: 0.0,
            seed,
        }
    }

    /// Whether this configuration injects any fault at all.
    pub fn active(&self) -> bool {
        self.drop_rate > 0.0
            || self.dup_rate > 0.0
            || self.reorder_rate > 0.0
    }
}

/// Seeded drop/duplicate/reorder decorator over any transport backend
/// (see the module docs). The logical frame count
/// ([`Transport::frames_sent`]) counts every *attempted* send — a
/// dropped frame still cost its sender a transmission — while
/// [`LossyTransport::frames_dropped`],
/// [`LossyTransport::frames_duplicated`] and
/// [`LossyTransport::frames_reordered`] expose the injected faults.
pub struct LossyTransport<T: Transport> {
    inner: T,
    rng: Rng,
    cfg: LossyConfig,
    /// A frame held back for reordering: released after the next send
    /// (or flushed at the next receive).
    held: Option<(u32, u32, Vec<u8>)>,
    sent: u64,
    dropped: u64,
    duplicated: u64,
    reordered: u64,
    obs_drop: Option<std::sync::Arc<crate::obs::CounterVec>>,
}

impl<T: Transport> LossyTransport<T> {
    /// Wrap `inner` with the given fault model.
    pub fn new(inner: T, cfg: LossyConfig) -> LossyTransport<T> {
        LossyTransport {
            inner,
            rng: Rng::new(cfg.seed ^ 0x1055_EEDF_0017_1CEE),
            cfg,
            held: None,
            sent: 0,
            dropped: 0,
            duplicated: 0,
            reordered: 0,
            obs_drop: None,
        }
    }

    /// Frames the decorator silently dropped so far.
    pub fn frames_dropped(&self) -> u64 {
        self.dropped
    }

    /// Frames the decorator sent twice so far.
    pub fn frames_duplicated(&self) -> u64 {
        self.duplicated
    }

    /// Frames the decorator held back to swap wire order so far.
    pub fn frames_reordered(&self) -> u64 {
        self.reordered
    }

    /// The wrapped backend (e.g. to read backend-specific counters).
    pub fn inner(&self) -> &T {
        &self.inner
    }

    /// Transmit on the backend, drawing the duplicate coin at actual
    /// transmission time.
    fn transmit(&mut self, src: u32, dst: u32, frame: &[u8]) -> Result<()> {
        self.inner.send(src, dst, frame)?;
        if self.cfg.dup_rate > 0.0 && self.rng.chance(self.cfg.dup_rate)
        {
            self.duplicated += 1;
            self.inner.send(src, dst, frame)?;
        }
        Ok(())
    }

    /// Release a held (reordered) frame, if any.
    fn flush_held(&mut self) -> Result<()> {
        if let Some((src, dst, frame)) = self.held.take() {
            self.transmit(src, dst, &frame)?;
        }
        Ok(())
    }
}

impl<T: Transport> Transport for LossyTransport<T> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn now_ms(&self) -> f64 {
        self.inner.now_ms()
    }

    fn send(&mut self, src: u32, dst: u32, frame: &[u8]) -> Result<()> {
        if src == dst || dst as usize >= self.inner.n() {
            // Delegate the error path so diagnostics stay uniform.
            return self.inner.send(src, dst, frame);
        }
        self.sent += 1;
        // The coins are drawn in a fixed order (drop, then reorder,
        // then — at actual transmission — duplicate), each only when
        // its rate is non-zero, so the fault pattern is a pure
        // function of (seed, send/recv call sequence).
        if self.cfg.drop_rate > 0.0 && self.rng.chance(self.cfg.drop_rate)
        {
            self.dropped += 1;
            if let Some(v) = &self.obs_drop {
                v.incr(src as usize, 1);
            }
            return Ok(());
        }
        if self.cfg.reorder_rate > 0.0
            && self.held.is_none()
            && self.rng.chance(self.cfg.reorder_rate)
        {
            // Hold this frame back; it goes out right after the next
            // transmitted frame, swapping their wire order.
            self.held = Some((src, dst, frame.to_vec()));
            self.reordered += 1;
            return Ok(());
        }
        self.transmit(src, dst, frame)?;
        self.flush_held()
    }

    fn recv(&mut self, dst: u32, timeout_ms: f64) -> Option<Delivery> {
        // A held frame must not outlive its collection phase: release
        // it before the receiver starts draining.
        if self.flush_held().is_err() {
            return None;
        }
        self.inner.recv(dst, timeout_ms)
    }

    fn set_latency(&mut self, w: &LatencyMatrix) -> Result<()> {
        self.inner.set_latency(w)
    }

    fn addr(&self, node: u32) -> String {
        self.inner.addr(node)
    }

    fn frames_sent(&self) -> u64 {
        self.sent
    }

    fn name(&self) -> &'static str {
        "lossy"
    }

    fn loss_hint(&self) -> f64 {
        // Duplication also perturbs barrier accounting, so any active
        // fault model opts the protocol into deadline-based write-off.
        if self.cfg.active() {
            self.cfg.drop_rate.max(0.01)
        } else {
            0.0
        }
    }

    fn attach_obs(&mut self, obs: &crate::obs::Obs) {
        // Per-sender drop accounting on the decorator, everything
        // else (tx/rx vectors, dial spans) on the wrapped backend.
        let n = self.inner.n();
        self.obs_drop =
            Some(obs.reg.counter_vec("net.peer.injected_drops", n));
        self.inner.attach_obs(obs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::SimTransport;

    fn w4() -> LatencyMatrix {
        LatencyMatrix::from_fn(4, |u, v| 5.0 + (u + v) as f32)
    }

    #[test]
    fn zero_rates_are_transparent() {
        let mut t = LossyTransport::new(
            SimTransport::new(w4()),
            LossyConfig {
                drop_rate: 0.0,
                dup_rate: 0.0,
                reorder_rate: 0.0,
                seed: 1,
            },
        );
        for _ in 0..16 {
            t.send(0, 1, b"x").unwrap();
        }
        assert_eq!(t.frames_sent(), 16);
        assert_eq!(t.frames_dropped(), 0);
        assert_eq!(t.frames_duplicated(), 0);
        let mut got = 0;
        while t.recv(1, 50.0).is_some() {
            got += 1;
        }
        assert_eq!(got, 16);
        assert_eq!(t.loss_hint(), 0.0);
    }

    #[test]
    fn drops_are_seed_deterministic() {
        let run = |seed: u64| -> (u64, Vec<bool>) {
            let mut t = LossyTransport::new(
                SimTransport::new(w4()),
                LossyConfig::drops(0.3, seed),
            );
            let mut pattern = Vec::new();
            for _ in 0..64 {
                let before = t.inner().frames_sent();
                t.send(0, 1, b"p").unwrap();
                pattern.push(t.inner().frames_sent() == before);
            }
            (t.frames_dropped(), pattern)
        };
        let (d1, p1) = run(7);
        let (d2, p2) = run(7);
        let (d3, p3) = run(8);
        assert_eq!(d1, d2);
        assert_eq!(p1, p2, "same seed must drop the same frames");
        assert!(d1 > 0, "0.3 over 64 sends must drop something");
        assert!(p1 != p3 || d1 != d3, "different seed, different fate");
    }

    #[test]
    fn duplicates_deliver_twice() {
        let mut t = LossyTransport::new(
            SimTransport::new(w4()),
            LossyConfig {
                drop_rate: 0.0,
                dup_rate: 1.0,
                reorder_rate: 0.0,
                seed: 3,
            },
        );
        t.send(0, 1, b"d").unwrap();
        assert_eq!(t.frames_sent(), 1, "logical count ignores the dup");
        assert_eq!(t.frames_duplicated(), 1);
        assert!(t.recv(1, 50.0).is_some());
        assert!(t.recv(1, 50.0).is_some(), "duplicate must also land");
        assert!(t.recv(1, 50.0).is_none());
        assert!(t.loss_hint() > 0.0);
    }

    #[test]
    fn reorder_swaps_consecutive_frames() {
        let mut t = LossyTransport::new(
            SimTransport::new(w4()),
            LossyConfig {
                drop_rate: 0.0,
                dup_rate: 0.0,
                reorder_rate: 1.0,
                seed: 5,
            },
        );
        t.send(0, 1, b"a").unwrap(); // held back
        t.send(0, 1, b"b").unwrap(); // transmitted, then "a" released
        // Same link, same delay: sim delivery follows inner send
        // order, so the wire order is swapped.
        let first = t.recv(1, 50.0).expect("first delivery");
        let second = t.recv(1, 50.0).expect("second delivery");
        assert_eq!(first.frame, b"b");
        assert_eq!(second.frame, b"a");
        assert_eq!(t.frames_reordered(), 1);
        // A held frame with no follow-up send flushes on receive.
        t.send(0, 2, b"tail").unwrap(); // held
        assert_eq!(t.frames_reordered(), 2);
        let d = t.recv(2, 50.0).expect("flushed on receive");
        assert_eq!(d.frame, b"tail");
    }
}
