//! Figures 14 (synthetic) and 18 (FABRIC/Bitnode): parallel DGRO — the
//! diameter of the K-ring overlay when each ring is built with
//! Algorithm 4 over M partitions, M = 1 (sequential) .. 2^9. The paper's
//! claim: partitioned construction matches the sequential diameter up to
//! ~32 partitions. Also reports construction wall-clock and the
//! sequential-step count N/M (the architectural speedup; this image has
//! one core, so wall-clock parallelism is not the claim under test —
//! DESIGN.md §3).

use anyhow::Result;

use crate::dgro::parallel::{parallel_ring, ParallelConfig};
use crate::graph::{diameter, Graph};
use crate::latency::Model;
use crate::metrics::Table;
use crate::topology::kring::KRing;
use crate::topology::{paper_k, random_ring};
use crate::util::rng::Rng;

use super::runner::SweepConfig;

/// Partition counts swept (paper: strides 2^1..2^9).
fn partition_counts(n: usize, quick: bool) -> Vec<usize> {
    let max_m = if quick { 32 } else { 512 };
    (0..=9)
        .map(|e| 1usize << e)
        .filter(|&m| m <= max_m && m <= n / 2)
        .collect()
}

/// Build the K-ring overlay with every ring constructed via M-partition
/// parallel DGRO (greedy scorer — the at-scale backend, §V).
fn build_parallel_kring(
    w: &crate::latency::LatencyMatrix,
    m: usize,
    rng: &mut Rng,
) -> Result<Graph> {
    let k = paper_k(w.n());
    let mut rings = Vec::with_capacity(k);
    for _ in 0..k {
        let base = random_ring(w.n(), rng);
        let ring = parallel_ring(w, &base, ParallelConfig::new(m), |_| {
            Box::new(crate::dgro::construct::GreedyScorer)
        })?;
        rings.push(ring);
    }
    Ok(KRing::new(rings).to_graph(w))
}

fn run_model(title: &str, model: Model, cfg: &SweepConfig) -> Result<Table> {
    // One representative size per the paper's parallel plots.
    let n = if cfg.quick { 128 } else { 512 };
    let ms = partition_counts(n, cfg.quick);
    let mut header = vec!["partitions".to_string(),
                          "diameter".to_string(),
                          "seq_steps_per_worker".to_string(),
                          "build_ms".to_string()];
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(title, &header_refs);
    header.clear();

    for &m in &ms {
        let mut dsum = 0.0f64;
        let mut tsum = 0.0f64;
        for run in 0..cfg.runs {
            let mut rng = Rng::new(cfg.seed ^ (m as u64) << 32 ^ run as u64);
            let w = model.sample(n, &mut rng);
            let t0 = std::time::Instant::now();
            let g = build_parallel_kring(&w, m, &mut rng)?;
            tsum += t0.elapsed().as_secs_f64() * 1e3;
            dsum += diameter::diameter(&g) as f64;
        }
        table.row(vec![
            m as f64,
            dsum / cfg.runs as f64,
            (n as f64 / m as f64).ceil(),
            tsum / cfg.runs as f64,
        ]);
    }
    Ok(table)
}

/// The synthetic-model instance (figure 14).
pub fn run_synthetic(cfg: &SweepConfig) -> Result<Vec<Table>> {
    Ok(vec![
        run_model(
            "Fig 14a: parallel DGRO partitions, uniform latency",
            Model::Uniform,
            cfg,
        )?,
        run_model(
            "Fig 14b: parallel DGRO partitions, gaussian latency",
            Model::Gaussian,
            cfg,
        )?,
    ])
}

/// The FABRIC/Bitnode instance (figure 18).
pub fn run_realistic(cfg: &SweepConfig) -> Result<Vec<Table>> {
    Ok(vec![
        run_model(
            "Fig 18a: parallel DGRO partitions, FABRIC latency",
            Model::Fabric,
            cfg,
        )?,
        run_model(
            "Fig 18b: parallel DGRO partitions, Bitnode latency",
            Model::Bitnode,
            cfg,
        )?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_sweep_diameter_stable() {
        let cfg = SweepConfig {
            sizes: vec![],
            runs: 1,
            seed: 4,
            quick: true,
        };
        let tables = run_synthetic(&cfg).unwrap();
        let t = &tables[0];
        assert!(t.rows.len() >= 4);
        // The paper's claim: partitioned construction stays in the same
        // diameter ballpark as sequential. The quick config runs once at
        // small N where absolute diameters are ~4 hops, so allow one
        // hop-latency of slack on top of a 1.6x band; the full-mode
        // sweep (EXPERIMENTS.md) measures the real curves.
        let d_seq = t.rows[0][1];
        for row in &t.rows {
            assert!(
                row[1] <= d_seq * 1.6 + 4.0,
                "M={} diameter {} vs sequential {}",
                row[0],
                row[1],
                d_seq
            );
        }
    }
}
