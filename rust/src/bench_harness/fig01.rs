//! Figure 1 (teaser): "DGRO has low diameter" — state-of-the-art
//! overlays vs DGRO's adaptive K-ring across network sizes. The paper
//! shows SOTA diameters up to ~3x DGRO's.

use anyhow::Result;

use crate::latency::Model;
use crate::metrics::Table;
use crate::topology::{chord::Chord, perigee, rapid::Rapid, random_ring};

use super::fig_baselines::dgro_adaptive;
use super::runner::{sweep_diameters, Method, SweepConfig};

/// Regenerate the figure: diameter vs network size for the base-ring comparison.
pub fn run(cfg: &SweepConfig) -> Result<Vec<Table>> {
    let methods = vec![
        Method::new("chord", |w, rng| {
            Chord::build(w.n(), rng).to_graph(w)
        }),
        Method::new("rapid", |w, rng| {
            Rapid::build(w.n(), rng).to_graph(w)
        }),
        Method::new("perigee", |w, rng| {
            let pg =
                perigee::build(w, perigee::PerigeeConfig::default(), rng);
            pg.union(&random_ring(w.n(), rng).to_graph(w))
        }),
        Method::new("dgro", |w, rng| dgro_adaptive(w, rng)),
    ];
    Ok(vec![sweep_diameters(
        "Fig 1: SOTA membership overlays vs DGRO (FABRIC latency)",
        Model::Fabric,
        &methods,
        cfg,
    )?])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dgro_wins_the_teaser_at_small_scale() {
        let cfg = SweepConfig {
            sizes: vec![68],
            runs: 2,
            seed: 1,
            quick: true,
        };
        let t = &run(&cfg).unwrap()[0];
        let row = &t.rows[0];
        let (chord, rapid, dgro) = (row[1], row[2], row[4]);
        assert!(
            dgro <= chord.min(rapid) * 1.05,
            "dgro {dgro} should beat chord {chord} / rapid {rapid}"
        );
    }
}
