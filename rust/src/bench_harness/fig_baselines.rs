//! Figures 13 (synthetic) and 17 (FABRIC/Bitnode): DGRO's K-ring against
//! the baseline family. The DGRO line is the ρ-adaptive mix (§V) — the
//! paper's own scaling argument: beyond ~200 nodes the Q-net hands off
//! to adaptive heuristic selection (DESIGN.md §5 "scale policy").

use anyhow::Result;

use crate::graph::Graph;
use crate::latency::{LatencyMatrix, Model};
use crate::metrics::Table;
use crate::topology::kring::hybrid_krings;
use crate::topology::{
    chord::Chord, paper_k, perigee, rapid::Rapid, random_ring,
};
use crate::util::rng::Rng;

use super::runner::{sweep_diameters, Method, SweepConfig};

/// The DGRO line: the §V adaptive loop ([`crate::dgro::select::adaptive_krings`]).
pub fn dgro_adaptive(w: &LatencyMatrix, rng: &mut Rng) -> Graph {
    crate::dgro::select::adaptive_krings(w, paper_k(w.n()), rng).to_graph(w)
}

fn methods() -> Vec<Method> {
    vec![
        Method::new("chord", |w, rng| {
            Chord::build(w.n(), rng).to_graph(w)
        }),
        Method::new("rapid", |w, rng| {
            Rapid::build(w.n(), rng).to_graph(w)
        }),
        Method::new("perigee_rand_ring", |w, rng| {
            let pg =
                perigee::build(w, perigee::PerigeeConfig::default(), rng);
            pg.union(&random_ring(w.n(), rng).to_graph(w))
        }),
        Method::new("shortest_kring", |w, rng| {
            hybrid_krings(w, paper_k(w.n()), 0, rng).to_graph(w)
        }),
        Method::new("hybrid_half", |w, rng| {
            let k = paper_k(w.n());
            hybrid_krings(w, k, k / 2, rng).to_graph(w)
        }),
        Method::new("dgro", |w, rng| dgro_adaptive(w, rng)),
    ]
}

/// The synthetic-model instance (figure 13).
pub fn run_synthetic(cfg: &SweepConfig) -> Result<Vec<Table>> {
    Ok(vec![
        sweep_diameters(
            "Fig 13a: DGRO vs baselines, uniform latency",
            Model::Uniform,
            &methods(),
            cfg,
        )?,
        sweep_diameters(
            "Fig 13b: DGRO vs baselines, gaussian latency",
            Model::Gaussian,
            &methods(),
            cfg,
        )?,
    ])
}

/// The FABRIC/Bitnode instance (figure 17).
pub fn run_realistic(cfg: &SweepConfig) -> Result<Vec<Table>> {
    Ok(vec![
        sweep_diameters(
            "Fig 17a: DGRO vs baselines, FABRIC latency",
            Model::Fabric,
            &methods(),
            cfg,
        )?,
        sweep_diameters(
            "Fig 17b: DGRO vs baselines, Bitnode latency",
            Model::Bitnode,
            &methods(),
            cfg,
        )?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dgro_adaptive_connected_and_competitive() {
        let mut rng = Rng::new(11);
        let w = Model::Fabric.sample(85, &mut rng);
        let g = dgro_adaptive(&w, &mut rng);
        assert!(crate::graph::components::is_connected(&g));
        let d_dgro = crate::graph::diameter::diameter(&g);
        let d_rapid = crate::graph::diameter::diameter(
            &Rapid::build(85, &mut rng).to_graph(&w),
        );
        assert!(
            d_dgro <= d_rapid * 1.1,
            "dgro {d_dgro} vs rapid {d_rapid}"
        );
    }

    #[test]
    fn baseline_table_shape() {
        let cfg = SweepConfig {
            sizes: vec![40],
            runs: 1,
            seed: 2,
            quick: true,
        };
        let tables = run_synthetic(&cfg).unwrap();
        assert_eq!(tables[0].header.len(), 7); // n + 6 methods
    }
}
