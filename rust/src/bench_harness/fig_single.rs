//! Figures 11 (synthetic) and 15 (FABRIC/Bitnode): single-heuristic
//! rings. Solid lines = each protocol with its native (random) ring;
//! dashed = the ring DGRO's ρ rule selects. The paper's claims: DGRO
//! moves Chord/RAPID to the shortest ring (big win on clustered
//! latencies), and keeps/moves Perigee to the *random* ring (the NN-only
//! topology blows up with size).

use anyhow::Result;

use crate::dgro::select::{decide, RingChoice, SelectConfig};
use crate::gossip::measure::{measure, MeasureConfig};
use crate::graph::Graph;
use crate::latency::{LatencyMatrix, Model};
use crate::metrics::Table;
use crate::topology::{
    chord::Chord, perigee, rapid::Rapid, random_ring, shortest_ring,
};
use crate::util::rng::Rng;

use super::runner::{sweep_diameters, Method, SweepConfig};

/// Apply the ρ rule to a built overlay and return the repaired overlay.
/// `swap` materializes the decision for the given protocol.
fn dgro_repair(
    w: &LatencyMatrix,
    g: Graph,
    rng: &mut Rng,
    swap: impl FnOnce(&LatencyMatrix, RingChoice, &mut Rng) -> Graph,
) -> Graph {
    let stats = measure(w, &g, MeasureConfig::default(), rng);
    let choice = decide(&stats, SelectConfig::default());
    match choice {
        RingChoice::Keep => g,
        c => swap(w, c, rng),
    }
}

fn chord_method(dgro: bool) -> Method {
    Method::new(
        if dgro { "chord_dgro" } else { "chord" },
        move |w, rng| {
            let c = Chord::build(w.n(), rng);
            let g = c.to_graph(w);
            if !dgro {
                return g;
            }
            dgro_repair(w, g, rng, |w, choice, rng| {
                let base = match choice {
                    RingChoice::Shortest => shortest_ring(w, 0),
                    _ => random_ring(w.n(), rng),
                };
                c.with_base_ring(base).to_graph(w)
            })
        },
    )
}

fn rapid_method(dgro: bool) -> Method {
    Method::new(
        if dgro { "rapid_dgro" } else { "rapid" },
        move |w, rng| {
            let r = Rapid::build(w.n(), rng);
            let g = r.to_graph(w);
            if !dgro {
                return g;
            }
            dgro_repair(w, g, rng, |w, choice, rng| match choice {
                RingChoice::Shortest => {
                    r.with_shortest_rings(w, 1).to_graph(w)
                }
                _ => Rapid::build(w.n(), rng).to_graph(w),
            })
        },
    )
}

fn perigee_method(dgro: bool) -> Method {
    Method::new(
        if dgro { "perigee_dgro" } else { "perigee" },
        move |w, rng| {
            let pg = perigee::build(w, perigee::PerigeeConfig::default(), rng);
            // Paper: "Perigee is combined with a ring otherwise no
            // connectivity guarantee." Default companion: shortest ring
            // (the latency-greedy choice a NN protocol would make).
            let nn = shortest_ring(w, 0).to_graph(w);
            let g = pg.union(&nn);
            if !dgro {
                return g;
            }
            dgro_repair(w, g, rng, |w, choice, rng| {
                // ρ ≈ 0 for NN-heavy overlays -> DGRO swaps the
                // companion to a random ring.
                let companion = match choice {
                    RingChoice::Random => {
                        random_ring(w.n(), rng).to_graph(w)
                    }
                    _ => shortest_ring(w, 0).to_graph(w),
                };
                pg.union(&companion)
            })
        },
    )
}

fn methods() -> Vec<Method> {
    vec![
        chord_method(false),
        chord_method(true),
        perigee_method(false),
        perigee_method(true),
        rapid_method(false),
        rapid_method(true),
    ]
}

/// The synthetic-model instance (figure 11).
pub fn run_synthetic(cfg: &SweepConfig) -> Result<Vec<Table>> {
    Ok(vec![
        sweep_diameters(
            "Fig 11a: single-heuristic rings, uniform latency",
            Model::Uniform,
            &methods(),
            cfg,
        )?,
        sweep_diameters(
            "Fig 11b: single-heuristic rings, gaussian latency",
            Model::Gaussian,
            &methods(),
            cfg,
        )?,
    ])
}

/// The FABRIC/Bitnode instance (figure 15).
pub fn run_realistic(cfg: &SweepConfig) -> Result<Vec<Table>> {
    Ok(vec![
        sweep_diameters(
            "Fig 15a: single-heuristic rings, FABRIC latency",
            Model::Fabric,
            &methods(),
            cfg,
        )?,
        sweep_diameters(
            "Fig 15b: single-heuristic rings, Bitnode latency",
            Model::Bitnode,
            &methods(),
            cfg,
        )?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_has_expected_shape() {
        let cfg = SweepConfig {
            sizes: vec![40],
            runs: 1,
            seed: 3,
            quick: true,
        };
        let tables = run_realistic(&cfg).unwrap();
        assert_eq!(tables.len(), 2);
        let t = &tables[0];
        assert_eq!(t.header.len(), 7);
        assert_eq!(t.rows.len(), 1);
        // On FABRIC, DGRO-repaired Chord must not be worse than Chord.
        let row = &t.rows[0];
        assert!(
            row[2] <= row[1] * 1.2,
            "chord_dgro {} vs chord {}",
            row[2],
            row[1]
        );
    }
}
