//! Beyond-paper figure (id 19): DGRO vs baselines across the scenario
//! catalog — mean alive-overlay diameter under churn + dynamic latency,
//! plus one per-scenario timeline table. `dgro scenario compare` prints
//! the same tables interactively; this entry wires them into the figure
//! pipeline so `dgro figures --all` / `cargo bench --bench figures`
//! regenerate the CSVs under reports/.

use anyhow::Result;

use crate::metrics::Table;
use crate::scenario::compare::compare;
use crate::scenario::engine::Topology;
use crate::scenario::spec::catalog;

/// Seed shared with the sweep harness so every figure ships from one
/// reproducibility key.
pub const SCENARIO_SEED: u64 = 20240711;

/// Regenerate the scenario-catalog comparison ("figure 19").
pub fn run_opts(opts: crate::bench_harness::FigureOpts) -> Result<Vec<Table>> {
    // Quick mode trims the baseline panel (Perigee and the random
    // K-ring are the slowest builders), not the catalog — every scenario
    // stays covered in CI. Threads fan the per-scenario topology runs
    // out; the tables are identical at any thread count.
    let topologies: &[Topology] = if opts.quick {
        &[Topology::Dgro, Topology::Chord, Topology::Rapid]
    } else {
        &Topology::ALL
    };
    let rep = compare(
        &catalog(),
        topologies,
        SCENARIO_SEED,
        crate::scenario::compare::DEFAULT_PERIOD_MS,
        opts.resolve_threads(),
    )?;
    let mut tables = vec![rep.summary];
    tables.extend(rep.timelines);
    Ok(tables)
}
