//! Figure 6: "DGRO helps RAPID reduce diameters" — the K-random-ring
//! expander with one ring swapped to the shortest ring (up to 43-44%
//! reduction in the paper).

use anyhow::Result;

use crate::latency::Model;
use crate::metrics::Table;
use crate::topology::rapid::Rapid;

use super::runner::{sweep_diameters, Method, SweepConfig};

fn methods() -> Vec<Method> {
    vec![
        Method::new("rapid_all_random", |w, rng| {
            Rapid::build(w.n(), rng).to_graph(w)
        }),
        Method::new("rapid_one_shortest", |w, rng| {
            Rapid::build(w.n(), rng)
                .with_shortest_rings(w, 1)
                .to_graph(w)
        }),
    ]
}

/// Regenerate the figure under the given sweep configuration.
pub fn run(cfg: &SweepConfig) -> Result<Vec<Table>> {
    Ok(vec![
        sweep_diameters(
            "Fig 6a: RAPID one-shortest swap, uniform latency",
            Model::Uniform,
            &methods(),
            cfg,
        )?,
        sweep_diameters(
            "Fig 6b: RAPID one-shortest swap, FABRIC latency",
            Model::Fabric,
            &methods(),
            cfg,
        )?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_shortest_ring_helps_rapid_on_fabric() {
        let cfg = SweepConfig {
            sizes: vec![85],
            runs: 3,
            seed: 13,
            quick: true,
        };
        let t = &run(&cfg).unwrap()[1];
        let row = &t.rows[0];
        assert!(
            row[2] <= row[1],
            "rapid+shortest {} !<= rapid {}",
            row[2],
            row[1]
        );
    }
}
