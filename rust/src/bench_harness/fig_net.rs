//! "Figure 21" (beyond the paper): trace-replay parity across
//! transports. One scenario spec, one seed, replayed twice through the
//! message-level [`NetCoordinator`](crate::net::NetCoordinator) — once
//! over the discrete-event [`SimTransport`](crate::net::SimTransport)
//! (exact RTTs) and once over [`UdpTransport`](crate::net::UdpTransport)
//! loopback (real sockets, shim-shaped delays, real scheduler jitter).
//! The table tracks the per-period alive diameter side by side; the
//! paper's deployment claim is that ρ-guided adaptation survives a real
//! network stack, so `abs_diff` staying inside the tolerance pinned by
//! rust/tests/net.rs is the headline.

use anyhow::Result;

use crate::metrics::Table;
use crate::net::TransportKind;
use crate::scenario::{
    ChurnSpec, ScenarioEngine, ScenarioReport, ScenarioSpec, Topology,
};

use super::FigureOpts;

/// The replayed workload: fabric latencies + background churn, sized so
/// the UDP replay stays in CI budgets.
fn parity_spec(n: usize, horizon: f64) -> ScenarioSpec {
    ScenarioSpec {
        name: "net-parity".into(),
        about: "transport parity replay for fig 21".into(),
        nodes: n,
        initial_alive: n,
        model: "fabric".into(),
        horizon,
        churn: vec![ChurnSpec::Poisson { rate: 0.001 }],
        latency: vec![],
    }
}

/// Regenerate the transport-parity table.
pub fn run_opts(opts: FigureOpts) -> Result<Vec<Table>> {
    let n = if opts.quick { 24 } else { 48 };
    let horizon = if opts.quick { 1000.0 } else { 2000.0 };
    let spec = parity_spec(n, horizon);
    let run = |kind: TransportKind| -> Result<ScenarioReport> {
        let mut engine = ScenarioEngine::new(spec.clone(), 0)?;
        engine.transport = Some(kind);
        engine.run(Topology::Dgro)
    };
    let sim = run(TransportKind::Sim)?;
    let udp = run(TransportKind::Udp)?;
    let mut table = Table::new(
        "Fig 21: transport parity sim vs udp (fabric)",
        &[
            "t_ms",
            "alive",
            "diameter_sim",
            "diameter_udp",
            "abs_diff",
            "rho_sim",
            "rho_udp",
            "swaps_sim",
            "swaps_udp",
        ],
    );
    for (a, b) in sim.rows.iter().zip(&udp.rows) {
        table.row(vec![
            a.t,
            a.alive as f64,
            a.diameter,
            b.diameter,
            (a.diameter - b.diameter).abs(),
            a.rho,
            b.rho,
            a.swaps as f64,
            b.swaps as f64,
        ]);
    }
    Ok(vec![table])
}
