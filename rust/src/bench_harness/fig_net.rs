//! "Figure 21" (beyond the paper): trace-replay parity across
//! transports, plus the loss sweep. One scenario spec, one seed,
//! replayed through the message-level
//! [`NetCoordinator`](crate::net::NetCoordinator) — over the
//! discrete-event [`SimTransport`](crate::net::SimTransport) (exact
//! RTTs), [`UdpTransport`](crate::net::UdpTransport) loopback and
//! [`TcpTransport`](crate::net::TcpTransport) streams (real sockets,
//! shim-shaped delays, real scheduler jitter). The parity table tracks
//! the per-period alive diameter side by side; the paper's deployment
//! claim is that ρ-guided adaptation survives a real network stack, so
//! `abs_diff_*` staying inside the tolerance pinned by
//! rust/tests/net.rs is the headline.
//!
//! The second table sweeps injected frame loss
//! ([`LossyTransport`](crate::net::LossyTransport) over the sim
//! backend, so the sweep is byte-deterministic): mean/final alive
//! diameter, drift vs the lossless replay, and the loss-protocol
//! counters (frames written off, probe retransmissions, stale frames
//! rejected at the epoch boundary).

use anyhow::Result;

use crate::metrics::Table;
use crate::net::TransportKind;
use crate::scenario::{
    ChurnSpec, ScenarioEngine, ScenarioReport, ScenarioSpec, Topology,
};

use super::FigureOpts;

/// Injected drop rates of the loss-sweep table (row 0 is the lossless
/// reference).
pub const LOSS_SWEEP: [f64; 4] = [0.0, 0.02, 0.05, 0.10];

/// The replayed workload: fabric latencies + background churn, sized so
/// the real-socket replays stay in CI budgets.
fn parity_spec(n: usize, horizon: f64) -> ScenarioSpec {
    ScenarioSpec {
        name: "net-parity".into(),
        about: "transport parity replay for fig 21".into(),
        nodes: n,
        initial_alive: n,
        model: "fabric".into(),
        horizon,
        churn: vec![ChurnSpec::Poisson { rate: 0.001 }],
        latency: vec![],
    }
}

/// Regenerate the transport-parity and loss-sweep tables.
pub fn run_opts(opts: FigureOpts) -> Result<Vec<Table>> {
    let n = if opts.quick { 24 } else { 48 };
    let horizon = if opts.quick { 1000.0 } else { 2000.0 };
    let spec = parity_spec(n, horizon);
    let run = |kind: TransportKind, loss: f64| -> Result<ScenarioReport> {
        let mut engine = ScenarioEngine::new(spec.clone(), 0)?;
        engine.opts.transport = Some(kind);
        engine.opts.loss_rate = loss;
        // Compress wall time harder than the interactive default so
        // three real-socket replays plus the sweep fit CI budgets.
        engine.opts.time_scale = 0.02;
        engine.run(Topology::Dgro)
    };

    // --- Parity table: sim vs udp vs tcp at 0% loss. -------------------
    let sim = run(TransportKind::Sim, 0.0)?;
    let udp = run(TransportKind::Udp, 0.0)?;
    let tcp = run(TransportKind::Tcp, 0.0)?;
    let mut parity = Table::new(
        "Fig 21: transport parity sim vs udp vs tcp (fabric)",
        &[
            "t_ms",
            "alive",
            "diameter_sim",
            "diameter_udp",
            "diameter_tcp",
            "abs_diff_udp",
            "abs_diff_tcp",
            "rho_sim",
            "swaps_sim",
        ],
    );
    for ((a, b), c) in sim.rows.iter().zip(&udp.rows).zip(&tcp.rows) {
        parity.row(vec![
            a.t,
            a.alive as f64,
            a.diameter,
            b.diameter,
            c.diameter,
            (a.diameter - b.diameter).abs(),
            (a.diameter - c.diameter).abs(),
            a.rho,
            a.swaps as f64,
        ]);
    }

    // --- Loss sweep: seeded drops over the sim backend. ----------------
    let mut sweep = Table::new(
        "Fig 21b: diameter drift under injected frame loss (sim)",
        &[
            "loss_rate",
            "mean_diameter",
            "final_diameter",
            "mean_abs_drift",
            "swaps",
            "frames_lost",
            "probe_retx",
            "stale_frames",
        ],
    );
    let baseline = &sim;
    for &loss in &LOSS_SWEEP {
        let rep = if loss == 0.0 {
            sim.clone()
        } else {
            run(TransportKind::Sim, loss)?
        };
        let mut drift = 0.0;
        for (a, b) in baseline.rows.iter().zip(&rep.rows) {
            drift += (a.diameter - b.diameter).abs();
        }
        drift /= baseline.rows.len().max(1) as f64;
        sweep.row(vec![
            loss,
            rep.mean_diameter(),
            rep.final_diameter(),
            drift,
            rep.total_swaps() as f64,
            rep.metrics.counter("net.frames_lost") as f64,
            rep.metrics.counter("net.probe_retx") as f64,
            rep.metrics.counter("net.stale_frames") as f64,
        ]);
    }
    Ok(vec![parity, sweep])
}
