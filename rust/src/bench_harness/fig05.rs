//! Figure 5: "DGRO helps Chord reduce diameters" — Chord with its
//! hash-random identifier ring vs the same finger structure over the
//! shortest ring (10-40% reduction in the paper).

use anyhow::Result;

use crate::latency::Model;
use crate::metrics::Table;
use crate::topology::{chord::Chord, shortest_ring};

use super::runner::{sweep_diameters, Method, SweepConfig};

fn methods() -> Vec<Method> {
    vec![
        Method::new("chord_random_ring", |w, rng| {
            Chord::build(w.n(), rng).to_graph(w)
        }),
        Method::new("chord_shortest_ring", |w, rng| {
            let c = Chord::build(w.n(), rng);
            c.with_base_ring(shortest_ring(w, 0)).to_graph(w)
        }),
    ]
}

/// Regenerate the figure under the given sweep configuration.
pub fn run(cfg: &SweepConfig) -> Result<Vec<Table>> {
    Ok(vec![
        sweep_diameters(
            "Fig 5a: Chord base-ring swap, uniform latency",
            Model::Uniform,
            &methods(),
            cfg,
        )?,
        sweep_diameters(
            "Fig 5b: Chord base-ring swap, FABRIC latency",
            Model::Fabric,
            &methods(),
            cfg,
        )?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shortest_base_ring_helps_chord_on_fabric() {
        let cfg = SweepConfig {
            sizes: vec![85],
            runs: 3,
            seed: 9,
            quick: true,
        };
        let t = &run(&cfg).unwrap()[1]; // FABRIC table
        let row = &t.rows[0];
        assert!(
            row[2] < row[1],
            "chord+shortest {} !< chord {}",
            row[2],
            row[1]
        );
    }
}
