//! Figures 12 (synthetic) and 16 (FABRIC/Bitnode): ablation on the ring
//! mix — RAPID's K rings with M random + (K−M) shortest, M swept as a
//! fraction of K (K = log2 N varies with N, so columns are mix
//! fractions). The paper's finding: no single M wins everywhere — under
//! uniform latency all-shortest *blows up* near N=1000, under Gaussian
//! more shortest monotonically helps — which is exactly why the adaptive
//! ρ rule exists.

use anyhow::Result;

use crate::latency::Model;
use crate::metrics::Table;
use crate::topology::kring::hybrid_krings;

use super::runner::{sweep_diameters, Method, SweepConfig};

/// Mix fractions swept (share of *random* rings among K).
const FRACTIONS: [(f64, &str); 5] = [
    (0.0, "random0of_k"),
    (0.25, "random1q_of_k"),
    (0.5, "random2q_of_k"),
    (0.75, "random3q_of_k"),
    (1.0, "random_all_k"),
];

fn methods() -> Vec<Method> {
    FRACTIONS
        .iter()
        .map(|&(frac, name)| {
            Method::new(name, move |w, rng| {
                let k = crate::topology::paper_k(w.n());
                let m = ((k as f64) * frac).round() as usize;
                hybrid_krings(w, k, m.min(k), rng).to_graph(w)
            })
        })
        .collect()
}

/// The synthetic-model instance (figure 12).
pub fn run_synthetic(cfg: &SweepConfig) -> Result<Vec<Table>> {
    Ok(vec![
        sweep_diameters(
            "Fig 12a: M random of K rings, uniform latency",
            Model::Uniform,
            &methods(),
            cfg,
        )?,
        sweep_diameters(
            "Fig 12b: M random of K rings, gaussian latency",
            Model::Gaussian,
            &methods(),
            cfg,
        )?,
    ])
}

/// The FABRIC/Bitnode instance (figure 16).
pub fn run_realistic(cfg: &SweepConfig) -> Result<Vec<Table>> {
    Ok(vec![
        sweep_diameters(
            "Fig 16a: M random of K rings, FABRIC latency",
            Model::Fabric,
            &methods(),
            cfg,
        )?,
        sweep_diameters(
            "Fig 16b: M random of K rings, Bitnode latency",
            Model::Bitnode,
            &methods(),
            cfg,
        )?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_columns_cover_mixes() {
        let cfg = SweepConfig {
            sizes: vec![32],
            runs: 1,
            seed: 5,
            quick: true,
        };
        let tables = run_synthetic(&cfg).unwrap();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].header.len(), 6); // n + 5 mixes
        for t in &tables {
            for row in &t.rows {
                assert!(row[1..].iter().all(|&d| d > 0.0));
            }
        }
    }
}
