//! Figure-regeneration harness: one module per paper figure (DESIGN.md
//! §5 maps each to its workload). Every `run_*` returns
//! [`crate::metrics::Table`]s whose rows mirror the figure's series;
//! `cargo bench --bench figures` and `dgro figures` drive them and write
//! CSVs under `reports/`.
//!
//! Figures 11/12/13/14 (synthetic) and 15/16/17/18 (FABRIC/Bitnode) are
//! the same experiment over different latency models, so the sweep logic
//! lives in [`runner`] and the figure modules bind the models.

pub mod fig01;
pub mod fig05;
pub mod fig06;
pub mod fig07;
pub mod fig10;
pub mod gate; // CI perf-regression gate over BENCH_hotpath.json
pub mod fig_ablation; // figs 12 & 16
pub mod fig_baselines; // figs 13 & 17
pub mod fig_net; // "fig 21": transport parity (sim vs udp replay)
pub mod fig_parallel; // figs 14 & 18
pub mod fig_scenarios; // "fig 19": beyond-paper scenario catalog
pub mod fig_sharded; // "fig 20": sharded-coordinator partition scaling
pub mod fig_single; // figs 11 & 15
pub mod runner;

use anyhow::Result;

use crate::metrics::Table;

/// Harness-wide knobs threaded from the CLI/bench entry points into the
/// figure modules that can use them.
#[derive(Clone, Copy, Debug)]
pub struct FigureOpts {
    /// Trimmed sizes/runs (CI mode).
    pub quick: bool,
    /// Paper-scale budgets (fig 10's GA runs the full 1e5 evaluations).
    pub full: bool,
    /// Evaluation worker threads; 0 = all cores.
    pub threads: usize,
}

impl FigureOpts {
    /// The historical `(fig, quick)` entry point's options: serial,
    /// default budgets.
    pub fn quick_mode(quick: bool) -> FigureOpts {
        FigureOpts {
            quick,
            full: false,
            threads: 1,
        }
    }

    /// `threads` with 0 resolved to the machine core count.
    pub fn resolve_threads(&self) -> usize {
        if self.threads == 0 {
            crate::graph::eval::EvalPool::default_threads()
        } else {
            self.threads
        }
    }
}

/// Which figures to regenerate (serial, default budgets — the
/// CI/`cargo test` entry point; [`run_figure_opts`] exposes the knobs).
pub fn run_figure(fig: usize, quick: bool) -> Result<Vec<Table>> {
    run_figure_opts(fig, FigureOpts::quick_mode(quick))
}

/// Which figures to regenerate, with explicit harness options.
pub fn run_figure_opts(fig: usize, opts: FigureOpts) -> Result<Vec<Table>> {
    let quick = opts.quick;
    let sweep = runner::SweepConfig::paper(quick);
    match fig {
        1 => fig01::run(&sweep),
        5 => fig05::run(&sweep),
        6 => fig06::run(&sweep),
        7 => fig07::run(&sweep),
        9 => runner::fig09_passthrough(),
        10 => fig10::run_opts(opts),
        11 => fig_single::run_synthetic(&sweep),
        12 => fig_ablation::run_synthetic(&sweep),
        13 => fig_baselines::run_synthetic(&sweep),
        14 => fig_parallel::run_synthetic(&sweep),
        15 => fig_single::run_realistic(&sweep),
        16 => fig_ablation::run_realistic(&sweep),
        17 => fig_baselines::run_realistic(&sweep),
        18 => fig_parallel::run_realistic(&sweep),
        19 => fig_scenarios::run_opts(opts),
        20 => fig_sharded::run_opts(opts),
        21 => fig_net::run_opts(opts),
        other => anyhow::bail!(
            "no figure {other} (valid: 1,5,6,7,9,10,11-18 from the paper, \
             19 = scenario catalog, 20 = sharded partition scaling, \
             21 = transport parity)"
        ),
    }
}

/// All figure ids: paper order, then the beyond-paper scenario catalog
/// (19), the sharded-coordinator partition scaling (20) and the
/// sim-vs-udp transport parity replay (21).
pub const ALL_FIGURES: [usize; 17] =
    [1, 5, 6, 7, 9, 10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21];
