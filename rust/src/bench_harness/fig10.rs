//! Figure 10: DGRO (deep Q-learning) vs Genetic Algorithm vs random —
//! (a) diameter normalized by the random K-ring, (b) construction /
//! search time. Protocol per §VII-B2: DGRO builds 10 K-ring topologies
//! from 10 start nodes and keeps the best; the GA searches `ga_budget`
//! topologies (paper: 1e5; default here scales with mode — override
//! with DGRO_GA_BUDGET, EXPERIMENTS.md records what was run).

use anyhow::Result;
use std::time::Instant;

use crate::dgro::construct::best_of_starts;
use crate::graph::diameter;
use crate::latency::synthetic;
use crate::metrics::Table;
use crate::qnet::native::NativeQnet;
use crate::runtime::ArtifactStore;
use crate::topology::genetic::{self, GaConfig};
use crate::topology::kring::random_krings;
use crate::topology::paper_k;
use crate::util::rng::Rng;

/// GA evaluation budget: `DGRO_GA_BUDGET` overrides; `--full` runs the
/// paper's 1e5 (tractable now that fitness evaluation is batched across
/// the pool); the default mid-size budget keeps un-flagged runs fast.
pub fn ga_budget(quick: bool, full: bool) -> usize {
    if let Ok(v) = std::env::var("DGRO_GA_BUDGET") {
        if let Ok(b) = v.parse() {
            return b;
        }
    }
    if quick {
        400
    } else if full {
        100_000
    } else {
        20_000
    }
}

/// Regenerate the GA-vs-DGRO comparison (`--full` restores the paper budget).
pub fn run_opts(opts: crate::bench_harness::FigureOpts) -> Result<Vec<Table>> {
    let quick = opts.quick;
    let threads = opts.resolve_threads();
    let sizes: Vec<usize> = if quick {
        vec![16, 32]
    } else {
        vec![16, 32, 64, 128, 200]
    };
    let runs = if quick { 1 } else { 3 };
    let starts = 10; // paper: 10 start nodes, keep best
    let budget = ga_budget(quick, opts.full);

    // The Q-net scorer: trained weights when artifacts exist, synthetic
    // otherwise (CI path); the table notes which.
    let (params, trained) =
        match ArtifactStore::discover(ArtifactStore::default_dir())
            .and_then(|s| s.load_params())
        {
            Ok(p) => (p, 1.0),
            Err(_) => (
                crate::qnet::params::QnetParams::synthetic(16, 32, 7),
                0.0,
            ),
        };
    let mut scorer = NativeQnet::new(params);

    let mut table = Table::new(
        &format!(
            "Fig 10: DGRO vs GA-{budget} vs random (normalized diameter; \
             trained_weights={})",
            trained as u8
        ),
        &[
            "n",
            "dgro_norm",
            "ga_norm",
            "random_norm",
            "dgro_ms",
            "ga_ms",
            "ga_evals_per_s",
        ],
    );

    for &n in &sizes {
        let k = paper_k(n).min(3); // small-N regime; K capped like §VII-B
        let mut dgro_sum = 0.0;
        let mut ga_sum = 0.0;
        let mut t_dgro = 0.0;
        let mut t_ga = 0.0;
        for run in 0..runs {
            let mut rng = Rng::new(0xF16_10 ^ (n as u64) << 16 ^ run as u64);
            let w = synthetic::uniform(n, &mut rng);

            // Random K-ring normalizer (mean of 5 draws).
            let mut rand_d = 0.0;
            for _ in 0..5 {
                rand_d += diameter::diameter(
                    &random_krings(n, k, &mut rng).to_graph(&w),
                ) as f64;
            }
            let rand_d = rand_d / 5.0;

            let t0 = Instant::now();
            let (_, _, d_dgro) =
                best_of_starts(&mut scorer, &w, k, starts, &mut rng)?;
            t_dgro += t0.elapsed().as_secs_f64() * 1e3;

            let t1 = Instant::now();
            let ga = genetic::search(
                &w,
                k,
                GaConfig {
                    budget,
                    threads,
                    ..Default::default()
                },
                &mut rng,
            );
            t_ga += t1.elapsed().as_secs_f64() * 1e3;

            dgro_sum += d_dgro as f64 / rand_d;
            ga_sum += ga.best_diameter as f64 / rand_d;
        }
        let ga_ms = t_ga / runs as f64;
        let evals_per_s = budget as f64 / (ga_ms / 1e3).max(1e-9);
        crate::log_info!(
            "fig10 n={n}: GA-{budget} at {evals_per_s:.0} evals/s \
             (threads={threads})"
        );
        table.row(vec![
            n as f64,
            dgro_sum / runs as f64,
            ga_sum / runs as f64,
            1.0,
            t_dgro / runs as f64,
            ga_ms,
            evals_per_s,
        ]);
    }
    Ok(vec![table])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_table_shape_and_normalization() {
        let tables =
            run_opts(crate::bench_harness::FigureOpts::quick_mode(true))
                .unwrap();
        let t = &tables[0];
        assert_eq!(t.rows.len(), 2);
        for row in &t.rows {
            assert_eq!(row[3], 1.0, "random column is the normalizer");
            assert!(row[1] > 0.0 && row[2] > 0.0);
            // Both optimizers should beat the random baseline.
            assert!(row[2] < 1.05, "GA should be under random: {}", row[2]);
            assert!(row[6] > 0.0, "evals/s must be recorded");
        }
    }
}
