//! Shared sweep machinery for the figure harness.
//!
//! Paper protocol (§VII-A3): network sizes [50, 100, ..., 1000], 10
//! independent runs per size with fresh latency draws, diameter via
//! exact APSP. `quick` mode (CI / `cargo test`) trims sizes and runs but
//! keeps every code path.

use anyhow::Result;

use crate::graph::{diameter, Graph};
use crate::latency::{LatencyMatrix, Model};
use crate::metrics::Table;
use crate::util::rng::Rng;

/// Sweep parameters shared by all figures.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Network sizes N swept.
    pub sizes: Vec<usize>,
    /// Independent runs per size (averaged).
    pub runs: usize,
    /// Base RNG seed; each (size, run) forks its own stream.
    pub seed: u64,
    /// Trimmed CI mode (smaller sizes, fewer runs).
    pub quick: bool,
}

impl SweepConfig {
    /// The paper's protocol, or a trimmed version for CI.
    pub fn paper(quick: bool) -> SweepConfig {
        if quick {
            SweepConfig {
                sizes: vec![50, 100, 200],
                runs: 2,
                seed: 20240711,
                quick,
            }
        } else {
            SweepConfig {
                sizes: (1..=10).map(|i| i * 100).collect::<Vec<_>>(),
                runs: 5,
                seed: 20240711,
                quick,
            }
        }
    }

    /// Sizes including the 50-node point the paper starts from.
    pub fn with_small_sizes(mut self) -> SweepConfig {
        if !self.sizes.contains(&50) {
            self.sizes.insert(0, 50);
        }
        self
    }
}

/// A named topology-building method measured by the sweeps: given the
/// latency matrix and a per-run RNG, produce the overlay graph.
pub struct Method {
    /// Series label (becomes the table column).
    pub name: &'static str,
    /// Overlay builder: latency matrix + per-run RNG -> graph.
    pub build: Box<dyn Fn(&LatencyMatrix, &mut Rng) -> Graph + Sync>,
}

impl Method {
    /// Wrap a builder closure with its series label.
    pub fn new(
        name: &'static str,
        build: impl Fn(&LatencyMatrix, &mut Rng) -> Graph + Sync + 'static,
    ) -> Method {
        Method {
            name,
            build: Box::new(build),
        }
    }
}

/// Run a sweep: rows = sizes, columns = [n, method0, method1, ...] with
/// each cell the mean diameter over `runs` fresh latency draws.
pub fn sweep_diameters(
    title: &str,
    model: Model,
    methods: &[Method],
    cfg: &SweepConfig,
) -> Result<Table> {
    let mut header: Vec<String> = vec!["n".to_string()];
    header.extend(methods.iter().map(|m| m.name.to_string()));
    let header_refs: Vec<&str> =
        header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(title, &header_refs);

    for &n in &cfg.sizes {
        let mut sums = vec![0.0f64; methods.len()];
        for run in 0..cfg.runs {
            let mut rng =
                Rng::new(cfg.seed ^ (n as u64) << 20 ^ run as u64);
            let w = model.sample(n, &mut rng);
            for (mi, m) in methods.iter().enumerate() {
                let mut mrng = rng.fork(mi as u64);
                let g = (m.build)(&w, &mut mrng);
                sums[mi] += diameter::diameter(&g) as f64;
            }
        }
        let mut row = vec![n as f64];
        row.extend(sums.iter().map(|s| s / cfg.runs as f64));
        table.row(row);
    }
    Ok(table)
}

/// Fig 9 is produced at build time by the Python trainer; the harness
/// passes the CSV through so `dgro figures --fig 9` behaves uniformly.
pub fn fig09_passthrough() -> Result<Vec<Table>> {
    let path = crate::runtime::ArtifactStore::default_dir()
        .join("training_curve.csv");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        anyhow::anyhow!(
            "fig 9 curve missing ({e}); run `make artifacts` — the DQN \
             trainer writes {path:?}"
        )
    })?;
    let mut table = Table::new(
        "Fig 9: DQN training/test curve (from make artifacts)",
        &["episode", "epsilon", "train_diameter", "test_diameter",
          "td_loss"],
    );
    for line in text.lines().skip(1) {
        let cells: Vec<f64> = line
            .split(',')
            .map(|c| c.parse().unwrap_or(f64::NAN))
            .collect();
        if cells.len() == 5 {
            table.row(cells);
        }
    }
    Ok(vec![table])
}

/// Write tables as CSVs under `reports/` and echo markdown to stdout.
pub fn emit(tables: &[Table], out_dir: &str) -> Result<()> {
    for t in tables {
        let slug: String = t
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c } else { '_' })
            .collect::<String>()
            .to_lowercase();
        let path = format!("{out_dir}/{}.csv", slug.trim_matches('_'));
        t.write_csv(&path)?;
        println!("{}", t.to_markdown());
        println!("wrote {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::random_ring;

    #[test]
    fn sweep_produces_full_table() {
        let cfg = SweepConfig {
            sizes: vec![20, 30],
            runs: 2,
            seed: 1,
            quick: true,
        };
        let methods = [
            Method::new("random", |w, rng| {
                random_ring(w.n(), rng).to_graph(w)
            }),
            Method::new("shortest", |w, _| {
                crate::topology::shortest_ring(w, 0).to_graph(w)
            }),
        ];
        let t = sweep_diameters("t", Model::Uniform, &methods, &cfg)
            .unwrap();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.header, vec!["n", "random", "shortest"]);
        // Shortest ring beats random ring on average at these sizes.
        for row in &t.rows {
            assert!(row[2] < row[1], "NN {} !< random {}", row[2], row[1]);
        }
    }

    #[test]
    fn paper_config_shapes() {
        let full = SweepConfig::paper(false);
        assert_eq!(full.sizes.len(), 10);
        assert_eq!(*full.sizes.last().unwrap(), 1000);
        let quick = SweepConfig::paper(true);
        assert!(quick.sizes.len() <= 3);
    }
}
