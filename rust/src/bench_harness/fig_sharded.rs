//! "Figure 20" (beyond the paper): partition scaling of the sharded
//! coordinator. The paper's §VI claim — partitioned ring construction
//! matches the sequential diameter up to ~32 partitions — lifted to
//! system level: the whole *coordinator* (membership, measurement,
//! ρ-selection, re-anchoring) runs partition-local, and the table
//! tracks the certified diameter and the adaptation throughput
//! (periods/s) as the shard count K grows. Row K = 1 is the centralized
//! coordinator, the parity reference; `diameter_vs_centralized` is the
//! ratio the paper claims stays ≈ 1.

use anyhow::Result;

use crate::metrics::Table;
use crate::scenario::{ChurnSpec, ScenarioEngine, ScenarioSpec, Topology};

use super::FigureOpts;

/// The sweep workload: FABRIC-like clustered latencies and background
/// Poisson churn, sized so even the largest shard count keeps ≥ 3
/// members per shard.
fn sweep_spec(n: usize, horizon: f64) -> ScenarioSpec {
    ScenarioSpec {
        name: "sharded-scaling".into(),
        about: "partition scaling sweep for fig 20".into(),
        nodes: n,
        initial_alive: n,
        model: "fabric".into(),
        horizon,
        churn: vec![ChurnSpec::Poisson { rate: 0.0005 }],
        latency: vec![],
    }
}

/// Shard counts swept (K = 1 is the centralized reference).
const SHARD_COUNTS: [usize; 6] = [1, 2, 4, 8, 16, 32];

/// Regenerate the partition-scaling table.
pub fn run_opts(opts: FigureOpts) -> Result<Vec<Table>> {
    let n = if opts.quick { 96 } else { 256 };
    let horizon = if opts.quick { 1000.0 } else { 3000.0 };
    let spec = sweep_spec(n, horizon);
    let mut table = Table::new(
        "Fig 20: sharded coordinator partition scaling (fabric)",
        &[
            "shards",
            "mean_diameter",
            "final_diameter",
            "swaps",
            "periods_per_s",
            "diameter_vs_centralized",
        ],
    );
    let mut centralized_mean = 0.0f64;
    for &k in &SHARD_COUNTS {
        if n / k < 3 {
            continue; // shard below the 3-member ring minimum
        }
        let mut engine = ScenarioEngine::new(spec.clone(), 7)?;
        engine.opts.threads = opts.resolve_threads();
        engine.opts.shards = k;
        let topology = if k == 1 {
            Topology::Dgro
        } else {
            Topology::DgroSharded
        };
        let t0 = std::time::Instant::now();
        let rep = engine.run(topology)?;
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        let mean_d = rep.mean_diameter();
        if k == 1 {
            centralized_mean = mean_d;
        }
        table.row(vec![
            k as f64,
            mean_d,
            rep.final_diameter(),
            rep.total_swaps() as f64,
            rep.rows.len() as f64 / dt,
            mean_d / centralized_mean.max(1e-9),
        ]);
    }
    Ok(vec![table])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharded_scaling_table_shows_diameter_parity() {
        let tables = run_opts(FigureOpts::quick_mode(true)).unwrap();
        let t = &tables[0];
        assert!(t.rows.len() >= 5, "sweep too short: {}", t.rows.len());
        assert_eq!(t.rows[0][0], 1.0, "row 0 must be centralized");
        for row in &t.rows {
            assert!(
                row.iter().all(|x| x.is_finite()),
                "non-finite cell at K={}",
                row[0]
            );
            assert!(row[1] > 0.0, "zero diameter at K={}", row[0]);
            // The §VI parity claim, system level: sharding must stay in
            // the centralized diameter ballpark through K=8 (the quick
            // sweep runs tiny shards; the full sweep measures the real
            // curve at n=256).
            if row[0] <= 8.0 {
                assert!(
                    row[5] <= 2.5,
                    "K={}: diameter ratio {} vs centralized",
                    row[0],
                    row[5]
                );
            }
        }
    }
}
