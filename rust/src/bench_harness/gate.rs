//! The perf-regression gate: compare a fresh `BENCH_hotpath.json`
//! against the committed `BENCH_baseline.json` and fail when a gated
//! throughput metric regressed more than the tolerance.
//!
//! CI has uploaded the per-commit perf trajectory since PR 2 — but an
//! artifact nobody diffs gates nothing. The `bench-gate` step runs the
//! quick hotpath bench and then `cargo run --bin bench_gate`, which
//! exits non-zero when periods/s or diameter-eval throughput dropped
//! >20% below the baseline, turning the trajectory into an enforced
//! floor. Refresh the floor deliberately with
//! `bench_gate --update` after a justified perf change.
//!
//! The gated metrics are mostly *throughputs* (higher is better),
//! chosen for stability in quick mode: scenario-engine periods/s (both
//! evaluation strategies), batched diameter-eval throughput, GA
//! evaluations/s, the sim-transport frame rate, the observability
//! overhead ratio, the causal-trace stamping ratio, the 10^5-node
//! scale-tier estimation throughputs and the traffic-plane
//! routed-request rate. The traffic p99 end-to-end
//! latency is the one *inverted* metric — lower is better, so its
//! baseline acts as a ceiling rather than a floor.

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Multiplicative slack a metric may fall below its baseline before
/// the gate fails (0.20 = fail under 80% of baseline).
pub const DEFAULT_TOLERANCE: f64 = 0.20;

/// One gated metric: its flat name in `BENCH_baseline.json` and how to
/// read the current value out of `BENCH_hotpath.json`. `invert` marks
/// lower-is-better metrics (latencies): their gate ratio is
/// `baseline / current`, so the committed value is a ceiling.
struct MetricDef {
    name: &'static str,
    read: fn(&Json) -> Result<f64>,
    invert: bool,
}

fn scenario_incremental(root: &Json) -> Result<f64> {
    root.get("scenario")?
        .get("incremental_periods_per_s")?
        .as_f64()
}

fn scenario_rebuild(root: &Json) -> Result<f64> {
    root.get("scenario")?.get("rebuild_periods_per_s")?.as_f64()
}

fn diameter_batch_throughput(root: &Json) -> Result<f64> {
    // Smallest size's batch row: batch graphs per second on the pool.
    let rows = root.get("diameter")?.as_arr()?;
    let row = rows
        .first()
        .context("diameter table is empty in the bench report")?;
    let batch = row.get("batch")?.as_f64()?;
    let ms = row.get("batch_par_ms")?.as_f64()?;
    Ok(batch / (ms / 1e3).max(1e-12))
}

fn ga_throughput(root: &Json) -> Result<f64> {
    root.get("ga")?.get("par_evals_per_s")?.as_f64()
}

fn net_sim_frames(root: &Json) -> Result<f64> {
    root.get("net")?.get("sim_frames_per_s")?.as_f64()
}

fn decentralized_periods(root: &Json) -> Result<f64> {
    // Adaptation periods per second of the coordinator-free runner's
    // full per-peer event loop over the sim transport — floored so
    // protocol chatter creep (extra floods, sync rounds, probe retx)
    // fails the gate.
    root.get("net")?.get("decentralized_periods_per_s")?.as_f64()
}

fn obs_overhead_ratio(root: &Json) -> Result<f64> {
    // Throughput with span recording enabled over disabled (1.0 = free
    // instrumentation). Floored like every other metric, so recording
    // creep on the adaptive hot loop fails the gate.
    root.get("obs")?.get("enabled_over_disabled_ratio")?.as_f64()
}

fn trace_overhead_ratio(root: &Json) -> Result<f64> {
    // Transport-backed throughput with causal-trace stamping enabled
    // over disabled (wire context + span-id derivation + deliver
    // spans). Floored so trace stamping on the frame hot path cannot
    // silently regress.
    root.get("trace")?.get("enabled_over_disabled_ratio")?.as_f64()
}

fn scale_nodes_per_s(root: &Json, family: &str) -> Result<f64> {
    // The 10^5 row of the requested family — the largest tier is the
    // one whose regression matters.
    let rows = root.get("scale")?.as_arr()?;
    for row in rows {
        if row.get("family")?.as_str()? == family
            && row.get("n")?.as_f64()? == 100_000.0
        {
            return row.get("est_nodes_per_s")?.as_f64();
        }
    }
    anyhow::bail!("no 1e5 {family} row in the scale table")
}

fn scale_circulant(root: &Json) -> Result<f64> {
    scale_nodes_per_s(root, "circulant")
}

fn scale_geometric(root: &Json) -> Result<f64> {
    scale_nodes_per_s(root, "geometric")
}

fn traffic_req_per_s(root: &Json) -> Result<f64> {
    root.get("traffic")?.get("req_per_s")?.as_f64()
}

fn traffic_p99_ms(root: &Json) -> Result<f64> {
    root.get("traffic")?.get("p99_ms")?.as_f64()
}

const METRICS: [MetricDef; 12] = [
    MetricDef {
        name: "scenario_incremental_periods_per_s",
        read: scenario_incremental,
        invert: false,
    },
    MetricDef {
        name: "scenario_rebuild_periods_per_s",
        read: scenario_rebuild,
        invert: false,
    },
    MetricDef {
        name: "diameter_batch_graphs_per_s",
        read: diameter_batch_throughput,
        invert: false,
    },
    MetricDef {
        name: "ga_par_evals_per_s",
        read: ga_throughput,
        invert: false,
    },
    MetricDef {
        name: "net_sim_frames_per_s",
        read: net_sim_frames,
        invert: false,
    },
    MetricDef {
        name: "decentralized_periods_per_s",
        read: decentralized_periods,
        invert: false,
    },
    MetricDef {
        name: "obs_enabled_over_disabled",
        read: obs_overhead_ratio,
        invert: false,
    },
    MetricDef {
        name: "trace_enabled_over_disabled",
        read: trace_overhead_ratio,
        invert: false,
    },
    MetricDef {
        name: "scale_circulant_1e5_nodes_per_s",
        read: scale_circulant,
        invert: false,
    },
    MetricDef {
        name: "scale_geometric_1e5_nodes_per_s",
        read: scale_geometric,
        invert: false,
    },
    MetricDef {
        name: "traffic_req_per_s",
        read: traffic_req_per_s,
        invert: false,
    },
    MetricDef {
        name: "traffic_p99_ms",
        read: traffic_p99_ms,
        invert: true,
    },
];

/// One gated metric's verdict.
#[derive(Clone, Debug)]
pub struct GateRow {
    /// Flat metric name (baseline key).
    pub name: &'static str,
    /// Committed floor value.
    pub baseline: f64,
    /// Value from the fresh bench report.
    pub current: f64,
    /// `current / baseline` — or `baseline / current` for inverted
    /// (lower-is-better) metrics (1.0 = parity, < 1 - tolerance =
    /// fail).
    pub ratio: f64,
    /// Whether this metric clears the gate.
    pub ok: bool,
}

/// Result of a gate run: every row plus the overall verdict.
#[derive(Clone, Debug)]
pub struct GateOutcome {
    /// Per-metric verdicts, in [`extract`] order.
    pub rows: Vec<GateRow>,
    /// Tolerance the rows were judged with.
    pub tolerance: f64,
}

impl GateOutcome {
    /// Whether every gated metric cleared the regression floor.
    pub fn passed(&self) -> bool {
        self.rows.iter().all(|r| r.ok)
    }

    /// Human-readable verdict table (one line per metric).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "bench-gate (fail below {:.0}% of baseline):",
            (1.0 - self.tolerance) * 100.0
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "  {:<36} baseline {:>12.1}  current {:>12.1}  \
                 ({:>6.1}%) {}",
                r.name,
                r.baseline,
                r.current,
                r.ratio * 100.0,
                if r.ok { "ok" } else { "REGRESSED" }
            );
        }
        out
    }
}

/// Pull the gated metric values out of a `BENCH_hotpath.json` report.
pub fn extract(report: &Json) -> Result<Vec<(&'static str, f64)>> {
    METRICS
        .iter()
        .map(|m| {
            (m.read)(report)
                .map(|v| (m.name, v))
                .with_context(|| format!("reading metric {}", m.name))
        })
        .collect()
}

/// Compare a fresh bench report against a committed baseline.
/// `baseline` is the `BENCH_baseline.json` document, `report` the
/// `BENCH_hotpath.json` one.
pub fn compare(
    baseline: &Json,
    report: &Json,
    tolerance: f64,
) -> Result<GateOutcome> {
    let floors = baseline.get("metrics")?;
    let mut rows = Vec::new();
    for m in &METRICS {
        let current = (m.read)(report)
            .with_context(|| format!("reading metric {}", m.name))?;
        let floor = floors
            .get(m.name)
            .with_context(|| {
                format!("baseline missing metric {}", m.name)
            })?
            .as_f64()?;
        let ratio = if m.invert {
            // Lower is better: the committed value is a ceiling and
            // the ratio degrades as `current` grows past it.
            if current > 0.0 {
                floor / current
            } else {
                1.0
            }
        } else if floor > 0.0 {
            current / floor
        } else {
            1.0
        };
        rows.push(GateRow {
            name: m.name,
            baseline: floor,
            current,
            ratio,
            ok: ratio >= 1.0 - tolerance,
        });
    }
    Ok(GateOutcome { rows, tolerance })
}

/// Build a fresh `BENCH_baseline.json` document from a bench report
/// (the `bench_gate --update` path).
pub fn baseline_from(report: &Json) -> Result<Json> {
    let metrics = extract(report)?
        .into_iter()
        .map(|(name, v)| (name, Json::num(v)))
        .collect::<Vec<_>>();
    Ok(Json::obj(vec![
        ("bench", Json::str("hotpath-baseline")),
        ("metrics", Json::obj(metrics)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn report(scale: f64) -> Json {
        Json::obj(vec![
            (
                "scenario",
                Json::obj(vec![
                    (
                        "incremental_periods_per_s",
                        Json::num(40.0 * scale),
                    ),
                    ("rebuild_periods_per_s", Json::num(10.0 * scale)),
                ]),
            ),
            (
                "diameter",
                Json::arr(vec![Json::obj(vec![
                    ("batch", Json::num(16.0)),
                    ("batch_par_ms", Json::num(8.0 / scale)),
                ])]),
            ),
            (
                "ga",
                Json::obj(vec![(
                    "par_evals_per_s",
                    Json::num(2000.0 * scale),
                )]),
            ),
            (
                "net",
                Json::obj(vec![
                    (
                        "sim_frames_per_s",
                        Json::num(50_000.0 * scale),
                    ),
                    (
                        "decentralized_periods_per_s",
                        Json::num(8.0 * scale),
                    ),
                ]),
            ),
            (
                "obs",
                Json::obj(vec![(
                    "enabled_over_disabled_ratio",
                    Json::num(scale),
                )]),
            ),
            (
                "trace",
                Json::obj(vec![(
                    "enabled_over_disabled_ratio",
                    Json::num(0.9 * scale),
                )]),
            ),
            (
                "scale",
                Json::arr(vec![
                    Json::obj(vec![
                        ("family", Json::str("circulant")),
                        ("n", Json::num(100_000.0)),
                        (
                            "est_nodes_per_s",
                            Json::num(250_000.0 * scale),
                        ),
                    ]),
                    Json::obj(vec![
                        ("family", Json::str("geometric")),
                        ("n", Json::num(100_000.0)),
                        (
                            "est_nodes_per_s",
                            Json::num(150_000.0 * scale),
                        ),
                    ]),
                ]),
            ),
            (
                // `p99_ms` is inverted: a slowdown (scale < 1) must
                // *raise* the latency for the gate to read it as a
                // regression, hence the division.
                "traffic",
                Json::obj(vec![
                    ("req_per_s", Json::num(500_000.0 * scale)),
                    ("p99_ms", Json::num(50.0 / scale)),
                ]),
            ),
        ])
    }

    #[test]
    fn parity_passes_and_injected_regression_fails() {
        let baseline = baseline_from(&report(1.0)).unwrap();
        // Parity and small noise pass.
        assert!(compare(&baseline, &report(1.0), DEFAULT_TOLERANCE)
            .unwrap()
            .passed());
        assert!(compare(&baseline, &report(0.85), DEFAULT_TOLERANCE)
            .unwrap()
            .passed());
        // An injected 25% regression fails the 20% gate.
        let out = compare(&baseline, &report(0.75), DEFAULT_TOLERANCE)
            .unwrap();
        assert!(!out.passed());
        assert!(out.rows.iter().all(|r| !r.ok), "all throughputs fell");
        assert!(out.render().contains("REGRESSED"));
        // Improvements always pass.
        assert!(compare(&baseline, &report(1.4), DEFAULT_TOLERANCE)
            .unwrap()
            .passed());
    }

    #[test]
    fn baseline_round_trips_through_json_text() {
        let baseline = baseline_from(&report(1.0)).unwrap();
        let parsed = json::parse(&baseline.to_string()).unwrap();
        let out =
            compare(&parsed, &report(1.0), DEFAULT_TOLERANCE).unwrap();
        assert!(out.passed());
        assert_eq!(out.rows.len(), 12);
        for r in out.rows {
            assert!((r.ratio - 1.0).abs() < 1e-9, "{}: {}", r.name, r.ratio);
        }
    }

    #[test]
    fn inverted_latency_metric_gates_as_a_ceiling() {
        let baseline = baseline_from(&report(1.0)).unwrap();
        // A slowdown *raises* p99; the inverted ratio must fall below
        // the tolerance exactly like a throughput drop would.
        let out = compare(&baseline, &report(0.75), DEFAULT_TOLERANCE)
            .unwrap();
        let row = out
            .rows
            .iter()
            .find(|r| r.name == "traffic_p99_ms")
            .unwrap();
        assert!(row.current > row.baseline, "slowdown raises p99");
        assert!((row.ratio - 0.75).abs() < 1e-9, "{}", row.ratio);
        assert!(!row.ok);
        // A speedup lowers p99 and passes with ratio > 1.
        let out = compare(&baseline, &report(1.4), DEFAULT_TOLERANCE)
            .unwrap();
        let row = out
            .rows
            .iter()
            .find(|r| r.name == "traffic_p99_ms")
            .unwrap();
        assert!(row.current < row.baseline);
        assert!(row.ratio > 1.0 && row.ok);
    }

    #[test]
    fn missing_metric_is_a_hard_error() {
        let baseline = Json::obj(vec![(
            "metrics",
            Json::obj(vec![("nope", Json::num(1.0))]),
        )]);
        assert!(
            compare(&baseline, &report(1.0), DEFAULT_TOLERANCE).is_err()
        );
    }
}
