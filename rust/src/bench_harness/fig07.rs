//! Figure 7: "DGRO finds better diameters for Perigee" — Perigee's
//! adaptive NN neighbor sets paired with a random ring vs the shortest
//! ring. The paper's counter-intuitive result: the *random* ring is the
//! right companion (the NN-heavy topology needs long-range shortcuts),
//! with the gap exploding toward N=1000.

use anyhow::Result;

use crate::latency::Model;
use crate::metrics::Table;
use crate::topology::{perigee, random_ring, shortest_ring};

use super::runner::{sweep_diameters, Method, SweepConfig};

fn methods() -> Vec<Method> {
    vec![
        Method::new("perigee_plus_random", |w, rng| {
            let pg =
                perigee::build(w, perigee::PerigeeConfig::default(), rng);
            pg.union(&random_ring(w.n(), rng).to_graph(w))
        }),
        Method::new("perigee_plus_shortest", |w, rng| {
            let pg =
                perigee::build(w, perigee::PerigeeConfig::default(), rng);
            pg.union(&shortest_ring(w, 0).to_graph(w))
        }),
    ]
}

/// Regenerate the figure under the given sweep configuration.
pub fn run(cfg: &SweepConfig) -> Result<Vec<Table>> {
    Ok(vec![
        sweep_diameters(
            "Fig 7a: Perigee ring choice, uniform latency",
            Model::Uniform,
            &methods(),
            cfg,
        )?,
        sweep_diameters(
            "Fig 7b: Perigee ring choice, FABRIC latency",
            Model::Fabric,
            &methods(),
            cfg,
        )?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_ring_companion_wins_at_scale() {
        // The crossover may need some size; test at a moderate N where
        // NN-chains already hurt.
        let cfg = SweepConfig {
            sizes: vec![150],
            runs: 2,
            seed: 21,
            quick: true,
        };
        let t = &run(&cfg).unwrap()[0]; // uniform
        let row = &t.rows[0];
        assert!(
            row[1] <= row[2] * 1.1,
            "perigee+random {} should be <= perigee+shortest {}",
            row[1],
            row[2]
        );
    }
}
