//! The shared atomic metrics registry: counters, per-index counter
//! vectors and fixed-bucket log-scale histograms that many threads
//! record into without `&mut` threading.
//!
//! Everything here is lock-free on the hot path: callers resolve a
//! handle (an `Arc` to the atomic cell) once, then record with plain
//! atomic adds. The registry's own maps are only locked on handle
//! resolution and on snapshot/exposition, never per event.
//!
//! Determinism contract: counters hold logical event counts, so a
//! seeded run over the sim transport produces identical snapshots
//! regardless of thread count or wall-clock speed. Histogram *bucket
//! counts* share that property when fed sim-time values; wall-time
//! histograms (period wall ms, decode µs) are diagnostic only and are
//! never merged into deterministic reports.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Number of finite log-scale buckets (plus one overflow slot).
pub const NBUCKETS: usize = 40;

/// Fixed-point scale for histogram sums: 1/1000 of the recorded unit
/// (µs when observing ms). Integer sums make addition associative, so
/// the total is identical whatever order threads record in.
const SUM_SCALE: f64 = 1e3;

/// Upper bound of finite bucket `i`: `0.001 * 2^i` (ms when observing
/// ms), covering 1 µs up to ~6.4 days. Values above the last bound
/// land in the overflow slot.
pub fn bucket_bound(i: usize) -> f64 {
    1e-3 * (i as f64).exp2()
}

/// A fixed-bucket log-scale histogram with atomic cells.
///
/// `observe` is wait-free per bucket; min/max are maintained with
/// compare-and-swap on the value's bit pattern (valid for the
/// non-negative durations recorded here), so the final min/max is
/// order-independent.
pub struct Histogram {
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum_fp: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            counts: (0..=NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_fp: AtomicU64::new(0),
            min_bits: AtomicU64::new(u64::MAX),
            max_bits: AtomicU64::new(0),
        }
    }

    /// Which bucket a value lands in (`NBUCKETS` = overflow).
    /// A value exactly on a bucket's upper bound belongs to that
    /// bucket (`le` semantics, as in Prometheus).
    pub fn bucket_index(v: f64) -> usize {
        if !(v > 0.0) {
            return 0;
        }
        (0..NBUCKETS)
            .position(|i| v <= bucket_bound(i))
            .unwrap_or(NBUCKETS)
    }

    /// Record one value (non-finite and negative values clamp to 0).
    pub fn observe(&self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.counts[Self::bucket_index(v)]
            .fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_fp
            .fetch_add((v * SUM_SCALE).round() as u64, Ordering::Relaxed);
        let bits = v.to_bits();
        let _ = self.min_bits.fetch_min(bits, Ordering::Relaxed);
        let _ = self.max_bits.fetch_max(bits, Ordering::Relaxed);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded values (fixed-point, 1e-3 resolution).
    pub fn sum(&self) -> f64 {
        self.sum_fp.load(Ordering::Relaxed) as f64 / SUM_SCALE
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> f64 {
        let bits = self.min_bits.load(Ordering::Relaxed);
        if bits == u64::MAX {
            0.0
        } else {
            f64::from_bits(bits)
        }
    }

    /// Largest recorded value (0 when empty).
    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Per-bucket counts (finite buckets then the overflow slot).
    pub fn buckets(&self) -> Vec<u64> {
        self.counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Bucket-resolution quantile: the upper bound of the bucket
    /// holding the `q`-th ranked value (`max()` for the overflow
    /// slot, 0 when empty).
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, c) in self.buckets().iter().enumerate() {
            seen += c;
            if seen >= rank {
                return if i == NBUCKETS {
                    self.max()
                } else {
                    bucket_bound(i)
                };
            }
        }
        self.max()
    }
}

/// A fixed-length vector of atomic counters, indexed by a small id
/// (peer index, shard index). Out-of-range indices are ignored.
pub struct CounterVec {
    slots: Vec<AtomicU64>,
}

impl CounterVec {
    fn new(len: usize) -> CounterVec {
        CounterVec {
            slots: (0..len).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether the vector has no slots.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Add `by` to slot `i` (no-op when out of range).
    pub fn incr(&self, i: usize, by: u64) {
        if let Some(s) = self.slots.get(i) {
            s.fetch_add(by, Ordering::Relaxed);
        }
    }

    /// Current value of slot `i` (0 when out of range).
    pub fn get(&self, i: usize) -> u64 {
        self.slots
            .get(i)
            .map(|s| s.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Sum over all slots.
    pub fn total(&self) -> u64 {
        self.slots.iter().map(|s| s.load(Ordering::Relaxed)).sum()
    }
}

/// The process-shareable registry. One instance per run (wrapped in an
/// [`Arc`] by [`crate::obs::Obs`]) keeps repeated in-process runs
/// independent — a hard requirement of the byte-determinism pins.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    vecs: Mutex<BTreeMap<String, Arc<CounterVec>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Resolve (creating if absent) the counter handle for `name`.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
            .clone()
    }

    /// Convenience: add `by` to counter `name` (resolves the handle).
    pub fn incr(&self, name: &str, by: u64) {
        self.counter(name).fetch_add(by, Ordering::Relaxed);
    }

    /// Current value of counter `name` (0 when never touched).
    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Resolve (creating if absent) a counter vector of at least
    /// `len` slots. An existing shorter vector is replaced by a wider
    /// one carrying the old slot values over.
    pub fn counter_vec(&self, name: &str, len: usize) -> Arc<CounterVec> {
        let mut map = self.vecs.lock().unwrap();
        if let Some(v) = map.get(name) {
            if v.len() >= len {
                return v.clone();
            }
            let wide = Arc::new(CounterVec::new(len));
            for i in 0..v.len() {
                wide.incr(i, v.get(i));
            }
            map.insert(name.to_string(), wide.clone());
            return wide;
        }
        let v = Arc::new(CounterVec::new(len));
        map.insert(name.to_string(), v.clone());
        v
    }

    /// Resolve (creating if absent) the histogram handle for `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.hists.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// Sorted snapshot of every plain counter.
    pub fn counters_snapshot(&self) -> BTreeMap<String, u64> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Prometheus text exposition of the full registry (names have
    /// `.` mapped to `_`; vector slots become an `idx` label).
    pub fn prometheus(&self) -> String {
        use std::fmt::Write as _;
        fn prom(name: &str) -> String {
            name.replace(['.', '-'], "_")
        }
        let mut out = String::new();
        for (name, v) in self.counters_snapshot() {
            let n = prom(&name);
            let _ = writeln!(out, "# TYPE {n} counter");
            let _ = writeln!(out, "{n} {v}");
        }
        for (name, vec) in self.vecs.lock().unwrap().iter() {
            let n = prom(name);
            let _ = writeln!(out, "# TYPE {n} counter");
            for i in 0..vec.len() {
                let _ =
                    writeln!(out, "{n}{{idx=\"{i}\"}} {}", vec.get(i));
            }
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            let n = prom(name);
            let _ = writeln!(out, "# TYPE {n} histogram");
            let buckets = h.buckets();
            let mut cum = 0u64;
            for (i, c) in buckets.iter().enumerate() {
                cum += c;
                if i == NBUCKETS {
                    let _ = writeln!(
                        out,
                        "{n}_bucket{{le=\"+Inf\"}} {cum}"
                    );
                } else {
                    let _ = writeln!(
                        out,
                        "{n}_bucket{{le=\"{}\"}} {cum}",
                        bucket_bound(i)
                    );
                }
            }
            let _ = writeln!(out, "{n}_sum {}", h.sum());
            let _ = writeln!(out, "{n}_count {}", h.count());
        }
        out
    }

    /// JSON snapshot (`counters`, `counter_vecs`, `histograms`) in the
    /// shape `dgro obs dump|diff` consumes.
    pub fn to_json(&self) -> Json {
        let counters = self
            .counters_snapshot()
            .into_iter()
            .map(|(k, v)| (k, Json::num(v as f64)))
            .collect::<Vec<_>>();
        let vecs = self
            .vecs
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| {
                let slots = (0..v.len())
                    .map(|i| Json::num(v.get(i) as f64))
                    .collect();
                (k.clone(), Json::arr(slots))
            })
            .collect::<Vec<_>>();
        let hists = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| {
                let buckets = h
                    .buckets()
                    .into_iter()
                    .map(|c| Json::num(c as f64))
                    .collect();
                (
                    k.clone(),
                    Json::obj(vec![
                        ("count", Json::num(h.count() as f64)),
                        ("sum", Json::num(h.sum())),
                        ("min", Json::num(h.min())),
                        ("max", Json::num(h.max())),
                        ("p99", Json::num(h.quantile(0.99))),
                        ("buckets", Json::arr(buckets)),
                    ]),
                )
            })
            .collect::<Vec<_>>();
        Json::Obj(
            [
                (
                    "counters".to_string(),
                    Json::Obj(counters.into_iter().collect()),
                ),
                (
                    "counter_vecs".to_string(),
                    Json::Obj(vecs.into_iter().collect()),
                ),
                (
                    "histograms".to_string(),
                    Json::Obj(hists.into_iter().collect()),
                ),
            ]
            .into_iter()
            .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_vectors_accumulate() {
        let reg = Registry::new();
        reg.incr("a.b", 2);
        reg.incr("a.b", 3);
        assert_eq!(reg.get("a.b"), 5);
        assert_eq!(reg.get("never"), 0);
        let v = reg.counter_vec("peer.tx", 4);
        v.incr(1, 7);
        v.incr(3, 1);
        v.incr(99, 1); // out of range: ignored
        assert_eq!(v.get(1), 7);
        assert_eq!(v.total(), 8);
        // Widening keeps old slots.
        let w = reg.counter_vec("peer.tx", 8);
        assert_eq!(w.len(), 8);
        assert_eq!(w.get(1), 7);
        assert_eq!(w.total(), 8);
    }

    #[test]
    fn histogram_bucket_boundaries_are_le_inclusive() {
        // Exactly on a bound lands in that bucket; just above moves
        // to the next one; zero/negative/NaN clamp to bucket 0.
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(-3.0), 0);
        assert_eq!(Histogram::bucket_index(f64::NAN), 0);
        assert_eq!(Histogram::bucket_index(bucket_bound(0)), 0);
        assert_eq!(Histogram::bucket_index(bucket_bound(1)), 1);
        for i in 0..NBUCKETS {
            assert_eq!(Histogram::bucket_index(bucket_bound(i)), i);
            if i + 1 < NBUCKETS {
                assert_eq!(
                    Histogram::bucket_index(bucket_bound(i) * 1.0001),
                    i + 1
                );
            }
        }
        assert_eq!(
            Histogram::bucket_index(bucket_bound(NBUCKETS - 1) * 2.0),
            NBUCKETS
        );
    }

    #[test]
    fn histogram_summary_stats_are_exact() {
        let reg = Registry::new();
        let h = reg.histogram("t");
        for v in [0.5, 1.5, 2.0, 8.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 12.0).abs() < 1e-9);
        assert!((h.mean() - 3.0).abs() < 1e-9);
        assert_eq!(h.min(), 0.5);
        assert_eq!(h.max(), 8.0);
        assert_eq!(h.quantile(1.0), h.max().max(bucket_bound(13)));
        assert!(h.quantile(0.25) >= 0.5);
    }

    #[test]
    fn snapshot_is_deterministic_across_thread_counts() {
        // The same logical workload recorded under 1, 2 and 8 threads
        // must produce identical counter snapshots, histogram bucket
        // vectors and min/max — the order-independence contract.
        let mut renders = Vec::new();
        for threads in [1usize, 2, 8] {
            let reg = std::sync::Arc::new(Registry::new());
            let per = 240 / threads;
            std::thread::scope(|s| {
                for t in 0..threads {
                    let reg = reg.clone();
                    s.spawn(move || {
                        let c = reg.counter("evt");
                        let h = reg.histogram("lat");
                        let v = reg.counter_vec("peer", 8);
                        for i in 0..per {
                            let k = t * per + i;
                            c.fetch_add(1, Ordering::Relaxed);
                            h.observe((k % 37) as f64 * 0.25);
                            v.incr(k % 8, 1);
                        }
                    });
                }
            });
            renders.push(reg.prometheus());
        }
        assert_eq!(renders[0], renders[1]);
        assert_eq!(renders[0], renders[2]);
        assert!(renders[0].contains("evt 240"));
    }

    #[test]
    fn prometheus_and_json_expose_everything() {
        let reg = Registry::new();
        reg.incr("net.frames_sent", 3);
        reg.counter_vec("net.peer.tx", 2).incr(0, 1);
        reg.histogram("period.wall_ms").observe(4.0);
        let prom = reg.prometheus();
        assert!(prom.contains("net_frames_sent 3"));
        assert!(prom.contains("net_peer_tx{idx=\"0\"} 1"));
        assert!(prom.contains("period_wall_ms_count 1"));
        assert!(prom.contains("le=\"+Inf\""));
        let js = reg.to_json();
        assert_eq!(
            js.get("counters")
                .unwrap()
                .get("net.frames_sent")
                .unwrap()
                .as_f64()
                .unwrap(),
            3.0
        );
        assert_eq!(
            js.get("histograms")
                .unwrap()
                .get("period.wall_ms")
                .unwrap()
                .get("count")
                .unwrap()
                .as_f64()
                .unwrap(),
            1.0
        );
    }
}
