//! Causal tracing on top of the flight [`Recorder`]: deterministic
//! trace/span id derivation, cross-node trace assembly into a causal
//! DAG, sim-time critical-path extraction, and text renderers.
//!
//! Determinism contract: every id is derived by hashing **seeded sim
//! inputs only** — the run seed, the period index, a site string and
//! site-chosen words (peer id, probe sequence, attempt). No wall
//! clock, no allocation order, no thread id ever feeds the hash, so a
//! seeded sim run produces byte-identical ids at any thread count.
//! Ids are `u64`; the value `0` is reserved to mean "none" (untraced
//! span / no parent) and is never returned by [`derive`].
//!
//! Ids are exported as 16-digit zero-padded hex strings because the
//! JSON substrate stores numbers as `f64` (exact only to 2^53).
//!
//! [`Recorder`]: super::Recorder

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

use super::recorder::Span;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Derive a deterministic 64-bit id from a seed, a site string and a
/// sequence of site-chosen words (FNV-1a over the concatenation).
/// Never returns 0 — that value is reserved for "none".
pub fn derive(seed: u64, site: &str, words: &[u64]) -> u64 {
    let mut h = FNV_OFFSET;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    };
    eat(&seed.to_le_bytes());
    eat(site.as_bytes());
    for w in words {
        eat(&w.to_le_bytes());
    }
    if h == 0 {
        1
    } else {
        h
    }
}

/// The trace id for one adaptation period of a seeded run.
pub fn trace_id(seed: u64, period: usize) -> u64 {
    derive(seed, "trace", &[period as u64])
}

/// A span id within `trace`, keyed by the span kind, its
/// discriminator `id` and a site-chosen `salt` (attempt/sequence
/// word) that separates otherwise-identical spans.
pub fn span_id(trace: u64, kind: &str, id: u64, salt: u64) -> u64 {
    derive(trace, kind, &[id, salt])
}

/// Trace context carried on a wire frame: the period's trace id and
/// the sender-side span the delivery belongs under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceCtx {
    /// Trace id (never 0 on the wire).
    pub trace: u64,
    /// Parent span id on the sending side (never 0 on the wire).
    pub parent: u64,
}

/// An owned span record, as assembled from the recorder or parsed
/// back from a `timeline.jsonl` export.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanRec {
    /// Span kind (`period`, `probe`, `retx`, `deliver`, ...).
    pub kind: String,
    /// Discriminator within the kind (period index, peer id, ...).
    pub id: u64,
    /// Sim-time start (ms).
    pub t_ms: f64,
    /// Sim-time duration (ms).
    pub dur_ms: f64,
    /// Trace id (0 = untraced).
    pub trace: u64,
    /// This span's id (0 = untraced).
    pub span: u64,
    /// Parent span id (0 = root).
    pub parent: u64,
}

impl From<&Span> for SpanRec {
    fn from(s: &Span) -> SpanRec {
        SpanRec {
            kind: s.kind.to_string(),
            id: s.id,
            t_ms: s.t_ms,
            dur_ms: s.dur_ms,
            trace: s.trace,
            span: s.span,
            parent: s.parent,
        }
    }
}

fn hex_field(js: &Json, key: &str) -> Result<u64> {
    match js.opt(key) {
        None => Ok(0),
        Some(v) => {
            let s = v.as_str()?;
            u64::from_str_radix(s, 16)
                .with_context(|| format!("bad hex id in '{key}': {s}"))
        }
    }
}

/// Parse a `timeline.jsonl` export back into span records. Blank
/// lines and annotation headers (lines without a `kind` field) are
/// skipped; trace/span/parent hex fields default to 0 when absent.
pub fn parse_jsonl(text: &str) -> Result<Vec<SpanRec>> {
    let mut out = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let js = json::parse(line)?;
        let Some(kind) = js.opt("kind") else {
            continue;
        };
        out.push(SpanRec {
            kind: kind.as_str()?.to_string(),
            id: js.get("id")?.as_f64()? as u64,
            t_ms: js.get("t_ms")?.as_f64()?,
            dur_ms: js.get("dur_ms")?.as_f64()?,
            trace: hex_field(&js, "trace")?,
            span: hex_field(&js, "span")?,
            parent: hex_field(&js, "parent")?,
        });
    }
    Ok(out)
}

/// One assembled causal trace: the spans of a single trace id, sorted
/// by the deterministic `(t_ms, kind, id, span)` order, with parent
/// links resolved into a child index.
#[derive(Clone, Debug)]
pub struct Trace {
    /// The trace id shared by every span below.
    pub trace: u64,
    /// Spans in deterministic order.
    pub spans: Vec<SpanRec>,
    /// `children[i]` lists the indices whose parent is `spans[i]`.
    pub children: Vec<Vec<usize>>,
    /// Indices of spans with no parent (`parent == 0`).
    pub roots: Vec<usize>,
    /// Indices whose parent id resolves to no recorded span.
    pub orphans: Vec<usize>,
}

impl Trace {
    /// The period index, when the trace has a `period` root span.
    pub fn period(&self) -> Option<u64> {
        self.roots
            .iter()
            .map(|&i| &self.spans[i])
            .find(|s| s.kind == "period")
            .map(|s| s.id)
    }

    /// Render the causal tree as indented text, one span per line.
    /// Orphans (unresolvable parents) are listed in a trailing
    /// section so broken stitching is visible, not silent.
    pub fn render_tree(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace {:016x}  spans {}  roots {}  orphans {}",
            self.trace,
            self.spans.len(),
            self.roots.len(),
            self.orphans.len()
        );
        let mut visited = vec![false; self.spans.len()];
        let mut stack: Vec<(usize, usize)> =
            self.roots.iter().rev().map(|&i| (i, 1)).collect();
        while let Some((i, depth)) = stack.pop() {
            if std::mem::replace(&mut visited[i], true) {
                continue;
            }
            let s = &self.spans[i];
            let _ = writeln!(
                out,
                "{:indent$}{}[{}] t={:.3} dur={:.3}",
                "",
                s.kind,
                s.id,
                s.t_ms,
                s.dur_ms,
                indent = depth * 2
            );
            for &c in self.children[i].iter().rev() {
                stack.push((c, depth + 1));
            }
        }
        for &i in &self.orphans {
            let s = &self.spans[i];
            let _ = writeln!(
                out,
                "  orphan {}[{}] t={:.3} dur={:.3} parent={:016x}",
                s.kind, s.id, s.t_ms, s.dur_ms, s.parent
            );
        }
        out
    }

    /// The sim-time critical path: starting from the root whose
    /// subtree ends latest, repeatedly descend into the child with
    /// the latest end time (`t_ms + dur_ms`; ties break to the first
    /// child in deterministic order). Returns span indices, root
    /// first. Empty when the trace has no roots.
    pub fn critical_path(&self) -> Vec<usize> {
        // end[i] = latest end time in the subtree rooted at i,
        // computed iteratively (post-order) to stay cycle-safe.
        let n = self.spans.len();
        let end_of = |i: usize| self.spans[i].t_ms + self.spans[i].dur_ms;
        let mut sub_end: Vec<f64> = (0..n).map(end_of).collect();
        let mut state = vec![0u8; n]; // 0=new 1=open 2=done
        for &r in &self.roots {
            let mut stack = vec![r];
            while let Some(&i) = stack.last() {
                match state[i] {
                    0 => {
                        state[i] = 1;
                        for &c in &self.children[i] {
                            if state[c] == 0 {
                                stack.push(c);
                            }
                        }
                    }
                    _ => {
                        stack.pop();
                        if state[i] == 1 {
                            state[i] = 2;
                            for &c in &self.children[i] {
                                if sub_end[c] > sub_end[i] {
                                    sub_end[i] = sub_end[c];
                                }
                            }
                        }
                    }
                }
            }
        }
        let Some(&start) = self.roots.iter().max_by(|&&a, &&b| {
            sub_end[a]
                .total_cmp(&sub_end[b])
                .then(std::cmp::Ordering::Greater) // tie: keep first
        }) else {
            return Vec::new();
        };
        let mut path = vec![start];
        let mut seen = vec![false; n];
        seen[start] = true;
        let mut cur = start;
        loop {
            let mut best: Option<usize> = None;
            for &c in &self.children[cur] {
                if seen[c] {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => sub_end[c] > sub_end[b],
                };
                if better {
                    best = Some(c);
                }
            }
            match best {
                Some(c) => {
                    seen[c] = true;
                    path.push(c);
                    cur = c;
                }
                None => break,
            }
        }
        path
    }

    /// The critical path as a `kind[id] -> kind[id] -> ...` chain
    /// plus its sim-time extent (root start to leaf end) in ms.
    pub fn critical_chain(&self) -> (String, f64) {
        let path = self.critical_path();
        if path.is_empty() {
            return (String::new(), 0.0);
        }
        let chain = path
            .iter()
            .map(|&i| {
                let s = &self.spans[i];
                format!("{}[{}]", s.kind, s.id)
            })
            .collect::<Vec<_>>()
            .join(" -> ");
        let first = &self.spans[path[0]];
        let last = &self.spans[*path.last().unwrap()];
        let extent = (last.t_ms + last.dur_ms - first.t_ms).max(0.0);
        (chain, extent)
    }
}

/// All traces assembled from one span set, sorted by (period, trace
/// id) so output order is deterministic.
#[derive(Clone, Debug, Default)]
pub struct Forest {
    /// Assembled traces in deterministic order.
    pub traces: Vec<Trace>,
}

impl Forest {
    /// The trace whose root period span carries `period`, if any.
    pub fn by_period(&self, period: u64) -> Option<&Trace> {
        self.traces.iter().find(|t| t.period() == Some(period))
    }

    /// One summary line per trace: the critical chain, its sim-time
    /// extent, and the span/root/orphan counts. Deterministic.
    pub fn summary_jsonl(&self) -> String {
        let mut out = String::new();
        for t in &self.traces {
            let (chain, crit_ms) = t.critical_chain();
            let mut fields = vec![
                ("critical", Json::str(&chain)),
                ("critical_ms", Json::num(crit_ms)),
                ("orphans", Json::num(t.orphans.len() as f64)),
            ];
            if let Some(p) = t.period() {
                fields.push(("period", Json::num(p as f64)));
            }
            fields.push(("roots", Json::num(t.roots.len() as f64)));
            fields.push(("spans", Json::num(t.spans.len() as f64)));
            fields.push(("trace", Json::str(&format!("{:016x}", t.trace))));
            out.push_str(&Json::obj(fields).to_string());
            out.push('\n');
        }
        out
    }
}

/// Assemble traced spans (`trace != 0`) into causal trees, one
/// [`Trace`] per distinct trace id. Untraced spans are ignored.
/// Within a trace, spans sort by `(t_ms, kind, id, span)`; parent
/// ids resolve to the *first* span with that id in sorted order, and
/// self-parent edges are dropped (both keep assembly total even on
/// corrupt input).
pub fn assemble(spans: &[SpanRec]) -> Forest {
    let mut by_trace: BTreeMap<u64, Vec<SpanRec>> = BTreeMap::new();
    for s in spans {
        if s.trace != 0 {
            by_trace.entry(s.trace).or_default().push(s.clone());
        }
    }
    let mut traces = Vec::with_capacity(by_trace.len());
    for (trace, mut spans) in by_trace {
        spans.sort_by(|a, b| {
            a.t_ms
                .total_cmp(&b.t_ms)
                .then_with(|| a.kind.cmp(&b.kind))
                .then_with(|| a.id.cmp(&b.id))
                .then_with(|| a.span.cmp(&b.span))
        });
        let mut index: BTreeMap<u64, usize> = BTreeMap::new();
        for (i, s) in spans.iter().enumerate() {
            if s.span != 0 {
                index.entry(s.span).or_insert(i);
            }
        }
        let mut children = vec![Vec::new(); spans.len()];
        let mut roots = Vec::new();
        let mut orphans = Vec::new();
        for (i, s) in spans.iter().enumerate() {
            if s.parent == 0 {
                roots.push(i);
            } else {
                match index.get(&s.parent) {
                    Some(&p) if p != i => children[p].push(i),
                    _ => orphans.push(i),
                }
            }
        }
        traces.push(Trace {
            trace,
            spans,
            children,
            roots,
            orphans,
        });
    }
    traces.sort_by_key(|t| (t.period().unwrap_or(u64::MAX), t.trace));
    Forest { traces }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(
        kind: &str,
        id: u64,
        t: f64,
        dur: f64,
        trace: u64,
        span: u64,
        parent: u64,
    ) -> SpanRec {
        SpanRec {
            kind: kind.to_string(),
            id,
            t_ms: t,
            dur_ms: dur,
            trace,
            span,
            parent,
        }
    }

    #[test]
    fn derive_is_deterministic_and_never_zero() {
        assert_eq!(derive(7, "trace", &[3]), derive(7, "trace", &[3]));
        assert_ne!(derive(7, "trace", &[3]), derive(7, "trace", &[4]));
        assert_ne!(derive(7, "trace", &[3]), derive(8, "trace", &[3]));
        assert_ne!(derive(7, "probe", &[3]), derive(7, "trace", &[3]));
        // Word boundaries matter: [1,2] vs [2,1] differ.
        assert_ne!(derive(0, "x", &[1, 2]), derive(0, "x", &[2, 1]));
        for i in 0..512 {
            assert_ne!(derive(i, "probe", &[i, i]), 0);
        }
    }

    #[test]
    fn assemble_builds_trees_and_flags_orphans() {
        let t = trace_id(0, 3);
        let root = span_id(t, "period", 3, 0);
        let m = span_id(t, "measure", 3, 0);
        let p = span_id(t, "probe", 17, 5);
        let spans = vec![
            rec("period", 3, 0.0, 250.0, t, root, 0),
            rec("measure", 3, 0.0, 80.0, t, m, root),
            rec("probe", 17, 1.0, 12.0, t, p, m),
            rec("deliver", 9, 5.0, 0.0, t, span_id(t, "deliver", 9, 1), 42),
            rec("decide", 0, 0.0, 0.0, 0, 0, 0), // untraced: ignored
        ];
        let forest = assemble(&spans);
        assert_eq!(forest.traces.len(), 1);
        let tr = &forest.traces[0];
        assert_eq!(tr.spans.len(), 4);
        assert_eq!(tr.roots.len(), 1);
        assert_eq!(tr.orphans.len(), 1, "parent 42 resolves nowhere");
        assert_eq!(tr.period(), Some(3));
        assert!(forest.by_period(3).is_some());
        assert!(forest.by_period(4).is_none());
        let tree = tr.render_tree();
        assert!(tree.contains("period[3]"), "{tree}");
        assert!(tree.contains("orphan deliver[9]"), "{tree}");
    }

    #[test]
    fn critical_path_picks_latest_ending_chain() {
        let t = 1u64;
        let spans = vec![
            rec("period", 0, 0.0, 100.0, t, 10, 0),
            rec("measure", 0, 0.0, 90.0, t, 20, 10),
            rec("probe", 1, 1.0, 5.0, t, 30, 20),
            rec("probe", 2, 1.0, 60.0, t, 40, 20),
            rec("retx", 2, 70.0, 19.0, t, 50, 40),
            rec("swap", 0, 95.0, 2.0, t, 60, 10),
        ];
        let forest = assemble(&spans);
        let tr = &forest.traces[0];
        let (chain, ms) = tr.critical_chain();
        assert_eq!(
            chain,
            "period[0] -> measure[0] -> probe[2] -> retx[2]"
        );
        assert!((ms - 100.0).abs() < 1e-9, "{ms}");
        let summary = forest.summary_jsonl();
        assert!(summary.contains("\"critical_ms\":100"), "{summary}");
        assert!(summary.contains("retx[2]"), "{summary}");
    }

    #[test]
    fn jsonl_round_trips_hex_ids_and_skips_annotations() {
        let line = concat!(
            "{\"annotation\": \"wall export\"}\n",
            "{\"dur_ms\": 2, \"id\": 17, \"kind\": \"probe\", ",
            "\"parent\": \"00000000000000aa\", ",
            "\"span\": \"00000000000000bb\", \"t_ms\": 1, ",
            "\"trace\": \"00000000000000cc\"}\n",
            "{\"dur_ms\": 0, \"id\": 1, \"kind\": \"decide\", ",
            "\"t_ms\": 3}\n"
        );
        let spans = parse_jsonl(line).unwrap();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].parent, 0xaa);
        assert_eq!(spans[0].span, 0xbb);
        assert_eq!(spans[0].trace, 0xcc);
        assert_eq!(spans[1].trace, 0, "absent ids default to none");
        assert!(parse_jsonl("{\"kind\": \"x\", \"id\": 0, \
                 \"t_ms\": 0, \"dur_ms\": 0, \"span\": \"zz\"}")
            .is_err());
    }

    #[test]
    fn assembly_survives_self_parent_cycles() {
        let spans = vec![
            rec("a", 0, 0.0, 1.0, 9, 5, 5), // self-parent
            rec("b", 1, 0.0, 1.0, 9, 6, 7),
            rec("c", 2, 0.0, 1.0, 9, 7, 6), // 6 <-> 7 cycle
        ];
        let forest = assemble(&spans);
        let tr = &forest.traces[0];
        assert!(tr.roots.is_empty());
        assert_eq!(tr.orphans, vec![0], "self-edge dropped to orphan");
        // No roots: rendering and critical path stay total.
        assert!(tr.critical_path().is_empty());
        let _ = tr.render_tree();
    }
}
