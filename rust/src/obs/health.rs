//! `health.json` SLO digest: pass/fail verdicts over a run's
//! registry snapshot (frame-loss hygiene, estimator gap, churn-guard
//! pressure) plus, when a traffic run supplies one, the user-facing
//! p99 / success-rate SLOs.
//!
//! The digest reads a *snapshot* [`Json`] (the exact object written
//! to `snapshot.json`) rather than the live registry, so producing it
//! never registers counters and cannot perturb the byte-deterministic
//! snapshot it sits next to.

use crate::util::json::Json;

/// Ceiling on stale (cross-epoch) frames per frame sent.
pub const MAX_STALE_RATE: f64 = 0.05;
/// Ceiling on duplicate deliveries per frame sent.
pub const MAX_DUP_RATE: f64 = 0.05;
/// Ceiling on probe retransmissions per frame sent.
pub const MAX_RETX_RATE: f64 = 0.10;
/// Ceiling on written-off (lost) frames per frame sent.
pub const MAX_LOST_RATE: f64 = 0.10;
/// Ceiling on the worst certified-estimate gap (% of upper bound).
pub const MAX_EST_GAP_PCT: f64 = 30.0;
/// Ceiling on churn-guard swap suppressions per run.
pub const MAX_GUARD_SKIPS: f64 = 8.0;
/// Ceiling on traffic p99 end-to-end latency (sim ms).
pub const MAX_P99_MS: f64 = 250.0;
/// Floor on traffic delivery success rate.
pub const MIN_SUCCESS_RATE: f64 = 0.995;

/// User-facing traffic SLO inputs, taken from a
/// [`TrafficReport`](crate::traffic::TrafficReport).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrafficSlo {
    /// p99 end-to-end request latency (sim ms).
    pub p99_ms: f64,
    /// Delivered / offered.
    pub success_rate: f64,
}

fn counter(snapshot: &Json, name: &str) -> f64 {
    snapshot
        .opt("counters")
        .and_then(|c| c.opt(name))
        .and_then(|v| v.as_f64().ok())
        .unwrap_or(0.0)
}

fn hist_max(snapshot: &Json, name: &str) -> Option<f64> {
    let h = snapshot.opt("histograms")?.opt(name)?;
    let count = h.opt("count")?.as_f64().ok()?;
    if count > 0.0 {
        h.opt("max")?.as_f64().ok()
    } else {
        None
    }
}

/// One check: `value` against `threshold` — a ceiling by default, a
/// floor (`value >= threshold`) when `floor` is set.
fn check(value: f64, threshold: f64, floor: bool) -> (bool, Json) {
    let pass = if floor {
        value >= threshold
    } else {
        value <= threshold
    };
    (
        pass,
        Json::obj(vec![
            ("pass", Json::Bool(pass)),
            ("threshold", Json::num(threshold)),
            ("value", Json::num(value)),
        ]),
    )
}

/// Build the `health.json` digest from a registry snapshot and an
/// optional traffic SLO. Frame-hygiene rates are computed against
/// `net.frames_sent`; with no frames sent they are 0 and pass
/// trivially. The estimator-gap check only appears when the run
/// recorded estimator activity. Overall `verdict` is `"pass"` iff
/// every present check passes.
pub fn health_json(snapshot: &Json, traffic: Option<&TrafficSlo>) -> Json {
    let sent = counter(snapshot, "net.frames_sent");
    let rate = |name: &str| {
        if sent > 0.0 {
            counter(snapshot, name) / sent
        } else {
            0.0
        }
    };
    let mut all_pass = true;
    let mut checks: Vec<(&str, Json)> = Vec::new();
    let mut push = |checks: &mut Vec<(&'static str, Json)>,
                    name: &'static str,
                    value: f64,
                    threshold: f64,
                    floor: bool| {
        let (pass, js) = check(value, threshold, floor);
        all_pass &= pass;
        checks.push((name, js));
    };
    push(
        &mut checks,
        "dup_rate",
        rate("net.dup_frames"),
        MAX_DUP_RATE,
        false,
    );
    if let Some(gap) = hist_max(snapshot, "eval.est_gap_pct") {
        push(&mut checks, "est_gap_pct", gap, MAX_EST_GAP_PCT, false);
    }
    push(
        &mut checks,
        "guard_skips",
        counter(snapshot, "rings.guard_skips"),
        MAX_GUARD_SKIPS,
        false,
    );
    push(
        &mut checks,
        "lost_rate",
        rate("net.frames_lost"),
        MAX_LOST_RATE,
        false,
    );
    push(
        &mut checks,
        "retx_rate",
        rate("net.probe_retx"),
        MAX_RETX_RATE,
        false,
    );
    push(
        &mut checks,
        "stale_rate",
        rate("net.stale_frames"),
        MAX_STALE_RATE,
        false,
    );
    if let Some(slo) = traffic {
        push(&mut checks, "traffic_p99_ms", slo.p99_ms, MAX_P99_MS, false);
        push(
            &mut checks,
            "traffic_success_rate",
            slo.success_rate,
            MIN_SUCCESS_RATE,
            true,
        );
    }
    Json::obj(vec![
        ("checks", Json::obj(checks)),
        ("frames_sent", Json::num(sent)),
        (
            "verdict",
            Json::str(if all_pass { "pass" } else { "fail" }),
        ),
    ])
}

/// Render a health digest as aligned text, one check per line.
pub fn render(health: &Json) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let verdict = health
        .opt("verdict")
        .and_then(|v| v.as_str().ok())
        .unwrap_or("?");
    let _ = writeln!(out, "health: {verdict}");
    if let Some(checks) = health.opt("checks").and_then(|c| c.as_obj().ok())
    {
        for (name, c) in checks {
            let pass = c
                .opt("pass")
                .and_then(|p| p.as_bool().ok())
                .unwrap_or(false);
            let value = c
                .opt("value")
                .and_then(|v| v.as_f64().ok())
                .unwrap_or(f64::NAN);
            let thr = c
                .opt("threshold")
                .and_then(|v| v.as_f64().ok())
                .unwrap_or(f64::NAN);
            let _ = writeln!(
                out,
                "  {:<4} {name:<22} value={value:<12.4} \
                 threshold={thr:.4}",
                if pass { "ok" } else { "FAIL" },
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Obs;

    #[test]
    fn clean_run_passes_and_skips_absent_checks() {
        let obs = Obs::new();
        obs.reg.incr("net.frames_sent", 100);
        obs.reg.incr("net.stale_frames", 1);
        let h = health_json(&obs.reg.to_json(), None);
        assert_eq!(h.get("verdict").unwrap().as_str().unwrap(), "pass");
        let checks = h.get("checks").unwrap();
        assert!(checks.opt("stale_rate").is_some());
        assert!(checks.opt("est_gap_pct").is_none(), "no estimator ran");
        assert!(checks.opt("traffic_p99_ms").is_none(), "no traffic");
        let v = checks.get("stale_rate").unwrap();
        assert_eq!(v.get("value").unwrap().as_f64().unwrap(), 0.01);
        assert!(v.get("pass").unwrap().as_bool().unwrap());
        let text = render(&h);
        assert!(text.contains("health: pass"), "{text}");
        assert!(text.contains("stale_rate"), "{text}");
    }

    #[test]
    fn violations_flip_the_verdict() {
        let obs = Obs::new();
        obs.reg.incr("net.frames_sent", 100);
        obs.reg.incr("net.frames_lost", 50);
        let h = health_json(&obs.reg.to_json(), None);
        assert_eq!(h.get("verdict").unwrap().as_str().unwrap(), "fail");
        let lost = h.get("checks").unwrap().get("lost_rate").unwrap();
        assert!(!lost.get("pass").unwrap().as_bool().unwrap());
        assert!(render(&h).contains("FAIL lost_rate"));
    }

    #[test]
    fn traffic_slo_checks_both_directions() {
        let snap = Json::obj(vec![]);
        let good = TrafficSlo {
            p99_ms: 12.0,
            success_rate: 1.0,
        };
        let h = health_json(&snap, Some(&good));
        assert_eq!(h.get("verdict").unwrap().as_str().unwrap(), "pass");
        let slow = TrafficSlo {
            p99_ms: 900.0,
            success_rate: 0.5,
        };
        let h = health_json(&snap, Some(&slow));
        assert_eq!(h.get("verdict").unwrap().as_str().unwrap(), "fail");
        let checks = h.get("checks").unwrap();
        assert!(!checks
            .get("traffic_p99_ms")
            .unwrap()
            .get("pass")
            .unwrap()
            .as_bool()
            .unwrap());
        assert!(!checks
            .get("traffic_success_rate")
            .unwrap()
            .get("pass")
            .unwrap()
            .as_bool()
            .unwrap());
    }
}
