//! Observability substrate: the shared atomic metrics [`Registry`]
//! and the span-based flight [`Recorder`], bundled per run as [`Obs`].
//!
//! Every coordinator ([`crate::net::NetCoordinator`],
//! [`crate::coordinator::Coordinator`],
//! [`crate::coordinator::sharded::ShardedCoordinator`]) owns an `Obs`
//! and hands clones to whatever records on its behalf — transports
//! via [`crate::net::Transport::attach_obs`], shards inside the
//! `scoped_map` fan-out, the [`crate::graph::eval::EvalPool`] — so
//! hot paths record through atomics instead of threading `&mut
//! metrics::Metrics` through every call.
//!
//! Counters are always on (an atomic add per event); span recording
//! is opt-in per run. At the end of a run the coordinator folds the
//! registry's *counters* back into its [`crate::metrics::Metrics`]
//! (see [`sync_counters`]) so rendered reports keep their
//! byte-determinism pins; wall-time histograms stay registry-only.
//!
//! Artifacts: [`Obs::write_dir`] emits `snapshot.json`,
//! `metrics.prom`, `timeline.jsonl`, `traces.jsonl` (assembled
//! causal-trace summaries) and `health.json` (SLO digest) into
//! `--obs-out DIR`; the `dgro obs` subcommand (`dump`, `diff`,
//! `top`, `trace`, `critical`, `health`) reads them back. Formats
//! are documented in `docs/OBSERVABILITY.md`.

pub mod health;
pub mod recorder;
pub mod registry;
pub mod trace;

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

pub use health::{health_json, TrafficSlo};
pub use recorder::{Recorder, Span, SpanTimer, DEFAULT_CAPACITY};
pub use registry::{bucket_bound, CounterVec, Histogram, Registry};
pub use trace::{span_id, trace_id, Forest, SpanRec, TraceCtx};

use crate::metrics::Metrics;
use crate::util::json::{self, Json};

/// One run's observability sinks: a registry plus a flight recorder.
/// Cloning shares both (they are `Arc`s), which is how shards, node
/// actors and transports all record into the same run.
#[derive(Clone)]
pub struct Obs {
    /// The metrics registry (counters always on).
    pub reg: Arc<Registry>,
    /// The span flight recorder (disabled until
    /// [`Recorder::set_enabled`]).
    pub rec: Arc<Recorder>,
}

impl Default for Obs {
    fn default() -> Obs {
        Obs::new()
    }
}

impl std::fmt::Debug for Obs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Obs")
            .field("counters", &self.reg.counters_snapshot().len())
            .field("spans", &self.rec.len())
            .field("recording", &self.rec.is_enabled())
            .finish()
    }
}

impl Obs {
    /// Fresh sinks with the default recorder capacity; spans are
    /// disabled until requested.
    pub fn new() -> Obs {
        Obs {
            reg: Arc::new(Registry::new()),
            rec: Arc::new(Recorder::new(DEFAULT_CAPACITY)),
        }
    }

    /// Fresh sinks with span recording already enabled.
    pub fn recording() -> Obs {
        let obs = Obs::new();
        obs.rec.set_enabled(true);
        obs
    }

    /// Full JSON snapshot (registry plus recorder occupancy).
    pub fn snapshot_json(&self) -> Json {
        let mut root = match self.reg.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("registry snapshot is an object"),
        };
        root.insert(
            "spans".to_string(),
            Json::obj(vec![
                ("buffered", Json::num(self.rec.len() as f64)),
                ("dropped", Json::num(self.rec.dropped() as f64)),
            ]),
        );
        Json::Obj(root)
    }

    /// Write the artifact set into `dir` (created if missing):
    /// `snapshot.json`, `metrics.prom`, `timeline.jsonl`,
    /// `traces.jsonl` (one summary line per assembled causal trace)
    /// and `health.json` (SLO digest over the snapshot). With
    /// `sim_only` the timeline omits wall-clock fields and every
    /// artifact is byte-deterministic for seeded sim runs; a
    /// recorder-ring overflow fails the sim-only export loudly
    /// instead of silently voiding that contract.
    pub fn write_dir(&self, dir: &Path, sim_only: bool) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        std::fs::write(
            dir.join("snapshot.json"),
            self.snapshot_json().to_string(),
        )?;
        std::fs::write(dir.join("metrics.prom"), self.reg.prometheus())?;
        std::fs::write(
            dir.join("timeline.jsonl"),
            self.rec.export_jsonl(sim_only)?,
        )?;
        let spans: Vec<SpanRec> =
            self.rec.spans().iter().map(SpanRec::from).collect();
        std::fs::write(
            dir.join("traces.jsonl"),
            trace::assemble(&spans).summary_jsonl(),
        )?;
        std::fs::write(
            dir.join("health.json"),
            health_json(&self.reg.to_json(), None).to_string(),
        )?;
        Ok(())
    }
}

/// Fold the registry's plain counters into a [`Metrics`] sink by
/// raising each metrics counter to the registry value (idempotent;
/// never decreases). Counter vectors and histograms are deliberately
/// excluded — they carry per-index or wall-clock detail that the
/// deterministic rendered reports must not depend on.
pub fn sync_counters(reg: &Registry, metrics: &mut Metrics) {
    for (name, v) in reg.counters_snapshot() {
        let have = metrics.counter(&name);
        if v > have {
            metrics.incr(&name, v - have);
        }
    }
}

// ---------------------------------------------------------------------
// `dgro obs` tooling: file-level dump / diff / top.
// ---------------------------------------------------------------------

/// Render a `snapshot.json` file as an aligned text table.
pub fn dump_snapshot(path: &Path) -> Result<String> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let root = json::parse(&text)?;
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "counters:");
    for (name, v) in root.get("counters")?.as_obj()? {
        let _ = writeln!(out, "  {name:<40} {}", v.as_f64()? as u64);
    }
    if let Some(vecs) = root.opt("counter_vecs") {
        for (name, slots) in vecs.as_obj()? {
            let total: f64 = slots
                .as_arr()?
                .iter()
                .map(|s| s.as_f64().unwrap_or(0.0))
                .sum();
            let _ = writeln!(
                out,
                "  {name:<40} {} (over {} slots)",
                total as u64,
                slots.as_arr()?.len()
            );
        }
    }
    let _ = writeln!(out, "histograms:");
    for (name, h) in root.get("histograms")?.as_obj()? {
        let count = h.get("count")?.as_f64()?;
        let sum = h.get("sum")?.as_f64()?;
        let mean = if count > 0.0 { sum / count } else { 0.0 };
        let _ = writeln!(
            out,
            "  {name:<40} n={:<8} mean={:<12.4} min={:<12.4} \
             max={:<12.4} p99<={:.4}",
            count as u64,
            mean,
            h.get("min")?.as_f64()?,
            h.get("max")?.as_f64()?,
            h.get("p99")?.as_f64()?,
        );
    }
    Ok(out)
}

/// Diff two `snapshot.json` files: one line per counter or histogram
/// whose value differs, `a -> b` with the delta. Returns an empty
/// diff section text when the snapshots agree.
pub fn diff_snapshots(a: &Path, b: &Path) -> Result<String> {
    let ja = json::parse(&std::fs::read_to_string(a)?)?;
    let jb = json::parse(&std::fs::read_to_string(b)?)?;
    use std::collections::BTreeSet;
    use std::fmt::Write as _;
    let mut out = String::new();
    let ca = ja.get("counters")?.as_obj()?;
    let cb = jb.get("counters")?.as_obj()?;
    let names: BTreeSet<&String> = ca.keys().chain(cb.keys()).collect();
    let mut differing = 0usize;
    for name in names {
        let va = ca.get(name).map(|v| v.as_f64()).transpose()?.unwrap_or(0.0);
        let vb = cb.get(name).map(|v| v.as_f64()).transpose()?.unwrap_or(0.0);
        if va != vb {
            differing += 1;
            let _ = writeln!(
                out,
                "counter   {name:<40} {va} -> {vb} ({:+})",
                vb - va
            );
        }
    }
    let ha = ja.get("histograms")?.as_obj()?;
    let hb = jb.get("histograms")?.as_obj()?;
    let names: BTreeSet<&String> = ha.keys().chain(hb.keys()).collect();
    for name in names {
        let count = |m: &std::collections::BTreeMap<String, Json>| {
            m.get(name)
                .and_then(|h| h.opt("count"))
                .and_then(|c| c.as_f64().ok())
                .unwrap_or(0.0)
        };
        let (na, nb) = (count(ha), count(hb));
        if na != nb {
            differing += 1;
            let _ = writeln!(
                out,
                "histogram {name:<40} n {na} -> {nb} ({:+})",
                nb - na
            );
        }
    }
    if differing == 0 {
        out.push_str("snapshots agree\n");
    }
    Ok(out)
}

/// The `N` slowest spans of a `timeline.jsonl` file, slowest first.
/// Ranks by wall time when present (full exports), sim duration
/// otherwise (deterministic exports).
pub fn top_slowest(path: &Path, n: usize) -> Result<String> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut rows: Vec<(f64, f64, f64, String, u64)> = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let js = json::parse(line)?;
        if js.opt("kind").is_none() {
            // Annotation header (e.g. ring-overflow note), not a span.
            continue;
        }
        let dur = js.get("dur_ms")?.as_f64()?;
        let wall = js
            .opt("wall_ms")
            .map(|w| w.as_f64())
            .transpose()?
            .unwrap_or(dur);
        rows.push((
            wall,
            dur,
            js.get("t_ms")?.as_f64()?,
            js.get("kind")?.as_str()?.to_string(),
            js.get("id")?.as_f64()? as u64,
        ));
    }
    rows.sort_by(|a, b| {
        b.0.total_cmp(&a.0)
            .then_with(|| a.2.total_cmp(&b.2))
            .then_with(|| a.3.cmp(&b.3))
    });
    rows.truncate(n);
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<10} {:>6} {:>12} {:>12} {:>12}",
        "kind", "id", "t_ms", "dur_ms", "wall_ms"
    );
    for (wall, dur, t, kind, id) in rows {
        let _ = writeln!(
            out,
            "{kind:<10} {id:>6} {t:>12.3} {dur:>12.3} {wall:>12.3}"
        );
    }
    Ok(out)
}

/// Estimator health lines for `dgro obs top`: the certified-gap
/// histogram (`eval.est_gap_pct`) and the peak scratch footprint
/// (`eval.peak_scratch_bytes`) from a `snapshot.json`. Returns an
/// empty string when the snapshot is missing or records no estimator
/// activity, so callers can append it unconditionally.
pub fn estimator_summary(path: &Path) -> Result<String> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Ok(String::new());
    };
    let root = json::parse(&text)?;
    use std::fmt::Write as _;
    let mut out = String::new();
    if let Some(h) = root
        .opt("histograms")
        .and_then(|hs| hs.opt("eval.est_gap_pct"))
    {
        let count = h.get("count")?.as_f64()?;
        if count > 0.0 {
            let mean = h.get("sum")?.as_f64()? / count;
            let _ = writeln!(
                out,
                "estimator gap: n={} mean={mean:.2}% max={:.2}% \
                 (upper-lower as % of upper)",
                count as u64,
                h.get("max")?.as_f64()?
            );
        }
    }
    if let Some(c) = root
        .opt("counters")
        .and_then(|cs| cs.opt("eval.peak_scratch_bytes"))
    {
        let bytes = c.as_f64()?;
        if bytes > 0.0 {
            let _ = writeln!(
                out,
                "estimator peak scratch: {:.2} MiB",
                bytes / (1024.0 * 1024.0)
            );
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_counters_is_idempotent_and_monotone() {
        let obs = Obs::new();
        obs.reg.incr("net.stale_frames", 3);
        let mut m = Metrics::default();
        m.incr("pre.existing", 1);
        sync_counters(&obs.reg, &mut m);
        sync_counters(&obs.reg, &mut m);
        assert_eq!(m.counter("net.stale_frames"), 3);
        assert_eq!(m.counter("pre.existing"), 1);
        obs.reg.incr("net.stale_frames", 2);
        sync_counters(&obs.reg, &mut m);
        assert_eq!(m.counter("net.stale_frames"), 5);
    }

    #[test]
    fn artifact_triple_round_trips_through_tooling() {
        let obs = Obs::recording();
        obs.reg.incr("gossip.messages", 12);
        obs.reg.histogram("period.wall_ms").observe(2.5);
        obs.rec.record("period", 0, 0.0, 250.0, 4.0);
        obs.rec.record("measure", 0, 0.0, 60.0, 2.0);
        let dir = std::env::temp_dir().join(format!(
            "dgro-obs-test-{}",
            std::process::id()
        ));
        obs.write_dir(&dir, true).unwrap();
        let dump = dump_snapshot(&dir.join("snapshot.json")).unwrap();
        assert!(dump.contains("gossip.messages"));
        assert!(dump.contains("period.wall_ms"));
        let top = top_slowest(&dir.join("timeline.jsonl"), 1).unwrap();
        assert!(top.contains("period"), "slowest span wins: {top}");
        // The causal artifacts ride along: untraced spans assemble
        // into no traces, and a loss-free run passes its SLOs.
        let traces =
            std::fs::read_to_string(dir.join("traces.jsonl")).unwrap();
        assert!(traces.is_empty(), "{traces}");
        let health =
            std::fs::read_to_string(dir.join("health.json")).unwrap();
        assert!(health.contains("\"verdict\":\"pass\""), "{health}");
        // A second identical run diffs clean against itself...
        let snap = dir.join("snapshot.json");
        let same = diff_snapshots(&snap, &snap).unwrap();
        assert!(same.contains("snapshots agree"));
        // ...and a mutated run shows the counter delta.
        let obs2 = Obs::new();
        obs2.reg.incr("gossip.messages", 15);
        obs2.reg.histogram("period.wall_ms").observe(2.5);
        let dir2 = dir.join("b");
        obs2.write_dir(&dir2, true).unwrap();
        let diff = diff_snapshots(
            &dir.join("snapshot.json"),
            &dir2.join("snapshot.json"),
        )
        .unwrap();
        assert!(diff.contains("gossip.messages"));
        assert!(diff.contains("12 -> 15"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn traced_artifacts_round_trip_and_tooling_skips_annotations() {
        let obs = Obs::recording();
        let t = trace::trace_id(0, 1);
        let root = trace::span_id(t, "period", 1, 0);
        let m = trace::span_id(t, "measure", 1, 0);
        obs.rec
            .record_traced("period", 1, 0.0, 100.0, 1.0, t, root, 0);
        obs.rec
            .record_traced("measure", 1, 0.0, 80.0, 1.0, t, m, root);
        let dir = std::env::temp_dir().join(format!(
            "dgro-obs-traced-{}",
            std::process::id()
        ));
        obs.write_dir(&dir, true).unwrap();
        let traces =
            std::fs::read_to_string(dir.join("traces.jsonl")).unwrap();
        assert_eq!(traces.lines().count(), 1);
        assert!(traces.contains("period[1] -> measure[1]"), "{traces}");
        assert!(traces.contains("\"orphans\":0"), "{traces}");
        // The timeline parses back into the same assembled summary.
        let timeline =
            std::fs::read_to_string(dir.join("timeline.jsonl")).unwrap();
        let spans = trace::parse_jsonl(&timeline).unwrap();
        assert_eq!(trace::assemble(&spans).summary_jsonl(), traces);
        // Annotation headers are skipped by the span tooling.
        let p = dir.join("wall.jsonl");
        std::fs::write(
            &p,
            "{\"annotation\":\"x\",\"dropped\":2}\n\
             {\"dur_ms\":1,\"id\":0,\"kind\":\"period\",\"t_ms\":0}\n",
        )
        .unwrap();
        let top = top_slowest(&p, 5).unwrap();
        assert!(top.contains("period"), "{top}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn estimator_summary_reads_snapshot_or_stays_silent() {
        let obs = Obs::new();
        obs.reg.histogram("eval.est_gap_pct").observe(4.0);
        obs.reg.histogram("eval.est_gap_pct").observe(8.0);
        let c = obs.reg.counter("eval.peak_scratch_bytes");
        c.fetch_max(3 << 20, std::sync::atomic::Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "dgro-obs-est-{}",
            std::process::id()
        ));
        obs.write_dir(&dir, true).unwrap();
        let s = estimator_summary(&dir.join("snapshot.json")).unwrap();
        assert!(s.contains("n=2 mean=6.00% max=8.00%"), "{s}");
        assert!(s.contains("peak scratch: 3.00 MiB"), "{s}");
        // Missing files and estimator-free snapshots render nothing.
        assert!(estimator_summary(&dir.join("no.json")).unwrap().is_empty());
        let quiet = Obs::new();
        quiet.reg.incr("gossip.messages", 1);
        let dir2 = dir.join("b");
        quiet.write_dir(&dir2, true).unwrap();
        let s2 = estimator_summary(&dir2.join("snapshot.json")).unwrap();
        assert!(s2.is_empty(), "{s2}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
