//! The span-based flight recorder: scoped spans (`period`, `measure`,
//! `gossip`, `decide`, `swap`, `reanchor`, `dial`, `probe`, `retx`,
//! `deliver`) carrying sim-time and wall-time into a bounded ring
//! buffer, exported as JSONL.
//!
//! Determinism contract: the sim-only export (`export_jsonl(true)`)
//! contains only sim-clock fields and is sorted by a total order on
//! `(t_ms, kind, id, dur_ms, span)`, so two seeded runs over the sim
//! transport — at any thread count — export byte-identical timelines
//! as long as the buffer never overflows. Overflow evicts the oldest
//! span in *arrival* order (which is scheduling-dependent), so
//! `dropped() > 0` voids the determinism guarantee; the sim-only
//! export **fails loudly** in that case instead of emitting a
//! scheduling-dependent timeline, and the wall export annotates its
//! header. Size the capacity for the run.
//!
//! Spans optionally carry causal identity — a trace id, their own
//! span id and a parent span id (see [`crate::obs::trace`] for the
//! deterministic derivation). All three are 0 on untraced spans and
//! are exported as 16-digit hex strings when present.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::util::json::Json;

/// Default ring capacity: comfortably above any scenario in the
/// catalog (16 periods × 10 shards × a handful of span kinds).
pub const DEFAULT_CAPACITY: usize = 65_536;

/// One recorded span.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    /// Span kind (`period`, `measure`, `gossip`, `decide`, `swap`,
    /// `reanchor`, `dial`, `probe`, `retx`, `deliver`).
    pub kind: &'static str,
    /// Discriminator within a kind: period index, shard index, peer
    /// index — whatever the recording site counts by.
    pub id: u64,
    /// Sim-time start (ms).
    pub t_ms: f64,
    /// Sim-time duration (ms); 0 for in-process work with no sim
    /// clock.
    pub dur_ms: f64,
    /// Wall-clock duration (ms); excluded from deterministic exports.
    pub wall_ms: f64,
    /// Trace id (0 = untraced).
    pub trace: u64,
    /// This span's causal id (0 = untraced).
    pub span: u64,
    /// Parent span id (0 = root or untraced).
    pub parent: u64,
}

struct Inner {
    spans: Vec<Span>,
    /// Next write slot once the ring is full.
    head: usize,
}

/// Bounded, thread-safe span sink. Disabled by default — a disabled
/// recorder's `record` is a single atomic load.
pub struct Recorder {
    enabled: AtomicBool,
    cap: usize,
    dropped: AtomicU64,
    inner: Mutex<Inner>,
}

impl Recorder {
    /// A disabled recorder with `cap` span slots.
    pub fn new(cap: usize) -> Recorder {
        Recorder {
            enabled: AtomicBool::new(false),
            cap: cap.max(1),
            dropped: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                spans: Vec::new(),
                head: 0,
            }),
        }
    }

    /// Turn span recording on or off (counters are unaffected).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Whether spans are currently recorded.
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record one finished untraced span (no-op while disabled).
    pub fn record(
        &self,
        kind: &'static str,
        id: u64,
        t_ms: f64,
        dur_ms: f64,
        wall_ms: f64,
    ) {
        self.record_traced(kind, id, t_ms, dur_ms, wall_ms, 0, 0, 0);
    }

    /// Record one finished span with causal identity (no-op while
    /// disabled). `trace`/`span`/`parent` of 0 mean untraced / root.
    #[allow(clippy::too_many_arguments)]
    pub fn record_traced(
        &self,
        kind: &'static str,
        id: u64,
        t_ms: f64,
        dur_ms: f64,
        wall_ms: f64,
        trace: u64,
        span: u64,
        parent: u64,
    ) {
        if !self.is_enabled() {
            return;
        }
        let span = Span {
            kind,
            id,
            t_ms,
            dur_ms,
            wall_ms,
            trace,
            span,
            parent,
        };
        let mut inner = self.inner.lock().unwrap();
        if inner.spans.len() < self.cap {
            inner.spans.push(span);
        } else {
            let head = inner.head;
            inner.spans[head] = span;
            inner.head = (head + 1) % self.cap;
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Start a span at sim-time `t_ms`; finish it with
    /// [`SpanTimer::finish`] once the end sim-time is known. Attach
    /// causal identity with [`SpanTimer::traced`].
    pub fn start(
        &self,
        kind: &'static str,
        id: u64,
        t_ms: f64,
    ) -> SpanTimer {
        SpanTimer {
            kind,
            id,
            t_ms,
            wall0: Instant::now(),
            trace: 0,
            span: 0,
            parent: 0,
        }
    }

    /// Number of spans currently buffered.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().spans.len()
    }

    /// Whether no spans are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans evicted by ring overflow (non-zero voids determinism).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Sorted copy of the buffered spans.
    pub fn spans(&self) -> Vec<Span> {
        let mut spans = self.inner.lock().unwrap().spans.clone();
        spans.sort_by(|a, b| {
            a.t_ms
                .total_cmp(&b.t_ms)
                .then_with(|| a.kind.cmp(b.kind))
                .then_with(|| a.id.cmp(&b.id))
                .then_with(|| a.dur_ms.total_cmp(&b.dur_ms))
                .then_with(|| a.span.cmp(&b.span))
        });
        spans
    }

    /// JSONL timeline export, one span per line, sorted. With
    /// `sim_only` the wall field is omitted and the output is
    /// byte-deterministic for seeded sim runs (see module docs) —
    /// unless the ring overflowed, in which case the timeline is
    /// scheduling-dependent and this **returns an error** instead of
    /// silently voiding the contract. The wall export tolerates
    /// overflow but leads with an annotation line (no `kind` field;
    /// readers skip it) recording the drop count.
    pub fn export_jsonl(&self, sim_only: bool) -> Result<String> {
        let dropped = self.dropped();
        if sim_only && dropped > 0 {
            anyhow::bail!(
                "recorder ring overflowed ({dropped} spans dropped in \
                 arrival order): the sim-only timeline would be \
                 scheduling-dependent; raise the recorder capacity \
                 (DEFAULT_CAPACITY={DEFAULT_CAPACITY}) or record \
                 fewer spans (e.g. a sparser --trace-sample)"
            );
        }
        let mut out = String::new();
        if dropped > 0 {
            out.push_str(
                &Json::obj(vec![
                    (
                        "annotation",
                        Json::str("ring overflow: timeline truncated"),
                    ),
                    ("dropped", Json::num(dropped as f64)),
                ])
                .to_string(),
            );
            out.push('\n');
        }
        for s in self.spans() {
            let mut fields = vec![
                ("dur_ms", Json::num(s.dur_ms)),
                ("id", Json::num(s.id as f64)),
                ("kind", Json::str(s.kind)),
                ("t_ms", Json::num(s.t_ms)),
            ];
            if s.trace != 0 {
                if s.parent != 0 {
                    fields.push((
                        "parent",
                        Json::str(&format!("{:016x}", s.parent)),
                    ));
                }
                fields
                    .push(("span", Json::str(&format!("{:016x}", s.span))));
                fields.push((
                    "trace",
                    Json::str(&format!("{:016x}", s.trace)),
                ));
            }
            if !sim_only {
                fields.push(("wall_ms", Json::num(s.wall_ms)));
            }
            out.push_str(&Json::obj(fields).to_string());
            out.push('\n');
        }
        Ok(out)
    }
}

/// An in-flight span started by [`Recorder::start`]: wall time runs
/// from construction; the caller supplies the end sim-time.
pub struct SpanTimer {
    kind: &'static str,
    id: u64,
    t_ms: f64,
    wall0: Instant,
    trace: u64,
    span: u64,
    parent: u64,
}

impl SpanTimer {
    /// Attach causal identity to the in-flight span (builder-style).
    pub fn traced(mut self, trace: u64, span: u64, parent: u64) -> Self {
        self.trace = trace;
        self.span = span;
        self.parent = parent;
        self
    }

    /// Close the span at sim-time `end_ms` and record it.
    pub fn finish(self, rec: &Recorder, end_ms: f64) {
        rec.record_traced(
            self.kind,
            self.id,
            self.t_ms,
            (end_ms - self.t_ms).max(0.0),
            self.wall0.elapsed().as_secs_f64() * 1e3,
            self.trace,
            self.span,
            self.parent,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let rec = Recorder::new(8);
        rec.record("period", 0, 0.0, 1.0, 1.0);
        assert!(rec.is_empty());
        rec.set_enabled(true);
        rec.record("period", 0, 0.0, 1.0, 1.0);
        assert_eq!(rec.len(), 1);
    }

    #[test]
    fn ring_bounds_memory_and_counts_drops() {
        let rec = Recorder::new(4);
        rec.set_enabled(true);
        for i in 0..10 {
            rec.record("measure", i, i as f64, 1.0, 0.5);
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 6);
        // Oldest spans were evicted: the survivors are the last four.
        let ids: Vec<u64> = rec.spans().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9]);
    }

    #[test]
    fn export_is_sorted_and_sim_only_omits_wall() {
        let rec = Recorder::new(16);
        rec.set_enabled(true);
        rec.record("swap", 2, 500.0, 0.0, 3.0);
        rec.record("measure", 0, 250.0, 40.0, 9.0);
        rec.record("decide", 1, 250.0, 0.0, 1.0);
        let sim = rec.export_jsonl(true).unwrap();
        let lines: Vec<&str> = sim.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("\"kind\":\"decide\""), "{sim}");
        assert!(lines[1].contains("\"kind\":\"measure\""), "{sim}");
        assert!(lines[2].contains("\"kind\":\"swap\""), "{sim}");
        assert!(!sim.contains("wall_ms"));
        assert!(rec.export_jsonl(false).unwrap().contains("wall_ms"));
    }

    #[test]
    fn span_timer_measures_wall_and_sim() {
        let rec = Recorder::new(8);
        rec.set_enabled(true);
        let t = rec.start("gossip", 3, 100.0);
        t.finish(&rec, 140.0);
        let spans = rec.spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].kind, "gossip");
        assert_eq!(spans[0].dur_ms, 40.0);
        assert!(spans[0].wall_ms >= 0.0);
    }

    #[test]
    fn traced_spans_export_hex_ids_and_sort_stably() {
        let rec = Recorder::new(8);
        rec.set_enabled(true);
        rec.record_traced("probe", 7, 1.0, 2.0, 0.1, 0xc, 0xb, 0xa);
        rec.record_traced("probe", 7, 1.0, 2.0, 0.1, 0xc, 0x9, 0xa);
        let t = rec.start("swap", 1, 5.0).traced(0xc, 0xd, 0);
        t.finish(&rec, 6.0);
        let sim = rec.export_jsonl(true).unwrap();
        let lines: Vec<&str> = sim.lines().collect();
        assert_eq!(lines.len(), 3);
        // Identical (t, kind, id, dur) probes tie-break on span id.
        assert!(lines[0].contains("\"span\":\"0000000000000009\""));
        assert!(lines[1].contains("\"span\":\"000000000000000b\""));
        assert!(lines[0].contains("\"parent\":\"000000000000000a\""));
        assert!(lines[0].contains("\"trace\":\"000000000000000c\""));
        // Root spans omit the parent field entirely.
        assert!(lines[2].contains("\"span\":\"000000000000000d\""));
        assert!(!lines[2].contains("parent"), "{sim}");
        // Untraced spans carry no trace fields at all.
        rec.record("decide", 0, 9.0, 0.0, 0.0);
        let sim = rec.export_jsonl(true).unwrap();
        let decide = sim
            .lines()
            .find(|l| l.contains("decide"))
            .unwrap();
        assert!(!decide.contains("trace"), "{decide}");
    }

    #[test]
    fn overflow_fails_sim_export_and_annotates_wall_export() {
        let rec = Recorder::new(2);
        rec.set_enabled(true);
        for i in 0..5 {
            rec.record("measure", i, i as f64, 1.0, 0.5);
        }
        assert_eq!(rec.dropped(), 3);
        // The deterministic export refuses to lie.
        let err = rec.export_jsonl(true).unwrap_err().to_string();
        assert!(err.contains("3 spans dropped"), "{err}");
        assert!(err.contains("scheduling-dependent"), "{err}");
        // The wall export leads with a kind-less annotation line.
        let wall = rec.export_jsonl(false).unwrap();
        let first = wall.lines().next().unwrap();
        assert!(first.contains("\"annotation\""), "{wall}");
        assert!(first.contains("\"dropped\":3"), "{wall}");
        assert!(!first.contains("\"kind\""), "{wall}");
        assert_eq!(wall.lines().count(), 3, "2 spans + annotation");
    }
}
