//! CLI argument parsing substrate (no clap offline — DESIGN.md §3).
//!
//! Model: `dgro <subcommand> [--flag value] [--switch]`. Flags are
//! declared up front so `--help` is generated and unknown flags are
//! rejected rather than silently ignored.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Declaration of one flag.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    /// Flag name (without the leading `--`).
    pub name: &'static str,
    /// One-line help shown in usage.
    pub help: &'static str,
    /// None = boolean switch; Some(default) = value flag.
    pub default: Option<String>,
}

/// A parsed command line.
#[derive(Clone, Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Value of a declared value flag (panics on undeclared names - a
    /// programming error, not user input).
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    /// [`Args::get`] parsed as usize, with a friendly error.
    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self.get(name);
        v.parse()
            .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'"))
    }

    /// [`Args::get`] parsed as f64, with a friendly error.
    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self.get(name);
        v.parse()
            .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'"))
    }

    /// [`Args::get`] parsed as u64, with a friendly error.
    pub fn get_u64(&self, name: &str) -> Result<u64> {
        let v = self.get(name);
        v.parse()
            .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'"))
    }

    /// Whether a declared boolean switch was passed.
    pub fn switch(&self, name: &str) -> bool {
        *self
            .switches
            .get(name)
            .unwrap_or_else(|| panic!("switch --{name} not declared"))
    }
}

/// A subcommand parser.
pub struct Command {
    /// Subcommand name (after the binary name).
    pub name: &'static str,
    /// One-line description for usage output.
    pub about: &'static str,
    flags: Vec<FlagSpec>,
}

impl Command {
    /// Start declaring a subcommand.
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command {
            name,
            about,
            flags: Vec::new(),
        }
    }

    /// Declare a value flag with a default.
    pub fn flag(
        mut self,
        name: &'static str,
        default: &str,
        help: &'static str,
    ) -> Command {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(default.to_string()),
        });
        self
    }

    /// Declare a boolean switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Command {
        self.flags.push(FlagSpec {
            name,
            help,
            default: None,
        });
        self
    }

    /// Render the flag table for `--help`/unknown-flag errors.
    pub fn usage(&self) -> String {
        let mut s = format!("dgro {} — {}\n\nflags:\n", self.name, self.about);
        for f in &self.flags {
            match &f.default {
                Some(d) => s.push_str(&format!(
                    "  --{:<18} {} (default: {})\n",
                    f.name, f.help, d
                )),
                None => {
                    s.push_str(&format!("  --{:<18} {}\n", f.name, f.help))
                }
            }
        }
        s
    }

    /// Parse raw args (everything after the subcommand token).
    pub fn parse(&self, raw: &[String]) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut switches = BTreeMap::new();
        for f in &self.flags {
            match &f.default {
                Some(d) => {
                    values.insert(f.name.to_string(), d.clone());
                }
                None => {
                    switches.insert(f.name.to_string(), false);
                }
            }
        }
        let mut positional = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(name) = tok.strip_prefix("--") {
                // --name=value or --name value or switch.
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                if switches.contains_key(name) {
                    if inline.is_some() {
                        bail!("switch --{name} takes no value");
                    }
                    switches.insert(name.to_string(), true);
                } else if values.contains_key(name) {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .ok_or_else(|| {
                                    anyhow::anyhow!("--{name} needs a value")
                                })?
                                .clone()
                        }
                    };
                    values.insert(name.to_string(), val);
                } else {
                    bail!(
                        "unknown flag --{name} for '{}'\n\n{}",
                        self.name,
                        self.usage()
                    );
                }
            } else {
                positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(Args {
            values,
            switches,
            positional,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("build", "build a topology")
            .flag("nodes", "100", "number of nodes")
            .flag("model", "uniform", "latency model")
            .switch("verbose", "chatty output")
    }

    fn s(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&s(&[])).unwrap();
        assert_eq!(a.get("nodes"), "100");
        assert_eq!(a.get_usize("nodes").unwrap(), 100);
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn values_and_switches() {
        let a = cmd()
            .parse(&s(&["--nodes", "50", "--verbose", "--model=fabric"]))
            .unwrap();
        assert_eq!(a.get_usize("nodes").unwrap(), 50);
        assert_eq!(a.get("model"), "fabric");
        assert!(a.switch("verbose"));
    }

    #[test]
    fn unknown_flag_rejected_with_usage() {
        let err = cmd().parse(&s(&["--bogus", "1"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown flag --bogus"));
        assert!(msg.contains("--nodes"));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&s(&["--nodes"])).is_err());
    }

    #[test]
    fn positional_args_collected() {
        let a = cmd().parse(&s(&["out.csv", "--nodes", "10"])).unwrap();
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn bad_number_is_an_error() {
        let a = cmd().parse(&s(&["--nodes", "ten"])).unwrap();
        assert!(a.get_usize("nodes").is_err());
    }

    #[test]
    fn usage_lists_flags() {
        let u = cmd().usage();
        assert!(u.contains("--nodes"));
        assert!(u.contains("default: 100"));
    }
}
