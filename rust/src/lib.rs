//! # DGRO — Diameter-Guided Ring Optimization
//!
//! Production reproduction of *DGRO: Diameter-Guided Ring Optimization
//! for Integrated Research Infrastructure Membership* (Wu, Raghavan, Di,
//! Chen, Cappello — CS.DC 2024) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the membership coordinator: latency models,
//!   overlay topology builders (Chord / RAPID / Perigee / GA baselines),
//!   DGRO ring construction + ρ-adaptive ring selection + parallel
//!   partitioned construction, a discrete-event membership/gossip
//!   runtime, the [`scenario`] engine (deterministic churn +
//!   dynamic-latency workloads — see docs/SCENARIOS.md), the
//!   [`coordinator`] services (centralized and sharded — the latter
//!   with partition-local membership and certified-diameter ring
//!   re-anchoring), and the figure-regeneration bench harness.
//! * **L2 (python/compile/model.py)** — the Q-network (structure2vec
//!   embedding + Q-head, Eqns 2–4), DQN-trained at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the embedding
//!   iteration and Q-head, lowered (interpret mode) into the AOT HLO
//!   artifacts executed here via PJRT ([`runtime`]).
//!
//! Python never runs on the request path: `make artifacts` exports
//! `artifacts/qnet_*.hlo.txt` + trained weights once, and the rust binary
//! is self-contained afterwards.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dgro::latency::{Model};
//! use dgro::util::rng::Rng;
//!
//! let mut rng = Rng::new(7);
//! let w = Model::Fabric.sample(170, &mut rng);
//! let ring = dgro::topology::shortest_ring(&w, 0);
//! let g = ring.to_graph(&w);
//! println!("diameter = {}", dgro::graph::diameter::diameter(&g));
//! ```
//!
//! See `examples/` for full scenarios, docs/ARCHITECTURE.md for the
//! module map and data flow, and docs/CLI.md for the `dgro` binary.

#![warn(missing_docs)]
// Clippy style lints the codebase deliberately deviates from (CI runs
// `cargo clippy --all-targets -- -D warnings`): configs are built by
// mutating a default (clearer diffs than struct-update syntax across
// many optional knobs), and constructors without a `Default` impl are
// intentional where a "default instance" would be meaningless.
#![allow(clippy::field_reassign_with_default)]
#![allow(clippy::new_without_default)]

pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dgro;
pub mod gossip;
pub mod graph;
pub mod latency;
pub mod membership;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod par;
pub mod prop;
pub mod qnet;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod topology;
pub mod traffic;
pub mod util;
