//! Infrastructure substrates built in-tree (the offline image carries no
//! general-purpose crates — see DESIGN.md §3).

pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;
