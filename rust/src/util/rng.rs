//! Deterministic pseudo-random number generation.
//!
//! The image has no `rand` crate, so this module provides the PRNG
//! substrate for the whole system: a [SplitMix64] stream for seeding and a
//! [Xoshiro256StarStar] generator (Blackman & Vigna) for everything else.
//! Determinism matters here: every experiment in EXPERIMENTS.md is keyed
//! by an explicit seed, and the paper's protocol ("10 independent runs,
//! randomly sampled link latencies") is reproduced by seed = base + run.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
/// Passes BigCrush when used as a stream; here it only seeds xoshiro.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the mixer (any value, including 0).
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the workhorse generator. 256-bit state, period
/// 2^256 − 1, excellent statistical quality, and fast on one core.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/correlated seeds still produce
    /// well-separated states (the xoshiro authors' recommendation).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child generator (used to hand one RNG per
    /// partition to the parallel builder deterministically).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection
    /// method (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "below(0) is meaningless");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Marsaglia polar (no trig, good tails).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with the given mean / standard deviation.
    #[inline]
    pub fn gaussian(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from `0..n` (Floyd's algorithm when k
    /// is small relative to n, full shuffle otherwise).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_right() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Rng::new(5);
        let p = r.permutation(50);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn sample_distinct_unique_and_in_range() {
        let mut r = Rng::new(9);
        for &(n, k) in &[(100usize, 5usize), (10, 10), (50, 40)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(1234);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let mut r = Rng::new(77);
        let mut v: Vec<u32> = (0..20).map(|i| i % 5).collect();
        let mut want = v.clone();
        r.shuffle(&mut v);
        want.sort_unstable();
        let mut got = v.clone();
        got.sort_unstable();
        assert_eq!(got, want);
    }
}
