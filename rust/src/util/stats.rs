//! Statistical summaries for benches and experiment reports.

/// Summary statistics over a sample of f64 measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median.
    pub p50: f64,
    /// 90th percentile.
    pub p90: f64,
    /// 99th percentile.
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean of a slice (0.0 when empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!((s.std - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile_sorted(&[7.0], 0.9), 7.0);
    }

    #[test]
    fn mean_empty() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }
}
