//! Leveled stderr logging (no `log` facade consumers on this image; the
//! coordinator and CLI want structured-but-simple progress lines).
//!
//! Level is process-global, settable from the CLI (`--log-level`) or the
//! `DGRO_LOG` environment variable (`error|warn|info|debug|trace`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
/// Log severity, ordered.
pub enum Level {
    /// Unrecoverable failures.
    Error = 0,
    /// Degraded-but-continuing conditions.
    Warn = 1,
    /// Operational milestones (default level).
    Info = 2,
    /// Per-period detail.
    Debug = 3,
    /// Everything.
    Trace = 4,
}

impl Level {
    /// Parse a `DGRO_LOG` level name.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    /// Fixed-width display tag.
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Initialize from the environment; called once from main (idempotent).
pub fn init_from_env() {
    if let Ok(val) = std::env::var("DGRO_LOG") {
        if let Some(level) = Level::parse(&val) {
            set_level(level);
        }
    }
    let _ = START.set(Instant::now());
}

/// Set the process-wide level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The process-wide level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Whether messages at level `l` are emitted.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Emit one log line; use through the `log_*!` macros.
pub fn emit(l: Level, module: &str, msg: std::fmt::Arguments) {
    if !enabled(l) {
        return;
    }
    let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
    eprintln!("[{:9.3}s {} {}] {}", t, l.tag(), module, msg);
}

/// Log at [`util::logging::Level::Error`](crate::util::logging::Level).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*))
    };
}

/// Log at [`util::logging::Level::Warn`](crate::util::logging::Level).
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*))
    };
}

/// Log at [`util::logging::Level::Info`](crate::util::logging::Level).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*))
    };
}

/// Log at [`util::logging::Level::Debug`](crate::util::logging::Level).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*))
    };
}

/// Log at [`util::logging::Level::Trace`](crate::util::logging::Level).
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Trace,
            module_path!(),
            format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The level is process-global; tests that mutate it serialize
    /// through this lock so they can't race each other.
    static LEVEL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("trace"), Some(Level::Trace));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        let _g = LEVEL_LOCK.lock().unwrap();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn trace_macro_gates_on_level() {
        let _g = LEVEL_LOCK.lock().unwrap();
        // Below Trace the macro's emit path is gated off...
        set_level(Level::Debug);
        assert!(!enabled(Level::Trace));
        log_trace!("suppressed at {:?}", level());
        // ...and at Trace it is live (emit writes to stderr; the
        // gating predicate is what we can assert on).
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        log_trace!("emitted at {:?}", level());
        set_level(Level::Info); // restore default for other tests
    }
}
