//! Minimal JSON parser + writer (no serde on this image).
//!
//! Scope: full JSON data model with the ergonomics the repo needs —
//! parsing `artifacts/qnet_weights.json` / `meta.json`, reading config
//! files, and writing metric/figure reports. Numbers are stored as `f64`
//! (all our payloads are float tensors, counts, and small ints, all of
//! which round-trip exactly through f64 up to 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Object keys are ordered (BTreeMap) so output is
/// deterministic — golden tests and hermetic rebuilds rely on it.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (always stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (BTreeMap: deterministic serialization order).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------
    // Accessors (all return Result so call sites read like a schema).
    // ---------------------------------------------------------------

    /// Object field `key`, as an error if absent.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("expected object while looking up '{key}'"),
        }
    }

    /// Object field `key`, None if absent.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// This value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// This value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    /// This value as a string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// This value as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// This value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got non-array"),
        }
    }

    /// This value as an object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object"),
        }
    }

    /// Array of numbers -> Vec<f32> (the weight-loading fast path).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    /// This value as a vector of usize.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---------------------------------------------------------------
    // Constructors for report writing.
    // ---------------------------------------------------------------

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Build a number.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Build a string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build an array.
    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    /// Build a number array.
    pub fn f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Errors carry byte offsets for debuggability.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other, self.pos),
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}', got {other:?}"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']', got {other:?}"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            s.push(
                                char::from_u32(code)
                                    .unwrap_or(char::REPLACEMENT_CHARACTER),
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (may be multi-byte).
                    let start = self.pos;
                    let text =
                        std::str::from_utf8(&self.bytes[start..])?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"dgro","nums":[1,2.5,-3],"ok":true,"nil":null}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""café \t \"q\"""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café \t \"q\"");
        let v2 = parse("\"héllo→\"").unwrap();
        assert_eq!(v2.as_str().unwrap(), "héllo→");
    }

    #[test]
    fn f32_vec_accessor() {
        let v = parse("[1, 2.5, -0.25]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0f32, 2.5, -0.25]);
        assert!(parse("[1, \"x\"]").unwrap().as_f32_vec().is_err());
    }

    #[test]
    fn accessor_errors_are_informative() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        let err = v.get("missing").unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");
        assert!(v.get("a").unwrap().as_str().is_err());
    }

    #[test]
    fn deterministic_output_order() {
        let a = parse(r#"{"z":1,"a":2}"#).unwrap().to_string();
        let b = parse(r#"{"a":2,"z":1}"#).unwrap().to_string();
        assert_eq!(a, b);
    }
}
