//! Wall-clock measurement helpers shared by the bench harness and the
//! coordinator's metrics.

use std::time::{Duration, Instant};

/// Time a closure; returns (result, elapsed).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Repeatedly time a closure: `warmup` unrecorded runs then `iters`
/// recorded runs. Returns per-iteration seconds.
pub fn time_iters<T>(
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> T,
) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// A simple stopwatch accumulating named segments (profiling aid).
#[derive(Default, Debug)]
pub struct Stopwatch {
    segments: Vec<(String, Duration)>,
}

impl Stopwatch {
    /// An empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f`, recording its wall time under `name`.
    pub fn measure<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.segments.push((name.to_string(), t0.elapsed()));
        out
    }

    /// Recorded (name, duration) segments, in order.
    pub fn segments(&self) -> &[(String, Duration)] {
        &self.segments
    }

    /// Sum of all segment durations.
    pub fn total(&self) -> Duration {
        self.segments.iter().map(|(_, d)| *d).sum()
    }

    /// Human-readable per-segment breakdown.
    pub fn report(&self) -> String {
        let total = self.total().as_secs_f64().max(1e-12);
        let mut s = String::new();
        for (name, d) in &self.segments {
            let secs = d.as_secs_f64();
            s.push_str(&format!(
                "{name:<28} {secs:>10.6}s  {:5.1}%\n",
                100.0 * secs / total
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures_something() {
        let (v, d) = time(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499500);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn time_iters_counts() {
        let xs = time_iters(2, 5, || 1 + 1);
        assert_eq!(xs.len(), 5);
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        let x = sw.measure("a", || 21 * 2);
        assert_eq!(x, 42);
        sw.measure("b", || std::thread::sleep(Duration::from_millis(1)));
        assert_eq!(sw.segments().len(), 2);
        assert!(sw.total() >= Duration::from_millis(1));
        assert!(sw.report().contains("a"));
    }
}
