//! The scenario engine: runs one [`ScenarioSpec`] against one overlay
//! topology under a shared seed. The same seed produces the same base
//! latency draw, the same churn trace and the same dynamic-latency
//! overlay for every topology, so DGRO and the baselines are compared
//! under byte-identical conditions.
//!
//! * `Topology::Dgro` drives the real coordinator event loop
//!   ([`AdaptiveRunner::run_with`]) — membership events, ρ-adaptive ring
//!   swaps, time-varying latency view.
//! * The static baselines (Chord / RAPID / Perigee / random K-ring)
//!   build their overlay once over the full universe and never re-wire —
//!   which is exactly the behavior under churn the comparison is about.
//!
//! All reported diameters are over the *alive* sub-overlay (faulty
//! nodes do not relay; largest component when disconnected), measured
//! identically on both paths.
//!
//! The static path is incremental (docs/SCENARIOS.md §Performance &
//! threading): overlay graphs are rebuilt only when the latency matrix
//! or the alive mask actually changed, unchanged periods reuse the
//! previous diameter, and certification is warm-started and parallel
//! ([`EvalPool`], sized by [`EngineOpts::threads`]). Set
//! [`EngineOpts::incremental`] to `false` to force the from-scratch
//! per-period rebuild (the A/B baseline). Between the two paths the
//! `t`/ρ/alive/swaps columns are bit-identical and diameters agree
//! within the bounding algorithm's ~1e-6 certification tolerance (the
//! sweep schedules differ); for a *fixed* path, reports are
//! byte-identical across thread counts and machines.

use std::collections::HashSet;
use std::fmt::Write as _;

use anyhow::{bail, Result};

use crate::config::Config;
use crate::coordinator::{
    AdaptiveRunner, Coordinator, DecentralizedRunner, RunOptions,
    ShardedConfig, ShardedCoordinator,
};
use crate::gossip::measure::{measure, MeasureConfig};
use crate::graph::eval::{CertifyConfig, EvalPool};
use crate::graph::{diameter, Graph};
use crate::latency::Model;
use crate::membership::list::{MemberState, MembershipList};
use crate::net::{
    LossyConfig, LossyTransport, NetCoordinator, SimTransport,
    TcpTransport, Transport, TransportKind, UdpTransport,
};
use crate::metrics::{Metrics, Table};
use crate::obs::Obs;
use crate::scenario::dynamics::DynamicLatency;
use crate::scenario::spec::ScenarioSpec;
use crate::topology::{
    chord::Chord, circulant::Circulant, kring, paper_k, perigee,
    random_ring, rapid::Rapid,
};
use crate::traffic::{
    OverlayObserver, TrafficConfig, TrafficReport, TrafficSim,
};
use crate::util::rng::Rng;

/// Which overlay a scenario drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// The adaptive DGRO coordinator (ρ-guided ring swaps).
    Dgro,
    /// The sharded DGRO coordinator: partition-local membership +
    /// anchor-stitched shards ([`ShardedCoordinator`]); shard count
    /// comes from [`EngineOpts::shards`].
    DgroSharded,
    /// Chord's finger-table overlay (latency-oblivious baseline).
    Chord,
    /// RAPID's expander overlay (K rings from K hash functions).
    Rapid,
    /// Perigee paired with a random ring (its standard companion — alone
    /// it gives no connectivity guarantee).
    Perigee,
    /// Static K random rings (consistent hashing).
    RandomKRing,
    /// Power-of-two circulant C_n({1, 2, 4, …}): the closed-form
    /// low-diameter construction (Huang et al., arXiv:2201.01342) —
    /// the scale tier's known-diameter reference baseline.
    Circulant,
    /// Coordinator-free DGRO ([`DecentralizedRunner`]): every node runs
    /// its own Algorithm-3 loop over gossip-piggybacked membership and
    /// a two-phase ring-swap agreement. Transport-backed by
    /// construction (defaults to the sim backend when
    /// [`EngineOpts::transport`] is unset).
    Decentralized,
}

impl Topology {
    /// The default comparison panel (the sharded coordinator is opt-in
    /// via `--shards`, so it is not part of the panel).
    pub const ALL: [Topology; 6] = [
        Topology::Dgro,
        Topology::Chord,
        Topology::Rapid,
        Topology::Perigee,
        Topology::RandomKRing,
        Topology::Circulant,
    ];

    /// Parse a CLI topology name.
    pub fn parse(s: &str) -> Result<Topology> {
        match s.to_ascii_lowercase().as_str() {
            "dgro" => Ok(Topology::Dgro),
            "sharded" | "dgro-sharded" => Ok(Topology::DgroSharded),
            "chord" => Ok(Topology::Chord),
            "rapid" => Ok(Topology::Rapid),
            "perigee" => Ok(Topology::Perigee),
            "random" | "kring" => Ok(Topology::RandomKRing),
            "circulant" => Ok(Topology::Circulant),
            "decentralized" => Ok(Topology::Decentralized),
            other => bail!(
                "unknown topology '{other}' \
                 (dgro|sharded|chord|rapid|perigee|random|circulant\
                 |decentralized)"
            ),
        }
    }

    /// Stable display/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Dgro => "dgro",
            Topology::DgroSharded => "sharded",
            Topology::Chord => "chord",
            Topology::Rapid => "rapid",
            Topology::Perigee => "perigee",
            Topology::RandomKRing => "random",
            Topology::Circulant => "circulant",
            Topology::Decentralized => "decentralized",
        }
    }
}

/// One adaptation/measurement period of a scenario run.
#[derive(Clone, Copy, Debug)]
pub struct PeriodRow {
    /// Sim time at the end of the period (ms).
    pub t: f64,
    /// ρ statistic from the period's gossip measurement, taken on the
    /// topology's *full* overlay with current latencies — the system's
    /// own operational view, crashed nodes included — exactly like the
    /// coordinator's adapt loop, so the column is comparable across
    /// topologies.
    pub rho: f64,
    /// Diameter of the alive sub-overlay (largest component).
    pub diameter: f64,
    /// Alive members.
    pub alive: usize,
    /// Ring swaps this period (always 0 for static baselines).
    pub swaps: u64,
}

/// Result of one scenario × topology run.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    /// Scenario name (from the spec).
    pub scenario: String,
    /// Which overlay ran.
    pub topology: Topology,
    /// The seed everything was derived from.
    pub seed: u64,
    /// One row per adaptation/measurement period.
    pub rows: Vec<PeriodRow>,
    /// Counters + per-period series recorded during the run.
    pub metrics: Metrics,
    /// The run's observability surface (registry + flight recorder) —
    /// what `--obs-out` exports. Never consulted by [`Self::render`],
    /// so rendered reports stay byte-deterministic.
    pub obs: Option<Obs>,
}

impl ScenarioReport {
    /// Mean alive-overlay diameter across periods.
    pub fn mean_diameter(&self) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        self.rows.iter().map(|r| r.diameter).sum::<f64>()
            / self.rows.len() as f64
    }

    /// Worst per-period alive-overlay diameter.
    pub fn peak_diameter(&self) -> f64 {
        self.rows.iter().map(|r| r.diameter).fold(0.0, f64::max)
    }

    /// The last period's alive-overlay diameter.
    pub fn final_diameter(&self) -> f64 {
        self.rows.last().map(|r| r.diameter).unwrap_or(0.0)
    }

    /// Total ring swaps across the run (0 for static baselines).
    pub fn total_swaps(&self) -> u64 {
        self.rows.iter().map(|r| r.swaps).sum()
    }

    /// Per-period table (CSV-able via [`Table`]).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Scenario {} on {}",
                self.scenario,
                self.topology.name()
            ),
            &["t_ms", "rho", "alive_diameter", "alive", "swaps"],
        );
        for r in &self.rows {
            t.row(vec![
                r.t,
                r.rho,
                r.diameter,
                r.alive as f64,
                r.swaps as f64,
            ]);
        }
        t
    }

    /// Deterministic text report: byte-identical across runs of the same
    /// (spec, topology, seed) — no wall-clock, no map-iteration
    /// nondeterminism (the metrics registry is BTreeMap-backed).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "scenario {} topology={} seed={} periods={}",
            self.scenario,
            self.topology.name(),
            self.seed,
            self.rows.len()
        );
        let _ = writeln!(
            out,
            "{:>8} {:>7} {:>10} {:>6} {:>6}",
            "t_ms", "rho", "diameter", "alive", "swaps"
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{:8.0} {:7.3} {:10.3} {:6} {:6}",
                r.t, r.rho, r.diameter, r.alive, r.swaps
            );
        }
        let _ = writeln!(
            out,
            "mean_diameter {:.3}  peak_diameter {:.3}  \
             final_diameter {:.3}  swaps {}",
            self.mean_diameter(),
            self.peak_diameter(),
            self.final_diameter(),
            self.total_swaps()
        );
        out.push_str(&self.metrics.report());
        out
    }
}

/// Every per-run knob of the scenario engine in one validated struct —
/// shared by CLI parsing, tests and the `bench_harness` figures, so
/// the next knob is added in exactly one place. The `Default` value
/// reproduces the classic engine behavior: 250 ms period, serial
/// evaluation, incremental static path, in-process coordinator, exact
/// certification.
#[derive(Clone, Debug)]
pub struct EngineOpts {
    /// Adaptation/measurement cadence in sim-ms.
    pub period: f64,
    /// Worker threads for per-period diameter evaluation on the static
    /// path (1 = serial). Never changes reported values, only the wall
    /// clock (`dgro scenario run --threads`).
    pub threads: usize,
    /// Static-path evaluation strategy. `true` (default): graphs are
    /// rebuilt only when the latency matrix or the alive set actually
    /// changed, unchanged periods reuse the previous diameter outright,
    /// and the Takes–Kosters sweep is warm-started from the previous
    /// period's landmark nodes. `false`: the pre-optimization
    /// from-scratch rebuild every period — kept as the A/B baseline for
    /// `rust/benches/hotpath.rs` and the equivalence tests.
    pub incremental: bool,
    /// Partition count for [`Topology::DgroSharded`] runs. 0 (the
    /// default) resolves to [`DEFAULT_SHARDS`]; 1 is a valid degenerate
    /// sharding (one partition, no anchors — the parity baseline);
    /// other topologies ignore it entirely.
    pub shards: usize,
    /// Transport backing [`Topology::Dgro`] and
    /// [`Topology::Decentralized`] runs. `None` (the default) keeps
    /// the in-process coordinator for Dgro — ρ inputs come straight
    /// from latency-matrix lookups — and resolves to the sim backend
    /// for Decentralized (which is transport-backed by construction).
    /// `Some(kind)` replays the *same* trace through the
    /// message-level runner: Algorithm-3 measurements are driven by
    /// real framed messages and measured RTTs over the chosen
    /// transport (`dgro scenario run --transport sim|udp|tcp`).
    pub transport: Option<TransportKind>,
    /// Wall-time compression for the real-socket transports
    /// ([`TransportKind::Udp`] / [`TransportKind::Tcp`]): real
    /// milliseconds of shaped delay per sim-ms of latency
    /// ([`UdpTransport::DEFAULT_TIME_SCALE`] by default).
    pub time_scale: f64,
    /// Injected per-frame drop probability for transport-backed runs
    /// (`--loss-rate`). When this, [`EngineOpts::dup_rate`] or
    /// [`EngineOpts::reorder_rate`] is non-zero the chosen backend is
    /// wrapped in a seeded [`LossyTransport`], so the fault pattern
    /// replays deterministically for a fixed scenario seed.
    pub loss_rate: f64,
    /// Injected per-frame duplication probability for transport-backed
    /// runs (`--dup-rate`).
    pub dup_rate: f64,
    /// Injected per-frame reorder probability for transport-backed
    /// runs (`--reorder-rate`): a hit frame is held back and released
    /// after the sender's next frame, swapping their wire order.
    pub reorder_rate: f64,
    /// Churn-aware ρ guard forwarded to the runner: skip the
    /// period's ring swap when more than this many membership events
    /// landed in it (0 = off; `--churn-guard`). Applies to every
    /// adaptive path (on the decentralized runner each node counts the
    /// membership news *it* applied this period).
    pub churn_guard: u64,
    /// Enable the span flight recorder for this run (`--obs-out` sets
    /// it). Registry counters are always on; span recording is the
    /// only opt-in part. Never changes reported values.
    pub obs_record: bool,
    /// Causal-trace sampling stride for transport-backed runs
    /// (`--trace-sample`): 0 (the default) disables tracing entirely —
    /// frames carry no trace context and the wire bytes are identical
    /// to an untraced build. `s ≥ 1` stamps every frame with the
    /// period's trace context and records a deliver span on every
    /// node whose id is a multiple of `s` (1 = all nodes). Ignored by
    /// the in-process paths, which exchange no frames. Never changes
    /// reported values.
    pub trace_sample: usize,
    /// How per-period diameters are certified (`--certify`,
    /// `--landmarks`, `--oracle-every`): exact certification every
    /// period (the default), budgeted estimates with a periodic exact
    /// oracle (`hybrid`), or budgeted estimates only (`sketch`).
    /// Applies to the static baselines and the sharded coordinator;
    /// the other adaptive paths always certify exactly
    /// (docs/SCENARIOS.md §Scaling & certification).
    pub certify: CertifyConfig,
}

impl Default for EngineOpts {
    fn default() -> EngineOpts {
        EngineOpts {
            period: 250.0,
            threads: 1,
            incremental: true,
            shards: 0,
            transport: None,
            time_scale: UdpTransport::DEFAULT_TIME_SCALE,
            loss_rate: 0.0,
            dup_rate: 0.0,
            reorder_rate: 0.0,
            churn_guard: 0,
            obs_record: false,
            trace_sample: 0,
            certify: CertifyConfig::exact(),
        }
    }
}

impl EngineOpts {
    /// Validate the topology-independent invariants: a positive finite
    /// period, fault rates in `[0, 1)`, a positive time scale and a
    /// well-formed certification policy. Topology-dependent rules
    /// (which topologies accept a transport, who may certify
    /// non-exactly) live in the engine's run path, which knows the
    /// topology.
    pub fn validate(&self) -> Result<()> {
        if !(self.period.is_finite() && self.period > 0.0) {
            bail!("--period must be positive, got {}", self.period);
        }
        if !(self.time_scale.is_finite() && self.time_scale > 0.0) {
            bail!(
                "--time-scale must be positive, got {}",
                self.time_scale
            );
        }
        for (name, rate) in [
            ("loss", self.loss_rate),
            ("dup", self.dup_rate),
            ("reorder", self.reorder_rate),
        ] {
            if !(0.0..1.0).contains(&rate) {
                bail!("--{name}-rate must be in [0, 1), got {rate}");
            }
        }
        if let Err(e) = self.certify.validate() {
            bail!("{e}");
        }
        Ok(())
    }
}

/// Runs a spec against topologies. Construction validates the spec
/// once; the per-run knobs live in [`ScenarioEngine::opts`].
pub struct ScenarioEngine {
    spec: ScenarioSpec,
    seed: u64,
    /// Per-run knobs (period, threads, transport, fault rates, obs,
    /// certification, …) — one validated struct shared with the CLI,
    /// tests and bench harness.
    pub opts: EngineOpts,
}

/// Shard count a [`Topology::DgroSharded`] run falls back to when
/// [`EngineOpts::shards`] was never set (`dgro scenario run
/// --topology sharded` without `--shards`).
pub const DEFAULT_SHARDS: usize = 4;

impl ScenarioEngine {
    /// Validate the spec and wrap it with default knobs
    /// ([`EngineOpts::default`]).
    pub fn new(spec: ScenarioSpec, seed: u64) -> Result<ScenarioEngine> {
        spec.validate()?;
        Ok(ScenarioEngine {
            spec,
            seed,
            opts: EngineOpts::default(),
        })
    }

    /// The validated workload description this engine runs.
    pub fn spec(&self) -> &ScenarioSpec {
        &self.spec
    }

    /// The partition count a [`Topology::DgroSharded`] run will use.
    pub fn effective_shards(&self) -> usize {
        if self.opts.shards >= 1 {
            self.opts.shards
        } else {
            DEFAULT_SHARDS
        }
    }

    /// The shared setting for this seed: base latency draw, dynamic
    /// view, and the full churn trace. Identical for every topology.
    fn setting(&self) -> Result<(DynamicLatency, crate::membership::events::EventTrace)> {
        let mut rng = Rng::new(self.seed);
        let model = Model::parse(&self.spec.model).ok_or_else(|| {
            anyhow::anyhow!("bad model {}", self.spec.model)
        })?;
        let base = model.sample(self.spec.nodes, &mut rng);
        // Same RNG order as ever (sample, then events) — the matrix is
        // only consulted by latency-aware generators, so traces of
        // pre-existing specs are byte-identical.
        let trace = self.spec.events(&base, &mut rng);
        let dyn_w = DynamicLatency::new(base, self.spec.latency.clone())?;
        Ok((dyn_w, trace))
    }

    fn effective_period(&self) -> f64 {
        self.opts.period.min(self.spec.horizon)
    }

    /// Run the spec against one topology. [`Topology::Dgro`] and
    /// [`Topology::DgroSharded`] drive the real coordinator event loops;
    /// everything else replays the periods over a statically built
    /// overlay.
    pub fn run(&self, topology: Topology) -> Result<ScenarioReport> {
        self.run_observed(topology, None)
    }

    /// Run the spec against one topology while a traffic plane rides
    /// along: each period's alive overlay feeds a
    /// [`TrafficSim`], and the resulting [`TrafficReport`] (p50/p99
    /// end-to-end latency, success rate, per-node load, greedy-routing
    /// stretch) comes back next to the diameter report. Deterministic
    /// like [`ScenarioEngine::run`]: same seed → byte-identical
    /// reports, across worker thread counts.
    pub fn run_traffic(
        &self,
        topology: Topology,
        tcfg: TrafficConfig,
    ) -> Result<(ScenarioReport, TrafficReport, Obs)> {
        tcfg.validate()?;
        let mut sim = TrafficSim::new(
            self.spec.nodes,
            self.seed,
            tcfg,
            self.opts.threads.max(1),
        );
        let rep = {
            let mut feed = |t: f64,
                            g: &Graph,
                            w: &crate::latency::LatencyMatrix,
                            alive: &[u32]| {
                sim.on_period(t, g, w, alive)
            };
            self.run_observed(topology, Some(&mut feed))?
        };
        let (traffic, obs) =
            sim.finish(&self.spec.name, topology.name(), self.seed);
        Ok((rep, traffic, obs))
    }

    /// Construct the boxed transport backend a message-driven run sits
    /// on: the requested kind (sim when `kind` is `None`), wrapped in
    /// the seeded [`LossyTransport`] decorator when any fault rate is
    /// non-zero so the fault pattern replays deterministically.
    fn build_backend(
        &self,
        kind: Option<TransportKind>,
        w0: &crate::latency::LatencyMatrix,
    ) -> Result<Box<dyn Transport>> {
        let base: Box<dyn Transport> =
            match kind.unwrap_or(TransportKind::Sim) {
                TransportKind::Sim => {
                    Box::new(SimTransport::new(w0.clone()))
                }
                TransportKind::Udp => Box::new(UdpTransport::bind(
                    w0.clone(),
                    self.opts.time_scale,
                )?),
                TransportKind::Tcp => Box::new(TcpTransport::bind(
                    w0.clone(),
                    self.opts.time_scale,
                )?),
            };
        let fault = LossyConfig {
            drop_rate: self.opts.loss_rate,
            dup_rate: self.opts.dup_rate,
            reorder_rate: self.opts.reorder_rate,
            seed: self.seed,
        };
        Ok(if fault.active() {
            Box::new(LossyTransport::new(base, fault))
        } else {
            base
        })
    }

    fn run_observed(
        &self,
        topology: Topology,
        observer: Option<OverlayObserver<'_>>,
    ) -> Result<ScenarioReport> {
        self.opts.validate()?;
        let message_driven = matches!(
            topology,
            Topology::Dgro | Topology::Decentralized
        );
        if self.opts.transport.is_some() && !message_driven {
            bail!(
                "--transport runs support --topology dgro or \
                 decentralized only (got {})",
                topology.name()
            );
        }
        let fault_active = self.opts.loss_rate > 0.0
            || self.opts.dup_rate > 0.0
            || self.opts.reorder_rate > 0.0;
        // Fault rates need framed messages to act on: an explicit
        // transport, or the decentralized topology (transport-backed
        // by construction, defaulting to sim).
        if fault_active
            && self.opts.transport.is_none()
            && topology != Topology::Decentralized
        {
            bail!(
                "--loss-rate/--dup-rate/--reorder-rate require a \
                 transport-backed run (--transport sim|udp|tcp or \
                 --topology decentralized)"
            );
        }
        if !self.opts.certify.is_exact() && message_driven {
            bail!(
                "--certify {} applies to sharded and static-baseline \
                 topologies (the {} runner always certifies exactly)",
                self.opts.certify.mode.name(),
                topology.name()
            );
        }
        match topology {
            Topology::Dgro
            | Topology::DgroSharded
            | Topology::Decentralized => {
                self.run_adaptive(topology, observer)
            }
            t => self.run_static(t, observer),
        }
    }

    /// Adaptive path: dispatch the spec's trace to one of the four
    /// [`AdaptiveRunner`]s (centralized, sharded, transport-backed
    /// net, decentralized) through the shared [`RunOptions`] surface —
    /// the run call itself is identical across runners, only
    /// construction differs.
    fn run_adaptive(
        &self,
        topology: Topology,
        observer: Option<OverlayObserver<'_>>,
    ) -> Result<ScenarioReport> {
        let (dyn_w, trace) = self.setting()?;
        let mut cfg = Config::default();
        cfg.nodes = self.spec.nodes;
        cfg.model = self.spec.model.clone();
        cfg.seed = self.seed;
        cfg.scorer = "greedy".to_string();
        cfg.adapt_period_ms = self.effective_period();
        cfg.churn_guard = self.opts.churn_guard;
        let horizon = self.spec.horizon;
        let mut prev_t = 0.0;
        let mut latency_at = |t: f64| {
            let out = if dyn_w.changes_within(prev_t, t) {
                Some(dyn_w.at(t))
            } else {
                None
            };
            prev_t = t;
            out
        };
        let run_opts = || {
            RunOptions::new()
                .record(self.opts.obs_record)
                .trace_sample(self.opts.trace_sample)
        };
        let (rep, metrics, obs) = match topology {
            Topology::DgroSharded => {
                let mut sopts =
                    ShardedConfig::new(self.effective_shards());
                sopts.threads = self.opts.threads.max(1);
                sopts.certify = self.opts.certify;
                let mut co = ShardedCoordinator::with_latency(
                    cfg,
                    dyn_w.at(0.0),
                    sopts,
                )?;
                let rep = co.run_with(
                    &trace,
                    horizon,
                    run_opts()
                        .latency(&mut latency_at)
                        .maybe_observer(observer),
                )?;
                let obs = co.obs.clone();
                (rep, co.metrics, obs)
            }
            Topology::Decentralized => {
                // Coordinator-free: every node runs its own loop over
                // framed messages; the engine only supplies the
                // backend (sim unless --transport says otherwise).
                let w0 = dyn_w.at(0.0);
                let backend =
                    self.build_backend(self.opts.transport, &w0)?;
                let mut co = DecentralizedRunner::new(cfg, w0, backend)?;
                let rep = co.run_with(
                    &trace,
                    horizon,
                    run_opts()
                        .latency(&mut latency_at)
                        .maybe_observer(observer),
                )?;
                let obs = co.obs.clone();
                (rep, co.metrics, obs)
            }
            Topology::Dgro if self.opts.transport.is_some() => {
                // Transport-backed replay: same spec, same seed-derived
                // trace and latency view, but ρ comes from measured
                // message RTTs on the chosen transport
                // (rust/tests/net.rs pins cross-transport parity on
                // this path).
                let w0 = dyn_w.at(0.0);
                let backend =
                    self.build_backend(self.opts.transport, &w0)?;
                let mut co = NetCoordinator::new(cfg, w0, backend)?;
                let rep = co.run_with(
                    &trace,
                    horizon,
                    run_opts()
                        .latency(&mut latency_at)
                        .maybe_observer(observer),
                )?;
                let obs = co.obs.clone();
                (rep, co.metrics, obs)
            }
            _ => {
                let mut co =
                    Coordinator::with_latency(cfg, dyn_w.at(0.0))?;
                let rep = co.run_with(
                    &trace,
                    horizon,
                    run_opts()
                        .latency(&mut latency_at)
                        .maybe_observer(observer),
                )?;
                let obs = co.obs.clone();
                (rep, co.metrics, obs)
            }
        };
        let series = |name: &str| -> Vec<f64> {
            metrics
                .series(name)
                .map(|s| s.values.clone())
                .unwrap_or_default()
        };
        let alive = series("overlay.alive");
        let alive_d = series("overlay.alive_diameter");
        let swaps = series("rings.swaps_per_period");
        let rows = rep
            .timeline
            .iter()
            .enumerate()
            .map(|(i, &(t, rho, _))| PeriodRow {
                t,
                rho,
                diameter: alive_d.get(i).copied().unwrap_or(0.0),
                alive: alive.get(i).copied().unwrap_or(0.0) as usize,
                swaps: swaps.get(i).copied().unwrap_or(0.0) as u64,
            })
            .collect();
        Ok(ScenarioReport {
            scenario: self.spec.name.clone(),
            topology,
            seed: self.seed,
            rows,
            metrics,
            obs: Some(obs),
        })
    }

    /// Baseline path: build the overlay once over the full universe,
    /// then replay the same periods — membership events restrict the
    /// alive sub-overlay, latency updates re-weight the fixed edges —
    /// without any re-wiring.
    fn run_static(
        &self,
        topology: Topology,
        mut observer: Option<OverlayObserver<'_>>,
    ) -> Result<ScenarioReport> {
        let (dyn_w, trace) = self.setting()?;
        let n = self.spec.nodes;
        // The t = 0 view, like the adaptive path's with_latency seed —
        // an effect whose window opens at t = 0 must hit both paths
        // (changes_within only reports edges strictly inside a period).
        let w0 = dyn_w.at(0.0);
        // Per-topology stream, forked off the scenario seed so adding a
        // topology never perturbs another's draw.
        let mut rng = Rng::new(self.seed ^ 0xB05E11E5);
        let g0 = match topology {
            Topology::Chord => Chord::build(n, &mut rng).to_graph(&w0),
            Topology::Rapid => Rapid::build(n, &mut rng).to_graph(&w0),
            Topology::Perigee => perigee::build(
                &w0,
                perigee::PerigeeConfig::default(),
                &mut rng,
            )
            .union(&random_ring(n, &mut rng).to_graph(&w0)),
            Topology::RandomKRing => {
                kring::random_krings(n, paper_k(n), &mut rng)
                    .to_graph(&w0)
            }
            // Deterministic by construction (no RNG draw): the
            // closed-form known-diameter reference for scale runs.
            Topology::Circulant => Circulant::power_two(n).to_graph(&w0),
            Topology::Dgro
            | Topology::DgroSharded
            | Topology::Decentralized => {
                bail!("dgro runs on the adaptive path")
            }
        };
        let edges: Vec<(u32, u32)> =
            g0.edges().iter().map(|&(u, v, _)| (u, v)).collect();

        let obs = Obs::new();
        if self.opts.obs_record {
            obs.rec.set_enabled(true);
        }
        let mut pool = EvalPool::new(self.opts.threads);
        pool.attach_obs(&obs);
        let mut membership = MembershipList::full(n);
        let mut metrics = Metrics::new();
        let mut rows = Vec::new();
        let period = self.effective_period();
        let mut w = w0;
        let mut t = 0.0;
        let mut prev_t = 0.0;
        let mut ev_idx = 0;
        // Incremental per-period state: both graphs are pure functions
        // of (edge set, weights, alive mask), so they are rebuilt only
        // when an input changed; the previous period's Takes–Kosters
        // landmarks warm-start the next diameter certification.
        let mut g_full: Option<Graph> = None;
        let mut g_alive: Option<Graph> = None;
        let mut prev_alive: Option<HashSet<u32>> = None;
        let mut landmarks: Vec<u32> = Vec::new();
        let mut d = 0.0f64;
        // Certification counter: hybrid's oracle cadence is indexed by
        // *evaluation* (periods where the alive overlay moved), so a
        // quiet stretch does not starve the oracle of fresh checks.
        let mut eval_idx = 0u64;
        while t < self.spec.horizon {
            t += period;
            let mut latency_changed = false;
            if dyn_w.changes_within(prev_t, t) {
                w = dyn_w.at(t);
                latency_changed = true;
                metrics.incr("latency.updates", 1);
            }
            prev_t = t;
            let mut applied = 0u64;
            while ev_idx < trace.events.len()
                && trace.events[ev_idx].time() <= t
            {
                membership.apply_trace_event(&trace.events[ev_idx]);
                ev_idx += 1;
                applied += 1;
            }
            metrics.incr("membership.events_applied", applied);

            let alive_set: HashSet<u32> = membership.alive().collect();
            let alive_changed =
                prev_alive.as_ref() != Some(&alive_set);
            // Two views, mirroring the coordinator exactly: ρ is each
            // system's internal control signal, measured on its *full*
            // overlay with current weights (adapt_once uses overlay(),
            // crashed nodes included) — while the reported diameter is
            // over the alive sub-overlay (faulty nodes do not relay).
            if !self.opts.incremental || latency_changed || g_full.is_none() {
                let mut g = Graph::empty(n);
                for &(u, v) in &edges {
                    g.add_edge(
                        u as usize,
                        v as usize,
                        w.get(u as usize, v as usize),
                    );
                }
                g_full = Some(g);
            }
            let alive_stale = !self.opts.incremental
                || latency_changed
                || alive_changed
                || g_alive.is_none();
            if alive_stale {
                let mut g = Graph::empty(n);
                for &(u, v) in &edges {
                    if alive_set.contains(&u) && alive_set.contains(&v) {
                        g.add_edge(
                            u as usize,
                            v as usize,
                            w.get(u as usize, v as usize),
                        );
                    }
                }
                g_alive = Some(g);
            }
            let stats = measure(
                &w,
                g_full.as_ref().expect("g_full built"),
                MeasureConfig::default(),
                &mut rng,
            );
            metrics.incr("gossip.messages", stats.messages as u64);
            if alive_stale {
                let ga = g_alive.as_ref().expect("g_alive built");
                d = if !self.opts.certify.is_exact() {
                    // Budgeted certified interval; report the upper
                    // bound (conservative) or, on hybrid oracle
                    // periods, the exact value after checking it lies
                    // inside the interval.
                    let est = pool.diameter_est(
                        ga,
                        &landmarks,
                        self.opts.certify.budget,
                    );
                    landmarks = est.landmarks.clone();
                    metrics
                        .observe("eval.est_lower", f64::from(est.lower));
                    metrics
                        .observe("eval.est_upper", f64::from(est.upper));
                    if self.opts.certify.oracle_period(eval_idx) {
                        metrics.incr("eval.oracle_checks", 1);
                        let exact = diameter::diameter(ga);
                        let tol = 1e-3 * exact.max(1.0);
                        if est.lower > exact + tol
                            || exact > est.upper + tol
                        {
                            bail!(
                                "hybrid oracle at t={t}: exact {exact} \
                                 outside certified [{}, {}]",
                                est.lower,
                                est.upper
                            );
                        }
                        f64::from(exact)
                    } else {
                        f64::from(est.upper)
                    }
                } else if self.opts.incremental {
                    let (dd, lm) =
                        pool.diameter_with_seeds(ga, &landmarks);
                    landmarks = lm;
                    dd as f64
                } else {
                    diameter::diameter(ga) as f64
                };
                eval_idx += 1;
            }
            // else: neither weights nor alive mask moved — the alive
            // sub-overlay is byte-identical, so `d` carries over.
            if let Some(f) = observer.as_mut() {
                let mut alive: Vec<u32> =
                    alive_set.iter().copied().collect();
                alive.sort_unstable();
                f(t, g_alive.as_ref().expect("g_alive built"), &w, &alive);
            }
            let alive_count = alive_set.len();
            prev_alive = Some(alive_set);
            metrics.observe("overlay.alive_diameter", d);
            metrics.observe("overlay.rho", stats.rho());
            metrics.observe("overlay.alive", alive_count as f64);
            rows.push(PeriodRow {
                t,
                rho: stats.rho(),
                diameter: d,
                alive: alive_count,
                swaps: 0,
            });
        }
        Ok(ScenarioReport {
            scenario: self.spec.name.clone(),
            topology,
            seed: self.seed,
            rows,
            metrics,
            obs: Some(obs),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::spec::{catalog, find, ChurnSpec};

    fn tiny_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "tiny".into(),
            about: "unit-test workload".into(),
            nodes: 24,
            initial_alive: 24,
            model: "uniform".into(),
            horizon: 1000.0,
            churn: vec![ChurnSpec::Poisson { rate: 0.001 }],
            latency: vec![],
        }
    }

    #[test]
    fn adaptive_and_static_paths_produce_aligned_rows() {
        let engine = ScenarioEngine::new(tiny_spec(), 5).unwrap();
        let a = engine.run(Topology::Dgro).unwrap();
        let b = engine.run(Topology::Chord).unwrap();
        assert_eq!(a.rows.len(), 4); // horizon 1000 / period 250
        assert_eq!(a.rows.len(), b.rows.len());
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            assert_eq!(ra.t, rb.t);
            assert!(ra.diameter.is_finite() && rb.diameter.is_finite());
            assert!(ra.alive >= 3 && rb.alive >= 3);
            assert_eq!(rb.swaps, 0, "static baseline must not re-wire");
        }
    }

    #[test]
    fn identical_seeds_are_byte_deterministic() {
        let spec = find("flash-crowd").unwrap();
        let r1 = ScenarioEngine::new(spec.clone(), 7)
            .unwrap()
            .run(Topology::Dgro)
            .unwrap();
        let r2 = ScenarioEngine::new(spec, 7)
            .unwrap()
            .run(Topology::Dgro)
            .unwrap();
        assert_eq!(r1.render(), r2.render());
    }

    #[test]
    fn every_topology_parses_its_own_name() {
        for t in Topology::ALL {
            assert_eq!(Topology::parse(t.name()).unwrap(), t);
        }
        // The sharded coordinator and the decentralized runner are
        // opt-in (not in ALL) but must still round-trip through the
        // CLI names.
        assert_eq!(
            Topology::parse(Topology::DgroSharded.name()).unwrap(),
            Topology::DgroSharded
        );
        assert_eq!(
            Topology::parse(Topology::Decentralized.name()).unwrap(),
            Topology::Decentralized
        );
        assert!(Topology::parse("mesh").is_err());
    }

    #[test]
    fn sharded_topology_runs_and_aligns_with_centralized() {
        let mut engine = ScenarioEngine::new(tiny_spec(), 5).unwrap();
        engine.opts.shards = 4;
        assert_eq!(engine.effective_shards(), 4);
        let s = engine.run(Topology::DgroSharded).unwrap();
        let c = engine.run(Topology::Dgro).unwrap();
        assert_eq!(s.rows.len(), c.rows.len());
        for (rs, rc) in s.rows.iter().zip(&c.rows) {
            assert_eq!(rs.t, rc.t);
            assert!(rs.diameter.is_finite() && rs.diameter > 0.0);
            assert!(rs.alive >= 3);
        }
        assert_eq!(s.topology.name(), "sharded");
        // Default resolution: only 0 falls back (1 is the valid
        // degenerate single-shard parity baseline).
        engine.opts.shards = 0;
        assert_eq!(engine.effective_shards(), DEFAULT_SHARDS);
        engine.opts.shards = 1;
        assert_eq!(engine.effective_shards(), 1);
    }

    #[test]
    fn transport_backed_run_covers_periods_and_rejects_baselines() {
        let mut engine = ScenarioEngine::new(tiny_spec(), 5).unwrap();
        engine.opts.transport = Some(TransportKind::Sim);
        let rep = engine.run(Topology::Dgro).unwrap();
        assert_eq!(rep.rows.len(), 4);
        for r in &rep.rows {
            assert!(r.diameter.is_finite() && r.diameter > 0.0);
            assert!((0.0..=1.0).contains(&r.rho));
            assert!(r.alive >= 3);
        }
        // Transports wrap the centralized coordinator only.
        assert!(engine.run(Topology::Chord).is_err());
        engine.opts.shards = 2;
        assert!(engine.run(Topology::DgroSharded).is_err());
    }

    #[test]
    fn traced_transport_run_exports_a_causal_timeline() {
        use crate::obs::trace;
        let run = || {
            let mut engine =
                ScenarioEngine::new(tiny_spec(), 5).unwrap();
            engine.opts.transport = Some(TransportKind::Sim);
            engine.opts.obs_record = true;
            engine.opts.trace_sample = 1;
            let rep = engine.run(Topology::Dgro).unwrap();
            rep.obs.unwrap().rec.export_jsonl(true).unwrap()
        };
        let timeline = run();
        assert_eq!(timeline, run(), "traced replay must be stable");
        let spans = trace::parse_jsonl(&timeline).unwrap();
        let forest = trace::assemble(&spans);
        assert_eq!(forest.traces.len(), 4, "one trace per period");
        for t in &forest.traces {
            assert!(t.orphans.is_empty(), "orphans: {:?}", t.orphans);
            assert!(!t.critical_chain().0.is_empty());
        }
        // trace_sample = 0 leaves the timeline trace-free.
        let mut off = ScenarioEngine::new(tiny_spec(), 5).unwrap();
        off.opts.transport = Some(TransportKind::Sim);
        off.opts.obs_record = true;
        let rep = off.run(Topology::Dgro).unwrap();
        let plain =
            rep.obs.unwrap().rec.export_jsonl(true).unwrap();
        assert!(!plain.contains("\"trace\""));
    }

    #[test]
    fn lossy_rates_validate_and_replay_deterministically() {
        let mut engine = ScenarioEngine::new(tiny_spec(), 5).unwrap();
        engine.opts.transport = Some(TransportKind::Sim);
        engine.opts.loss_rate = 0.1;
        let a = engine.run(Topology::Dgro).unwrap();
        let b = engine.run(Topology::Dgro).unwrap();
        assert_eq!(
            a.render(),
            b.render(),
            "seeded loss must replay byte-identically"
        );
        // Fault rates without a transport-backed run are rejected.
        let mut bad = ScenarioEngine::new(tiny_spec(), 5).unwrap();
        bad.opts.loss_rate = 0.1;
        assert!(bad.run(Topology::Dgro).is_err());
        // Out-of-range rates are rejected.
        let mut oob = ScenarioEngine::new(tiny_spec(), 5).unwrap();
        oob.opts.transport = Some(TransportKind::Sim);
        oob.opts.dup_rate = 1.5;
        assert!(oob.run(Topology::Dgro).is_err());
    }

    #[test]
    fn catalog_names_resolve_through_the_engine() {
        // Construction (validation) must succeed for the whole catalog;
        // full runs live in rust/tests/scenarios.rs.
        for spec in catalog() {
            ScenarioEngine::new(spec, 1).unwrap();
        }
    }

    #[test]
    fn circulant_baseline_runs_statically() {
        let engine = ScenarioEngine::new(tiny_spec(), 5).unwrap();
        let rep = engine.run(Topology::Circulant).unwrap();
        assert_eq!(rep.rows.len(), 4);
        assert_eq!(rep.total_swaps(), 0);
        for r in &rep.rows {
            assert!(r.diameter.is_finite() && r.diameter > 0.0);
        }
        // Deterministic by construction: byte-identical re-run.
        let again = engine.run(Topology::Circulant).unwrap();
        assert_eq!(rep.render(), again.render());
    }

    #[test]
    fn certify_modes_validate_and_bracket_on_the_static_path() {
        use crate::graph::eval::CertifyMode;
        let mut engine = ScenarioEngine::new(tiny_spec(), 5).unwrap();
        let exact = engine.run(Topology::Chord).unwrap();
        // Hybrid with an every-evaluation oracle: every reported
        // diameter IS the oracle value, pinned inside the estimator's
        // own bounds (the run errors out otherwise).
        engine.opts.certify.mode = CertifyMode::Hybrid;
        engine.opts.certify.oracle_every = 1;
        engine.opts.certify.budget = 4;
        let hybrid = engine.run(Topology::Chord).unwrap();
        assert_eq!(exact.rows.len(), hybrid.rows.len());
        for (e, h) in exact.rows.iter().zip(&hybrid.rows) {
            assert_eq!(e.t, h.t);
            assert_eq!(e.alive, h.alive);
            assert!(
                (e.diameter - h.diameter).abs()
                    <= 1e-3 * e.diameter.max(1.0),
                "t={}: {} vs {}",
                e.t,
                e.diameter,
                h.diameter
            );
        }
        // Sketch reports the certified upper bound: never below exact
        // by more than the certification tolerance.
        engine.opts.certify.mode = CertifyMode::Sketch;
        let sketch = engine.run(Topology::Chord).unwrap();
        for (e, s) in exact.rows.iter().zip(&sketch.rows) {
            assert!(
                s.diameter >= e.diameter - 1e-3 * e.diameter.max(1.0),
                "t={}: sketch {} below exact {}",
                e.t,
                s.diameter,
                e.diameter
            );
        }
        // Validation: bad knobs and unsupported topologies reject.
        engine.opts.certify.budget = 0;
        assert!(engine.run(Topology::Chord).is_err());
        engine.opts.certify.budget = 4;
        assert!(engine.run(Topology::Dgro).is_err());
    }
}
