//! Churn generators: deterministic, seeded streams of timed membership
//! events. Each generator returns a plain `Vec<MembershipEvent>` so the
//! scenario spec can compose several of them with [`merge`]; overlap
//! between generators is safe because the SWIM merge rule in
//! [`crate::membership::list::MembershipList::apply_trace_event`] turns
//! a re-departure of an already-gone node into a no-op.

use std::collections::HashMap;

use crate::latency::LatencyMatrix;
use crate::membership::events::{EventTrace, MembershipEvent};
use crate::util::rng::Rng;

/// Background Poisson join/leave/crash churn over the id range
/// `0..n_alive` (delegates to [`EventTrace::churn`] — same process,
/// surfaced here so every generator lives under one roof).
pub fn poisson(
    n_alive: usize,
    horizon: f64,
    rate: f64,
    rng: &mut Rng,
) -> Vec<MembershipEvent> {
    EventTrace::churn(n_alive, horizon, rate, rng).events
}

/// Nodes `first..first + count` start the scenario absent: they are
/// marked Left at t = 0 and only exist once a later generator (a flash
/// crowd) joins them.
pub fn absent_at_start(first: u32, count: u32) -> Vec<MembershipEvent> {
    (first..first + count)
        .map(|node| MembershipEvent::Leave { time: 0.0, node })
        .collect()
}

/// A flash crowd: nodes `first..first + count` join in a burst spread
/// uniformly over `[at, at + over)` — the "whole collaboration logs on
/// for the observation window" workload.
pub fn flash_crowd(
    first: u32,
    count: u32,
    at: f64,
    over: f64,
    rng: &mut Rng,
) -> Vec<MembershipEvent> {
    let mut evs: Vec<MembershipEvent> = (first..first + count)
        .map(|node| MembershipEvent::Join {
            time: at + rng.f64() * over.max(0.0),
            node,
        })
        .collect();
    sort_by_time(&mut evs);
    evs
}

/// A correlated failure: the contiguous id block `first..first + count`
/// (a rack / site under the block-structured latency models) crashes
/// within a `spread`-wide window starting at `at` — near-simultaneous,
/// like a PDU or uplink failure, but not byte-identical times.
pub fn correlated_crash(
    first: u32,
    count: u32,
    at: f64,
    spread: f64,
    rng: &mut Rng,
) -> Vec<MembershipEvent> {
    let mut evs: Vec<MembershipEvent> = (first..first + count)
        .map(|node| MembershipEvent::Crash {
            time: at + rng.f64() * spread.max(0.0),
            node,
        })
        .collect();
    sort_by_time(&mut evs);
    evs
}

/// A transient partition as the coordinator sees it: the block drops out
/// (crashes) around `at` and every member rejoins around `heal_at`.
pub fn partition_rejoin(
    first: u32,
    count: u32,
    at: f64,
    heal_at: f64,
    rng: &mut Rng,
) -> Vec<MembershipEvent> {
    let jitter = ((heal_at - at) * 0.05).max(0.0);
    let mut evs = Vec::with_capacity(2 * count as usize);
    for node in first..first + count {
        evs.push(MembershipEvent::Crash {
            time: at + rng.f64() * jitter,
            node,
        });
    }
    for node in first..first + count {
        evs.push(MembershipEvent::Join {
            time: heal_at + rng.f64() * jitter,
            node,
        });
    }
    sort_by_time(&mut evs);
    evs
}

/// Adversarial anchor storm: every `interval` ms (starting at `at`,
/// `waves` times) the `count` currently-up nodes with the **lowest
/// latency eccentricity** crash, then rejoin `down` ms later. Low
/// eccentricity = most central in latency space — exactly the nodes
/// DGRO's shortest rings anchor their locality on, so each wave knocks
/// out the overlay's best hubs right after the coordinator has adapted
/// onto them. With `down < interval` the same anchors are hit wave
/// after wave ("kill whatever the ring is currently built around");
/// with `down > interval` the storm walks down the centrality ranking.
/// Targets are restricted to `0..population` so the storm never
/// resurrects nodes a flash-crowd block holds in reserve.
pub fn anchor_storm(
    w: &LatencyMatrix,
    population: usize,
    count: u32,
    at: f64,
    interval: f64,
    waves: u32,
    down: f64,
    rng: &mut Rng,
) -> Vec<MembershipEvent> {
    let pop = population.min(w.n());
    // Centrality ranking: eccentricity ecc(u) = max_v w(u, v), ties
    // broken by id so the ranking is total and deterministic.
    let mut ranked: Vec<(f32, u32)> = (0..pop)
        .map(|u| {
            let ecc = (0..w.n())
                .filter(|&v| v != u)
                .map(|v| w.get(u, v))
                .fold(0.0f32, f32::max);
            (ecc, u as u32)
        })
        .collect();
    ranked.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let jitter = (interval * 0.05).max(0.0);
    let mut down_until: HashMap<u32, f64> = HashMap::new();
    let mut evs = Vec::new();
    for wave in 0..waves {
        let t = at + wave as f64 * interval;
        let mut killed = 0u32;
        for &(_, node) in &ranked {
            if killed >= count {
                break;
            }
            if down_until.get(&node).copied().unwrap_or(f64::MIN) > t {
                continue; // still down from an earlier wave
            }
            let kill_t = t + rng.f64() * jitter;
            let back_t = kill_t + down;
            evs.push(MembershipEvent::Crash {
                time: kill_t,
                node,
            });
            evs.push(MembershipEvent::Join {
                time: back_t,
                node,
            });
            down_until.insert(node, back_t);
            killed += 1;
        }
    }
    sort_by_time(&mut evs);
    evs
}

/// Merge generator outputs into one time-sorted trace. The sort is
/// stable, so equal-time events keep generator order and composition is
/// deterministic.
pub fn merge(parts: Vec<Vec<MembershipEvent>>) -> EventTrace {
    let mut events: Vec<MembershipEvent> =
        parts.into_iter().flatten().collect();
    sort_by_time(&mut events);
    EventTrace { events }
}

fn sort_by_time(evs: &mut [MembershipEvent]) {
    evs.sort_by(|a, b| a.time().total_cmp(&b.time()));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_sorted(evs: &[MembershipEvent]) -> bool {
        evs.windows(2).all(|w| w[0].time() <= w[1].time())
    }

    #[test]
    fn flash_crowd_joins_inside_window() {
        let mut rng = Rng::new(1);
        let evs = flash_crowd(50, 20, 1000.0, 250.0, &mut rng);
        assert_eq!(evs.len(), 20);
        assert!(is_sorted(&evs));
        for ev in &evs {
            assert!(matches!(ev, MembershipEvent::Join { .. }));
            assert!(ev.time() >= 1000.0 && ev.time() < 1250.0);
            assert!((50..70).contains(&ev.node()));
        }
    }

    #[test]
    fn correlated_crash_hits_exactly_the_block() {
        let mut rng = Rng::new(2);
        let evs = correlated_crash(10, 5, 500.0, 10.0, &mut rng);
        let mut nodes: Vec<u32> = evs.iter().map(|e| e.node()).collect();
        nodes.sort_unstable();
        assert_eq!(nodes, vec![10, 11, 12, 13, 14]);
        assert!(evs
            .iter()
            .all(|e| matches!(e, MembershipEvent::Crash { .. })));
        assert!(evs.iter().all(|e| (500.0..510.0).contains(&e.time())));
    }

    #[test]
    fn partition_rejoin_crashes_then_rejoins_everyone() {
        let mut rng = Rng::new(3);
        let evs = partition_rejoin(4, 6, 100.0, 400.0, &mut rng);
        assert_eq!(evs.len(), 12);
        assert!(is_sorted(&evs));
        let crashes = evs
            .iter()
            .filter(|e| matches!(e, MembershipEvent::Crash { .. }))
            .count();
        assert_eq!(crashes, 6);
        // Every crash precedes every rejoin.
        let last_crash = evs
            .iter()
            .filter(|e| matches!(e, MembershipEvent::Crash { .. }))
            .map(|e| e.time())
            .fold(0.0f64, f64::max);
        let first_join = evs
            .iter()
            .filter(|e| matches!(e, MembershipEvent::Join { .. }))
            .map(|e| e.time())
            .fold(f64::INFINITY, f64::min);
        assert!(last_crash < first_join);
    }

    #[test]
    fn merge_is_sorted_and_deterministic() {
        let mut rng = Rng::new(4);
        let a = flash_crowd(30, 10, 0.0, 1000.0, &mut rng);
        let b = correlated_crash(0, 8, 500.0, 50.0, &mut rng);
        let trace = merge(vec![a.clone(), b.clone()]);
        assert_eq!(trace.len(), 18);
        assert!(is_sorted(&trace.events));
        let again = merge(vec![a, b]);
        assert_eq!(trace.events, again.events);
    }

    #[test]
    fn anchor_storm_targets_the_most_central_nodes() {
        let mut rng = Rng::new(7);
        // Node 0 is near everyone (lowest eccentricity), node ids grow
        // more peripheral: ecc(u) = 1 + u + max_v v is increasing in u.
        let w = LatencyMatrix::from_fn(12, |u, v| 1.0 + (u + v) as f32);
        let evs = anchor_storm(&w, 12, 3, 100.0, 200.0, 2, 50.0, &mut rng);
        // 2 waves x 3 targets x (crash + rejoin).
        assert_eq!(evs.len(), 12);
        assert!(is_sorted(&evs));
        let crashed: std::collections::BTreeSet<u32> = evs
            .iter()
            .filter(|e| matches!(e, MembershipEvent::Crash { .. }))
            .map(|e| e.node())
            .collect();
        // down < interval: both waves hit the same three most-central
        // nodes (the current anchors), nothing else.
        assert_eq!(
            crashed.into_iter().collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        // Every crash is followed by its rejoin ~50 ms later.
        let mut down = std::collections::HashMap::new();
        for ev in &evs {
            match ev {
                MembershipEvent::Crash { time, node } => {
                    down.insert(*node, *time);
                }
                MembershipEvent::Join { time, node } => {
                    let t0 = down.remove(node).expect("crash first");
                    assert!((time - t0 - 50.0).abs() < 1e-9);
                }
                _ => panic!("unexpected event {ev:?}"),
            }
        }
        assert!(down.is_empty(), "every wave heals");
    }

    #[test]
    fn anchor_storm_walks_the_ranking_when_down_exceeds_interval() {
        let mut rng = Rng::new(8);
        let w = LatencyMatrix::from_fn(10, |u, v| 1.0 + (u + v) as f32);
        // Wave 2 fires while wave 1's victims are still down, so it
        // must pick the next-most-central nodes instead.
        let evs =
            anchor_storm(&w, 10, 2, 0.0, 100.0, 2, 1000.0, &mut rng);
        let mut crashed: Vec<u32> = evs
            .iter()
            .filter(|e| matches!(e, MembershipEvent::Crash { .. }))
            .map(|e| e.node())
            .collect();
        crashed.sort_unstable();
        assert_eq!(crashed, vec![0, 1, 2, 3]);
    }

    #[test]
    fn absent_at_start_marks_block_left_at_zero() {
        let evs = absent_at_start(8, 4);
        assert_eq!(evs.len(), 4);
        for ev in &evs {
            assert_eq!(ev.time(), 0.0);
            assert!(matches!(ev, MembershipEvent::Leave { .. }));
        }
    }
}
