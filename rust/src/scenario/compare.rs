//! Scenario comparison: run every scenario × topology under identical
//! conditions (one seed → one base latency draw + one churn trace per
//! scenario, shared by all topologies) and tabulate diameter-under-churn
//! — the DGRO-vs-baselines view the paper's static figures cannot show.
//!
//! With [`CompareOpts::traffic`] set, every run also drives the traffic
//! plane ([`crate::traffic`]) over the evolving overlay and the report
//! grows p99-latency and greedy-stretch columns next to diameter — the
//! Papillon-style "is the low diameter actually routable?" view.
//! [`CompareOpts::certify`] selects the per-topology certification mode
//! (PR 7 upper-envelope semantics for `hybrid`/`sketch`); the
//! centralized DGRO column always certifies exactly, since its adaptive
//! path steers on true diameters. [`CompareOpts::trace_sample`] turns
//! on causal tracing for every cell and collects per-(scenario,
//! topology) `traces.jsonl` timelines in
//! [`CompareReport::trace_exports`].

use std::fmt::Write as _;

use anyhow::Result;

use crate::graph::eval::CertifyConfig;
use crate::metrics::Table;
use crate::scenario::engine::{ScenarioEngine, ScenarioReport, Topology};
use crate::scenario::spec::ScenarioSpec;
use crate::traffic::{TrafficConfig, TrafficReport};

/// Output of [`compare`].
#[derive(Clone, Debug)]
pub struct CompareReport {
    /// Scenario names, in run order (row labels).
    pub scenarios: Vec<String>,
    /// Topology panel, in column order.
    pub topologies: Vec<Topology>,
    /// Rows `[scenario_index, mean alive-overlay diameter per topology…]`
    /// (Table cells are numeric; [`CompareReport::render`] adds names).
    pub summary: Table,
    /// One table per scenario: per-period alive-overlay diameter for
    /// every topology.
    pub timelines: Vec<Table>,
    /// Traffic summary (rows `[scenario_index, p99_ms and mean_stretch
    /// per topology…]`) when [`CompareOpts::traffic`] was set.
    pub traffic_summary: Option<Table>,
    /// One traffic detail table per scenario (row per topology:
    /// success rate, p50/p99, stretch, load imbalance, failure counts)
    /// when traffic was enabled; empty otherwise.
    pub traffic_tables: Vec<Table>,
    /// Per-cell causal-trace timelines when
    /// [`CompareOpts::trace_sample`] was non-zero: one
    /// `(scenario, topology, traces.jsonl)` triple per (scenario,
    /// topology) cell, in run order. The JSONL payload is the same
    /// one-summary-line-per-trace format `--obs-out` writes; cells
    /// whose runner exchanges no frames export an empty string.
    pub trace_exports: Vec<(String, String, String)>,
}

impl CompareReport {
    /// Markdown-ish summary with scenario names attached. Deterministic.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "| scenario          ");
        for t in &self.topologies {
            let _ = write!(out, "| {:>8} ", t.name());
        }
        let _ = writeln!(out, "|");
        let _ = write!(out, "|---");
        for _ in &self.topologies {
            let _ = write!(out, "|---");
        }
        let _ = writeln!(out, "|");
        for (i, name) in self.scenarios.iter().enumerate() {
            let _ = write!(out, "| {name:<17} ");
            for j in 0..self.topologies.len() {
                let _ =
                    write!(out, "| {:8.3} ", self.summary.rows[i][j + 1]);
            }
            let _ = writeln!(out, "|");
        }
        if let Some(ts) = &self.traffic_summary {
            let _ = writeln!(out);
            let _ = writeln!(out, "traffic: p99 latency (ms) / stretch");
            let _ = write!(out, "| scenario          ");
            for t in &self.topologies {
                let _ = write!(out, "| {:>16} ", t.name());
            }
            let _ = writeln!(out, "|");
            let _ = write!(out, "|---");
            for _ in &self.topologies {
                let _ = write!(out, "|---");
            }
            let _ = writeln!(out, "|");
            for (i, name) in self.scenarios.iter().enumerate() {
                let _ = write!(out, "| {name:<17} ");
                for j in 0..self.topologies.len() {
                    let _ = write!(
                        out,
                        "| {:8.3} /{:6.3} ",
                        ts.rows[i][1 + 2 * j],
                        ts.rows[i][2 + 2 * j]
                    );
                }
                let _ = writeln!(out, "|");
            }
        }
        out
    }
}

/// Default adaptation/measurement cadence (sim-ms), shared with
/// [`ScenarioEngine`]'s construction default.
pub const DEFAULT_PERIOD_MS: f64 = 250.0;

/// Knobs threaded from the CLI into every engine the cross product
/// constructs.
#[derive(Clone, Copy, Debug)]
pub struct CompareOpts {
    /// Measurement cadence in sim-ms ([`DEFAULT_PERIOD_MS`]).
    pub period: f64,
    /// Worker threads for the topology fan-out + per-engine evaluation.
    pub threads: usize,
    /// Partition count for [`Topology::DgroSharded`] columns (ignored
    /// by every other topology; 0 resolves to the engine default).
    pub shards: usize,
    /// Per-topology diameter certification (`--certify`). Non-exact
    /// modes apply PR 7's upper-envelope semantics to the static and
    /// sharded columns; the centralized DGRO column is always forced
    /// to exact (its adaptive path steers on true diameters).
    pub certify: CertifyConfig,
    /// When set, every run also drives the traffic plane and the
    /// report grows p99/stretch columns plus per-scenario traffic
    /// detail tables.
    pub traffic: Option<TrafficConfig>,
    /// Causal-trace sampling stride (`--trace-sample`): 0 leaves
    /// tracing off; `s >= 1` enables span recording on every cell and
    /// stamps message-driven cells' frames with trace context, and the
    /// report grows one `(scenario, topology, traces.jsonl)` export
    /// per cell in [`CompareReport::trace_exports`]. In-process
    /// columns exchange no frames, so their exports are empty — the
    /// traced view is the transport-backed (`dgro`/`decentralized`)
    /// cells'.
    pub trace_sample: usize,
}

impl Default for CompareOpts {
    fn default() -> Self {
        CompareOpts {
            period: DEFAULT_PERIOD_MS,
            threads: 1,
            shards: 0,
            certify: CertifyConfig::exact(),
            traffic: None,
            trace_sample: 0,
        }
    }
}

/// The certification a given topology column actually runs under:
/// centralized DGRO is pinned to exact, everything else follows the
/// caller's choice.
fn effective_certify(certify: CertifyConfig, topo: Topology) -> CertifyConfig {
    if topo == Topology::Dgro {
        CertifyConfig::exact()
    } else {
        certify
    }
}

/// Run the cross product and collect mean alive-overlay diameters
/// (per-period timelines included). `seed` keys everything; re-running
/// with the same inputs reproduces the tables byte-for-byte — including
/// across `threads` counts, since every (scenario, topology) run is a
/// pure function of (spec, topology, seed). `period` is the measurement
/// cadence in sim-ms ([`DEFAULT_PERIOD_MS`]); `threads > 1` fans the
/// per-scenario topology runs out across the evaluation pool. The
/// sharded-coordinator column (if requested) uses the engine-default
/// shard count; use [`compare_opts`] to set it explicitly.
pub fn compare(
    specs: &[ScenarioSpec],
    topologies: &[Topology],
    seed: u64,
    period: f64,
    threads: usize,
) -> Result<CompareReport> {
    compare_opts(
        specs,
        topologies,
        seed,
        CompareOpts {
            period,
            threads,
            ..CompareOpts::default()
        },
    )
}

/// [`compare`] with the full option set — the `dgro scenario compare
/// --shards K` entry point, which appends a [`Topology::DgroSharded`]
/// column so sharded and centralized DGRO face identical conditions.
pub fn compare_opts(
    specs: &[ScenarioSpec],
    topologies: &[Topology],
    seed: u64,
    opts: CompareOpts,
) -> Result<CompareReport> {
    let CompareOpts {
        period,
        threads,
        shards,
        certify,
        traffic,
        trace_sample,
    } = opts;
    assert!(!specs.is_empty() && !topologies.is_empty());
    let mut header: Vec<String> = vec!["scenario".to_string()];
    header.extend(topologies.iter().map(|t| t.name().to_string()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut summary = Table::new(
        "Scenario compare: mean diameter under churn",
        &header_refs,
    );

    let mut traffic_summary: Option<Table> = traffic.map(|_| {
        let mut th: Vec<String> = vec!["scenario".to_string()];
        for t in topologies {
            th.push(format!("{}_p99_ms", t.name()));
            th.push(format!("{}_stretch", t.name()));
        }
        let th_refs: Vec<&str> = th.iter().map(|s| s.as_str()).collect();
        Table::new(
            "Scenario compare: traffic p99 latency and greedy stretch",
            &th_refs,
        )
    });
    let mut traffic_tables = Vec::new();
    let mut timelines = Vec::with_capacity(specs.len());
    let mut names = Vec::with_capacity(specs.len());
    let mut trace_exports = Vec::new();
    for (si, spec) in specs.iter().enumerate() {
        // One engine per (spec, topology) run so the cross product can
        // fan out; each run re-derives everything from (spec, seed) and
        // the diameter sweep schedule is thread-invariant, so results
        // are identical to the serial order. Threads beyond the
        // topology fan-out go to each engine's own evaluation pool.
        let inner_threads = (threads / topologies.len()).max(1);
        type Run = (ScenarioReport, Option<TrafficReport>);
        let one_run = |topo: Topology,
                       engine_threads: usize|
         -> Result<Run> {
            let mut engine = ScenarioEngine::new(spec.clone(), seed)?;
            engine.opts.period = period;
            engine.opts.threads = engine_threads;
            engine.opts.shards = shards;
            engine.opts.certify = effective_certify(certify, topo);
            engine.opts.trace_sample = trace_sample;
            engine.opts.obs_record = trace_sample != 0;
            match traffic {
                Some(tcfg) => {
                    let (rep, traf, _obs) =
                        engine.run_traffic(topo, tcfg)?;
                    Ok((rep, Some(traf)))
                }
                None => Ok((engine.run(topo)?, None)),
            }
        };
        let runs: Vec<Run> = if threads > 1 {
            crate::par::scoped_map(
                topologies.to_vec(),
                threads,
                |_, topo| one_run(topo, inner_threads),
            )
            .into_iter()
            .collect::<Result<Vec<_>>>()?
        } else {
            let mut v = Vec::with_capacity(topologies.len());
            for &topo in topologies {
                v.push(one_run(topo, 1)?);
            }
            v
        };
        let mut row = vec![si as f64];
        for (rep, _) in &runs {
            row.push(rep.mean_diameter());
        }
        summary.row(row);
        if trace_sample != 0 {
            for (topo, (rep, _)) in topologies.iter().zip(&runs) {
                let jsonl = rep
                    .obs
                    .as_ref()
                    .map(|obs| {
                        let spans: Vec<crate::obs::SpanRec> = obs
                            .rec
                            .spans()
                            .iter()
                            .map(crate::obs::SpanRec::from)
                            .collect();
                        crate::obs::trace::assemble(&spans)
                            .summary_jsonl()
                    })
                    .unwrap_or_default();
                trace_exports.push((
                    spec.name.clone(),
                    topo.name().to_string(),
                    jsonl,
                ));
            }
        }
        if traffic.is_some() {
            let mut trow = vec![si as f64];
            let mut tt = Table::new(
                &format!("Scenario {}: traffic", spec.name),
                &[
                    "topology_idx",
                    "success_rate",
                    "p50_ms",
                    "p99_ms",
                    "mean_stretch",
                    "max_stretch",
                    "load_imbalance",
                    "timeouts",
                    "retries",
                    "routing_failures",
                ],
            );
            for (ti, (_, traf)) in runs.iter().enumerate() {
                let tr = traf.as_ref().expect("traffic enabled");
                trow.push(tr.p99_ms);
                trow.push(tr.mean_stretch);
                tt.row(vec![
                    ti as f64,
                    tr.success_rate(),
                    tr.p50_ms,
                    tr.p99_ms,
                    tr.mean_stretch,
                    tr.max_stretch,
                    tr.load_imbalance(),
                    tr.timeouts as f64,
                    tr.retries as f64,
                    tr.routing_failures as f64,
                ]);
            }
            traffic_summary
                .as_mut()
                .expect("traffic summary allocated")
                .row(trow);
            traffic_tables.push(tt);
        }

        let mut tl_header: Vec<String> = vec!["t_ms".to_string()];
        tl_header.extend(topologies.iter().map(|t| t.name().to_string()));
        let tl_refs: Vec<&str> =
            tl_header.iter().map(|s| s.as_str()).collect();
        let mut tl = Table::new(
            &format!("Scenario {}: diameter under churn", spec.name),
            &tl_refs,
        );
        // Every run shares the spec's horizon/period, so rows align.
        for p in 0..runs[0].0.rows.len() {
            let mut cells = vec![runs[0].0.rows[p].t];
            for (run, _) in &runs {
                cells.push(
                    run.rows.get(p).map(|r| r.diameter).unwrap_or(0.0),
                );
            }
            tl.row(cells);
        }
        timelines.push(tl);
        names.push(spec.name.clone());
    }
    Ok(CompareReport {
        scenarios: names,
        topologies: topologies.to_vec(),
        summary,
        timelines,
        traffic_summary,
        traffic_tables,
        trace_exports,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::spec::{ChurnSpec, ScenarioSpec};

    fn mini(name: &str) -> ScenarioSpec {
        ScenarioSpec {
            name: name.into(),
            about: "compare unit test".into(),
            nodes: 20,
            initial_alive: 20,
            model: "uniform".into(),
            horizon: 500.0,
            churn: vec![ChurnSpec::Poisson { rate: 0.001 }],
            latency: vec![],
        }
    }

    #[test]
    fn compare_shapes_and_determinism() {
        let specs = vec![mini("a"), mini("b")];
        let topos = [Topology::Dgro, Topology::Chord];
        let r1 =
            compare(&specs, &topos, 3, DEFAULT_PERIOD_MS, 1).unwrap();
        assert_eq!(r1.summary.rows.len(), 2);
        assert_eq!(r1.summary.header.len(), 3);
        assert_eq!(r1.timelines.len(), 2);
        for t in &r1.timelines {
            assert_eq!(t.rows.len(), 2); // horizon 500 / period 250
            for row in &t.rows {
                assert!(row.iter().all(|x| x.is_finite()));
            }
        }
        let r2 =
            compare(&specs, &topos, 3, DEFAULT_PERIOD_MS, 1).unwrap();
        assert_eq!(r1.render(), r2.render());
        assert_eq!(r1.summary.to_csv(), r2.summary.to_csv());
        assert!(r1.render().contains("| a"));
    }

    #[test]
    fn sharded_column_rides_the_cross_product() {
        let specs = vec![mini("a")];
        let topos = [Topology::Dgro, Topology::DgroSharded];
        let opts = CompareOpts {
            shards: 4,
            ..CompareOpts::default()
        };
        let r1 = compare_opts(&specs, &topos, 5, opts).unwrap();
        assert_eq!(r1.summary.header.len(), 3);
        for row in &r1.summary.rows {
            for cell in &row[1..] {
                assert!(cell.is_finite() && *cell > 0.0);
            }
        }
        assert!(r1.render().contains("sharded"));
        // Deterministic like every other column.
        let r2 = compare_opts(&specs, &topos, 5, opts).unwrap();
        assert_eq!(r1.render(), r2.render());
    }

    #[test]
    fn traffic_columns_ride_the_cross_product() {
        let specs = vec![mini("a")];
        let topos = [Topology::Dgro, Topology::Chord];
        let mut tcfg = TrafficConfig::default();
        tcfg.rate = 20_000.0;
        let opts = CompareOpts {
            traffic: Some(tcfg),
            ..CompareOpts::default()
        };
        let r1 = compare_opts(&specs, &topos, 7, opts).unwrap();
        let ts = r1.traffic_summary.as_ref().unwrap();
        assert_eq!(ts.rows.len(), 1);
        assert_eq!(ts.rows[0].len(), 1 + 2 * topos.len());
        assert_eq!(r1.traffic_tables.len(), 1);
        for j in 0..topos.len() {
            let stretch = ts.rows[0][2 + 2 * j];
            assert!(
                stretch == 0.0 || stretch >= 1.0,
                "stretch must be ≥ 1 when sampled, got {stretch}"
            );
        }
        assert!(r1.render().contains("traffic: p99"));
        // Deterministic, including across thread counts.
        let r2 = compare_opts(&specs, &topos, 7, opts).unwrap();
        assert_eq!(r1.render(), r2.render());
        let rp = compare_opts(
            &specs,
            &topos,
            7,
            CompareOpts {
                threads: 4,
                ..opts
            },
        )
        .unwrap();
        assert_eq!(r1.render(), rp.render());
        for (a, b) in r1.traffic_tables.iter().zip(&rp.traffic_tables) {
            assert_eq!(a.to_csv(), b.to_csv());
        }
    }

    #[test]
    fn trace_sample_threads_through_compare_cells() {
        let specs = vec![mini("a")];
        let topos = [Topology::Dgro, Topology::Decentralized];
        let opts = CompareOpts {
            trace_sample: 1,
            ..CompareOpts::default()
        };
        let r1 = compare_opts(&specs, &topos, 13, opts).unwrap();
        assert_eq!(r1.trace_exports.len(), topos.len());
        assert_eq!(r1.trace_exports[0].0, "a");
        assert_eq!(r1.trace_exports[0].1, "dgro");
        assert_eq!(r1.trace_exports[1].1, "decentralized");
        // The decentralized cell runs message-driven over the sim
        // transport, so its frames carry trace context and assemble
        // into at least one causal trace.
        assert!(
            !r1.trace_exports[1].2.is_empty(),
            "decentralized cell must export assembled traces"
        );
        // Untraced compare keeps the report trace-free.
        let off = compare_opts(
            &specs,
            &topos,
            13,
            CompareOpts::default(),
        )
        .unwrap();
        assert!(off.trace_exports.is_empty());
        // Byte-deterministic like every other compare artifact.
        let r2 = compare_opts(&specs, &topos, 13, opts).unwrap();
        assert_eq!(r1.trace_exports, r2.trace_exports);
    }

    #[test]
    fn hybrid_certification_is_allowed_on_compare() {
        use crate::graph::eval::CertifyMode;
        let specs = vec![mini("a")];
        let topos = [Topology::Dgro, Topology::Chord, Topology::Rapid];
        let exact =
            compare_opts(&specs, &topos, 11, CompareOpts::default())
                .unwrap();
        let mut certify = CertifyConfig::exact();
        certify.mode = CertifyMode::Hybrid;
        certify.budget = 8;
        certify.oracle_every = 4;
        let hybrid = compare_opts(
            &specs,
            &topos,
            11,
            CompareOpts {
                certify,
                ..CompareOpts::default()
            },
        )
        .unwrap();
        for (er, hr) in
            exact.summary.rows.iter().zip(&hybrid.summary.rows)
        {
            assert_eq!(er[1], hr[1], "dgro column stays exact");
            // Upper-envelope semantics: non-exact columns report
            // conservative (≥ exact) mean diameters.
            for j in 2..er.len() {
                assert!(
                    hr[j] >= er[j] - 1e-9,
                    "upper envelope violated: {} < {}",
                    hr[j],
                    er[j]
                );
            }
        }
    }

    #[test]
    fn parallel_cross_product_matches_serial() {
        let specs = vec![mini("a"), mini("b")];
        let topos = [Topology::Dgro, Topology::Chord, Topology::Rapid];
        let serial =
            compare(&specs, &topos, 9, DEFAULT_PERIOD_MS, 1).unwrap();
        let par =
            compare(&specs, &topos, 9, DEFAULT_PERIOD_MS, 4).unwrap();
        assert_eq!(serial.render(), par.render());
        assert_eq!(serial.summary.to_csv(), par.summary.to_csv());
        for (a, b) in serial.timelines.iter().zip(&par.timelines) {
            assert_eq!(a.to_csv(), b.to_csv());
        }
    }
}
