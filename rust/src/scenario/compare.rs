//! Scenario comparison: run every scenario × topology under identical
//! conditions (one seed → one base latency draw + one churn trace per
//! scenario, shared by all topologies) and tabulate diameter-under-churn
//! — the DGRO-vs-baselines view the paper's static figures cannot show.

use std::fmt::Write as _;

use anyhow::Result;

use crate::metrics::Table;
use crate::scenario::engine::{ScenarioEngine, ScenarioReport, Topology};
use crate::scenario::spec::ScenarioSpec;

/// Output of [`compare`].
#[derive(Clone, Debug)]
pub struct CompareReport {
    /// Scenario names, in run order (row labels).
    pub scenarios: Vec<String>,
    /// Topology panel, in column order.
    pub topologies: Vec<Topology>,
    /// Rows `[scenario_index, mean alive-overlay diameter per topology…]`
    /// (Table cells are numeric; [`CompareReport::render`] adds names).
    pub summary: Table,
    /// One table per scenario: per-period alive-overlay diameter for
    /// every topology.
    pub timelines: Vec<Table>,
}

impl CompareReport {
    /// Markdown-ish summary with scenario names attached. Deterministic.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "| scenario          ");
        for t in &self.topologies {
            let _ = write!(out, "| {:>8} ", t.name());
        }
        let _ = writeln!(out, "|");
        let _ = write!(out, "|---");
        for _ in &self.topologies {
            let _ = write!(out, "|---");
        }
        let _ = writeln!(out, "|");
        for (i, name) in self.scenarios.iter().enumerate() {
            let _ = write!(out, "| {name:<17} ");
            for j in 0..self.topologies.len() {
                let _ =
                    write!(out, "| {:8.3} ", self.summary.rows[i][j + 1]);
            }
            let _ = writeln!(out, "|");
        }
        out
    }
}

/// Default adaptation/measurement cadence (sim-ms), shared with
/// [`ScenarioEngine`]'s construction default.
pub const DEFAULT_PERIOD_MS: f64 = 250.0;

/// Knobs threaded from the CLI into every engine the cross product
/// constructs.
#[derive(Clone, Copy, Debug)]
pub struct CompareOpts {
    /// Measurement cadence in sim-ms ([`DEFAULT_PERIOD_MS`]).
    pub period: f64,
    /// Worker threads for the topology fan-out + per-engine evaluation.
    pub threads: usize,
    /// Partition count for [`Topology::DgroSharded`] columns (ignored
    /// by every other topology; 0 resolves to the engine default).
    pub shards: usize,
}

impl Default for CompareOpts {
    fn default() -> Self {
        CompareOpts {
            period: DEFAULT_PERIOD_MS,
            threads: 1,
            shards: 0,
        }
    }
}

/// Run the cross product and collect mean alive-overlay diameters
/// (per-period timelines included). `seed` keys everything; re-running
/// with the same inputs reproduces the tables byte-for-byte — including
/// across `threads` counts, since every (scenario, topology) run is a
/// pure function of (spec, topology, seed). `period` is the measurement
/// cadence in sim-ms ([`DEFAULT_PERIOD_MS`]); `threads > 1` fans the
/// per-scenario topology runs out across the evaluation pool. The
/// sharded-coordinator column (if requested) uses the engine-default
/// shard count; use [`compare_opts`] to set it explicitly.
pub fn compare(
    specs: &[ScenarioSpec],
    topologies: &[Topology],
    seed: u64,
    period: f64,
    threads: usize,
) -> Result<CompareReport> {
    compare_opts(
        specs,
        topologies,
        seed,
        CompareOpts {
            period,
            threads,
            shards: 0,
        },
    )
}

/// [`compare`] with the full option set — the `dgro scenario compare
/// --shards K` entry point, which appends a [`Topology::DgroSharded`]
/// column so sharded and centralized DGRO face identical conditions.
pub fn compare_opts(
    specs: &[ScenarioSpec],
    topologies: &[Topology],
    seed: u64,
    opts: CompareOpts,
) -> Result<CompareReport> {
    let CompareOpts {
        period,
        threads,
        shards,
    } = opts;
    assert!(!specs.is_empty() && !topologies.is_empty());
    let mut header: Vec<String> = vec!["scenario".to_string()];
    header.extend(topologies.iter().map(|t| t.name().to_string()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut summary = Table::new(
        "Scenario compare: mean diameter under churn",
        &header_refs,
    );

    let mut timelines = Vec::with_capacity(specs.len());
    let mut names = Vec::with_capacity(specs.len());
    for (si, spec) in specs.iter().enumerate() {
        // One engine per (spec, topology) run so the cross product can
        // fan out; each run re-derives everything from (spec, seed) and
        // the diameter sweep schedule is thread-invariant, so results
        // are identical to the serial order. Threads beyond the
        // topology fan-out go to each engine's own evaluation pool.
        let inner_threads = (threads / topologies.len()).max(1);
        let runs: Vec<ScenarioReport> = if threads > 1 {
            crate::par::scoped_map(
                topologies.to_vec(),
                threads,
                |_, topo| -> Result<ScenarioReport> {
                    let mut engine =
                        ScenarioEngine::new(spec.clone(), seed)?;
                    engine.period = period;
                    engine.threads = inner_threads;
                    engine.shards = shards;
                    engine.run(topo)
                },
            )
            .into_iter()
            .collect::<Result<Vec<_>>>()?
        } else {
            let mut engine = ScenarioEngine::new(spec.clone(), seed)?;
            engine.period = period;
            engine.shards = shards;
            let mut v = Vec::with_capacity(topologies.len());
            for &topo in topologies {
                v.push(engine.run(topo)?);
            }
            v
        };
        let mut row = vec![si as f64];
        for rep in &runs {
            row.push(rep.mean_diameter());
        }
        summary.row(row);

        let mut tl_header: Vec<String> = vec!["t_ms".to_string()];
        tl_header.extend(topologies.iter().map(|t| t.name().to_string()));
        let tl_refs: Vec<&str> =
            tl_header.iter().map(|s| s.as_str()).collect();
        let mut tl = Table::new(
            &format!("Scenario {}: diameter under churn", spec.name),
            &tl_refs,
        );
        // Every run shares the spec's horizon/period, so rows align.
        for p in 0..runs[0].rows.len() {
            let mut cells = vec![runs[0].rows[p].t];
            for run in &runs {
                cells.push(
                    run.rows.get(p).map(|r| r.diameter).unwrap_or(0.0),
                );
            }
            tl.row(cells);
        }
        timelines.push(tl);
        names.push(spec.name.clone());
    }
    Ok(CompareReport {
        scenarios: names,
        topologies: topologies.to_vec(),
        summary,
        timelines,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::spec::{ChurnSpec, ScenarioSpec};

    fn mini(name: &str) -> ScenarioSpec {
        ScenarioSpec {
            name: name.into(),
            about: "compare unit test".into(),
            nodes: 20,
            initial_alive: 20,
            model: "uniform".into(),
            horizon: 500.0,
            churn: vec![ChurnSpec::Poisson { rate: 0.001 }],
            latency: vec![],
        }
    }

    #[test]
    fn compare_shapes_and_determinism() {
        let specs = vec![mini("a"), mini("b")];
        let topos = [Topology::Dgro, Topology::Chord];
        let r1 =
            compare(&specs, &topos, 3, DEFAULT_PERIOD_MS, 1).unwrap();
        assert_eq!(r1.summary.rows.len(), 2);
        assert_eq!(r1.summary.header.len(), 3);
        assert_eq!(r1.timelines.len(), 2);
        for t in &r1.timelines {
            assert_eq!(t.rows.len(), 2); // horizon 500 / period 250
            for row in &t.rows {
                assert!(row.iter().all(|x| x.is_finite()));
            }
        }
        let r2 =
            compare(&specs, &topos, 3, DEFAULT_PERIOD_MS, 1).unwrap();
        assert_eq!(r1.render(), r2.render());
        assert_eq!(r1.summary.to_csv(), r2.summary.to_csv());
        assert!(r1.render().contains("| a"));
    }

    #[test]
    fn sharded_column_rides_the_cross_product() {
        let specs = vec![mini("a")];
        let topos = [Topology::Dgro, Topology::DgroSharded];
        let opts = CompareOpts {
            shards: 4,
            ..CompareOpts::default()
        };
        let r1 = compare_opts(&specs, &topos, 5, opts).unwrap();
        assert_eq!(r1.summary.header.len(), 3);
        for row in &r1.summary.rows {
            for cell in &row[1..] {
                assert!(cell.is_finite() && *cell > 0.0);
            }
        }
        assert!(r1.render().contains("sharded"));
        // Deterministic like every other column.
        let r2 = compare_opts(&specs, &topos, 5, opts).unwrap();
        assert_eq!(r1.render(), r2.render());
    }

    #[test]
    fn parallel_cross_product_matches_serial() {
        let specs = vec![mini("a"), mini("b")];
        let topos = [Topology::Dgro, Topology::Chord, Topology::Rapid];
        let serial =
            compare(&specs, &topos, 9, DEFAULT_PERIOD_MS, 1).unwrap();
        let par =
            compare(&specs, &topos, 9, DEFAULT_PERIOD_MS, 4).unwrap();
        assert_eq!(serial.render(), par.render());
        assert_eq!(serial.summary.to_csv(), par.summary.to_csv());
        for (a, b) in serial.timelines.iter().zip(&par.timelines) {
            assert_eq!(a.to_csv(), b.to_csv());
        }
    }
}
