//! Scenario engine: deterministic churn + dynamic-latency workloads for
//! the DGRO coordinator (docs/SCENARIOS.md).
//!
//! The paper evaluates DGRO on static latency matrices; long-lived
//! research infrastructure is anything but static. This subsystem
//! composes seeded **churn generators** ([`churn`]: Poisson join/leave,
//! flash crowds, correlated rack crashes, rejoin-after-partition) with
//! **dynamic latency models** ([`dynamics`]: diurnal drift, link
//! degradation, transient WAN partitions as a time-varying overlay on
//! [`crate::latency::LatencyMatrix`]) into named, JSON-parsable
//! [`spec::ScenarioSpec`]s, then drives the coordinator event loop (or a
//! static baseline) through them ([`engine`]) and tabulates
//! diameter-under-churn across topologies ([`compare`](mod@compare)).
//!
//! Everything is a pure function of (spec, topology, seed): two runs
//! with the same inputs emit byte-identical reports, which is what lets
//! `dgro scenario compare` serve as a regression harness for robustness
//! claims.
//!
//! ```no_run
//! use dgro::scenario::{find, ScenarioEngine, Topology};
//! let spec = find("flash-crowd").unwrap();
//! let engine = ScenarioEngine::new(spec, 7).unwrap();
//! let report = engine.run(Topology::Dgro).unwrap();
//! println!("{}", report.render());
//! ```

pub mod churn;
pub mod compare;
pub mod dynamics;
pub mod engine;
pub mod spec;

pub use compare::{compare, compare_opts, CompareOpts, CompareReport};
pub use dynamics::{DynamicLatency, LatencyEffect};
pub use engine::{PeriodRow, ScenarioEngine, ScenarioReport, Topology};
pub use spec::{catalog, find, ChurnSpec, ScenarioSpec};
