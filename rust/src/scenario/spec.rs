//! The scenario spec: a named, seedable description of a dynamic
//! workload — which nodes exist, which churn generators run, and which
//! latency effects overlay the base matrix. Specs are JSON-parsable
//! (same in-tree parser as [`crate::config`], unknown keys rejected) and
//! ship with a built-in catalog; see docs/SCENARIOS.md for the format.

use anyhow::{bail, Context, Result};

use crate::latency::Model;
use crate::membership::events::{EventTrace, MembershipEvent};
use crate::scenario::churn;
use crate::scenario::dynamics::LatencyEffect;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// One churn component of a scenario.
#[derive(Clone, Debug, PartialEq)]
pub enum ChurnSpec {
    /// Background Poisson join/leave/crash churn at `rate` per node-ms
    /// over the initially-alive population.
    Poisson { rate: f64 },
    /// `count` fresh nodes (`first..first+count`) join in a burst over
    /// `[at, at + over)`.
    FlashCrowd { first: u32, count: u32, at: f64, over: f64 },
    /// The contiguous block `first..first+count` crashes within
    /// `[at, at + spread)`.
    CorrelatedCrash { first: u32, count: u32, at: f64, spread: f64 },
    /// The block drops out at `at` and rejoins at `heal_at`.
    PartitionRejoin { first: u32, count: u32, at: f64, heal_at: f64 },
    /// Adversarial anchor storm: `waves` waves, `interval` ms apart
    /// starting at `at`, each crashing the `count` currently-up nodes
    /// with the lowest latency eccentricity (the ring-anchor hubs);
    /// victims rejoin `down` ms after their crash. See
    /// [`churn::anchor_storm`].
    AnchorStorm { count: u32, at: f64, interval: f64, waves: u32, down: f64 },
}

impl ChurnSpec {
    /// Serialize for spec files (round-trips through `from_json`).
    pub fn to_json(&self) -> Json {
        match *self {
            ChurnSpec::Poisson { rate } => Json::obj(vec![
                ("kind", Json::str("poisson")),
                ("rate", Json::num(rate)),
            ]),
            ChurnSpec::FlashCrowd {
                first,
                count,
                at,
                over,
            } => Json::obj(vec![
                ("kind", Json::str("flash-crowd")),
                ("first", Json::num(first as f64)),
                ("count", Json::num(count as f64)),
                ("at", Json::num(at)),
                ("over", Json::num(over)),
            ]),
            ChurnSpec::CorrelatedCrash {
                first,
                count,
                at,
                spread,
            } => Json::obj(vec![
                ("kind", Json::str("correlated-crash")),
                ("first", Json::num(first as f64)),
                ("count", Json::num(count as f64)),
                ("at", Json::num(at)),
                ("spread", Json::num(spread)),
            ]),
            ChurnSpec::PartitionRejoin {
                first,
                count,
                at,
                heal_at,
            } => Json::obj(vec![
                ("kind", Json::str("partition-rejoin")),
                ("first", Json::num(first as f64)),
                ("count", Json::num(count as f64)),
                ("at", Json::num(at)),
                ("heal_at", Json::num(heal_at)),
            ]),
            ChurnSpec::AnchorStorm {
                count,
                at,
                interval,
                waves,
                down,
            } => Json::obj(vec![
                ("kind", Json::str("anchor-storm")),
                ("count", Json::num(count as f64)),
                ("at", Json::num(at)),
                ("interval", Json::num(interval)),
                ("waves", Json::num(waves as f64)),
                ("down", Json::num(down)),
            ]),
        }
    }

    /// Parse one churn object (see docs/SCENARIOS.md).
    pub fn from_json(v: &Json) -> Result<ChurnSpec> {
        Ok(match v.get("kind")?.as_str()? {
            "poisson" => ChurnSpec::Poisson {
                rate: v.get("rate")?.as_f64()?,
            },
            "flash-crowd" => ChurnSpec::FlashCrowd {
                first: v.get("first")?.as_usize()? as u32,
                count: v.get("count")?.as_usize()? as u32,
                at: v.get("at")?.as_f64()?,
                over: v.get("over")?.as_f64()?,
            },
            "correlated-crash" => ChurnSpec::CorrelatedCrash {
                first: v.get("first")?.as_usize()? as u32,
                count: v.get("count")?.as_usize()? as u32,
                at: v.get("at")?.as_f64()?,
                spread: v.get("spread")?.as_f64()?,
            },
            "partition-rejoin" => ChurnSpec::PartitionRejoin {
                first: v.get("first")?.as_usize()? as u32,
                count: v.get("count")?.as_usize()? as u32,
                at: v.get("at")?.as_f64()?,
                heal_at: v.get("heal_at")?.as_f64()?,
            },
            "anchor-storm" => ChurnSpec::AnchorStorm {
                count: v.get("count")?.as_usize()? as u32,
                at: v.get("at")?.as_f64()?,
                interval: v.get("interval")?.as_f64()?,
                waves: v.get("waves")?.as_usize()? as u32,
                down: v.get("down")?.as_f64()?,
            },
            other => bail!("unknown churn kind '{other}'"),
        })
    }
}

/// A named, reproducible dynamic workload.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    /// Unique workload name (catalog key, report label).
    pub name: String,
    /// One-line description shown by `dgro scenario list`.
    pub about: String,
    /// Node universe (latency matrix size).
    pub nodes: usize,
    /// Nodes alive at t = 0 (`initial_alive..nodes` start absent and may
    /// join later — flash crowds). Must be in `3..=nodes`.
    pub initial_alive: usize,
    /// Latency model name (uniform | gaussian | fabric | bitnode).
    pub model: String,
    /// Sim-time horizon (ms).
    pub horizon: f64,
    /// Churn components, merged into one trace.
    pub churn: Vec<ChurnSpec>,
    /// Dynamic-latency effects overlaying the base matrix.
    pub latency: Vec<LatencyEffect>,
}

impl ScenarioSpec {
    /// Check every cross-field invariant (ranges, block bounds).
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("scenario name must not be empty");
        }
        if self.nodes < 3 {
            bail!("nodes must be >= 3, got {}", self.nodes);
        }
        if !(3..=self.nodes).contains(&self.initial_alive) {
            bail!(
                "initial_alive must be in 3..=nodes, got {} (nodes {})",
                self.initial_alive,
                self.nodes
            );
        }
        if Model::parse(&self.model).is_none() {
            bail!("unknown latency model '{}'", self.model);
        }
        if !(self.horizon > 0.0) {
            bail!("horizon must be > 0, got {}", self.horizon);
        }
        for c in &self.churn {
            match *c {
                ChurnSpec::Poisson { rate } => {
                    if rate < 0.0 {
                        bail!("poisson rate must be >= 0, got {rate}");
                    }
                }
                ChurnSpec::AnchorStorm {
                    count,
                    interval,
                    waves,
                    down,
                    ..
                } => {
                    if count == 0 || waves == 0 {
                        bail!("anchor storm needs count and waves >= 1");
                    }
                    if !(interval > 0.0) {
                        bail!(
                            "anchor storm interval must be > 0, got \
                             {interval}"
                        );
                    }
                    if !(down > 0.0) {
                        bail!(
                            "anchor storm down time must be > 0, got {down}"
                        );
                    }
                    // With down > interval, consecutive waves overlap
                    // and each walks further down the centrality
                    // ranking — bound the worst-case *concurrently*
                    // down population, not just one wave.
                    let overlap =
                        ((down / interval).ceil() as u32).max(1).min(waves);
                    let concurrent = count as usize * overlap as usize;
                    if concurrent + 3 > self.initial_alive {
                        bail!(
                            "anchor storm can take down {concurrent} \
                             nodes at once ({count} x {overlap} \
                             overlapping waves), leaving fewer than 3 \
                             of {} initially-alive nodes",
                            self.initial_alive
                        );
                    }
                }
                ChurnSpec::FlashCrowd { first, count, .. }
                | ChurnSpec::CorrelatedCrash { first, count, .. }
                | ChurnSpec::PartitionRejoin { first, count, .. } => {
                    if count == 0 {
                        bail!("churn block must be non-empty");
                    }
                    if first as usize + count as usize > self.nodes {
                        bail!(
                            "churn block {}..{} exceeds nodes {}",
                            first,
                            first as usize + count as usize,
                            self.nodes
                        );
                    }
                    if let ChurnSpec::PartitionRejoin {
                        at, heal_at, ..
                    } = *c
                    {
                        if !(heal_at > at) {
                            bail!(
                                "partition-rejoin heal_at {heal_at} must \
                                 come after at {at}"
                            );
                        }
                    }
                }
            }
        }
        for e in &self.latency {
            e.validate()?;
            // Effect targets must exist, mirroring the churn-block
            // bounds check — a typo'd id would otherwise be a silent
            // no-op (factor() never matches).
            match *e {
                LatencyEffect::Degrade { node, .. } => {
                    if node as usize >= self.nodes {
                        bail!(
                            "degrade node {node} out of range for {} nodes",
                            self.nodes
                        );
                    }
                }
                LatencyEffect::Partition { boundary, .. } => {
                    if boundary == 0 || boundary as usize >= self.nodes {
                        bail!(
                            "partition boundary {boundary} splits nothing \
                             for {} nodes (need 1..nodes)",
                            self.nodes
                        );
                    }
                }
                LatencyEffect::Diurnal { .. } => {}
            }
        }
        Ok(())
    }

    /// Generate the full deterministic membership trace for this spec
    /// (merge of every churn component, plus t = 0 departures for the
    /// initially-absent block). Takes the base latency matrix because
    /// latency-aware generators ([`ChurnSpec::AnchorStorm`]) rank their
    /// targets by centrality in `w`.
    pub fn events(&self, w: &crate::latency::LatencyMatrix, rng: &mut Rng) -> EventTrace {
        let mut parts: Vec<Vec<MembershipEvent>> = Vec::new();
        if self.initial_alive < self.nodes {
            parts.push(churn::absent_at_start(
                self.initial_alive as u32,
                (self.nodes - self.initial_alive) as u32,
            ));
        }
        for c in &self.churn {
            parts.push(match *c {
                ChurnSpec::Poisson { rate } => churn::poisson(
                    self.initial_alive,
                    self.horizon,
                    rate,
                    rng,
                ),
                ChurnSpec::AnchorStorm {
                    count,
                    at,
                    interval,
                    waves,
                    down,
                } => churn::anchor_storm(
                    w,
                    self.initial_alive,
                    count,
                    at,
                    interval,
                    waves,
                    down,
                    rng,
                ),
                ChurnSpec::FlashCrowd {
                    first,
                    count,
                    at,
                    over,
                } => churn::flash_crowd(first, count, at, over, rng),
                ChurnSpec::CorrelatedCrash {
                    first,
                    count,
                    at,
                    spread,
                } => churn::correlated_crash(first, count, at, spread, rng),
                ChurnSpec::PartitionRejoin {
                    first,
                    count,
                    at,
                    heal_at,
                } => churn::partition_rejoin(first, count, at, heal_at, rng),
            });
        }
        churn::merge(parts)
    }

    // -----------------------------------------------------------------
    // JSON round-trip (spec files).
    // -----------------------------------------------------------------

    /// Serialize to the JSON spec format.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("about", Json::str(self.about.clone())),
            ("nodes", Json::num(self.nodes as f64)),
            ("initial_alive", Json::num(self.initial_alive as f64)),
            ("model", Json::str(self.model.clone())),
            ("horizon", Json::num(self.horizon)),
            (
                "churn",
                Json::arr(self.churn.iter().map(|c| c.to_json()).collect()),
            ),
            (
                "latency",
                Json::arr(
                    self.latency.iter().map(|e| e.to_json()).collect(),
                ),
            ),
        ])
    }

    /// Parse from JSON text, rejecting unknown keys.
    pub fn parse(text: &str) -> Result<ScenarioSpec> {
        let root = json::parse(text).context("parsing scenario JSON")?;
        let obj = root.as_obj()?;
        let mut spec = ScenarioSpec {
            name: String::new(),
            about: String::new(),
            nodes: 0,
            initial_alive: 0,
            model: "uniform".to_string(),
            horizon: 0.0,
            churn: Vec::new(),
            latency: Vec::new(),
        };
        let mut saw_initial = false;
        for (key, val) in obj {
            match key.as_str() {
                "name" => spec.name = val.as_str()?.to_string(),
                "about" => spec.about = val.as_str()?.to_string(),
                "nodes" => spec.nodes = val.as_usize()?,
                "initial_alive" => {
                    spec.initial_alive = val.as_usize()?;
                    saw_initial = true;
                }
                "model" => spec.model = val.as_str()?.to_string(),
                "horizon" => spec.horizon = val.as_f64()?,
                "churn" => {
                    spec.churn = val
                        .as_arr()?
                        .iter()
                        .map(ChurnSpec::from_json)
                        .collect::<Result<Vec<_>>>()?;
                }
                "latency" => {
                    spec.latency = val
                        .as_arr()?
                        .iter()
                        .map(LatencyEffect::from_json)
                        .collect::<Result<Vec<_>>>()?;
                }
                other => bail!("unknown scenario key '{other}'"),
            }
        }
        if !saw_initial {
            spec.initial_alive = spec.nodes;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Load and validate a spec file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<ScenarioSpec> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(
            || format!("reading scenario {:?}", path.as_ref()),
        )?;
        ScenarioSpec::parse(&text)
    }
}

/// The built-in catalog: eight named workloads stressing the parts of
/// DGRO the paper's static figures never touch. Sizes are kept modest so
/// the whole catalog sweeps in CI; scale `nodes`/`horizon` up via spec
/// files for real studies.
pub fn catalog() -> Vec<ScenarioSpec> {
    vec![
        ScenarioSpec {
            name: "steady-state".into(),
            about: "low background churn, static latency (control)".into(),
            nodes: 72,
            initial_alive: 72,
            model: "fabric".into(),
            horizon: 4000.0,
            churn: vec![ChurnSpec::Poisson { rate: 0.0002 }],
            latency: vec![],
        },
        ScenarioSpec {
            name: "flash-crowd".into(),
            about: "36 nodes join in a 500 ms burst mid-run".into(),
            nodes: 96,
            initial_alive: 60,
            model: "fabric".into(),
            horizon: 4000.0,
            churn: vec![
                ChurnSpec::Poisson { rate: 0.0002 },
                ChurnSpec::FlashCrowd {
                    first: 60,
                    count: 36,
                    at: 1500.0,
                    over: 500.0,
                },
            ],
            latency: vec![],
        },
        ScenarioSpec {
            name: "churn-storm".into(),
            about: "sustained 5x-baseline Poisson churn with rejoins"
                .into(),
            nodes: 80,
            initial_alive: 80,
            model: "fabric".into(),
            horizon: 4000.0,
            churn: vec![ChurnSpec::Poisson { rate: 0.001 }],
            latency: vec![],
        },
        ScenarioSpec {
            name: "rack-failure".into(),
            about: "correlated crash of a 15-node id block at t=2000"
                .into(),
            nodes: 85,
            initial_alive: 85,
            model: "fabric".into(),
            horizon: 4000.0,
            churn: vec![
                ChurnSpec::Poisson { rate: 0.0002 },
                ChurnSpec::CorrelatedCrash {
                    first: 20,
                    count: 15,
                    at: 2000.0,
                    spread: 50.0,
                },
            ],
            latency: vec![],
        },
        ScenarioSpec {
            name: "anchor-storm".into(),
            about: "waves of crashes hit the lowest-eccentricity \
                    (anchor) nodes"
                .into(),
            nodes: 76,
            initial_alive: 76,
            model: "fabric".into(),
            horizon: 4000.0,
            churn: vec![
                ChurnSpec::Poisson { rate: 0.0002 },
                ChurnSpec::AnchorStorm {
                    count: 6,
                    at: 1000.0,
                    interval: 750.0,
                    waves: 4,
                    down: 500.0,
                },
            ],
            latency: vec![],
        },
        ScenarioSpec {
            name: "wan-partition".into(),
            about: "cross-boundary links 8x slower during [1500, 3000)"
                .into(),
            nodes: 80,
            initial_alive: 80,
            model: "fabric".into(),
            horizon: 4500.0,
            churn: vec![ChurnSpec::Poisson { rate: 0.0002 }],
            latency: vec![LatencyEffect::Partition {
                boundary: 40,
                factor: 8.0,
                start: 1500.0,
                end: 3000.0,
            }],
        },
        ScenarioSpec {
            name: "diurnal-drift".into(),
            about: "all-link sinusoidal drift (amplitude 0.6)".into(),
            nodes: 72,
            initial_alive: 72,
            model: "fabric".into(),
            horizon: 4000.0,
            churn: vec![ChurnSpec::Poisson { rate: 0.0002 }],
            latency: vec![LatencyEffect::Diurnal {
                period: 2000.0,
                amplitude: 0.6,
                phase: 0.0,
            }],
        },
        ScenarioSpec {
            name: "link-degradation".into(),
            about: "two nodes' links degrade 6x in sliding windows".into(),
            nodes: 76,
            initial_alive: 76,
            model: "fabric".into(),
            horizon: 4000.0,
            churn: vec![],
            latency: vec![
                LatencyEffect::Degrade {
                    node: 3,
                    factor: 6.0,
                    start: 1000.0,
                    end: 2500.0,
                },
                LatencyEffect::Degrade {
                    node: 41,
                    factor: 6.0,
                    start: 1800.0,
                    end: 3200.0,
                },
            ],
        },
    ]
}

/// Look up a catalog scenario by name.
pub fn find(name: &str) -> Result<ScenarioSpec> {
    catalog()
        .into_iter()
        .find(|s| s.name == name)
        .ok_or_else(|| {
            let names: Vec<String> =
                catalog().into_iter().map(|s| s.name).collect();
            anyhow::anyhow!(
                "no catalog scenario '{name}' (have: {})",
                names.join(", ")
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_specs_validate_and_have_unique_names() {
        let specs = catalog();
        assert!(specs.len() >= 6, "catalog must cover >= 6 scenarios");
        let mut names = std::collections::BTreeSet::new();
        for s in &specs {
            s.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name));
            assert!(names.insert(s.name.clone()), "dup name {}", s.name);
        }
    }

    #[test]
    fn catalog_json_roundtrip() {
        for spec in catalog() {
            let text = spec.to_json().to_string();
            let back = ScenarioSpec::parse(&text)
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn parse_rejects_unknown_keys_and_bad_blocks() {
        assert!(ScenarioSpec::parse(r#"{"bogus": 1}"#).is_err());
        let over = r#"{"name":"x","nodes":10,"model":"uniform",
            "horizon":100,
            "churn":[{"kind":"flash-crowd","first":8,"count":5,
                      "at":0,"over":10}]}"#;
        let err = ScenarioSpec::parse(over).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn validate_rejects_inverted_heal_window() {
        let mut s = find("steady-state").unwrap();
        s.churn.push(ChurnSpec::PartitionRejoin {
            first: 0,
            count: 10,
            at: 4000.0,
            heal_at: 400.0,
        });
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("heal_at"), "{err}");
    }

    #[test]
    fn validate_rejects_out_of_range_effect_targets() {
        let mut s = find("steady-state").unwrap();
        s.latency.push(LatencyEffect::Degrade {
            node: s.nodes as u32,
            factor: 2.0,
            start: 0.0,
            end: 100.0,
        });
        assert!(s.validate().unwrap_err().to_string().contains("range"));
        let mut s = find("steady-state").unwrap();
        s.latency.push(LatencyEffect::Partition {
            boundary: 0,
            factor: 2.0,
            start: 0.0,
            end: 100.0,
        });
        assert!(s
            .validate()
            .unwrap_err()
            .to_string()
            .contains("boundary"));
    }

    #[test]
    fn validate_rejects_oversized_anchor_storm() {
        let mut s = find("steady-state").unwrap();
        s.churn.push(ChurnSpec::AnchorStorm {
            count: s.initial_alive as u32, // would leave nobody alive
            at: 0.0,
            interval: 100.0,
            waves: 1,
            down: 50.0,
        });
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("anchor storm"), "{err}");
        let mut s = find("steady-state").unwrap();
        s.churn.push(ChurnSpec::AnchorStorm {
            count: 2,
            at: 0.0,
            interval: 0.0,
            waves: 1,
            down: 50.0,
        });
        assert!(s.validate().is_err(), "zero interval must be rejected");
        // Overlapping waves stack: down >> interval means each wave
        // walks further down the ranking while earlier victims are
        // still out, so the *concurrent* down population is bounded.
        let mut s = find("steady-state").unwrap();
        let n = s.initial_alive as u32;
        s.churn.push(ChurnSpec::AnchorStorm {
            count: n / 2, // fine alone, fatal once two waves overlap
            at: 0.0,
            interval: 100.0,
            waves: 2,
            down: 1000.0,
        });
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("overlapping"), "{err}");
    }

    #[test]
    fn anchor_storm_catalog_entry_generates_central_crashes() {
        let spec = find("anchor-storm").unwrap();
        let mut rng = Rng::new(3);
        let model = Model::parse(&spec.model).unwrap();
        let w = model.sample(spec.nodes, &mut rng);
        let trace = spec.events(&w, &mut rng);
        // 4 waves x 6 anchors, crash + rejoin each, plus background
        // Poisson churn.
        let storm_crashes = trace
            .events
            .iter()
            .filter(|e| {
                matches!(e, MembershipEvent::Crash { time, .. }
                         if *time >= 1000.0)
            })
            .count();
        assert!(storm_crashes >= 24, "got {storm_crashes} storm crashes");
    }

    #[test]
    fn initial_alive_defaults_to_nodes() {
        let s = ScenarioSpec::parse(
            r#"{"name":"x","nodes":12,"model":"uniform","horizon":50}"#,
        )
        .unwrap();
        assert_eq!(s.initial_alive, 12);
    }

    #[test]
    fn events_are_sorted_and_respect_initial_population() {
        let spec = find("flash-crowd").unwrap();
        let mut rng = Rng::new(9);
        let w = crate::latency::LatencyMatrix::from_fn(
            spec.nodes,
            |u, v| 1.0 + (u + v) as f32,
        );
        let trace = spec.events(&w, &mut rng);
        assert!(!trace.is_empty());
        for w in trace.events.windows(2) {
            assert!(w[0].time() <= w[1].time());
        }
        // The absent block departs at t = 0 before anything else.
        let zero_leaves = trace
            .events
            .iter()
            .filter(|e| {
                e.time() == 0.0
                    && matches!(e, MembershipEvent::Leave { .. })
            })
            .count();
        assert_eq!(zero_leaves, spec.nodes - spec.initial_alive);
    }

    #[test]
    fn find_unknown_scenario_lists_catalog() {
        let err = find("nope").unwrap_err().to_string();
        assert!(err.contains("flash-crowd"), "{err}");
    }
}
