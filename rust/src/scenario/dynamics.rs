//! Dynamic latency models: a time-varying multiplicative overlay on a
//! base [`LatencyMatrix`]. Effects compose (factors multiply per link),
//! every materialized matrix keeps the §III invariants (symmetric, zero
//! diagonal, strictly positive off-diagonal), and everything is a pure
//! function of (base, effects, t) — no hidden state, so scenario runs
//! are bit-reproducible.

use anyhow::{bail, Result};

use crate::latency::LatencyMatrix;
use crate::util::json::Json;

/// One time-varying effect on the latency overlay.
#[derive(Clone, Debug, PartialEq)]
pub enum LatencyEffect {
    /// Diurnal drift: every link scales by
    /// `1 + amplitude * sin(2π (t − phase) / period)` — WAN RTTs
    /// breathing with the day/night load cycle. `amplitude` must sit in
    /// `[0, 1)` so latencies stay positive.
    Diurnal { period: f64, amplitude: f64, phase: f64 },
    /// Link degradation: every link incident to `node` scales by
    /// `factor` during `[start, end)` — a failing NIC or congested
    /// access uplink.
    Degrade { node: u32, factor: f64, start: f64, end: f64 },
    /// Transient WAN partition: links crossing the id boundary
    /// (`u < boundary <= v`) scale by `factor` during `[start, end)` —
    /// an inter-site trunk brownout.
    Partition { boundary: u32, factor: f64, start: f64, end: f64 },
}

impl LatencyEffect {
    /// Multiplier this effect applies to link `(u, v)` at time `t`.
    fn factor(&self, u: usize, v: usize, t: f64) -> f64 {
        match *self {
            LatencyEffect::Diurnal {
                period,
                amplitude,
                phase,
            } => {
                1.0 + amplitude
                    * (std::f64::consts::TAU * (t - phase) / period).sin()
            }
            LatencyEffect::Degrade {
                node,
                factor,
                start,
                end,
            } => {
                let hit = u == node as usize || v == node as usize;
                if hit && t >= start && t < end {
                    factor
                } else {
                    1.0
                }
            }
            LatencyEffect::Partition {
                boundary,
                factor,
                start,
                end,
            } => {
                let b = boundary as usize;
                if (u < b) != (v < b) && t >= start && t < end {
                    factor
                } else {
                    1.0
                }
            }
        }
    }

    /// Whether this effect's multiplier can differ anywhere in `(t0, t1]`
    /// from its `t0` value — drives the engine's "re-materialize this
    /// period?" decision.
    fn changes_within(&self, t0: f64, t1: f64) -> bool {
        match *self {
            LatencyEffect::Diurnal { .. } => t1 > t0,
            LatencyEffect::Degrade { start, end, .. }
            | LatencyEffect::Partition { start, end, .. } => {
                // An activation or deactivation edge inside the window.
                (t0 < start && start <= t1) || (t0 < end && end <= t1)
            }
        }
    }

    /// Check ranges (factors >= 1, windows ordered, amplitude < 1).
    pub fn validate(&self) -> Result<()> {
        match *self {
            LatencyEffect::Diurnal {
                period, amplitude, ..
            } => {
                if period <= 0.0 {
                    bail!("diurnal period must be > 0, got {period}");
                }
                if !(0.0..1.0).contains(&amplitude) {
                    bail!(
                        "diurnal amplitude must be in [0, 1), got {amplitude}"
                    );
                }
            }
            LatencyEffect::Degrade {
                factor, start, end, ..
            }
            | LatencyEffect::Partition {
                factor, start, end, ..
            } => {
                if factor <= 0.0 {
                    bail!("effect factor must be > 0, got {factor}");
                }
                if !(start < end) {
                    bail!("effect window [{start}, {end}) is empty");
                }
            }
        }
        Ok(())
    }

    /// JSON form (used by the scenario spec files).
    pub fn to_json(&self) -> Json {
        match *self {
            LatencyEffect::Diurnal {
                period,
                amplitude,
                phase,
            } => Json::obj(vec![
                ("kind", Json::str("diurnal")),
                ("period", Json::num(period)),
                ("amplitude", Json::num(amplitude)),
                ("phase", Json::num(phase)),
            ]),
            LatencyEffect::Degrade {
                node,
                factor,
                start,
                end,
            } => Json::obj(vec![
                ("kind", Json::str("degrade")),
                ("node", Json::num(node as f64)),
                ("factor", Json::num(factor)),
                ("start", Json::num(start)),
                ("end", Json::num(end)),
            ]),
            LatencyEffect::Partition {
                boundary,
                factor,
                start,
                end,
            } => Json::obj(vec![
                ("kind", Json::str("partition")),
                ("boundary", Json::num(boundary as f64)),
                ("factor", Json::num(factor)),
                ("start", Json::num(start)),
                ("end", Json::num(end)),
            ]),
        }
    }

    /// Parse one effect object (see docs/SCENARIOS.md).
    pub fn from_json(v: &Json) -> Result<LatencyEffect> {
        let effect = match v.get("kind")?.as_str()? {
            "diurnal" => LatencyEffect::Diurnal {
                period: v.get("period")?.as_f64()?,
                amplitude: v.get("amplitude")?.as_f64()?,
                phase: v.get("phase")?.as_f64()?,
            },
            "degrade" => LatencyEffect::Degrade {
                node: v.get("node")?.as_usize()? as u32,
                factor: v.get("factor")?.as_f64()?,
                start: v.get("start")?.as_f64()?,
                end: v.get("end")?.as_f64()?,
            },
            "partition" => LatencyEffect::Partition {
                boundary: v.get("boundary")?.as_usize()? as u32,
                factor: v.get("factor")?.as_f64()?,
                start: v.get("start")?.as_f64()?,
                end: v.get("end")?.as_f64()?,
            },
            other => bail!("unknown latency effect kind '{other}'"),
        };
        effect.validate()?;
        Ok(effect)
    }
}

/// A time-varying latency view: base matrix + composed effects.
#[derive(Clone, Debug)]
pub struct DynamicLatency {
    base: LatencyMatrix,
    effects: Vec<LatencyEffect>,
}

impl DynamicLatency {
    /// A view over `base` with the given effects (validated).
    pub fn new(
        base: LatencyMatrix,
        effects: Vec<LatencyEffect>,
    ) -> Result<DynamicLatency> {
        for e in &effects {
            e.validate()?;
        }
        Ok(DynamicLatency { base, effects })
    }

    /// The t = 0 base matrix the effects overlay.
    pub fn base(&self) -> &LatencyMatrix {
        &self.base
    }

    /// Whether no effect ever changes the matrix.
    pub fn is_static(&self) -> bool {
        self.effects.is_empty()
    }

    /// Materialize the effective matrix at sim-time `t`
    /// (O(n² · effects); called once per adaptation period).
    pub fn at(&self, t: f64) -> LatencyMatrix {
        if self.effects.is_empty() {
            return self.base.clone();
        }
        LatencyMatrix::from_fn(self.base.n(), |u, v| {
            let mut w = self.base.get(u, v) as f64;
            for e in &self.effects {
                w *= e.factor(u, v, t);
            }
            w as f32
        })
    }

    /// True when some effect changes the matrix within `(t0, t1]`.
    pub fn changes_within(&self, t0: f64, t1: f64) -> bool {
        self.effects.iter().any(|e| e.changes_within(t0, t1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::Model;
    use crate::util::rng::Rng;

    fn base(n: usize) -> LatencyMatrix {
        let mut rng = Rng::new(11);
        Model::Uniform.sample(n, &mut rng)
    }

    #[test]
    fn static_view_passes_the_base_through() {
        let w = base(12);
        let d = DynamicLatency::new(w.clone(), vec![]).unwrap();
        assert!(d.is_static());
        assert_eq!(d.at(0.0), w);
        assert_eq!(d.at(1e6), w);
        assert!(!d.changes_within(0.0, 1e9));
    }

    #[test]
    fn diurnal_drift_stays_valid_and_oscillates() {
        let w = base(16);
        let d = DynamicLatency::new(
            w.clone(),
            vec![LatencyEffect::Diurnal {
                period: 1000.0,
                amplitude: 0.6,
                phase: 0.0,
            }],
        )
        .unwrap();
        // Peak of the sine at t = period/4, trough at 3·period/4.
        let hi = d.at(250.0);
        let lo = d.at(750.0);
        hi.validate().unwrap();
        lo.validate().unwrap();
        let f_hi = hi.get(0, 1) / w.get(0, 1);
        let f_lo = lo.get(0, 1) / w.get(0, 1);
        assert!((f_hi - 1.6).abs() < 1e-3, "peak factor {f_hi}");
        assert!((f_lo - 0.4).abs() < 1e-3, "trough factor {f_lo}");
        assert!(d.changes_within(0.0, 1.0));
    }

    #[test]
    fn degrade_touches_only_the_node_and_only_in_window() {
        let w = base(10);
        let d = DynamicLatency::new(
            w.clone(),
            vec![LatencyEffect::Degrade {
                node: 3,
                factor: 5.0,
                start: 100.0,
                end: 200.0,
            }],
        )
        .unwrap();
        let during = d.at(150.0);
        during.validate().unwrap();
        assert!((during.get(3, 7) - 5.0 * w.get(3, 7)).abs() < 1e-4);
        assert!((during.get(1, 7) - w.get(1, 7)).abs() < 1e-6);
        let before = d.at(50.0);
        assert_eq!(before, w);
        let after = d.at(200.0); // end is exclusive
        assert_eq!(after, w);
        assert!(d.changes_within(50.0, 150.0)); // activation edge
        assert!(d.changes_within(150.0, 250.0)); // deactivation edge
        assert!(!d.changes_within(110.0, 190.0)); // flat inside
        assert!(!d.changes_within(300.0, 400.0)); // flat after
    }

    #[test]
    fn partition_scales_only_cross_boundary_links() {
        let w = base(8);
        let d = DynamicLatency::new(
            w.clone(),
            vec![LatencyEffect::Partition {
                boundary: 4,
                factor: 8.0,
                start: 0.0,
                end: 10.0,
            }],
        )
        .unwrap();
        let m = d.at(5.0);
        m.validate().unwrap();
        assert!((m.get(1, 6) - 8.0 * w.get(1, 6)).abs() < 1e-3);
        assert!((m.get(0, 3) - w.get(0, 3)).abs() < 1e-6);
        assert!((m.get(5, 7) - w.get(5, 7)).abs() < 1e-6);
    }

    #[test]
    fn effects_compose_multiplicatively() {
        let w = base(6);
        let d = DynamicLatency::new(
            w.clone(),
            vec![
                LatencyEffect::Degrade {
                    node: 0,
                    factor: 2.0,
                    start: 0.0,
                    end: 100.0,
                },
                LatencyEffect::Partition {
                    boundary: 3,
                    factor: 3.0,
                    start: 0.0,
                    end: 100.0,
                },
            ],
        )
        .unwrap();
        let m = d.at(10.0);
        // (0, 5) is incident to node 0 AND crosses the boundary: 6x.
        assert!((m.get(0, 5) - 6.0 * w.get(0, 5)).abs() < 1e-3);
    }

    #[test]
    fn validation_rejects_bad_parameters() {
        assert!(LatencyEffect::Diurnal {
            period: 0.0,
            amplitude: 0.5,
            phase: 0.0
        }
        .validate()
        .is_err());
        assert!(LatencyEffect::Diurnal {
            period: 10.0,
            amplitude: 1.0,
            phase: 0.0
        }
        .validate()
        .is_err());
        assert!(LatencyEffect::Degrade {
            node: 0,
            factor: 0.0,
            start: 0.0,
            end: 1.0
        }
        .validate()
        .is_err());
        assert!(LatencyEffect::Partition {
            boundary: 2,
            factor: 2.0,
            start: 5.0,
            end: 5.0
        }
        .validate()
        .is_err());
    }

    #[test]
    fn json_roundtrip() {
        let effects = vec![
            LatencyEffect::Diurnal {
                period: 2000.0,
                amplitude: 0.5,
                phase: 100.0,
            },
            LatencyEffect::Degrade {
                node: 7,
                factor: 4.0,
                start: 10.0,
                end: 20.0,
            },
            LatencyEffect::Partition {
                boundary: 32,
                factor: 6.0,
                start: 1.0,
                end: 2.0,
            },
        ];
        for e in effects {
            let text = e.to_json().to_string();
            let back = LatencyEffect::from_json(
                &crate::util::json::parse(&text).unwrap(),
            )
            .unwrap();
            assert_eq!(back, e);
        }
    }
}
