//! Q-network parameter loading (`artifacts/qnet_weights.json`).
//!
//! The JSON layout is written by python/compile/train.py::save_weights
//! (`format: dgro-qnet-v1`); PARAM_ORDER must match model.PARAM_ORDER.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json;

/// Canonical parameter order — identical to python model.PARAM_ORDER and
/// to the AOT HLO's leading parameter positions.
pub const PARAM_ORDER: [&str; 10] =
    ["t1", "t2", "t3", "t4", "t5", "t6", "t7", "t8", "t9", "t10"];

/// One theta: shape + row-major f32 data.
#[derive(Clone, Debug)]
pub struct Tensor {
    /// Dimensions, outermost first.
    pub shape: Vec<usize>,
    /// Row-major values.
    pub data: Vec<f32>,
}

impl Tensor {
    /// Total number of elements.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The full trained parameter set.
#[derive(Clone, Debug)]
pub struct QnetParams {
    /// Embedding width p (Eqn 2).
    pub embed_dim: usize,
    /// Hidden width of the Q-head MLP.
    pub hidden_dim: usize,
    /// structure2vec iterations T.
    pub n_iters: usize,
    /// Tensors in PARAM_ORDER.
    pub thetas: Vec<Tensor>,
}

impl QnetParams {
    /// Parameter tensor by name (panics on unknown names - the
    /// artifact format is fixed at export time).
    pub fn theta(&self, name: &str) -> &Tensor {
        let idx = PARAM_ORDER
            .iter()
            .position(|&n| n == name)
            .unwrap_or_else(|| panic!("unknown theta '{name}'"));
        &self.thetas[idx]
    }

    /// Load from the artifact JSON.
    pub fn load(path: impl AsRef<Path>) -> Result<QnetParams> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(|| {
            format!("reading qnet weights {:?}", path.as_ref())
        })?;
        Self::parse(&text)
    }

    /// Parse the exported weights text format.
    pub fn parse(text: &str) -> Result<QnetParams> {
        let root = json::parse(text)?;
        let format = root.get("format")?.as_str()?;
        if format != "dgro-qnet-v1" {
            bail!("unsupported weight format '{format}'");
        }
        let embed_dim = root.get("embed_dim")?.as_usize()?;
        let hidden_dim = root.get("hidden_dim")?.as_usize()?;
        let n_iters = root.get("n_iters")?.as_usize()?;
        let params = root.get("params")?;
        let mut thetas = Vec::with_capacity(PARAM_ORDER.len());
        for name in PARAM_ORDER {
            let entry = params
                .get(name)
                .with_context(|| format!("theta '{name}'"))?;
            let shape = entry.get("shape")?.as_usize_vec()?;
            let data = entry.get("data")?.as_f32_vec()?;
            let numel: usize = shape.iter().product();
            if data.len() != numel {
                bail!(
                    "theta '{name}': shape {shape:?} wants {numel} values, \
                     got {}",
                    data.len()
                );
            }
            thetas.push(Tensor { shape, data });
        }
        let qp = QnetParams {
            embed_dim,
            hidden_dim,
            n_iters,
            thetas,
        };
        qp.validate()?;
        Ok(qp)
    }

    /// Check the canonical shapes (mirror of model.param_shapes).
    pub fn validate(&self) -> Result<()> {
        let p = self.embed_dim;
        let h = self.hidden_dim;
        let want: [(&str, Vec<usize>); 10] = [
            ("t1", vec![p]),
            ("t2", vec![p, p]),
            ("t3", vec![p, p]),
            ("t4", vec![p]),
            ("t5", vec![p, p]),
            ("t6", vec![p, p]),
            ("t7", vec![p, p]),
            ("t8", vec![h, 3 * p + 1]),
            ("t9", vec![h, h]),
            ("t10", vec![h]),
        ];
        for (i, (name, shape)) in want.iter().enumerate() {
            if &self.thetas[i].shape != shape {
                bail!(
                    "theta '{name}' has shape {:?}, want {shape:?}",
                    self.thetas[i].shape
                );
            }
            if !self.thetas[i].data.iter().all(|x| x.is_finite()) {
                bail!("theta '{name}' contains non-finite values");
            }
        }
        if self.n_iters == 0 || self.n_iters > 16 {
            bail!("implausible n_iters {}", self.n_iters);
        }
        Ok(())
    }

    /// Deterministic synthetic parameters for tests (no artifact needed).
    pub fn synthetic(embed_dim: usize, hidden_dim: usize, seed: u64) -> QnetParams {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let p = embed_dim;
        let h = hidden_dim;
        let shapes: [Vec<usize>; 10] = [
            vec![p],
            vec![p, p],
            vec![p, p],
            vec![p],
            vec![p, p],
            vec![p, p],
            vec![p, p],
            vec![h, 3 * p + 1],
            vec![h, h],
            vec![h],
        ];
        let thetas = shapes
            .into_iter()
            .map(|shape| {
                let numel: usize = shape.iter().product();
                let fan_in = *shape.last().unwrap();
                let scale = (2.0 / fan_in as f64).sqrt();
                let data = (0..numel)
                    .map(|_| (rng.normal() * scale) as f32)
                    .collect();
                Tensor { shape, data }
            })
            .collect();
        QnetParams {
            embed_dim,
            hidden_dim,
            n_iters: 3,
            thetas,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_json() -> String {
        // p=1, h=1 -> t8 is (1, 4).
        let t = |vals: &str, shape: &str| {
            format!("{{\"shape\": {shape}, \"data\": {vals}}}")
        };
        format!(
            "{{\"format\": \"dgro-qnet-v1\", \"embed_dim\": 1, \
             \"hidden_dim\": 1, \"n_iters\": 2, \"params\": {{\
             \"t1\": {}, \"t2\": {}, \"t3\": {}, \"t4\": {}, \
             \"t5\": {}, \"t6\": {}, \"t7\": {}, \"t8\": {}, \
             \"t9\": {}, \"t10\": {}}}}}",
            t("[0.1]", "[1]"),
            t("[0.2]", "[1,1]"),
            t("[0.3]", "[1,1]"),
            t("[0.4]", "[1]"),
            t("[0.5]", "[1,1]"),
            t("[0.6]", "[1,1]"),
            t("[0.7]", "[1,1]"),
            t("[1,2,3,4]", "[1,4]"),
            t("[0.9]", "[1,1]"),
            t("[1.0]", "[1]"),
        )
    }

    #[test]
    fn parse_valid_weights() {
        let qp = QnetParams::parse(&tiny_json()).unwrap();
        assert_eq!(qp.embed_dim, 1);
        assert_eq!(qp.n_iters, 2);
        assert_eq!(qp.theta("t8").data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(qp.theta("t1").shape, vec![1]);
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = tiny_json().replace("dgro-qnet-v1", "v999");
        assert!(QnetParams::parse(&bad).is_err());
    }

    #[test]
    fn rejects_shape_mismatch() {
        let bad = tiny_json().replace(
            "{\"shape\": [1], \"data\": [0.1]}",
            "{\"shape\": [2], \"data\": [0.1]}",
        );
        assert!(QnetParams::parse(&bad).is_err());
    }

    #[test]
    fn synthetic_params_validate() {
        let qp = QnetParams::synthetic(16, 32, 7);
        qp.validate().unwrap();
        assert_eq!(qp.theta("t8").shape, vec![32, 49]);
    }

    #[test]
    fn loads_real_artifact_if_present() {
        let path = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/qnet_weights.json"
        );
        if std::path::Path::new(path).exists() {
            let qp = QnetParams::load(path).unwrap();
            assert_eq!(qp.embed_dim, 16);
            assert_eq!(qp.hidden_dim, 32);
        }
    }
}
