//! Construction-state encoding S_t = (W, A_t, deg, v_t) shared by the
//! native and PJRT scorers, with incremental edge updates so Algorithm
//! 1's loop never rebuilds the matrices.

use crate::latency::LatencyMatrix;

/// Mutable Q-net input state for one construction episode.
///
/// `a` is the row-major adjacency of the partial solution G_t (0/1 f32 —
/// the exact dtype the HLO expects), `deg` the degree feature, `cur` the
/// cursor node v_t, `visited` the mask the scorers' caller applies before
/// argmax. `wscale` is fixed at episode start from the *unpadded* matrix
/// (see python model.default_wscale).
#[derive(Clone, Debug)]
pub struct State {
    /// Number of nodes.
    pub n: usize,
    /// The latency matrix construction runs against.
    pub w: LatencyMatrix,
    /// Dense adjacency of the partial tour (row-major n x n).
    pub a: Vec<f32>,
    /// Per-node degree in the partial tour.
    pub deg: Vec<f32>,
    /// The tour head (last node added).
    pub cur: usize,
    /// Whether each node is already on the tour.
    pub visited: Vec<bool>,
    /// Latency normalization scale (keeps Q inputs O(1)).
    pub wscale: f32,
}

impl State {
    /// Fresh state: empty partial solution, cursor at `start`.
    pub fn new(w: &LatencyMatrix, start: usize) -> State {
        let n = w.n();
        assert!(start < n);
        let mut visited = vec![false; n];
        visited[start] = true;
        State {
            n,
            w: w.clone(),
            a: vec![0.0; n * n],
            deg: vec![0.0; n],
            cur: start,
            visited,
            wscale: w.wscale(),
        }
    }

    /// Continue an episode on an existing partial topology (K-ring
    /// construction accumulates A across rings, paper §IV-B).
    pub fn with_cursor(mut self, start: usize) -> State {
        assert!(start < self.n);
        self.visited.fill(false);
        self.visited[start] = true;
        self.cur = start;
        self
    }

    /// Record edge (cur -> next) and advance the cursor.
    pub fn step(&mut self, next: usize) {
        assert!(!self.visited[next], "node {next} already visited");
        self.add_edge(self.cur, next);
        self.visited[next] = true;
        self.cur = next;
    }

    /// Close the ring back to `start` (does not move the cursor).
    pub fn close(&mut self, start: usize) {
        self.add_edge(self.cur, start);
    }

    /// Add an undirected edge into A / deg (idempotent).
    pub fn add_edge(&mut self, u: usize, v: usize) {
        if u == v || self.a[u * self.n + v] != 0.0 {
            return;
        }
        self.a[u * self.n + v] = 1.0;
        self.a[v * self.n + u] = 1.0;
        self.deg[u] += 1.0;
        self.deg[v] += 1.0;
    }

    /// One-hot cursor vector (allocated; the PJRT scorer builds its own
    /// padded version instead).
    pub fn vcur(&self) -> Vec<f32> {
        let mut v = vec![0.0; self.n];
        v[self.cur] = 1.0;
        v
    }

    /// Indices still selectable.
    pub fn unvisited(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.n).filter(|&i| !self.visited[i])
    }

    /// Whether every node has been added.
    pub fn done(&self) -> bool {
        self.visited.iter().all(|&v| v)
    }

    /// Mask a raw Q vector: visited nodes to -inf, then argmax. Returns
    /// None when everything is visited.
    pub fn argmax_unvisited(&self, q: &[f32]) -> Option<usize> {
        debug_assert!(q.len() >= self.n);
        let mut best = None;
        let mut best_q = f32::NEG_INFINITY;
        for i in 0..self.n {
            if !self.visited[i] && q[i] > best_q {
                best_q = q[i];
                best = Some(i);
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::synthetic;
    use crate::util::rng::Rng;

    fn state() -> State {
        let mut rng = Rng::new(1);
        let w = synthetic::uniform(6, &mut rng);
        State::new(&w, 2)
    }

    #[test]
    fn fresh_state_invariants() {
        let st = state();
        assert_eq!(st.cur, 2);
        assert!(st.visited[2]);
        assert_eq!(st.visited.iter().filter(|&&v| v).count(), 1);
        assert!(st.a.iter().all(|&x| x == 0.0));
        assert!(st.wscale > 0.0);
    }

    #[test]
    fn step_updates_adjacency_and_cursor() {
        let mut st = state();
        st.step(4);
        assert_eq!(st.cur, 4);
        assert!(st.visited[4]);
        assert_eq!(st.a[2 * 6 + 4], 1.0);
        assert_eq!(st.a[4 * 6 + 2], 1.0);
        assert_eq!(st.deg[2], 1.0);
        assert_eq!(st.deg[4], 1.0);
    }

    #[test]
    fn close_adds_final_edge() {
        let mut st = state();
        for v in [0usize, 1, 3, 4, 5] {
            st.step(v);
        }
        assert!(st.done());
        st.close(2);
        assert_eq!(st.a[5 * 6 + 2], 1.0);
        assert_eq!(st.deg[2], 2.0);
    }

    #[test]
    fn argmax_respects_mask() {
        let mut st = state();
        st.step(0);
        let q = vec![100.0, 5.0, 100.0, 7.0, 1.0, 2.0];
        // 0 and 2 are visited -> best unvisited is 3.
        assert_eq!(st.argmax_unvisited(&q), Some(3));
    }

    #[test]
    fn argmax_none_when_done() {
        let mut st = state();
        for v in [0usize, 1, 3, 4, 5] {
            st.step(v);
        }
        assert_eq!(st.argmax_unvisited(&[0.0; 6]), None);
    }

    #[test]
    fn with_cursor_keeps_topology_resets_visits() {
        let mut st = state();
        st.step(0);
        st.step(1);
        let st2 = st.clone().with_cursor(5);
        assert_eq!(st2.cur, 5);
        assert_eq!(st2.visited.iter().filter(|&&v| v).count(), 1);
        // Edges survive into the next ring's episode.
        assert_eq!(st2.a[2 * 6 + 0], 1.0);
        assert_eq!(st2.deg[1], 1.0);
    }
}
