//! The DGRO Q-network on the Rust side.
//!
//! * [`params`] — loads `artifacts/qnet_weights.json` (the thetas trained
//!   by python/compile/train.py).
//! * [`state`]  — the S_t = (W, A_t, deg, v_t) encoding shared by every
//!   scorer.
//! * [`native`] — a pure-Rust mirror of the Q-net forward (Eqns 2–4),
//!   bit-comparable to the JAX oracle; used to cross-validate the PJRT
//!   path and as a dependency-free fallback scorer.
//!
//! The production scorer (PJRT executing the AOT HLO built from the
//! Pallas kernels) lives in [`crate::runtime`]; both implement
//! [`QScorer`].

pub mod native;
pub mod params;
pub mod state;

/// Anything that can score all candidate next-hops at a construction
/// state (Algorithm 1's `argmax_v Q(S_t, v)` needs the full vector so the
/// caller can mask visited nodes).
pub trait QScorer {
    /// Q-values for every node as the candidate `u` of edge (v_t -> u).
    fn score(&mut self, st: &state::State) -> anyhow::Result<Vec<f32>>;

    /// Human-readable backend name (for logs and bench labels).
    fn name(&self) -> &'static str;
}
