//! Pure-Rust mirror of the Q-network forward (paper Eqns 2–4).
//!
//! Semantics are locked to python/compile/kernels/ref.py — any change
//! there must be mirrored here. The integration test
//! rust/tests/runtime_roundtrip.rs asserts this implementation and the
//! PJRT-executed AOT HLO agree to float tolerance on the same trained
//! weights, which is what lets it serve as (a) a cross-validation oracle
//! for the artifact path and (b) a dependency-free fallback scorer.

use anyhow::Result;

use super::params::QnetParams;
use super::state::State;
use super::QScorer;

/// Native scorer with preallocated scratch (the Algorithm-1 inner loop
/// calls `score` N times per ring; no allocation after the first call).
pub struct NativeQnet {
    params: QnetParams,
    // Scratch buffers, sized on first use.
    wn: Vec<f32>,
    mu: Vec<f32>,
    mu_next: Vec<f32>,
    neigh: Vec<f32>,
    lat: Vec<f32>,
    n_cached: usize,
    // The Eqn-2 latency aggregate depends only on (W, wscale), which are
    // fixed across a construction episode — cache it keyed by a
    // fingerprint instead of recomputing the O(N^2 * p) reduction every
    // step (EXPERIMENTS.md §Perf, L3 iteration 1).
    lat_key: u64,
}

impl NativeQnet {
    /// A scorer over the given (trained or synthetic) parameters.
    pub fn new(params: QnetParams) -> NativeQnet {
        NativeQnet {
            params,
            wn: Vec::new(),
            mu: Vec::new(),
            mu_next: Vec::new(),
            neigh: Vec::new(),
            lat: Vec::new(),
            n_cached: usize::MAX,
            lat_key: 0,
        }
    }

    /// The parameters this scorer runs.
    pub fn params(&self) -> &QnetParams {
        &self.params
    }

    fn ensure_scratch(&mut self, n: usize) {
        let p = self.params.embed_dim;
        if self.n_cached == n {
            return;
        }
        self.wn = vec![0.0; n * n];
        self.mu = vec![0.0; n * p];
        self.mu_next = vec![0.0; n * p];
        self.neigh = vec![0.0; n * p];
        self.lat = vec![0.0; n * p];
        self.n_cached = n;
    }

    /// Full forward; returns Q for every candidate node.
    pub fn forward(&mut self, st: &State) -> Vec<f32> {
        let n = st.n;
        let p = self.params.embed_dim;
        let h = self.params.hidden_dim;
        let resized = self.n_cached != n;
        self.ensure_scratch(n);

        // (W, wscale) fingerprint for the per-episode caches.
        let key = {
            let mut h = 0xcbf29ce484222325u64 ^ (n as u64);
            h ^= st.wscale.to_bits() as u64;
            h = h.wrapping_mul(0x100000001b3);
            let data = st.w.data();
            let stride = (data.len() / 512).max(1);
            for i in (0..data.len()).step_by(stride) {
                h ^= data[i].to_bits() as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
            h
        };
        let w_changed = resized || key != self.lat_key;
        if w_changed {
            // Normalize W once per episode (wscale fixed per episode).
            let inv = 1.0 / st.wscale;
            for (o, &x) in self.wn.iter_mut().zip(st.w.data()) {
                *o = x * inv;
            }
        }

        let t1 = &self.params.thetas[0].data;
        let t2 = &self.params.thetas[1].data;
        let t3 = &self.params.thetas[2].data;
        let t4 = &self.params.thetas[3].data;
        let t5 = &self.params.thetas[4].data;
        let t6 = &self.params.thetas[5].data;
        let t7 = &self.params.thetas[6].data;
        let t8 = &self.params.thetas[7].data;
        let t9 = &self.params.thetas[8].data;
        let t10 = &self.params.thetas[9].data;

        // The latency aggregate lat[v,k] = sum_u relu(wn[v,u] * t4[k]) is
        // iteration- AND step-independent (depends only on W/wscale):
        // recompute only when the episode's matrix changes.
        if w_changed {
            for v in 0..n {
                let row = &self.wn[v * n..(v + 1) * n];
                let out = &mut self.lat[v * p..(v + 1) * p];
                out.fill(0.0);
                for &wvu in row {
                    if wvu == 0.0 {
                        continue; // diagonal / padding
                    }
                    for k in 0..p {
                        let x = wvu * t4[k];
                        if x > 0.0 {
                            out[k] += x;
                        }
                    }
                }
            }
            self.lat_key = key;
        }

        // T embedding iterations.
        self.mu.fill(0.0);
        for _ in 0..self.params.n_iters {
            // neigh = A @ mu  (A is 0/1: sum neighbor embeddings).
            self.neigh.fill(0.0);
            for v in 0..n {
                let arow = &st.a[v * n..(v + 1) * n];
                let nrow_start = v * p;
                for (u, &auv) in arow.iter().enumerate() {
                    if auv != 0.0 {
                        let murow = &self.mu[u * p..(u + 1) * p];
                        let nrow =
                            &mut self.neigh[nrow_start..nrow_start + p];
                        for k in 0..p {
                            nrow[k] += auv * murow[k];
                        }
                    }
                }
            }
            // mu' = relu(deg*t1 + neigh@t2^T + lat@t3^T)
            for v in 0..n {
                let nrow = &self.neigh[v * p..(v + 1) * p];
                let lrow = &self.lat[v * p..(v + 1) * p];
                let orow = &mut self.mu_next[v * p..(v + 1) * p];
                for k in 0..p {
                    let mut acc = st.deg[v] * t1[k];
                    let t2row = &t2[k * p..(k + 1) * p];
                    let t3row = &t3[k * p..(k + 1) * p];
                    for j in 0..p {
                        acc += t2row[j] * nrow[j] + t3row[j] * lrow[j];
                    }
                    orow[k] = acc.max(0.0);
                }
            }
            std::mem::swap(&mut self.mu, &mut self.mu_next);
        }

        // Head features.
        let mut musum = vec![0.0f32; p];
        for v in 0..n {
            let murow = &self.mu[v * p..(v + 1) * p];
            for k in 0..p {
                musum[k] += murow[k];
            }
        }
        let muv = &self.mu[st.cur * p..(st.cur + 1) * p];
        let matvec = |m: &[f32], x: &[f32]| -> Vec<f32> {
            (0..p)
                .map(|k| {
                    m[k * p..(k + 1) * p]
                        .iter()
                        .zip(x)
                        .map(|(a, b)| a * b)
                        .sum()
                })
                .collect()
        };
        let gsum = matvec(t5, &musum);
        let gcur = matvec(t6, muv);
        // Head feature wrow = w(v_t, u) / mean(W). The embedding buffer
        // holds w / (N * mean) = w / wscale, so scale by N.
        let wrow: Vec<f32> = self.wn
            [st.cur * n..(st.cur + 1) * n]
            .iter()
            .map(|&x| x * n as f32)
            .collect();

        // Per-candidate MLP (Eqns 3-4), with the candidate-independent
        // first-layer contribution hoisted out of the loop — the same
        // rank-1 factorization the Pallas qhead kernel uses:
        //   relu(x)@t8^T = relu(w)      * t8[:,0]
        //               + relu(gsum)    @ t8[:,1..p+1]^T      (hoisted)
        //               + relu(gcur)    @ t8[:,p+1..2p+1]^T   (hoisted)
        //               + relu(t7@mu_u) @ t8[:,2p+1..]^T
        // (EXPERIMENTS.md §Perf, L3 iteration 2.)
        let d = 3 * p + 1;
        let mut q = vec![0.0f32; n];
        let mut gcand = vec![0.0f32; p];
        let mut h1 = vec![0.0f32; h];
        let mut h2 = vec![0.0f32; h];
        // const_h[i] = sum_k t8[i,1+k]*relu(gsum[k]) + t8[i,1+p+k]*relu(gcur[k])
        let mut const_h = vec![0.0f32; h];
        for i in 0..h {
            let row = &t8[i * d..(i + 1) * d];
            let mut acc = 0.0f32;
            for k in 0..p {
                acc += row[1 + k] * gsum[k].max(0.0)
                    + row[1 + p + k] * gcur[k].max(0.0);
            }
            const_h[i] = acc;
        }
        for u in 0..n {
            let muu = &self.mu[u * p..(u + 1) * p];
            for k in 0..p {
                // relu(t7 @ mu_u)
                gcand[k] = t7[k * p..(k + 1) * p]
                    .iter()
                    .zip(muu)
                    .map(|(a, b)| a * b)
                    .sum::<f32>()
                    .max(0.0);
            }
            let wpos = wrow[u].max(0.0);
            for i in 0..h {
                let row = &t8[i * d..(i + 1) * d];
                let mut acc = const_h[i] + row[0] * wpos;
                let cand_row = &row[1 + 2 * p..d];
                for k in 0..p {
                    acc += cand_row[k] * gcand[k];
                }
                h1[i] = acc.max(0.0);
            }
            // h2 = relu(t9 @ h1)
            for i in 0..h {
                let row = &t9[i * h..(i + 1) * h];
                let mut acc = 0.0f32;
                for j in 0..h {
                    acc += row[j] * h1[j];
                }
                h2[i] = acc.max(0.0);
            }
            q[u] = h2.iter().zip(t10).map(|(a, b)| a * b).sum();
        }
        q
    }
}

impl QScorer for NativeQnet {
    fn score(&mut self, st: &State) -> Result<Vec<f32>> {
        Ok(self.forward(st))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::synthetic;
    use crate::qnet::params::QnetParams;
    use crate::util::rng::Rng;

    fn setup(n: usize) -> (NativeQnet, State) {
        let params = QnetParams::synthetic(16, 32, 7);
        let mut rng = Rng::new(n as u64);
        let w = synthetic::uniform(n, &mut rng);
        (NativeQnet::new(params), State::new(&w, 0))
    }

    #[test]
    fn forward_shape_and_finite() {
        let (mut net, st) = setup(20);
        let q = net.forward(&st);
        assert_eq!(q.len(), 20);
        assert!(q.iter().all(|x| x.is_finite()));
        // Non-degenerate: candidates must not all score identically
        // (wrow and mu_u differ per candidate).
        let spread = q.iter().cloned().fold(f32::MIN, f32::max)
            - q.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread > 0.0);
    }

    #[test]
    fn forward_deterministic() {
        let (mut net, st) = setup(16);
        let q1 = net.forward(&st);
        let q2 = net.forward(&st);
        assert_eq!(q1, q2);
    }

    #[test]
    fn state_changes_change_scores() {
        let (mut net, mut st) = setup(12);
        let q0 = net.forward(&st);
        st.step(5);
        let q1 = net.forward(&st);
        assert_ne!(q0, q1);
    }

    #[test]
    fn scale_invariance_of_default_wscale() {
        // Scaling W (and wscale with it) must not change Q at all.
        let params = QnetParams::synthetic(16, 32, 9);
        let mut rng = Rng::new(5);
        let w = synthetic::uniform(14, &mut rng);
        let mut st1 = State::new(&w, 0);
        let w10 =
            crate::latency::LatencyMatrix::from_fn(14, |u, v| w.get(u, v) * 10.0);
        let mut st2 = State::new(&w10, 0);
        st1.step(3);
        st2.step(3);
        let mut net = NativeQnet::new(params);
        let q1 = net.forward(&st1);
        let q2 = net.forward(&st2);
        for (a, b) in q1.iter().zip(&q2) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn scratch_reuse_across_sizes() {
        let params = QnetParams::synthetic(16, 32, 3);
        let mut net = NativeQnet::new(params);
        for n in [8usize, 16, 8, 24] {
            let mut rng = Rng::new(n as u64);
            let w = synthetic::uniform(n, &mut rng);
            let st = State::new(&w, 0);
            let q = net.forward(&st);
            assert_eq!(q.len(), n);
            assert!(q.iter().all(|x| x.is_finite()));
        }
    }
}
