//! Stand-in for `runtime::pjrt` when the `pjrt` cargo feature is off.
//!
//! Presents the same public surface as the real backend so every call
//! site compiles unchanged; construction returns an error, which the
//! existing fallback paths (ScorerKind::make, the benches, the e2e
//! example, the round-trip tests) treat as "backend unavailable".

use anyhow::{bail, Result};

use super::artifacts::ArtifactStore;
use crate::qnet::state::State;
use crate::qnet::QScorer;

/// Disabled PJRT Q-net scorer. Cannot be constructed.
pub struct PjrtQnet {
    _private: (),
}

impl PjrtQnet {
    /// Always fails: the binary was built without the `pjrt` feature.
    pub fn new(_store: ArtifactStore) -> Result<PjrtQnet> {
        bail!(
            "dgro was built without the `pjrt` feature; use \
             --scorer native|greedy, or add the `xla` dependency and \
             rebuild with `--features pjrt` (see Cargo.toml)"
        )
    }

    /// Convenience mirror of the real backend's constructor.
    pub fn from_default_artifacts() -> Result<PjrtQnet> {
        PjrtQnet::new(ArtifactStore::discover(ArtifactStore::default_dir())?)
    }

    /// Unreachable in practice (no constructor succeeds).
    pub fn forward(&mut self, _st: &State) -> Result<Vec<f32>> {
        bail!("pjrt backend not compiled in")
    }
}

impl QScorer for PjrtQnet {
    fn score(&mut self, st: &State) -> Result<Vec<f32>> {
        self.forward(st)
    }

    fn name(&self) -> &'static str {
        "pjrt-disabled"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_construction_explains_the_feature_gate() {
        let err = PjrtQnet::from_default_artifacts().unwrap_err().to_string();
        // Either artifact discovery or the gate itself must point the
        // user at a fix.
        assert!(
            err.contains("pjrt") || err.contains("artifacts"),
            "unhelpful error: {err}"
        );
    }
}
