//! Artifact discovery: `artifacts/meta.json` + size-bucketed HLO files.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::qnet::params::QnetParams;
use crate::util::json;

/// The artifact set produced by `make artifacts`.
#[derive(Clone, Debug)]
pub struct ArtifactStore {
    /// Directory the artifacts live in.
    pub dir: PathBuf,
    /// Ascending size buckets (node capacity per exported HLO).
    pub buckets: Vec<usize>,
    /// Embedding width baked into the HLO.
    pub embed_dim: usize,
    /// Q-head hidden width baked into the HLO.
    pub hidden_dim: usize,
    /// structure2vec iterations baked into the HLO.
    pub n_iters: usize,
}

impl ArtifactStore {
    /// Discover artifacts in `dir` (reads meta.json and verifies the HLO
    /// files exist).
    pub fn discover(dir: impl AsRef<Path>) -> Result<ArtifactStore> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} (run `make artifacts`)"))?;
        let meta = json::parse(&text)?;
        if meta.get("format")?.as_str()? != "dgro-artifacts-v1" {
            bail!("unsupported artifact format");
        }
        let mut buckets = meta.get("buckets")?.as_usize_vec()?;
        buckets.sort_unstable();
        if buckets.is_empty() {
            bail!("no HLO buckets in meta.json");
        }
        let store = ArtifactStore {
            embed_dim: meta.get("embed_dim")?.as_usize()?,
            hidden_dim: meta.get("hidden_dim")?.as_usize()?,
            n_iters: meta.get("n_iters")?.as_usize()?,
            dir,
            buckets,
        };
        for &b in &store.buckets {
            let p = store.hlo_path(b);
            if !p.exists() {
                bail!("missing HLO artifact {p:?}");
            }
        }
        Ok(store)
    }

    /// Default location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    /// Path of the AOT HLO for the given size bucket.
    pub fn hlo_path(&self, bucket: usize) -> PathBuf {
        self.dir.join(format!("qnet_{bucket}.hlo.txt"))
    }

    /// Path of the exported weights file.
    pub fn weights_path(&self) -> PathBuf {
        self.dir.join("qnet_weights.json")
    }

    /// Load the trained thetas and check they match the artifact dims.
    pub fn load_params(&self) -> Result<QnetParams> {
        let qp = QnetParams::load(self.weights_path())?;
        if qp.embed_dim != self.embed_dim
            || qp.hidden_dim != self.hidden_dim
            || qp.n_iters != self.n_iters
        {
            bail!(
                "weights dims (p={}, h={}, T={}) do not match artifacts \
                 (p={}, h={}, T={})",
                qp.embed_dim,
                qp.hidden_dim,
                qp.n_iters,
                self.embed_dim,
                self.hidden_dim,
                self.n_iters
            );
        }
        Ok(qp)
    }

    /// Smallest bucket that can hold `n` nodes.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "graph size {n} exceeds the largest HLO bucket {} — \
                     paper §V: the Q-net regime tops out around N=200; use \
                     the adaptive heuristic path for larger overlays",
                    self.buckets.last().unwrap()
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn real_store() -> Option<ArtifactStore> {
        ArtifactStore::discover(ArtifactStore::default_dir()).ok()
    }

    #[test]
    fn bucket_selection() {
        if let Some(store) = real_store() {
            assert_eq!(store.bucket_for(10).unwrap(), 16);
            assert_eq!(store.bucket_for(16).unwrap(), 16);
            assert_eq!(store.bucket_for(17).unwrap(), 32);
            assert_eq!(store.bucket_for(200).unwrap(), 256);
            assert!(store.bucket_for(100_000).is_err());
        }
    }

    #[test]
    fn discover_reports_missing_dir() {
        let err = ArtifactStore::discover("/nonexistent-dir-xyz").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn params_match_artifacts() {
        if let Some(store) = real_store() {
            let qp = store.load_params().unwrap();
            assert_eq!(qp.embed_dim, store.embed_dim);
        }
    }
}
