//! PJRT-backed Q-net scorer: compiles the AOT HLO once per size bucket
//! and executes it from Algorithm 1's inner loop.
//!
//! Perf notes (EXPERIMENTS.md §Perf):
//!   * executables are compiled lazily and cached per bucket;
//!   * the 10 theta tensors are uploaded once per bucket as
//!     device-resident `PjRtBuffer`s and reused via `execute_b` — only
//!     the 4 state tensors (W, A, deg, vcur) + the wscale scalar move
//!     per call, and W only when the graph changes;
//!   * graphs are zero-padded to the bucket size; the exported model
//!     takes the *unpadded* wscale so padding does not perturb Q-values
//!     (see python/tests/test_aot.py::test_padding_to_bucket_preserves_q_values).

use std::collections::HashMap;

use anyhow::{Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifacts::ArtifactStore;
use crate::qnet::params::QnetParams;
use crate::qnet::state::State;
use crate::qnet::QScorer;

struct BucketExe {
    exe: PjRtLoadedExecutable,
    /// Device-resident theta buffers (uploaded once).
    theta_bufs: Vec<PjRtBuffer>,
    /// Cached device-resident W for the current graph (keyed by a cheap
    /// fingerprint of the matrix) — ring construction calls score() N
    /// times on the same W.
    w_buf: Option<(u64, PjRtBuffer)>,
}

/// Q-net scorer executing the AOT artifact on the PJRT CPU client.
pub struct PjrtQnet {
    client: PjRtClient,
    store: ArtifactStore,
    params: QnetParams,
    exes: HashMap<usize, BucketExe>,
    // Reusable padded host staging buffers.
    stage_a: Vec<f32>,
    stage_deg: Vec<f32>,
    stage_vcur: Vec<f32>,
}

impl PjrtQnet {
    /// Build from an artifact directory (compiles nothing yet).
    pub fn new(store: ArtifactStore) -> Result<PjrtQnet> {
        let params = store.load_params()?;
        let client = PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(PjrtQnet {
            client,
            store,
            params,
            exes: HashMap::new(),
            stage_a: Vec::new(),
            stage_deg: Vec::new(),
            stage_vcur: Vec::new(),
        })
    }

    /// Convenience: discover artifacts in the default location.
    pub fn from_default_artifacts() -> Result<PjrtQnet> {
        PjrtQnet::new(ArtifactStore::discover(ArtifactStore::default_dir())?)
    }

    /// The loaded weights.
    pub fn params(&self) -> &QnetParams {
        &self.params
    }

    /// The artifact store this executor was built from.
    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    /// Compile (or fetch) the executable for a bucket, with the theta
    /// buffers already device-resident.
    fn bucket_exe(&mut self, bucket: usize) -> Result<&mut BucketExe> {
        if !self.exes.contains_key(&bucket) {
            let path = self.store.hlo_path(bucket);
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(to_anyhow)
                .with_context(|| format!("parsing HLO {path:?}"))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(to_anyhow)?;
            let theta_bufs = self
                .params
                .thetas
                .iter()
                .map(|t| {
                    self.client
                        .buffer_from_host_buffer(&t.data, &t.shape, None)
                        .map_err(to_anyhow)
                })
                .collect::<Result<Vec<_>>>()?;
            self.exes.insert(
                bucket,
                BucketExe {
                    exe,
                    theta_bufs,
                    w_buf: None,
                },
            );
        }
        Ok(self.exes.get_mut(&bucket).unwrap())
    }

    /// Execute the Q-net for `st`, returning Q for the first `st.n`
    /// candidates (pad lanes dropped).
    pub fn forward(&mut self, st: &State) -> Result<Vec<f32>> {
        let n = st.n;
        let bucket = self.store.bucket_for(n)?;

        // Stage padded state tensors on the host.
        self.stage_a.clear();
        self.stage_a.resize(bucket * bucket, 0.0);
        for r in 0..n {
            self.stage_a[r * bucket..r * bucket + n]
                .copy_from_slice(&st.a[r * n..(r + 1) * n]);
        }
        self.stage_deg.clear();
        self.stage_deg.resize(bucket, 0.0);
        self.stage_deg[..n].copy_from_slice(&st.deg);
        self.stage_vcur.clear();
        self.stage_vcur.resize(bucket, 0.0);
        self.stage_vcur[st.cur] = 1.0;

        let w_fp = fingerprint(st.w.data(), st.n);
        let client = self.client.clone();

        // Upload the per-call state tensors before taking the mutable
        // borrow on the bucket cache (borrow-checker friendly ordering).
        let a_buf = client
            .buffer_from_host_buffer(&self.stage_a, &[bucket, bucket], None)
            .map_err(to_anyhow)?;
        let deg_buf = client
            .buffer_from_host_buffer(&self.stage_deg, &[bucket], None)
            .map_err(to_anyhow)?;
        let vcur_buf = client
            .buffer_from_host_buffer(&self.stage_vcur, &[bucket], None)
            .map_err(to_anyhow)?;
        let scale_buf = client
            .buffer_from_host_buffer(&[st.wscale], &[], None)
            .map_err(to_anyhow)?;
        // Head-feature normalizer: mean(W) of the *unpadded* matrix
        // (= wscale / N; see python model.default_wmean).
        let wmean = st.wscale / st.n as f32;
        let mean_buf = client
            .buffer_from_host_buffer(&[wmean], &[], None)
            .map_err(to_anyhow)?;

        let be = self.bucket_exe(bucket)?;

        // Upload W only when the graph changed since the last call.
        let need_w = match &be.w_buf {
            Some((fp, _)) => *fp != w_fp,
            None => true,
        };
        if need_w {
            let padded = st.w.padded_data(bucket);
            let buf = client
                .buffer_from_host_buffer(&padded, &[bucket, bucket], None)
                .map_err(to_anyhow)?;
            be.w_buf = Some((w_fp, buf));
        }

        let mut args: Vec<&PjRtBuffer> = be.theta_bufs.iter().collect();
        let (_, w_buf) = be.w_buf.as_ref().unwrap();
        args.push(w_buf);
        args.push(&a_buf);
        args.push(&deg_buf);
        args.push(&vcur_buf);
        args.push(&scale_buf);
        args.push(&mean_buf);

        let outs = be.exe.execute_b(&args).map_err(to_anyhow)?;
        let lit = outs[0][0].to_literal_sync().map_err(to_anyhow)?;
        let q_lit = lit.to_tuple1().map_err(to_anyhow)?;
        let mut q = q_lit.to_vec::<f32>().map_err(to_anyhow)?;
        q.truncate(n);
        Ok(q)
    }
}

impl QScorer for PjrtQnet {
    fn score(&mut self, st: &State) -> Result<Vec<f32>> {
        self.forward(st)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Cheap structural fingerprint of a latency matrix (FNV over the bits).
fn fingerprint(data: &[f32], n: usize) -> u64 {
    let mut h = 0xcbf29ce484222325u64 ^ (n as u64);
    // Sample up to 1024 entries + the full first row for speed.
    let stride = (data.len() / 1024).max(1);
    for i in (0..data.len()).step_by(stride) {
        h ^= data[i].to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// xla::Error -> anyhow::Error adapter (xla's error type is not Send-safe
/// friendly with `?` into anyhow directly because it lacks the blanket
/// impl on this version).
fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    //! The heavier PJRT round-trip tests (vs NativeQnet on trained
    //! weights, bucket padding equivalence) live in
    //! rust/tests/runtime_roundtrip.rs since they need artifacts.

    use super::*;

    #[test]
    fn fingerprint_discriminates() {
        let a = vec![1.0f32; 64];
        let mut b = a.clone();
        b[5] = 2.0;
        assert_ne!(fingerprint(&a, 8), fingerprint(&b, 8));
        assert_eq!(fingerprint(&a, 8), fingerprint(&a.clone(), 8));
    }
}
