//! PJRT runtime: load and execute the AOT HLO artifacts from the L3 hot
//! path. Python never runs here — `artifacts/qnet_*.hlo.txt` were
//! lowered once by `make artifacts` (python/compile/aot.py) and this
//! module replays them on the `xla` crate's CPU PJRT client.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::ArtifactStore;
pub use pjrt::PjrtQnet;
