//! PJRT runtime: load and execute the AOT HLO artifacts from the L3 hot
//! path. Python never runs here — `artifacts/qnet_*.hlo.txt` were
//! lowered once by `make artifacts` (python/compile/aot.py) and this
//! module replays them on the `xla` crate's CPU PJRT client.
//!
//! The backend is gated behind the `pjrt` cargo feature because the
//! `xla` crate is not available on the offline registry this repo builds
//! against. Without the feature, [`pjrt_stub`] provides the identical
//! public surface: construction fails with an explanatory error and
//! every caller (coordinator, benches, examples, round-trip tests)
//! already falls back to the native scorer or skips.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
pub mod pjrt_stub;

pub use artifacts::ArtifactStore;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtQnet;
#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::PjrtQnet;
