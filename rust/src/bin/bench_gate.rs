//! `bench_gate` — the CI perf-regression gate.
//!
//! Reads the fresh `BENCH_hotpath.json` (written by
//! `cargo bench --bench hotpath -- --quick`) and the committed
//! `BENCH_baseline.json`, compares the gated throughput metrics, and
//! exits non-zero when any of them regressed more than the tolerance
//! (default 20%). `--update` rewrites the baseline from the current
//! report instead — run it deliberately after a justified perf change
//! and commit the result.
//!
//! ```console
//! $ cargo bench --bench hotpath -- --quick
//! $ cargo run --release --bin bench_gate
//! bench-gate (fail below 80% of baseline):
//!   scenario_incremental_periods_per_s   baseline ... current ... ok
//! ```

use anyhow::{bail, Context, Result};

use dgro::bench_harness::gate;
use dgro::util::json;

fn load(path: &str) -> Result<json::Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path}"))?;
    json::parse(&text).with_context(|| format!("parsing {path}"))
}

fn run() -> Result<bool> {
    let mut baseline = "BENCH_baseline.json".to_string();
    let mut current = "BENCH_hotpath.json".to_string();
    let mut tolerance = gate::DEFAULT_TOLERANCE;
    let mut update = false;
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let (flag, value) = match args[i].split_once('=') {
            Some((f, v)) => (f.to_string(), Some(v.to_string())),
            None => (args[i].clone(), None),
        };
        let take = |i: &mut usize| -> Result<String> {
            if let Some(v) = &value {
                return Ok(v.clone());
            }
            *i += 1;
            args.get(*i)
                .cloned()
                .with_context(|| format!("{flag} needs a value"))
        };
        match flag.as_str() {
            "--baseline" => baseline = take(&mut i)?,
            "--current" => current = take(&mut i)?,
            "--tolerance" => {
                tolerance = take(&mut i)?
                    .parse()
                    .context("--tolerance must be a number")?;
                if !(0.0..1.0).contains(&tolerance) {
                    bail!("--tolerance must be in [0, 1)");
                }
            }
            "--update" => update = true,
            other => bail!(
                "unknown flag '{other}' (--baseline P | --current P | \
                 --tolerance F | --update)"
            ),
        }
        i += 1;
    }

    let report = load(&current)?;
    if update {
        let doc = gate::baseline_from(&report)?;
        std::fs::write(&baseline, doc.to_string())
            .with_context(|| format!("writing {baseline}"))?;
        println!("wrote {baseline} from {current}");
        return Ok(true);
    }
    let floors = load(&baseline)?;
    let outcome = gate::compare(&floors, &report, tolerance)?;
    print!("{}", outcome.render());
    // Per-commit trend artifact: the same verdict rows in machine
    // shape, uploaded by CI next to BENCH_hotpath.json so the
    // baseline-tightening flow can chart ratio drift across commits.
    let rows = outcome
        .rows
        .iter()
        .map(|r| {
            json::Json::obj(vec![
                ("name", json::Json::str(r.name)),
                ("baseline", json::Json::num(r.baseline)),
                ("current", json::Json::num(r.current)),
                ("ratio", json::Json::num(r.ratio)),
                ("ok", json::Json::num(if r.ok { 1.0 } else { 0.0 })),
            ])
        })
        .collect();
    let trend = json::Json::obj(vec![
        ("bench", json::Json::str("hotpath-trend")),
        ("tolerance", json::Json::num(outcome.tolerance)),
        (
            "passed",
            json::Json::num(if outcome.passed() { 1.0 } else { 0.0 }),
        ),
        ("rows", json::Json::arr(rows)),
    ]);
    std::fs::write("BENCH_trend.json", trend.to_string())
        .context("writing BENCH_trend.json")?;
    println!("wrote BENCH_trend.json");
    Ok(outcome.passed())
}

fn main() {
    match run() {
        Ok(true) => {}
        Ok(false) => {
            eprintln!("bench-gate: perf regression past tolerance");
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("bench-gate: {e:#}");
            std::process::exit(2);
        }
    }
}
