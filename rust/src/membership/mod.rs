//! Membership substrate: the list every node maintains (paper §III-A:
//! "each node also keeps a local database, which is routinely updated
//! through message exchanges"), SWIM-style failure detection, and
//! join/leave/fail workload traces for the end-to-end driver.

pub mod events;
pub mod list;
pub mod swim;

pub use events::{EventTrace, MembershipEvent};
pub use list::{MemberState, MembershipList};
pub use swim::{SwimConfig, SwimSim};
