//! The membership table: per-member state with SWIM-style incarnation
//! numbers so refutations and stale gossip resolve deterministically.

use std::collections::BTreeMap;

use super::events::MembershipEvent;

/// Lifecycle state of a member.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemberState {
    /// Healthy and relaying.
    Alive,
    /// Suspected faulty (SWIM: awaiting refutation or confirmation).
    Suspect,
    /// Confirmed failed.
    Faulty,
    /// Departed gracefully.
    Left,
}

/// One member's record.
#[derive(Clone, Debug)]
pub struct Member {
    /// Current lifecycle state.
    pub state: MemberState,
    /// SWIM incarnation: higher wins; Alive at incarnation i refutes
    /// Suspect at incarnation i.
    pub incarnation: u64,
    /// Sim-time of the last update (for timeout bookkeeping).
    pub updated_at: f64,
}

/// A node-local membership list.
#[derive(Clone, Debug, Default)]
pub struct MembershipList {
    members: BTreeMap<u32, Member>,
}

impl MembershipList {
    /// An empty table (use [`MembershipList::full`] to bootstrap).
    pub fn new() -> MembershipList {
        MembershipList::default()
    }

    /// Bootstrap with `n` alive members at time 0.
    pub fn full(n: usize) -> MembershipList {
        let mut list = MembershipList::new();
        for id in 0..n as u32 {
            list.members.insert(
                id,
                Member {
                    state: MemberState::Alive,
                    incarnation: 0,
                    updated_at: 0.0,
                },
            );
        }
        list
    }

    /// Number of known members (any state).
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// Whether the table is empty.
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Member `id`'s record, if known.
    pub fn get(&self, id: u32) -> Option<&Member> {
        self.members.get(&id)
    }

    /// Ids of alive members, ascending.
    pub fn alive(&self) -> impl Iterator<Item = u32> + '_ {
        self.members
            .iter()
            .filter(|(_, m)| m.state == MemberState::Alive)
            .map(|(&id, _)| id)
    }

    /// Every known member as `(id, state, incarnation)`, ascending by
    /// id — the comparable snapshot the transport-convergence tests
    /// diff between node-local views.
    pub fn snapshot(&self) -> Vec<(u32, MemberState, u64)> {
        self.members
            .iter()
            .map(|(&id, m)| (id, m.state, m.incarnation))
            .collect()
    }

    /// Number of members currently in state `s`.
    pub fn count_state(&self, s: MemberState) -> usize {
        self.members.values().filter(|m| m.state == s).count()
    }

    /// Apply an update (the SWIM merge rule). Returns true if the record
    /// changed (i.e. the update is news worth re-gossiping).
    pub fn apply(
        &mut self,
        id: u32,
        state: MemberState,
        incarnation: u64,
        now: f64,
    ) -> bool {
        match self.members.get_mut(&id) {
            None => {
                self.members.insert(
                    id,
                    Member {
                        state,
                        incarnation,
                        updated_at: now,
                    },
                );
                true
            }
            Some(m) => {
                let supersedes = incarnation > m.incarnation
                    || (incarnation == m.incarnation
                        && rank(state) > rank(m.state));
                if supersedes
                    && (m.state != state || m.incarnation != incarnation)
                {
                    m.state = state;
                    m.incarnation = incarnation;
                    m.updated_at = now;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Apply a timed workload event ([`MembershipEvent`]) with the
    /// coordinator's incarnation convention: a Join bumps the node's
    /// incarnation (so a rejoin supersedes its earlier Leave/Crash at
    /// the same incarnation), Leave/Crash keep it. Returns whether the
    /// record changed — re-departing an already-gone node is a no-op,
    /// which is what makes independently generated churn streams safe
    /// to merge.
    pub fn apply_trace_event(&mut self, ev: &MembershipEvent) -> bool {
        match *ev {
            MembershipEvent::Join { time, node } => {
                let inc = self
                    .get(node)
                    .map(|m| m.incarnation + 1)
                    .unwrap_or(0);
                self.apply(node, MemberState::Alive, inc, time)
            }
            MembershipEvent::Leave { time, node } => {
                let inc =
                    self.get(node).map(|m| m.incarnation).unwrap_or(0);
                self.apply(node, MemberState::Left, inc, time)
            }
            MembershipEvent::Crash { time, node } => {
                let inc =
                    self.get(node).map(|m| m.incarnation).unwrap_or(0);
                self.apply(node, MemberState::Faulty, inc, time)
            }
        }
    }
}

/// Precedence at equal incarnation: Alive < Suspect < Faulty/Left
/// (SWIM's "suspicion overrides alive, confirmation overrides both").
fn rank(s: MemberState) -> u8 {
    match s {
        MemberState::Alive => 0,
        MemberState::Suspect => 1,
        MemberState::Faulty => 2,
        MemberState::Left => 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bootstrap_full() {
        let l = MembershipList::full(5);
        assert_eq!(l.len(), 5);
        assert_eq!(l.alive().count(), 5);
    }

    #[test]
    fn suspect_overrides_alive_same_incarnation() {
        let mut l = MembershipList::full(3);
        assert!(l.apply(1, MemberState::Suspect, 0, 1.0));
        assert_eq!(l.get(1).unwrap().state, MemberState::Suspect);
        // Re-applying the same fact is not news.
        assert!(!l.apply(1, MemberState::Suspect, 0, 2.0));
    }

    #[test]
    fn higher_incarnation_refutes_suspicion() {
        let mut l = MembershipList::full(3);
        l.apply(1, MemberState::Suspect, 0, 1.0);
        // Node 1 bumps incarnation to refute.
        assert!(l.apply(1, MemberState::Alive, 1, 2.0));
        assert_eq!(l.get(1).unwrap().state, MemberState::Alive);
    }

    #[test]
    fn stale_alive_does_not_resurrect_faulty() {
        let mut l = MembershipList::full(3);
        l.apply(2, MemberState::Faulty, 0, 1.0);
        assert!(!l.apply(2, MemberState::Alive, 0, 2.0));
        assert_eq!(l.get(2).unwrap().state, MemberState::Faulty);
    }

    #[test]
    fn join_inserts_new_member() {
        let mut l = MembershipList::full(2);
        assert!(l.apply(7, MemberState::Alive, 0, 3.0));
        assert_eq!(l.len(), 3);
        assert_eq!(l.count_state(MemberState::Alive), 3);
    }

    #[test]
    fn trace_events_roundtrip_leave_then_rejoin() {
        let mut l = MembershipList::full(3);
        assert!(l.apply_trace_event(&MembershipEvent::Leave {
            time: 1.0,
            node: 1,
        }));
        assert_eq!(l.get(1).unwrap().state, MemberState::Left);
        // Rejoin bumps the incarnation and supersedes the departure.
        assert!(l.apply_trace_event(&MembershipEvent::Join {
            time: 2.0,
            node: 1,
        }));
        assert_eq!(l.get(1).unwrap().state, MemberState::Alive);
        assert_eq!(l.get(1).unwrap().incarnation, 1);
        // Crashing an already-departed node is not news (safe merge of
        // overlapping churn generators).
        l.apply_trace_event(&MembershipEvent::Crash { time: 3.0, node: 2 });
        assert!(!l.apply_trace_event(&MembershipEvent::Leave {
            time: 4.0,
            node: 2,
        }));
    }
}
