//! SWIM-style failure detection over an overlay (Das et al., DSN'02 —
//! the protocol family the paper's membership layer assumes, §I/§II).
//!
//! Simulated on the discrete-event engine: each protocol period every
//! alive node probes a random overlay neighbor; a missing ack within the
//! round-trip bound marks the target Suspect, disseminated by gossip
//! along the overlay; suspicion times out into Faulty. The quantity the
//! paper cares about — how fast a membership change reaches everyone —
//! is dominated by the overlay diameter, which is what DGRO minimizes.

use crate::graph::Graph;
use crate::membership::list::{MemberState, MembershipList};
use crate::sim::broadcast::broadcast_times;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
/// Knobs of the SWIM failure-detection simulation.
pub struct SwimConfig {
    /// Protocol period (time between probe rounds).
    pub period: f64,
    /// Suspicion timeout in periods.
    pub suspicion_periods: usize,
}

impl Default for SwimConfig {
    fn default() -> Self {
        SwimConfig {
            period: 10.0,
            suspicion_periods: 3,
        }
    }
}

/// Outcome of simulating detection + dissemination of one crash.
#[derive(Clone, Debug)]
pub struct DetectionReport {
    /// Time from crash to first detection (probe miss -> Suspect).
    pub detect_time: f64,
    /// Time from crash until every alive node has the Faulty record
    /// (detection + suspicion timeout + dissemination broadcast).
    pub everyone_knows: f64,
    /// Dissemination (broadcast) completion component alone.
    pub dissemination: f64,
}

/// SWIM simulator bound to one overlay graph.
pub struct SwimSim<'a> {
    /// The overlay probes travel on.
    pub overlay: &'a Graph,
    /// Protocol knobs.
    pub cfg: SwimConfig,
    /// The simulated observer's membership table.
    pub list: MembershipList,
}

impl<'a> SwimSim<'a> {
    /// A simulation over `overlay` with everyone initially alive.
    pub fn new(overlay: &'a Graph, cfg: SwimConfig) -> SwimSim<'a> {
        SwimSim {
            overlay,
            cfg,
            list: MembershipList::full(overlay.n()),
        }
    }

    /// Simulate the detection of a crash of `victim` at t=0 and the
    /// dissemination of the resulting Faulty record.
    ///
    /// Expected first-probe delay: each neighbor of the victim probes a
    /// uniform neighbor each period, so detection is the minimum of
    /// geometric waiting times — simulated exactly with the RNG.
    pub fn crash_and_measure(
        &mut self,
        victim: usize,
        proc: &[f64],
        rng: &mut Rng,
    ) -> DetectionReport {
        let nbrs = self.overlay.neighbors(victim);
        assert!(
            !nbrs.is_empty(),
            "victim must be connected for detection"
        );
        // Round in which some neighbor of the victim first probes it.
        let mut detect_round = usize::MAX;
        let mut detector = nbrs[0].0 as usize;
        for &(u, _) in nbrs {
            let u = u as usize;
            let deg = self.overlay.degree(u);
            // Geometric trial: each round u probes victim w.p. 1/deg.
            let mut round = 1usize;
            loop {
                if rng.chance(1.0 / deg as f64) {
                    break;
                }
                round += 1;
                if round > 64 {
                    break; // cap the tail; cheap and deterministic
                }
            }
            if round < detect_round {
                detect_round = round;
                detector = u;
            }
        }
        let detect_time = detect_round as f64 * self.cfg.period;

        // Suspect immediately, Faulty after the suspicion timeout.
        self.list.apply(victim as u32, MemberState::Suspect, 0, detect_time);
        let confirm_time = detect_time
            + self.cfg.suspicion_periods as f64 * self.cfg.period;
        self.list.apply(victim as u32, MemberState::Faulty, 0, confirm_time);

        // Dissemination: broadcast the Faulty record from the detector
        // over the overlay (victim no longer relays).
        let mut pruned = Graph::empty(self.overlay.n());
        for (u, v, w) in self.overlay.edges() {
            if u as usize != victim && v as usize != victim {
                pruned.add_edge(u as usize, v as usize, w);
            }
        }
        let rep = broadcast_times(&pruned, detector, proc);
        DetectionReport {
            detect_time,
            everyone_knows: confirm_time + rep.completion,
            dissemination: rep.completion,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::synthetic;
    use crate::topology::kring::random_krings;

    fn overlay(n: usize, seed: u64) -> (Graph, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let w = synthetic::uniform(n, &mut rng);
        let kr = random_krings(n, 3, &mut rng);
        (kr.to_graph(&w), vec![1.0; n])
    }

    #[test]
    fn crash_detected_and_disseminated() {
        let (g, proc) = overlay(30, 1);
        let mut swim = SwimSim::new(&g, SwimConfig::default());
        let mut rng = Rng::new(2);
        let rep = swim.crash_and_measure(7, &proc, &mut rng);
        assert!(rep.detect_time >= SwimConfig::default().period);
        assert!(rep.dissemination > 0.0);
        assert!(rep.everyone_knows >= rep.detect_time + 30.0);
        assert_eq!(
            swim.list.get(7).unwrap().state,
            MemberState::Faulty
        );
    }

    #[test]
    fn lower_diameter_overlay_disseminates_faster() {
        // The paper's core motivation, as a membership-level property:
        // the same crash disseminates faster on a lower-diameter overlay.
        let mut rng = Rng::new(3);
        let w = crate::latency::fabric::sample(68, &mut rng);
        let random_g =
            crate::topology::random_ring(68, &mut rng).to_graph(&w);
        let nn_g = crate::topology::shortest_ring(&w, 0).to_graph(&w);
        let chord_like = random_krings(68, 4, &mut rng).to_graph(&w);
        let proc = vec![1.0; 68];

        let mut avg = |g: &Graph| -> f64 {
            let mut swim = SwimSim::new(g, SwimConfig::default());
            let mut total = 0.0;
            for v in [5usize, 20, 40] {
                total += swim
                    .crash_and_measure(v, &proc, &mut rng)
                    .dissemination;
            }
            total / 3.0
        };
        let d_kring = avg(&chord_like);
        let d_random_ring = avg(&random_g);
        let _d_nn = avg(&nn_g);
        // A 4-ring expander must beat a single random ring.
        assert!(
            d_kring < d_random_ring,
            "kring {d_kring} vs ring {d_random_ring}"
        );
    }

    #[test]
    fn membership_list_converges_to_faulty() {
        let (g, proc) = overlay(20, 4);
        let mut swim = SwimSim::new(&g, SwimConfig::default());
        let mut rng = Rng::new(5);
        let _ = swim.crash_and_measure(3, &proc, &mut rng);
        assert_eq!(swim.list.count_state(MemberState::Faulty), 1);
        assert_eq!(swim.list.count_state(MemberState::Alive), 19);
    }
}
