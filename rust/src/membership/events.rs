//! Membership workload traces: timed join / leave / crash events for the
//! end-to-end driver and the coordinator tests.

use crate::util::rng::Rng;

/// One scheduled membership change.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MembershipEvent {
    /// Node (re)joins the overlay.
    Join { time: f64, node: u32 },
    /// Node departs gracefully.
    Leave { time: f64, node: u32 },
    /// Node fails without notice (still in the table as Faulty).
    Crash { time: f64, node: u32 },
}

impl MembershipEvent {
    /// Sim-time the event fires.
    pub fn time(&self) -> f64 {
        match *self {
            MembershipEvent::Join { time, .. }
            | MembershipEvent::Leave { time, .. }
            | MembershipEvent::Crash { time, .. } => time,
        }
    }

    /// The node the event is about.
    pub fn node(&self) -> u32 {
        match *self {
            MembershipEvent::Join { node, .. }
            | MembershipEvent::Leave { node, .. }
            | MembershipEvent::Crash { node, .. } => node,
        }
    }
}

/// A time-sorted trace of events.
#[derive(Clone, Debug, Default)]
pub struct EventTrace {
    /// Events in nondecreasing time order.
    pub events: Vec<MembershipEvent>,
}

impl EventTrace {
    /// Generate a churn trace over `horizon` time units: `n_alive` nodes
    /// exist at t=0; crashes and leaves hit random alive nodes at
    /// exponential-ish spacing; crashed/left nodes may rejoin later.
    pub fn churn(
        n_alive: usize,
        horizon: f64,
        churn_rate: f64,
        rng: &mut Rng,
    ) -> EventTrace {
        let mut events = Vec::new();
        let mut alive: Vec<u32> = (0..n_alive as u32).collect();
        let mut gone: Vec<u32> = Vec::new();
        let mut t = 0.0;
        // Mean inter-event gap = 1 / (churn_rate * n).
        let lambda = churn_rate * n_alive as f64;
        while t < horizon {
            // Exponential(λ) via inverse CDF.
            t += -(1.0 - rng.f64()).ln() / lambda.max(1e-9);
            if t >= horizon {
                break;
            }
            let rejoin = !gone.is_empty() && rng.chance(0.4);
            if rejoin {
                let idx = rng.index(gone.len());
                let node = gone.swap_remove(idx);
                alive.push(node);
                events.push(MembershipEvent::Join { time: t, node });
            } else if alive.len() > 3 {
                let idx = rng.index(alive.len());
                let node = alive.swap_remove(idx);
                gone.push(node);
                if rng.chance(0.5) {
                    events.push(MembershipEvent::Crash { time: t, node });
                } else {
                    events.push(MembershipEvent::Leave { time: t, node });
                }
            }
        }
        EventTrace { events }
    }

    /// Number of events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether the trace has no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_trace_is_time_sorted_and_consistent() {
        let mut rng = Rng::new(1);
        let trace = EventTrace::churn(50, 100.0, 0.01, &mut rng);
        assert!(!trace.is_empty());
        for w in trace.events.windows(2) {
            assert!(w[0].time() <= w[1].time());
        }
        // A node can only rejoin after leaving.
        let mut gone = std::collections::HashSet::new();
        for ev in &trace.events {
            match ev {
                MembershipEvent::Join { node, .. } => {
                    assert!(gone.remove(node), "join of never-left {node}");
                }
                MembershipEvent::Leave { node, .. }
                | MembershipEvent::Crash { node, .. } => {
                    assert!(gone.insert(*node), "double departure {node}");
                }
            }
        }
    }

    #[test]
    fn zero_rate_gives_empty_trace() {
        let mut rng = Rng::new(2);
        let trace = EventTrace::churn(10, 10.0, 0.0, &mut rng);
        assert!(trace.is_empty());
    }
}
