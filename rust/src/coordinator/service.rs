//! Coordinator service: event loop over simulated time.
//!
//! The coordinator maintains a K-ring overlay over the alive membership.
//! Each adaptation period it (1) runs Algorithm 3 gossip measurement,
//! (2) applies the ρ decision, (3) swaps at most one ring per period
//! (bounded churn — real systems cannot re-wire everything at once), and
//! (4) records metrics. Membership events rebuild the node set lazily:
//! joins/leaves mark the overlay dirty and the next period re-anchors
//! the rings over the alive set.

use anyhow::{bail, Result};

use crate::config::Config;
use crate::dgro::select::{decide, materialize, RingChoice, SelectConfig};
use crate::gossip::measure::{measure, MeasureConfig};
use crate::graph::{diameter, Graph};
use crate::latency::{LatencyMatrix, Model};
use crate::membership::events::{EventTrace, MembershipEvent};
use crate::membership::list::{MemberState, MembershipList};
use crate::metrics::Metrics;
use crate::obs::Obs;
use crate::qnet::native::NativeQnet;
use crate::qnet::params::QnetParams;
use crate::qnet::QScorer;
use crate::runtime::{ArtifactStore, PjrtQnet};
use crate::topology::kring::KRing;
use crate::topology::random_ring;
use crate::util::rng::Rng;

use super::runner::{AdaptiveRunner, RunOptions};

/// Which scorer backend the coordinator constructs rings with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScorerKind {
    /// AOT HLO artifacts executed via PJRT (feature-gated; falls back
    /// to Native when artifacts are missing).
    Pjrt,
    /// In-tree forward pass of the trained Q-net.
    Native,
    /// Latency-greedy scoring (no learned model).
    Greedy,
}

impl ScorerKind {
    /// Parse a CLI scorer name.
    pub fn parse(s: &str) -> Result<ScorerKind> {
        match s {
            "pjrt" => Ok(ScorerKind::Pjrt),
            "native" => Ok(ScorerKind::Native),
            "greedy" => Ok(ScorerKind::Greedy),
            other => bail!("unknown scorer '{other}'"),
        }
    }

    /// Build a scorer instance. PJRT falls back to Native (with a log
    /// line) when artifacts are missing so the coordinator can run on a
    /// fresh checkout.
    pub fn make(self, cfg: &Config) -> Box<dyn QScorer> {
        match self {
            ScorerKind::Greedy => {
                Box::new(crate::dgro::construct::GreedyScorer)
            }
            ScorerKind::Native => {
                let params = ArtifactStore::discover(&cfg.artifacts_dir)
                    .and_then(|s| s.load_params())
                    .unwrap_or_else(|_| {
                        crate::log_warn!(
                            "no trained weights; using synthetic params"
                        );
                        QnetParams::synthetic(16, 32, cfg.seed)
                    });
                Box::new(NativeQnet::new(params))
            }
            ScorerKind::Pjrt => {
                match ArtifactStore::discover(&cfg.artifacts_dir)
                    .and_then(PjrtQnet::new)
                {
                    Ok(q) => Box::new(q),
                    Err(e) => {
                        crate::log_warn!(
                            "pjrt unavailable ({e}); falling back to native"
                        );
                        ScorerKind::Native.make(cfg)
                    }
                }
            }
        }
    }
}

/// The ring-swap policy shared by the centralized [`Coordinator`] and
/// the sharded one ([`super::sharded::ShardedCoordinator`]): when moving
/// toward Shortest, replace the longest ring (the most random-looking
/// one); when moving toward Random, replace the shortest ring. "Ring
/// randomness" is proxied by circumference — random rings are long,
/// nearest-neighbour rings short.
pub(crate) fn swap_slot(
    krings: &KRing,
    w: &LatencyMatrix,
    choice: RingChoice,
) -> usize {
    let lengths: Vec<f32> =
        krings.rings.iter().map(|r| r.length(w)).collect();
    let (mut best, mut best_len) = (0usize, lengths[0]);
    for (i, &len) in lengths.iter().enumerate() {
        let better = match choice {
            RingChoice::Shortest => len > best_len, // replace longest
            _ => len < best_len,                    // replace shortest
        };
        if better {
            best = i;
            best_len = len;
        }
    }
    best
}

/// The overlay restricted to alive members (faulty nodes do not relay)
/// — shared by the centralized coordinator and the transport-backed
/// [`NetCoordinator`](crate::net::NetCoordinator) so the alive filter
/// can never drift between them.
pub(crate) fn alive_overlay_graph(
    krings: &KRing,
    w: &LatencyMatrix,
    membership: &MembershipList,
) -> Graph {
    let alive: std::collections::HashSet<u32> =
        membership.alive().collect();
    let mut g = Graph::empty(w.n());
    for ring in &krings.rings {
        for (u, v) in ring.edges() {
            if alive.contains(&u) && alive.contains(&v) {
                g.add_edge(
                    u as usize,
                    v as usize,
                    w.get(u as usize, v as usize),
                );
            }
        }
    }
    g
}

/// Execute a non-Keep ρ decision: materialize the ring (consuming the
/// same RNG draws as ever — start index, then the ring itself), pick
/// the slot via [`swap_slot`] and replace it. Returns the slot and the
/// new visit order (for wire announcement) when a swap happened; the
/// caller records metrics. Shared by both coordinator event loops.
pub(crate) fn execute_swap(
    krings: &mut KRing,
    w: &LatencyMatrix,
    choice: RingChoice,
    rng: &mut Rng,
) -> Option<(usize, Vec<u32>)> {
    let start = rng.index(w.n());
    let ring = materialize(choice, w, start, rng)?;
    let slot = swap_slot(krings, w, choice);
    let order = ring.order().to_vec();
    krings.replace(slot, ring);
    Some((slot, order))
}

/// Record the per-period series both coordinator event loops emit —
/// one place to add a column, so scenario reports stay comparable
/// across the in-process and transport-backed paths.
pub(crate) fn record_period(
    metrics: &mut Metrics,
    d: f32,
    rho: f64,
    alive_cnt: usize,
    alive_d: f32,
    swap_delta: u64,
    applied: u64,
) {
    metrics.observe("overlay.diameter", d as f64);
    metrics.observe("overlay.rho", rho);
    metrics.observe("overlay.alive", alive_cnt as f64);
    metrics.observe("overlay.alive_diameter", alive_d as f64);
    metrics.observe("rings.swaps_per_period", swap_delta as f64);
    metrics.incr("membership.events_applied", applied);
}

/// Snapshot returned by [`Coordinator::run`].
#[derive(Clone, Debug)]
pub struct CoordinatorReport {
    /// (sim time, rho, diameter) per adaptation period.
    pub timeline: Vec<(f64, f64, f32)>,
    /// Final overlay diameter.
    pub final_diameter: f32,
    /// Initial overlay diameter (before any adaptation).
    pub initial_diameter: f32,
    /// Ring swaps performed.
    pub swaps: usize,
    /// Alive members at the end.
    pub alive: usize,
}

/// The coordinator itself.
pub struct Coordinator {
    /// Shared runtime configuration.
    pub cfg: Config,
    /// Physical latency matrix the overlay is scored against.
    pub w: LatencyMatrix,
    /// The global membership table.
    pub membership: MembershipList,
    /// The current K-ring overlay.
    pub krings: KRing,
    /// Counters and per-period series for this run. Event counters
    /// accumulate in [`Coordinator::obs`] and are folded back in here
    /// at the end of every [`Coordinator::adapt_once_guarded`].
    pub metrics: Metrics,
    /// This run's observability surface: lock-free counters +
    /// histograms and the span flight recorder (disabled by default).
    pub obs: Obs,
    rng: Rng,
    scorer_kind: ScorerKind,
}

impl Coordinator {
    /// Bootstrap: sample the latency model, start from the latency-
    /// oblivious overlay (K random rings — what consistent hashing gives
    /// every deployed system before DGRO kicks in).
    pub fn new(cfg: Config) -> Result<Coordinator> {
        cfg.validate()?;
        let mut rng = Rng::new(cfg.seed);
        let model = Model::parse(&cfg.model)
            .ok_or_else(|| anyhow::anyhow!("bad model {}", cfg.model))?;
        let w = model.sample(cfg.nodes, &mut rng);
        Coordinator::from_parts(cfg, w, rng)
    }

    /// Bootstrap over an externally supplied latency matrix. The
    /// scenario engine uses this to hand DGRO and every baseline the
    /// *same* draw (identical conditions), and to seed a time-varying
    /// latency view at its t = 0 state.
    pub fn with_latency(cfg: Config, w: LatencyMatrix) -> Result<Coordinator> {
        cfg.validate()?;
        if w.n() != cfg.nodes {
            bail!(
                "latency matrix has {} nodes but cfg.nodes = {}",
                w.n(),
                cfg.nodes
            );
        }
        let rng = Rng::new(cfg.seed);
        Coordinator::from_parts(cfg, w, rng)
    }

    fn from_parts(
        cfg: Config,
        w: LatencyMatrix,
        mut rng: Rng,
    ) -> Result<Coordinator> {
        let k = cfg.effective_k();
        let krings = KRing::new(
            (0..k).map(|_| random_ring(cfg.nodes, &mut rng)).collect(),
        );
        let scorer_kind = ScorerKind::parse(&cfg.scorer)?;
        Ok(Coordinator {
            membership: MembershipList::full(cfg.nodes),
            metrics: Metrics::new(),
            obs: Obs::new(),
            w,
            krings,
            rng,
            scorer_kind,
            cfg,
        })
    }

    /// Swap in an updated latency matrix (dynamic-latency scenarios:
    /// diurnal drift, link degradation, WAN partitions). The overlay
    /// structure is kept; subsequent measurements, ring swaps and
    /// diameter reports all see the new latencies.
    pub fn set_latency(&mut self, w: LatencyMatrix) -> Result<()> {
        if w.n() != self.w.n() {
            bail!(
                "latency update has {} nodes, overlay has {}",
                w.n(),
                self.w.n()
            );
        }
        self.w = w;
        self.metrics.incr("latency.updates", 1);
        Ok(())
    }

    /// Current overlay graph over the full node set.
    pub fn overlay(&self) -> Graph {
        self.krings.to_graph(&self.w)
    }

    /// Overlay restricted to alive members (faulty nodes do not relay).
    pub fn alive_overlay(&self) -> Graph {
        alive_overlay_graph(&self.krings, &self.w, &self.membership)
    }

    /// One adaptation period: measure, decide, (maybe) swap one ring.
    /// Returns (rho, decision).
    pub fn adapt_once(&mut self) -> Result<(f64, RingChoice)> {
        self.adapt_once_guarded(false)
    }

    /// [`Coordinator::adapt_once`] with the churn guard applied: when
    /// `guard` is true the period still measures (ρ keeps tracking the
    /// overlay) but the ring swap is suppressed — re-anchoring in the
    /// middle of a churn storm replaces rings that the next burst of
    /// events immediately invalidates. `run_dynamic` raises the guard
    /// whenever a period applied more than [`Config::churn_guard`]
    /// membership events.
    pub fn adapt_once_guarded(
        &mut self,
        guard: bool,
    ) -> Result<(f64, RingChoice)> {
        let g = self.overlay();
        let stats = measure(
            &self.w,
            &g,
            MeasureConfig {
                samples: self.cfg.gossip_samples,
                rounds: self.cfg.gossip_rounds,
            },
            &mut self.rng,
        );
        self.obs.reg.incr("gossip.messages", stats.messages as u64);
        let choice = decide(
            &stats,
            SelectConfig {
                epsilon: self.cfg.epsilon,
            },
        );
        match choice {
            RingChoice::Keep => {}
            _ if guard => {
                self.obs.reg.incr("rings.guard_skips", 1);
            }
            choice => {
                if execute_swap(
                    &mut self.krings,
                    &self.w,
                    choice,
                    &mut self.rng,
                )
                .is_some()
                {
                    self.obs.reg.incr("rings.swapped", 1);
                }
            }
        }
        // Fold the registry's event counters back into the owned
        // [`Metrics`] right away: `adapt_once` is a public entry point,
        // so callers must see counters current after every period.
        crate::obs::sync_counters(&self.obs.reg, &mut self.metrics);
        Ok((stats.rho(), choice))
    }

    /// Rebuild one ring with the configured scorer + partitioning (used
    /// by `dgro build --scorer pjrt` and the examples; the adaptive loop
    /// itself uses the cheap heuristic rings per §V).
    pub fn rebuild_ring_dgro(&mut self, slot: usize) -> Result<()> {
        let base = random_ring(self.w.n(), &mut self.rng);
        let cfg = crate::dgro::parallel::ParallelConfig {
            partitions: self.cfg.partitions,
            threads: self.cfg.threads.max(1),
        };
        let kind = self.scorer_kind;
        let app_cfg = self.cfg.clone();
        let ring = crate::dgro::parallel::parallel_ring(
            &self.w,
            &base,
            cfg,
            move |_| kind.make(&app_cfg),
        )?;
        self.krings.replace(slot, ring);
        Ok(())
    }

    /// Apply one membership event.
    pub fn apply_event(&mut self, ev: &MembershipEvent) {
        let counter = match ev {
            MembershipEvent::Join { .. } => "membership.joins",
            MembershipEvent::Leave { .. } => "membership.leaves",
            MembershipEvent::Crash { .. } => "membership.crashes",
        };
        self.membership.apply_trace_event(ev);
        self.metrics.incr(counter, 1);
    }

    /// Run the coordinator over a membership trace for `horizon`
    /// sim-time, adapting every `cfg.adapt_period_ms`. Equivalent to
    /// [`AdaptiveRunner::run_with`] under default [`RunOptions`].
    pub fn run(&mut self, trace: &EventTrace, horizon: f64) -> Result<CoordinatorReport> {
        self.run_with(trace, horizon, RunOptions::new())
    }

    /// Deprecated spelling of `run_with(..., RunOptions::new()
    /// .latency(latency_at))` — per-period latency updates are a
    /// [`RunOptions`] knob now.
    #[deprecated(
        since = "0.10.0",
        note = "use AdaptiveRunner::run_with with RunOptions::latency"
    )]
    pub fn run_dynamic(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
        latency_at: impl FnMut(f64) -> Option<LatencyMatrix>,
    ) -> Result<CoordinatorReport> {
        self.run_with(
            trace,
            horizon,
            RunOptions::new().latency(latency_at),
        )
    }

    /// Deprecated spelling of `run_with(..., RunOptions::new()
    /// .latency(latency_at).maybe_observer(observer))`.
    #[deprecated(
        since = "0.10.0",
        note = "use AdaptiveRunner::run_with with \
                RunOptions::latency + RunOptions::observer"
    )]
    pub fn run_dynamic_observed(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
        latency_at: impl FnMut(f64) -> Option<LatencyMatrix>,
        observer: Option<crate::traffic::OverlayObserver<'_>>,
    ) -> Result<CoordinatorReport> {
        self.run_with(
            trace,
            horizon,
            RunOptions::new()
                .latency(latency_at)
                .maybe_observer(observer),
        )
    }
}

impl AdaptiveRunner for Coordinator {
    fn kind(&self) -> &'static str {
        "centralized"
    }

    /// The centralized Algorithm-3 event loop. Per adaptation period:
    /// apply the latency view, drain due membership events, measure ρ,
    /// decide and (churn guard permitting) swap one ring, then record
    /// `overlay.diameter` / `overlay.rho` / `overlay.alive` /
    /// `overlay.alive_diameter` / `rings.swaps_per_period` so scenario
    /// runs stay comparable across topologies. Exchanges no frames, so
    /// [`RunOptions::trace_sample`] is a no-op here; a non-exact
    /// [`RunOptions::certify`] override is rejected.
    fn run_with(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
        mut opts: RunOptions<'_>,
    ) -> Result<CoordinatorReport> {
        super::runner::reject_non_exact_certify(
            self.kind(),
            opts.certify,
        )?;
        if let Some(g) = opts.churn_guard {
            self.cfg.churn_guard = g;
        }
        if opts.record {
            self.obs.rec.set_enabled(true);
        }
        let mut latency_at = opts.take_latency();
        let mut observer = opts.observer;
        let initial_diameter = diameter::diameter(&self.overlay());
        let mut timeline = Vec::new();
        let initial_swaps = self.metrics.counter("rings.swapped");
        let mut swaps0 = initial_swaps;
        let mut t = 0.0;
        let mut ev_idx = 0;
        let period_wall =
            self.obs.reg.histogram("coordinator.period_wall_ms");
        while t < horizon {
            t += self.cfg.adapt_period_ms;
            let period_wall0 = std::time::Instant::now();
            let p_span = self.obs.rec.start(
                "period",
                timeline.len() as u64 + 1,
                t,
            );
            if let Some(w) = latency_at(t) {
                self.set_latency(w)?;
            }
            let mut applied = 0u64;
            while ev_idx < trace.events.len()
                && trace.events[ev_idx].time() <= t
            {
                let ev = trace.events[ev_idx];
                self.apply_event(&ev);
                ev_idx += 1;
                applied += 1;
            }
            let guard =
                self.cfg.churn_guard > 0 && applied > self.cfg.churn_guard;
            let (rho, _) = self.adapt_once_guarded(guard)?;
            let d = diameter::diameter(&self.overlay());
            let alive_cnt = self.membership.count_state(MemberState::Alive);
            // With every member alive the sub-overlay IS the overlay —
            // skip the second diameter (the dominant per-period cost on
            // the churn-free `dgro serve` path).
            let alive_d = if alive_cnt == self.membership.len() {
                d
            } else {
                diameter::diameter(&self.alive_overlay())
            };
            let swaps_now = self.metrics.counter("rings.swapped");
            record_period(
                &mut self.metrics,
                d,
                rho,
                alive_cnt,
                alive_d,
                swaps_now - swaps0,
                applied,
            );
            swaps0 = swaps_now;
            timeline.push((t, rho, d));
            if let Some(f) = observer.as_mut() {
                let ga = self.alive_overlay();
                let mut alive: Vec<u32> =
                    self.membership.alive().collect();
                alive.sort_unstable();
                f(t, &ga, &self.w, &alive);
            }
            period_wall
                .observe(period_wall0.elapsed().as_secs_f64() * 1e3);
            p_span.finish(&self.obs.rec, t);
        }
        Ok(CoordinatorReport {
            final_diameter: timeline
                .last()
                .map(|&(_, _, d)| d)
                .unwrap_or(initial_diameter),
            initial_diameter,
            swaps: (swaps0 - initial_swaps) as usize,
            alive: self.membership.count_state(MemberState::Alive),
            timeline,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::shortest_ring;

    fn cfg(model: &str, nodes: usize) -> Config {
        let mut c = Config::default();
        c.model = model.to_string();
        c.nodes = nodes;
        c.scorer = "greedy".to_string();
        c.adapt_period_ms = 100.0;
        c
    }

    #[test]
    fn coordinator_adapts_random_overlay_toward_lower_diameter() {
        // On FABRIC-like clustered latencies, K random rings have high ρ
        // -> the coordinator should swap in shortest rings and cut the
        // diameter (the paper's Fig 5/6 effect at system level).
        let mut co = Coordinator::new(cfg("fabric", 68)).unwrap();
        let trace = EventTrace::default();
        let rep = co.run(&trace, 1000.0).unwrap();
        assert!(rep.swaps >= 1, "expected at least one swap");
        assert!(
            rep.final_diameter < rep.initial_diameter,
            "diameter {} -> {} should improve",
            rep.initial_diameter,
            rep.final_diameter
        );
    }

    #[test]
    fn coordinator_handles_churn() {
        let mut co = Coordinator::new(cfg("uniform", 40)).unwrap();
        let mut rng = Rng::new(9);
        let trace = EventTrace::churn(40, 1000.0, 0.002, &mut rng);
        let rep = co.run(&trace, 1000.0).unwrap();
        assert!(rep.alive <= 40);
        assert!(!rep.timeline.is_empty());
        // Metrics recorded each period.
        assert_eq!(
            co.metrics.series("overlay.diameter").unwrap().values.len(),
            rep.timeline.len()
        );
    }

    #[test]
    fn alive_overlay_excludes_faulty() {
        let mut co = Coordinator::new(cfg("uniform", 20)).unwrap();
        co.apply_event(&MembershipEvent::Crash {
            time: 1.0,
            node: 5,
        });
        let g = co.alive_overlay();
        assert_eq!(g.degree(5), 0);
        assert_eq!(co.membership.count_state(MemberState::Alive), 19);
    }

    #[test]
    fn rebuild_ring_dgro_produces_valid_ring() {
        let mut co = Coordinator::new(cfg("uniform", 24)).unwrap();
        co.rebuild_ring_dgro(0).unwrap();
        co.krings.rings[0].validate().unwrap();
    }

    #[test]
    fn with_latency_injects_matrix_and_checks_size() {
        let c = cfg("uniform", 20);
        let w = LatencyMatrix::from_fn(20, |u, v| (u + v) as f32);
        let co = Coordinator::with_latency(c.clone(), w.clone()).unwrap();
        assert_eq!(co.w, w);
        let bad = LatencyMatrix::from_fn(10, |u, v| (u + v) as f32);
        assert!(Coordinator::with_latency(c, bad).is_err());
    }

    #[test]
    fn run_dynamic_applies_latency_updates_and_records_series() {
        let mut co = Coordinator::new(cfg("uniform", 24)).unwrap();
        let base = co.w.clone();
        let rep = co
            .run_with(
                &EventTrace::default(),
                500.0,
                RunOptions::new().latency(|t| {
                    if t >= 300.0 {
                        Some(LatencyMatrix::from_fn(base.n(), |u, v| {
                            base.get(u, v) * 3.0
                        }))
                    } else {
                        None
                    }
                }),
            )
            .unwrap();
        // Periods fire at t = 100..=500; the view updates from t = 300.
        assert_eq!(co.metrics.counter("latency.updates"), 3);
        assert!((co.w.get(0, 1) - base.get(0, 1) * 3.0).abs() < 1e-5);
        let n_periods = rep.timeline.len();
        assert_eq!(n_periods, 5);
        for s in [
            "overlay.alive",
            "overlay.alive_diameter",
            "rings.swaps_per_period",
        ] {
            assert_eq!(
                co.metrics.series(s).unwrap().values.len(),
                n_periods,
                "series {s}"
            );
        }
        // set_latency rejects a size mismatch.
        let bad = LatencyMatrix::from_fn(5, |u, v| (u + v) as f32);
        assert!(co.set_latency(bad).is_err());
    }

    #[test]
    fn churn_guard_skips_swaps_during_storms() {
        // Heavy churn (~40 events per 100 ms period) with a nearly
        // degenerate Keep band, so every period reaches a swap decision:
        // the guard, not indecision, must be what stops re-anchoring.
        let mut free_cfg = cfg("fabric", 40);
        free_cfg.epsilon = 0.45;
        let mut guard_cfg = free_cfg.clone();
        guard_cfg.churn_guard = 2;
        let mut rng = Rng::new(11);
        let trace = EventTrace::churn(40, 1000.0, 0.01, &mut rng);

        let mut free = Coordinator::new(free_cfg).unwrap();
        let rep_free = free.run(&trace, 1000.0).unwrap();
        let mut guarded = Coordinator::new(guard_cfg).unwrap();
        let rep_guard = guarded.run(&trace, 1000.0).unwrap();

        assert!(rep_free.swaps >= 1, "unguarded run must swap");
        assert!(
            guarded.metrics.counter("rings.guard_skips") >= 1,
            "guard never fired under storm churn"
        );
        assert!(
            rep_guard.swaps <= rep_free.swaps,
            "guard must not increase swaps: {} vs {}",
            rep_guard.swaps,
            rep_free.swaps
        );
        assert_eq!(free.metrics.counter("rings.guard_skips"), 0);
    }

    #[test]
    fn deprecated_shims_match_run_with() {
        // The legacy ladder must stay byte-equivalent to the RunOptions
        // spelling until it is removed.
        let trace = EventTrace::default();
        let mut a = Coordinator::new(cfg("fabric", 30)).unwrap();
        let rep_a = a.run(&trace, 600.0).unwrap();
        #[allow(deprecated)]
        let rep_b = {
            let mut b = Coordinator::new(cfg("fabric", 30)).unwrap();
            b.run_dynamic_observed(&trace, 600.0, |_| None, None)
                .unwrap()
        };
        assert_eq!(rep_a.timeline, rep_b.timeline);
        assert_eq!(rep_a.swaps, rep_b.swaps);
        assert_eq!(a.kind(), "centralized");
    }

    #[test]
    fn non_exact_certify_override_is_rejected() {
        use crate::graph::eval::{CertifyConfig, CertifyMode};
        let mut co = Coordinator::new(cfg("uniform", 20)).unwrap();
        let mut sketch = CertifyConfig::exact();
        sketch.mode = CertifyMode::Sketch;
        let err = co
            .run_with(
                &EventTrace::default(),
                100.0,
                RunOptions::new().certify(sketch),
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("certifies diameters exactly"), "{err}");
    }

    #[test]
    fn swap_slot_targets_right_ring() {
        let mut co = Coordinator::new(cfg("fabric", 34)).unwrap();
        // Make ring 0 the shortest ring: it must be spared when moving
        // toward Shortest, and targeted when moving toward Random.
        let s = shortest_ring(&co.w, 0);
        co.krings.replace(0, s);
        let slot_for_shortest =
            swap_slot(&co.krings, &co.w, RingChoice::Shortest);
        assert_ne!(slot_for_shortest, 0, "should replace a long ring");
        let slot_for_random =
            swap_slot(&co.krings, &co.w, RingChoice::Random);
        assert_eq!(slot_for_random, 0, "should replace the NN ring");
    }
}
