//! The membership coordinator — the long-running L3 service that ties
//! DGRO together: it owns the overlay topology, reacts to membership
//! events (join / leave / crash), runs periodic gossip latency
//! measurements, and adapts the ring mix per the ρ rule (§V), rebuilding
//! rings in parallel (§VI) when the overlay drifts.

pub mod service;

pub use service::{Coordinator, CoordinatorReport, ScorerKind};
