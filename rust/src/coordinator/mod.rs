//! The membership coordinator layer — the long-running L3 service that
//! ties DGRO together: it owns the overlay topology, reacts to
//! membership events (join / leave / crash), runs periodic gossip
//! latency measurements, and adapts the ring mix per the ρ rule (§V),
//! rebuilding rings in parallel (§VI) when the overlay drifts.
//!
//! Four runners share one entry point — the [`AdaptiveRunner`] trait
//! driven by a [`RunOptions`] builder (see [`runner`]):
//!
//! * [`Coordinator`] — the centralized service: one membership table,
//!   one K-ring overlay over the whole universe.
//! * [`ShardedCoordinator`] — partition-local membership: the universe
//!   is split into K latency-aware shards, each running DGRO ring
//!   construction and ρ-selection on its own sub-overlay, stitched by
//!   inter-shard anchor links chosen to minimize the certified global
//!   diameter (see [`sharded`]).
//! * [`NetCoordinator`](crate::net::NetCoordinator) — the centralized
//!   loop driven by framed messages over a real transport.
//! * [`DecentralizedRunner`] — no coordinator at all: every node runs
//!   its own Algorithm-3 loop over gossip-piggybacked membership and a
//!   two-phase ring-swap agreement (see [`decentralized`] and
//!   docs/DECENTRALIZED.md).

pub mod decentralized;
pub mod runner;
pub mod service;
pub mod sharded;

pub use decentralized::DecentralizedRunner;
pub use runner::{AdaptiveRunner, RunOptions};
pub use service::{Coordinator, CoordinatorReport, ScorerKind};
pub use sharded::{Shard, ShardedConfig, ShardedCoordinator};
