//! The membership coordinator — the long-running L3 service that ties
//! DGRO together: it owns the overlay topology, reacts to membership
//! events (join / leave / crash), runs periodic gossip latency
//! measurements, and adapts the ring mix per the ρ rule (§V), rebuilding
//! rings in parallel (§VI) when the overlay drifts.
//!
//! Two implementations share the same event-loop interface
//! ([`CoordinatorReport`], [`MembershipEvent`](crate::membership::MembershipEvent)
//! routing, `run`/`run_dynamic`):
//!
//! * [`Coordinator`] — the centralized service: one membership table,
//!   one K-ring overlay over the whole universe.
//! * [`ShardedCoordinator`] — partition-local membership: the universe
//!   is split into K latency-aware shards, each running DGRO ring
//!   construction and ρ-selection on its own sub-overlay, stitched by
//!   inter-shard anchor links chosen to minimize the certified global
//!   diameter (see [`sharded`]).

pub mod service;
pub mod sharded;

pub use service::{Coordinator, CoordinatorReport, ScorerKind};
pub use sharded::{Shard, ShardedConfig, ShardedCoordinator};
