//! The unified runner surface: one trait, one options builder, four
//! adaptive runners.
//!
//! Before this module, each coordinator grew its own near-duplicate
//! `run` / `run_dynamic` / `run_dynamic_observed` entry-point ladder,
//! with the knobs (latency view, traffic observer, obs recording, trace
//! sampling, churn guard, certification) plumbed as divergent positional
//! parameters. [`RunOptions`] is the single builder for those knobs and
//! [`AdaptiveRunner`] the single dispatch point, implemented by:
//!
//! * [`Coordinator`](super::Coordinator) — in-process centralized loop,
//! * [`ShardedCoordinator`](super::ShardedCoordinator) — K partitions +
//!   anchor stitch,
//! * [`NetCoordinator`](crate::net::NetCoordinator) — centralized loop
//!   driven by framed messages over a [`Transport`](crate::net::Transport),
//! * [`DecentralizedRunner`](super::DecentralizedRunner) — no
//!   coordinator at all; every node runs Algorithm 3 itself
//!   (docs/DECENTRALIZED.md).
//!
//! A runner applies the options it supports and **rejects** (rather than
//! silently ignores) options that contradict its contract — e.g. a
//! non-exact [`CertifyConfig`] on the runners that always certify
//! exactly. Options that are meaningless but harmless for a runner
//! (trace sampling on the frameless in-process paths) are documented
//! no-ops, so the scenario engine can set them uniformly.

use anyhow::Result;

use crate::graph::eval::CertifyConfig;
use crate::latency::LatencyMatrix;
use crate::membership::events::EventTrace;
use crate::traffic::OverlayObserver;

use super::CoordinatorReport;

/// Per-run knobs shared by every [`AdaptiveRunner`]. Build with the
/// chaining setters; the zero-argument default reproduces the classic
/// `run(trace, horizon)` behavior on every runner.
#[derive(Default)]
pub struct RunOptions<'a> {
    /// Time-varying latency view: before each adaptation period the
    /// runner calls this with the period-end time and applies the
    /// returned matrix (`None` = unchanged).
    pub(crate) latency:
        Option<Box<dyn FnMut(f64) -> Option<LatencyMatrix> + 'a>>,
    /// Per-period overlay observer (the traffic-plane hook).
    pub(crate) observer: Option<OverlayObserver<'a>>,
    /// Enable the span flight recorder for the run.
    pub(crate) record: bool,
    /// Causal-trace sampling stride for frame-exchanging runners
    /// (0 = untraced; see [`crate::net::NetCoordinator::trace_sample`]).
    pub(crate) trace_sample: usize,
    /// Override the runner's churn guard threshold for this run.
    pub(crate) churn_guard: Option<u64>,
    /// Override the runner's diameter certification policy for this
    /// run (sharded coordinator only; the others certify exactly and
    /// reject a non-exact override).
    pub(crate) certify: Option<CertifyConfig>,
}

impl<'a> RunOptions<'a> {
    /// Options equivalent to the classic `run(trace, horizon)` call.
    pub fn new() -> RunOptions<'a> {
        RunOptions::default()
    }

    /// Drive the run with a time-varying latency view.
    pub fn latency(
        mut self,
        f: impl FnMut(f64) -> Option<LatencyMatrix> + 'a,
    ) -> Self {
        self.latency = Some(Box::new(f));
        self
    }

    /// Attach a per-period overlay observer (alive sub-overlay, current
    /// latency view, sorted alive list) — the traffic-plane hook.
    pub fn observer(mut self, obs: OverlayObserver<'a>) -> Self {
        self.observer = Some(obs);
        self
    }

    /// Like [`RunOptions::observer`] but taking the `Option` the call
    /// sites usually already hold.
    pub fn maybe_observer(
        mut self,
        obs: Option<OverlayObserver<'a>>,
    ) -> Self {
        self.observer = obs;
        self
    }

    /// Enable the span flight recorder for this run.
    pub fn record(mut self, on: bool) -> Self {
        self.record = on;
        self
    }

    /// Set the causal-trace sampling stride (0 = untraced). A no-op on
    /// runners that exchange no frames.
    pub fn trace_sample(mut self, stride: usize) -> Self {
        self.trace_sample = stride;
        self
    }

    /// Override [`Config::churn_guard`](crate::config::Config::churn_guard)
    /// for this run.
    pub fn churn_guard(mut self, guard: u64) -> Self {
        self.churn_guard = Some(guard);
        self
    }

    /// Override the diameter certification policy for this run. Only
    /// the sharded coordinator accepts a non-exact policy; the other
    /// runners reject it at `run_with` time.
    pub fn certify(mut self, certify: CertifyConfig) -> Self {
        self.certify = Some(certify);
        self
    }

    /// Unwrap the latency view into a callable (static `None` view when
    /// unset). For runner implementations.
    pub(crate) fn take_latency(
        &mut self,
    ) -> Box<dyn FnMut(f64) -> Option<LatencyMatrix> + 'a> {
        self.latency.take().unwrap_or_else(|| Box::new(|_| None))
    }
}

/// The one entry point every adaptive runner exposes: drive the
/// Algorithm-3 loop over a membership trace for `horizon` sim-ms under
/// the given [`RunOptions`]. Object-safe, so the scenario engine and
/// CLI can hold `&mut dyn AdaptiveRunner` and dispatch uniformly.
pub trait AdaptiveRunner {
    /// Stable runner name for reports and error messages.
    fn kind(&self) -> &'static str;

    /// Run the adaptation loop. Equivalent legacy ladder:
    /// `run` = default options, `run_dynamic` = `.latency(f)`,
    /// `run_dynamic_observed` = `.latency(f).maybe_observer(o)`.
    fn run_with(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
        opts: RunOptions<'_>,
    ) -> Result<CoordinatorReport>;
}

/// Reject a non-exact certification override on runners whose loop
/// certifies exactly by construction.
pub(crate) fn reject_non_exact_certify(
    kind: &str,
    certify: Option<CertifyConfig>,
) -> Result<()> {
    if let Some(c) = certify {
        c.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        if !c.is_exact() {
            anyhow::bail!(
                "the {kind} runner always certifies diameters exactly; \
                 a {} policy only applies to the sharded coordinator \
                 and the static baselines",
                c.mode.name()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::eval::CertifyMode;

    #[test]
    fn builder_chains_and_defaults_hold() {
        let mut w_seen = 0usize;
        let mut opts = RunOptions::new()
            .record(true)
            .trace_sample(4)
            .churn_guard(3)
            .certify(CertifyConfig::exact())
            .latency(|_| {
                w_seen += 1;
                None
            });
        assert!(opts.record);
        assert_eq!(opts.trace_sample, 4);
        assert_eq!(opts.churn_guard, Some(3));
        assert!(opts.certify.unwrap().is_exact());
        let mut f = opts.take_latency();
        assert!(f(1.0).is_none());
        drop(f);
        assert_eq!(w_seen, 1);
        // Unset latency resolves to the static view.
        let mut plain = RunOptions::new();
        let mut f = plain.take_latency();
        assert!(f(10.0).is_none());
    }

    #[test]
    fn non_exact_certify_is_rejected_where_unsupported() {
        assert!(reject_non_exact_certify("centralized", None).is_ok());
        assert!(reject_non_exact_certify(
            "centralized",
            Some(CertifyConfig::exact())
        )
        .is_ok());
        let mut sketch = CertifyConfig::exact();
        sketch.mode = CertifyMode::Sketch;
        let err = reject_non_exact_certify("net", Some(sketch))
            .unwrap_err()
            .to_string();
        assert!(err.contains("net runner"), "{err}");
    }
}
