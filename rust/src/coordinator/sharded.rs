//! Sharded coordinator: partition-local membership + ring re-anchoring.
//!
//! The centralized [`Coordinator`](super::Coordinator) owns the whole
//! overlay in one membership table — fine for hundreds of controllers,
//! the single biggest blocker on the millions-of-members target. The
//! paper's Algorithm 4 (§VI) already shows ring *construction* splits
//! across partitions with no diameter loss up to ~32 of them; this
//! module extends the split to the *ownership* of the overlay:
//!
//! * **Latency-aware partitioning** — the node universe is ordered by a
//!   nearest-neighbour ring and cut into K contiguous segments with
//!   [`crate::dgro::parallel::partition`] (Algorithm 4's splitter), so
//!   each shard owns a latency-close neighbourhood.
//! * **Partition-local membership** — every shard keeps its own
//!   [`MembershipList`] over its members; membership events are routed
//!   to the owning shard and never touch the others.
//! * **Per-shard DGRO** — each shard runs Algorithm 3 gossip
//!   measurement, the ρ decision (§V) and at-most-one ring swap per
//!   period over its own sub-latency-matrix, concurrently across
//!   [`crate::par::scoped_map`] workers. Per-shard RNG streams are
//!   forked from the seed, so results are bit-identical across thread
//!   counts.
//! * **Ring re-anchoring** — shards are stitched into one overlay by
//!   inter-shard anchor links: a cycle over the shards (consecutive
//!   shards are latency-close by construction) plus halving chords,
//!   each anchor chosen among the lowest-latency alive cross pairs and
//!   refined to minimize the *certified* global diameter
//!   ([`EvalPool::diameter_with_seeds`], warm-started from the previous
//!   round's landmarks). Membership churn, latency updates and ring
//!   swaps mark the stitching dirty; clean periods reuse it outright.
//!
//! The sharded coordinator speaks the same
//! [`MembershipEvent`]/[`CoordinatorReport`] interfaces as the
//! centralized one, so the scenario engine drives both unchanged
//! (`dgro scenario run|compare --shards K`) and
//! `rust/tests/sharded.rs` pins diameter parity at K ∈ {1, 4, 8}.

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::config::Config;
use crate::coordinator::runner::{AdaptiveRunner, RunOptions};
use crate::coordinator::service::swap_slot;
use crate::coordinator::CoordinatorReport;
use crate::dgro::parallel::partition;
use crate::dgro::select::{decide, materialize, RingChoice, SelectConfig};
use crate::gossip::measure::{measure, MeasureConfig};
use crate::graph::eval::{CertifyConfig, DiameterEst, EvalPool};
use crate::graph::{diameter, Graph};
use crate::latency::LatencyMatrix;
use crate::membership::events::{EventTrace, MembershipEvent};
use crate::membership::list::{MemberState, MembershipList};
use crate::metrics::Metrics;
use crate::obs::Obs;
use crate::topology::kring::KRing;
use crate::topology::{random_ring, shortest_ring};
use crate::util::rng::Rng;

/// Knobs of the sharded coordinator (everything else comes from the
/// shared [`Config`]).
#[derive(Clone, Copy, Debug)]
pub struct ShardedConfig {
    /// Number of partitions K (each must end up with ≥ 3 members).
    pub shards: usize,
    /// Worker threads for the per-period shard adaptation fan-out and
    /// the certified-diameter pool (1 = serial; never changes results).
    pub threads: usize,
    /// Candidate anchor pairs examined per shard boundary when
    /// re-anchoring (1 = pure lowest-latency stitching, no
    /// certified-diameter refinement).
    pub anchor_candidates: usize,
    /// Certification policy for the reported overlay/alive diameters
    /// and the re-anchoring refinement. Ring-swap decisions never
    /// consult a diameter, so every mode produces identical swap
    /// sequences — only the reported values (and their cost) differ.
    pub certify: CertifyConfig,
}

impl ShardedConfig {
    /// K shards, serial, with the default refinement budget and exact
    /// certification.
    pub fn new(shards: usize) -> ShardedConfig {
        ShardedConfig {
            shards,
            threads: 1,
            anchor_candidates: 3,
            certify: CertifyConfig::exact(),
        }
    }
}

/// One partition: a latency-close slice of the universe with its own
/// membership table, its own K-ring overlay and its own RNG stream.
pub struct Shard {
    /// Global node ids owned by this shard, in latency-aware ring order.
    pub members: Vec<u32>,
    /// Partition-local membership table (keys are global node ids).
    pub membership: MembershipList,
    /// The shard's ring mix, over *local* indices `0..members.len()`.
    pub krings: KRing,
    /// Shard-local latency view (sub-matrix of the global one).
    sub_w: LatencyMatrix,
    /// Per-shard RNG stream, forked off the coordinator seed.
    rng: Rng,
    /// ρ from the last adaptation period.
    rho: f64,
    /// Gossip messages spent in the last period.
    messages: usize,
    /// Whether the last period swapped a ring.
    swapped: bool,
}

impl Shard {
    fn new(
        members: Vec<u32>,
        w: &LatencyMatrix,
        k: usize,
        mut rng: Rng,
    ) -> Shard {
        let s = members.len();
        debug_assert!(s >= 3, "shard needs >= 3 members");
        let mut membership = MembershipList::new();
        for &m in &members {
            membership.apply(m, MemberState::Alive, 0, 0.0);
        }
        let sub_w = LatencyMatrix::from_fn(s, |a, b| {
            w.get(members[a] as usize, members[b] as usize)
        });
        let krings = KRing::new(
            (0..k).map(|_| random_ring(s, &mut rng)).collect(),
        );
        Shard {
            members,
            membership,
            krings,
            sub_w,
            rng,
            rho: 0.5,
            messages: 0,
            swapped: false,
        }
    }

    /// Rebuild the shard-local latency view from an updated global
    /// matrix.
    fn refresh_latency(&mut self, w: &LatencyMatrix) {
        let members = &self.members;
        self.sub_w = LatencyMatrix::from_fn(members.len(), |a, b| {
            w.get(members[a] as usize, members[b] as usize)
        });
    }

    /// Alive members (global ids, ascending — the membership table is
    /// BTreeMap-backed, so this is deterministic).
    fn alive(&self) -> Vec<u32> {
        self.membership.alive().collect()
    }

    /// One adaptation period on this shard alone: Algorithm 3 gossip
    /// measurement on the shard sub-overlay, the ρ decision, at most one
    /// ring swap (the same bounded-churn policy as the centralized
    /// coordinator, via [`swap_slot`]).
    fn adapt_once(&mut self, select: SelectConfig, mcfg: MeasureConfig) {
        let g = self.krings.to_graph(&self.sub_w);
        let stats = measure(&self.sub_w, &g, mcfg, &mut self.rng);
        self.rho = stats.rho();
        self.messages = stats.messages;
        self.swapped = false;
        let choice = decide(&stats, select);
        match choice {
            RingChoice::Keep => {}
            choice => {
                let start = self.rng.index(self.sub_w.n());
                if let Some(ring) =
                    materialize(choice, &self.sub_w, start, &mut self.rng)
                {
                    let slot = swap_slot(&self.krings, &self.sub_w, choice);
                    self.krings.replace(slot, ring);
                    self.swapped = true;
                }
            }
        }
    }
}

/// The sharded coordinator: K [`Shard`]s plus the anchor links that
/// stitch them into one overlay. Same event-loop interface as the
/// centralized [`Coordinator`](super::Coordinator).
pub struct ShardedCoordinator {
    /// Shared runtime configuration (seed, ε, gossip budget, cadence).
    pub cfg: Config,
    /// Sharding knobs.
    pub opts: ShardedConfig,
    /// Global latency matrix (shards hold sub-views of it).
    pub w: LatencyMatrix,
    /// The partitions.
    pub shards: Vec<Shard>,
    /// Metrics registry (same series names as the centralized
    /// coordinator, plus `shard.*`).
    pub metrics: Metrics,
    /// This run's observability surface: per-shard `shard.{i}.period_ms`
    /// wall-time histograms, re-anchor counters/spans and the flight
    /// recorder (disabled by default). Wall-time instruments live here
    /// and never feed [`ShardedCoordinator::metrics`], which stays
    /// thread-count-invariant.
    pub obs: Obs,
    /// node id -> owning shard index.
    owner: Vec<usize>,
    /// Current inter-shard anchor links (global ids).
    anchors: Vec<(u32, u32)>,
    /// Certified-diameter pool for stitching refinement and reporting.
    pool: EvalPool,
    /// Warm-start landmarks for the alive-overlay diameter.
    alive_landmarks: Vec<u32>,
    /// Warm-start landmarks for the full-overlay diameter.
    full_landmarks: Vec<u32>,
    /// Set when membership, latency or a ring swap invalidated the
    /// current stitching.
    dirty: bool,
    /// Per-shard staleness: shard `i` saw a membership change or ring
    /// swap since the last re-stitch, so only boundaries incident to a
    /// stale shard need re-picking.
    shard_dirty: Vec<bool>,
    /// Redo every boundary: set at construction and on latency updates
    /// (which re-weight every candidate pair at once).
    stitch_all: bool,
}

impl ShardedCoordinator {
    /// Bootstrap: sample the configured latency model, partition, and
    /// stitch the initial overlay.
    pub fn new(cfg: Config, opts: ShardedConfig) -> Result<ShardedCoordinator> {
        cfg.validate()?;
        let mut rng = Rng::new(cfg.seed);
        let model = crate::latency::Model::parse(&cfg.model)
            .ok_or_else(|| anyhow::anyhow!("bad model {}", cfg.model))?;
        let w = model.sample(cfg.nodes, &mut rng);
        ShardedCoordinator::with_latency(cfg, w, opts)
    }

    /// Bootstrap over an externally supplied latency matrix (the
    /// scenario engine's entry point, mirroring
    /// [`Coordinator::with_latency`](super::Coordinator::with_latency)).
    pub fn with_latency(
        cfg: Config,
        w: LatencyMatrix,
        opts: ShardedConfig,
    ) -> Result<ShardedCoordinator> {
        cfg.validate()?;
        if w.n() != cfg.nodes {
            bail!(
                "latency matrix has {} nodes but cfg.nodes = {}",
                w.n(),
                cfg.nodes
            );
        }
        if opts.shards == 0 {
            bail!("shards must be >= 1");
        }
        if let Err(e) = opts.certify.validate() {
            bail!("{e}");
        }
        if cfg.nodes / opts.shards < 3 {
            bail!(
                "{} nodes across {} shards leaves a shard below 3 \
                 members (rings need >= 3)",
                cfg.nodes,
                opts.shards
            );
        }
        let mut rng = Rng::new(cfg.seed);
        // Latency-aware partitioning: order the universe by a
        // nearest-neighbour ring, then cut it into K contiguous
        // segments with Algorithm 4's splitter — each shard owns a
        // latency-close neighbourhood, and consecutive shards are
        // adjacent along the NN tour (which is what makes the cyclic
        // stitching below cheap).
        let base = shortest_ring(&w, rng.index(cfg.nodes));
        let parts = partition(base.order(), opts.shards);
        let k = cfg.effective_k();
        let shards: Vec<Shard> = parts
            .into_iter()
            .enumerate()
            .map(|(i, members)| {
                let srng = rng.fork(0x5AAD + i as u64);
                Shard::new(members, &w, k, srng)
            })
            .collect();
        let mut owner = vec![0usize; cfg.nodes];
        for (i, shard) in shards.iter().enumerate() {
            for &m in &shard.members {
                owner[m as usize] = i;
            }
        }
        let obs = Obs::new();
        let mut pool = EvalPool::new(opts.threads.max(1));
        pool.attach_obs(&obs);
        let shard_dirty = vec![false; opts.shards];
        let mut co = ShardedCoordinator {
            cfg,
            opts,
            w,
            shards,
            metrics: Metrics::new(),
            obs,
            owner,
            anchors: Vec::new(),
            pool,
            alive_landmarks: Vec::new(),
            full_landmarks: Vec::new(),
            dirty: false,
            shard_dirty,
            stitch_all: true,
        };
        co.re_anchor();
        Ok(co)
    }

    /// Number of partitions.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard owning `node` (None if the id is outside the universe).
    pub fn shard_of(&self, node: u32) -> Option<usize> {
        self.owner.get(node as usize).copied()
    }

    /// Current inter-shard anchor links (global node ids).
    pub fn anchors(&self) -> &[(u32, u32)] {
        &self.anchors
    }

    /// Total members across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.membership.len()).sum()
    }

    /// True when the universe is empty (it never is after construction).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Alive members across all shards.
    pub fn alive_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.membership.count_state(MemberState::Alive))
            .sum()
    }

    /// Swap in an updated latency matrix; every shard refreshes its
    /// sub-view and the stitching is marked dirty.
    pub fn set_latency(&mut self, w: LatencyMatrix) -> Result<()> {
        if w.n() != self.w.n() {
            bail!(
                "latency update has {} nodes, overlay has {}",
                w.n(),
                self.w.n()
            );
        }
        for shard in &mut self.shards {
            shard.refresh_latency(&w);
        }
        self.w = w;
        self.dirty = true;
        self.stitch_all = true;
        self.metrics.incr("latency.updates", 1);
        Ok(())
    }

    /// Route one membership event to its owning shard's table.
    pub fn apply_event(&mut self, ev: &MembershipEvent) {
        let (node, counter) = match ev {
            MembershipEvent::Join { node, .. } => (*node, "membership.joins"),
            MembershipEvent::Leave { node, .. } => {
                (*node, "membership.leaves")
            }
            MembershipEvent::Crash { node, .. } => {
                (*node, "membership.crashes")
            }
        };
        let Some(&shard) = self.owner.get(node as usize) else {
            return; // outside the universe: drop, like a stale packet
        };
        if self.shards[shard].membership.apply_trace_event(ev) {
            self.dirty = true;
            self.shard_dirty[shard] = true;
        }
        self.metrics.incr(counter, 1);
    }

    /// The full stitched overlay: every shard's rings (all members,
    /// crashed included — same view as the centralized coordinator's
    /// `overlay()`) plus the anchor links.
    pub fn overlay(&self) -> Graph {
        let n = self.w.n();
        let mut g = Graph::empty(n);
        for shard in &self.shards {
            for ring in &shard.krings.rings {
                for (lu, lv) in ring.edges() {
                    let u = shard.members[lu as usize] as usize;
                    let v = shard.members[lv as usize] as usize;
                    g.add_edge(u, v, self.w.get(u, v));
                }
            }
        }
        for &(u, v) in &self.anchors {
            g.add_edge(u as usize, v as usize, self.w.get(u as usize, v as usize));
        }
        g
    }

    /// The stitched overlay restricted to alive members (faulty nodes do
    /// not relay).
    pub fn alive_overlay(&self) -> Graph {
        let alive = self.alive_set();
        self.alive_overlay_with(&self.anchors, &alive)
    }

    fn alive_set(&self) -> HashSet<u32> {
        let mut set = HashSet::new();
        for shard in &self.shards {
            set.extend(shard.membership.alive());
        }
        set
    }

    /// The shard-ring edges restricted to alive members, with no anchor
    /// links — the invariant part of every trial overlay the
    /// re-anchoring refinement evaluates (built once per re-stitch,
    /// cloned per candidate).
    fn alive_ring_graph(&self, alive: &HashSet<u32>) -> Graph {
        let n = self.w.n();
        let mut g = Graph::empty(n);
        for shard in &self.shards {
            for ring in &shard.krings.rings {
                for (lu, lv) in ring.edges() {
                    let u = shard.members[lu as usize];
                    let v = shard.members[lv as usize];
                    if alive.contains(&u) && alive.contains(&v) {
                        g.add_edge(
                            u as usize,
                            v as usize,
                            self.w.get(u as usize, v as usize),
                        );
                    }
                }
            }
        }
        g
    }

    /// Add the anchor links whose endpoints are alive to `g`.
    fn add_alive_anchors(
        &self,
        g: &mut Graph,
        anchors: &[(u32, u32)],
        alive: &HashSet<u32>,
    ) {
        for &(u, v) in anchors {
            if alive.contains(&u) && alive.contains(&v) {
                g.add_edge(
                    u as usize,
                    v as usize,
                    self.w.get(u as usize, v as usize),
                );
            }
        }
    }

    /// Alive sub-overlay under a *trial* anchor set.
    fn alive_overlay_with(
        &self,
        anchors: &[(u32, u32)],
        alive: &HashSet<u32>,
    ) -> Graph {
        let mut g = self.alive_ring_graph(alive);
        self.add_alive_anchors(&mut g, anchors, alive);
        g
    }

    /// The `count` lowest-latency cross pairs between two member sets
    /// (deterministic: ties break on node ids).
    fn top_pairs(
        &self,
        from: &[u32],
        to: &[u32],
        count: usize,
    ) -> Vec<(u32, u32)> {
        let mut pairs: Vec<(f32, u32, u32)> =
            Vec::with_capacity(from.len() * to.len());
        for &u in from {
            for &v in to {
                pairs.push((self.w.get(u as usize, v as usize), u, v));
            }
        }
        pairs.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .expect("finite latency")
                .then(a.1.cmp(&b.1))
                .then(a.2.cmp(&b.2))
        });
        pairs.truncate(count.max(1));
        pairs.into_iter().map(|(_, u, v)| (u, v)).collect()
    }

    /// Recompute the inter-shard anchor links over the current alive
    /// set. Called automatically whenever a period found the stitching
    /// dirty (membership event, latency update or ring swap); public so
    /// tests and tools can force a re-stitch.
    ///
    /// Structure: a cycle over all K shards in partition order
    /// (latency-adjacent by construction) plus halving chords when
    /// K ≥ 5, which bounds the shard-graph diameter at ~K/4 hops. Every
    /// anchor starts as the lowest-latency cross pair — alive×alive when
    /// both sides have alive members, any×any otherwise, so the *full*
    /// overlay never strands a partition. When
    /// [`ShardedConfig::anchor_candidates`] > 1, one coordinate-descent
    /// pass then re-picks each anchor among its candidates to minimize
    /// the certified alive-overlay diameter, warm-started from the
    /// previous evaluation's landmarks
    /// ([`EvalPool::diameter_with_seeds`] when certifying exactly,
    /// the budgeted [`EvalPool::diameter_est`] upper envelope
    /// otherwise).
    ///
    /// Staleness is per shard: only boundaries incident to a shard that
    /// saw a membership change or ring swap since the last stitch are
    /// re-picked (a kept boundary's endpoints are provably still alive —
    /// both its shards are unchanged). Latency updates and the first
    /// stitch refresh every boundary.
    pub fn re_anchor(&mut self) {
        let ord = self.obs.reg.get("shard.reanchors");
        let span = self.obs.rec.start("reanchor", ord, 0.0);
        let ks = self.shards.len();
        self.dirty = false;
        if ks <= 1 {
            self.anchors = Vec::new();
            self.stitch_all = false;
            span.finish(&self.obs.rec, 0.0);
            return;
        }
        // Per-shard anchorable sets: alive members, falling back to the
        // full member list for all-dead shards (the full overlay must
        // stay stitched; the alive view filters those links out).
        let sets: Vec<Vec<u32>> = self
            .shards
            .iter()
            .map(|s| {
                let alive = s.alive();
                if alive.is_empty() {
                    s.members.clone()
                } else {
                    alive
                }
            })
            .collect();
        // Shard-graph boundaries: the cycle, then halving chords.
        let mut bounds: Vec<(usize, usize)> = Vec::new();
        if ks == 2 {
            bounds.push((0, 1));
        } else {
            for i in 0..ks {
                bounds.push((i, (i + 1) % ks));
            }
            if ks >= 5 {
                let h = ks / 2;
                for i in 0..h {
                    bounds.push((i, (i + h) % ks));
                }
            }
        }
        // Which boundaries need re-picking: all of them on the first
        // stitch / after a latency update, else only those incident to
        // a stale shard.
        let full = self.stitch_all || self.anchors.len() != bounds.len();
        let refresh: Vec<bool> = bounds
            .iter()
            .map(|&(a, b)| {
                full || self.shard_dirty[a] || self.shard_dirty[b]
            })
            .collect();
        let cands: Vec<Vec<(u32, u32)>> = bounds
            .iter()
            .enumerate()
            .map(|(i, &(a, b))| {
                if refresh[i] {
                    self.top_pairs(
                        &sets[a],
                        &sets[b],
                        self.opts.anchor_candidates,
                    )
                } else {
                    vec![self.anchors[i]] // kept as is
                }
            })
            .collect();
        // Seed: lowest-latency pick on refreshed boundaries, the
        // previous anchor elsewhere.
        let mut anchors: Vec<(u32, u32)> =
            cands.iter().map(|c| c[0]).collect();
        // Refinement: one coordinate-descent pass over the refreshed
        // boundaries minimizing the certified alive diameter,
        // warm-started across evaluations. The ring-only alive graph is
        // invariant across trials, so it is built once and cloned.
        if self.opts.anchor_candidates > 1 {
            let alive = self.alive_set();
            let base = self.alive_ring_graph(&alive);
            for (bi, c) in cands.iter().enumerate() {
                if !refresh[bi] || c.len() < 2 {
                    continue;
                }
                let mut best = (f32::INFINITY, c[0]);
                for &cand in c {
                    anchors[bi] = cand;
                    let mut g = base.clone();
                    self.add_alive_anchors(&mut g, &anchors, &alive);
                    // Candidate ranking is a relative comparison, so
                    // the non-exact modes rank by the budgeted upper
                    // envelope instead of converging every trial.
                    let d = if self.opts.certify.is_exact() {
                        let (d, lm) = self
                            .pool
                            .diameter_with_seeds(&g, &self.alive_landmarks);
                        self.alive_landmarks = lm;
                        d
                    } else {
                        let est = self.pool.diameter_est(
                            &g,
                            &self.alive_landmarks,
                            self.opts.certify.budget,
                        );
                        self.alive_landmarks = est.landmarks;
                        est.upper
                    };
                    if d < best.0 {
                        best = (d, cand);
                    }
                }
                anchors[bi] = best.1;
            }
        }
        self.anchors = anchors;
        for d in &mut self.shard_dirty {
            *d = false;
        }
        self.stitch_all = false;
        span.finish(&self.obs.rec, 0.0);
        self.obs.reg.incr("shard.reanchors", 1);
        self.metrics.incr("shard.reanchors", 1);
    }

    /// One adaptation period across all shards, fanned out over
    /// [`ShardedConfig::threads`] workers. Returns (mean ρ across
    /// shards, ring swaps this period). Results are identical for every
    /// thread count: each shard's RNG stream is its own.
    pub fn adapt_once(&mut self) -> (f64, u64) {
        let select = SelectConfig {
            epsilon: self.cfg.epsilon,
        };
        let mcfg = MeasureConfig {
            samples: self.cfg.gossip_samples,
            rounds: self.cfg.gossip_rounds,
        };
        let shards = std::mem::take(&mut self.shards);
        let threads = self.opts.threads.max(1).min(shards.len());
        // Per-shard wall-time histograms: atomic observes, so the
        // workers record without any `&mut` threading back to the
        // owner (and without perturbing the deterministic metrics).
        let timings: Vec<_> = (0..shards.len())
            .map(|i| {
                self.obs.reg.histogram(&format!("shard.{i}.period_ms"))
            })
            .collect();
        self.shards = if threads > 1 {
            crate::par::scoped_map(shards, threads, move |i, mut s: Shard| {
                let t0 = std::time::Instant::now();
                s.adapt_once(select, mcfg);
                timings[i].observe(t0.elapsed().as_secs_f64() * 1e3);
                s
            })
        } else {
            shards
                .into_iter()
                .enumerate()
                .map(|(i, mut s)| {
                    let t0 = std::time::Instant::now();
                    s.adapt_once(select, mcfg);
                    timings[i].observe(t0.elapsed().as_secs_f64() * 1e3);
                    s
                })
                .collect()
        };
        let mut rho_sum = 0.0f64;
        let mut swaps = 0u64;
        let mut messages = 0u64;
        for (i, s) in self.shards.iter().enumerate() {
            rho_sum += s.rho;
            swaps += u64::from(s.swapped);
            messages += s.messages as u64;
            if s.swapped {
                self.shard_dirty[i] = true;
            }
        }
        if swaps > 0 {
            self.dirty = true;
            self.metrics.incr("rings.swapped", swaps);
        }
        self.metrics.incr("gossip.messages", messages);
        (rho_sum / self.shards.len() as f64, swaps)
    }

    /// Run over a membership trace for `horizon` sim-time (static
    /// latency), adapting every `cfg.adapt_period_ms`. Equivalent to
    /// [`AdaptiveRunner::run_with`] under default [`RunOptions`].
    pub fn run(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
    ) -> Result<CoordinatorReport> {
        self.run_with(trace, horizon, RunOptions::new())
    }

    /// Certified diameter of `g` under [`ShardedConfig::certify`],
    /// warm-starting from (and refreshing) the landmark cache selected
    /// by `alive`. Exact mode converges the bounding algorithm;
    /// sketch/hybrid spend `certify.budget` sweeps and report the
    /// certified upper envelope, with hybrid additionally pinning the
    /// interval against the exact oracle on every
    /// [`CertifyConfig::oracle_period`] evaluation `idx` (and
    /// reporting the exact value there).
    fn certified_diameter(
        &mut self,
        g: &Graph,
        alive: bool,
        idx: u64,
    ) -> Result<f32> {
        let cert = self.opts.certify;
        if cert.is_exact() {
            let (d, lm) = if alive {
                self.pool.diameter_with_seeds(g, &self.alive_landmarks)
            } else {
                self.pool.diameter_with_seeds(g, &self.full_landmarks)
            };
            if alive {
                self.alive_landmarks = lm;
            } else {
                self.full_landmarks = lm;
            }
            return Ok(d);
        }
        let est = if alive {
            self.pool.diameter_est(g, &self.alive_landmarks, cert.budget)
        } else {
            self.pool.diameter_est(g, &self.full_landmarks, cert.budget)
        };
        let DiameterEst { lower, upper, landmarks, .. } = est;
        if alive {
            self.alive_landmarks = landmarks;
        } else {
            self.full_landmarks = landmarks;
        }
        self.metrics.observe("eval.est_lower", f64::from(lower));
        self.metrics.observe("eval.est_upper", f64::from(upper));
        if cert.oracle_period(idx) {
            self.metrics.incr("eval.oracle_checks", 1);
            let exact = diameter::diameter(g);
            let tol = 1e-3 * exact.max(1.0);
            if lower > exact + tol || exact > upper + tol {
                bail!(
                    "hybrid oracle at evaluation {idx}: exact {exact} \
                     outside certified [{lower}, {upper}]"
                );
            }
            return Ok(exact);
        }
        Ok(upper)
    }

    /// Run with a time-varying latency view — the scenario-engine entry
    /// point, interface-compatible with
    /// [`Coordinator::run_dynamic`](super::Coordinator::run_dynamic):
    /// per period the metrics registry records `overlay.diameter`,
    /// `overlay.rho` (mean of the partition-local ρ's), `overlay.alive`,
    /// `overlay.alive_diameter`, `rings.swaps_per_period` and
    /// `shard.anchor_links`. Reported diameters follow
    /// [`ShardedConfig::certify`]: exact mode converges the
    /// warm-started bounding algorithm of
    /// [`EvalPool::diameter_with_seeds`] (~1e-6 certification
    /// tolerance); sketch reports the budgeted certified upper
    /// envelope; hybrid additionally pins the interval against the
    /// exact oracle every `oracle_every`-th evaluation. Ring-swap
    /// decisions never consult a diameter, so all modes produce
    /// identical swap sequences.
    #[deprecated(
        since = "0.10.0",
        note = "use AdaptiveRunner::run_with with RunOptions::latency"
    )]
    pub fn run_dynamic(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
        latency_at: impl FnMut(f64) -> Option<LatencyMatrix>,
    ) -> Result<CoordinatorReport> {
        self.run_with(
            trace,
            horizon,
            RunOptions::new().latency(latency_at),
        )
    }

    /// [`ShardedCoordinator::run_dynamic`] with a per-period overlay
    /// observer: after each period the callback receives the stitched
    /// alive sub-overlay (shard rings + anchor links), the current
    /// latency view and the sorted alive list — the traffic-plane
    /// hook. `None` is byte-identical to
    /// [`ShardedCoordinator::run_dynamic`].
    #[deprecated(
        since = "0.10.0",
        note = "use AdaptiveRunner::run_with with \
                RunOptions::latency + RunOptions::observer"
    )]
    pub fn run_dynamic_observed(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
        latency_at: impl FnMut(f64) -> Option<LatencyMatrix>,
        observer: Option<crate::traffic::OverlayObserver<'_>>,
    ) -> Result<CoordinatorReport> {
        self.run_with(
            trace,
            horizon,
            RunOptions::new()
                .latency(latency_at)
                .maybe_observer(observer),
        )
    }
}

impl AdaptiveRunner for ShardedCoordinator {
    fn kind(&self) -> &'static str {
        "sharded"
    }

    /// The sharded event loop: per period the metrics registry records
    /// `overlay.diameter`, `overlay.rho` (mean of the partition-local
    /// ρ's), `overlay.alive`, `overlay.alive_diameter`,
    /// `rings.swaps_per_period` and `shard.anchor_links`. Reported
    /// diameters follow [`ShardedConfig::certify`] — this is the one
    /// runner that honors a non-exact [`RunOptions::certify`]
    /// override. Exchanges no frames, so [`RunOptions::trace_sample`]
    /// is a no-op here.
    fn run_with(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
        mut opts: RunOptions<'_>,
    ) -> Result<CoordinatorReport> {
        if let Some(c) = opts.certify {
            if let Err(e) = c.validate() {
                bail!("{e}");
            }
            self.opts.certify = c;
        }
        if let Some(g) = opts.churn_guard {
            self.cfg.churn_guard = g;
        }
        if opts.record {
            self.obs.rec.set_enabled(true);
        }
        let mut latency_at = opts.take_latency();
        let mut observer = opts.observer;
        let g0 = self.overlay();
        let initial_diameter = self.certified_diameter(&g0, false, 0)?;
        drop(g0);
        let mut timeline = Vec::new();
        let mut total_swaps = 0u64;
        let mut t = 0.0;
        let mut ev_idx = 0;
        let mut eval_idx = 1u64;
        let mut alive_d = 0.0f64;
        let mut alive_d_fresh = false;
        while t < horizon {
            t += self.cfg.adapt_period_ms;
            if let Some(w) = latency_at(t) {
                self.set_latency(w)?;
                alive_d_fresh = false;
            }
            let mut applied = 0u64;
            while ev_idx < trace.events.len()
                && trace.events[ev_idx].time() <= t
            {
                let ev = trace.events[ev_idx];
                self.apply_event(&ev);
                ev_idx += 1;
                applied += 1;
            }
            let (rho, swaps) = self.adapt_once();
            total_swaps += swaps;
            if self.dirty {
                self.re_anchor();
                alive_d_fresh = false;
            }
            let g_full = self.overlay();
            let d = self.certified_diameter(&g_full, false, eval_idx)?;
            drop(g_full);
            self.metrics.observe("overlay.diameter", d as f64);
            self.metrics.observe("overlay.rho", rho);
            let alive_cnt = self.alive_count();
            // Same shortcut as the centralized loop: with everyone
            // alive, the alive sub-overlay IS the overlay.
            if alive_cnt == self.len() {
                alive_d = d as f64;
            } else if !alive_d_fresh {
                let g_alive = self.alive_overlay();
                alive_d = f64::from(
                    self.certified_diameter(&g_alive, true, eval_idx)?,
                );
            }
            alive_d_fresh = true;
            self.metrics.observe("overlay.alive", alive_cnt as f64);
            self.metrics.observe("overlay.alive_diameter", alive_d);
            self.metrics
                .observe("rings.swaps_per_period", swaps as f64);
            self.metrics
                .observe("shard.anchor_links", self.anchors.len() as f64);
            self.metrics.incr("membership.events_applied", applied);
            timeline.push((t, rho, d));
            if let Some(f) = observer.as_mut() {
                let ga = self.alive_overlay();
                let mut alive: Vec<u32> =
                    self.alive_set().into_iter().collect();
                alive.sort_unstable();
                f(t, &ga, &self.w, &alive);
            }
            eval_idx += 1;
        }
        Ok(CoordinatorReport {
            final_diameter: timeline
                .last()
                .map(|&(_, _, d)| d)
                .unwrap_or(initial_diameter),
            initial_diameter,
            swaps: total_swaps as usize,
            alive: self.alive_count(),
            timeline,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components;

    fn cfg(model: &str, nodes: usize) -> Config {
        let mut c = Config::default();
        c.model = model.to_string();
        c.nodes = nodes;
        c.scorer = "greedy".to_string();
        c.adapt_period_ms = 250.0;
        c
    }

    #[test]
    fn partitions_cover_the_universe_disjointly() {
        let co = ShardedCoordinator::new(
            cfg("fabric", 64),
            ShardedConfig::new(8),
        )
        .unwrap();
        assert_eq!(co.shard_count(), 8);
        let mut seen = std::collections::BTreeSet::new();
        for shard in &co.shards {
            assert!(shard.members.len() >= 3);
            for &m in &shard.members {
                assert!(seen.insert(m), "node {m} owned twice");
            }
        }
        assert_eq!(seen.len(), 64);
        for node in 0..64u32 {
            let s = co.shard_of(node).unwrap();
            assert!(co.shards[s].members.contains(&node));
        }
    }

    #[test]
    fn stitched_overlay_is_connected() {
        for shards in [1usize, 2, 4, 8] {
            let co = ShardedCoordinator::new(
                cfg("uniform", 48),
                ShardedConfig::new(shards),
            )
            .unwrap();
            let g = co.overlay();
            assert!(
                components::is_connected(&g),
                "K={shards}: stitched overlay disconnected"
            );
            if shards == 1 {
                assert!(co.anchors().is_empty());
            } else {
                assert!(!co.anchors().is_empty());
            }
        }
    }

    #[test]
    fn rejects_too_many_shards() {
        let err = ShardedCoordinator::new(
            cfg("uniform", 10),
            ShardedConfig::new(4),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("below 3"), "{err}");
    }

    #[test]
    fn events_route_to_the_owning_shard_only() {
        let mut co = ShardedCoordinator::new(
            cfg("uniform", 24),
            ShardedConfig::new(4),
        )
        .unwrap();
        let victim = 7u32;
        let s = co.shard_of(victim).unwrap();
        let before: Vec<usize> = co
            .shards
            .iter()
            .map(|sh| sh.membership.count_state(MemberState::Alive))
            .collect();
        co.apply_event(&MembershipEvent::Crash {
            time: 1.0,
            node: victim,
        });
        for (i, sh) in co.shards.iter().enumerate() {
            let alive = sh.membership.count_state(MemberState::Alive);
            if i == s {
                assert_eq!(alive, before[i] - 1);
            } else {
                assert_eq!(alive, before[i], "shard {i} perturbed");
            }
        }
        assert_eq!(co.alive_count(), 23);
        // The crashed node relays nothing in the alive view.
        assert_eq!(co.alive_overlay().degree(victim as usize), 0);
    }

    #[test]
    fn run_produces_aligned_timeline_and_metrics() {
        let mut co = ShardedCoordinator::new(
            cfg("fabric", 60),
            ShardedConfig::new(4),
        )
        .unwrap();
        let mut rng = Rng::new(5);
        let trace = EventTrace::churn(60, 1000.0, 0.001, &mut rng);
        let rep = co.run(&trace, 1000.0).unwrap();
        assert_eq!(rep.timeline.len(), 4);
        for s in [
            "overlay.diameter",
            "overlay.rho",
            "overlay.alive",
            "overlay.alive_diameter",
            "rings.swaps_per_period",
            "shard.anchor_links",
        ] {
            assert_eq!(
                co.metrics.series(s).unwrap().values.len(),
                4,
                "series {s}"
            );
        }
        assert!(rep.final_diameter.is_finite());
        assert!(rep.alive <= 60);
    }

    #[test]
    fn thread_count_does_not_change_the_run() {
        let trace = EventTrace::default();
        let mut reports = Vec::new();
        for threads in [1usize, 4] {
            let mut opts = ShardedConfig::new(4);
            opts.threads = threads;
            let mut co =
                ShardedCoordinator::new(cfg("fabric", 48), opts).unwrap();
            let rep = co.run(&trace, 1000.0).unwrap();
            reports.push((rep.timeline, co.metrics.report()));
        }
        assert_eq!(reports[0].0, reports[1].0, "timelines differ");
        assert_eq!(reports[0].1, reports[1].1, "metrics differ");
    }

    #[test]
    fn re_anchor_falls_back_to_dead_shards_for_the_full_view() {
        let mut co = ShardedCoordinator::new(
            cfg("uniform", 24),
            ShardedConfig::new(4),
        )
        .unwrap();
        // Kill every member of shard 2: the alive view loses it, but the
        // full overlay must stay stitched through the fallback anchors.
        let victims = co.shards[2].members.clone();
        for &v in &victims {
            co.apply_event(&MembershipEvent::Crash { time: 1.0, node: v });
        }
        co.re_anchor();
        assert!(components::is_connected(&co.overlay()));
    }
}
