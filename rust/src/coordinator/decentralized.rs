//! The [`DecentralizedRunner`]: coordinator-free DGRO. Every node runs
//! its own Algorithm-3 loop (docs/DECENTRALIZED.md); the runner object
//! is only the *physical world* — it pumps the transport, powers peers
//! on and off per the oracle trace, and evaluates the reported
//! diameters against the oracle latency view, exactly like the
//! [`NetCoordinator`](crate::net::NetCoordinator) does for its actors.
//! No protocol state lives outside the peers:
//!
//! 1. **Membership (SWIM merge rule).** Lifecycle news travels as
//!    [`Message::MemberUpdate`] records folded through
//!    [`MembershipList::apply`] — higher incarnation wins, ties break
//!    on state rank. A node announces its own join/leave; a crash is
//!    announced by the lowest-id live peer (the stand-in for a SWIM
//!    failure detector, which is out of scope here). Records flood
//!    along each receiver's *own* ring-neighbor view and are
//!    re-forwarded only when the merge advanced the view, so the flood
//!    self-quenches; origins re-send for [`PROBE_RETX`] extra epochs
//!    to ride out frame loss.
//! 2. **Measurement.** The message-level Algorithm 3 of the net
//!    coordinator, run peer-locally: RTT probes against the peer's own
//!    view of alive neighbors and alive random targets, then push-sum
//!    gossip rounds — each peer reads out its *own* mass-weighted ρ.
//!    Peers whose probe mass was lost entirely sit the period out
//!    (no ρ, no proposal) instead of acting on a biased estimate.
//! 3. **Two-phase ring swap.** A peer whose ρ leaves the Keep band —
//!    and that beats all its overlay neighbors under the shared
//!    per-period priority hash (a coordinator-free independent-set
//!    gate; without it a fully-out-of-band overlay would deadlock on
//!    self-locked grants) —
//!    proposes: it materializes a candidate ring, picks the replacement
//!    slot from its own view, and sends [`Message::SwapPropose`] to the
//!    slot ring's alive predecessor and successor (walking past peers
//!    its view says are dead). A responder grants at most **one**
//!    proposal per period ([`Message::SwapAck`]); a proposer locks its
//!    own grant when proposing. Full grants commit: the proposer
//!    installs the ring under version `(period, proposer)` and floods
//!    [`Message::SwapCommit`]. Receivers install a commit only when its
//!    version is newer (higher period wins, ties break toward the
//!    lower proposer id). Every commit carries a full permutation, so
//!    any subset of commits applied in any order leaves every ring a
//!    valid cycle — concurrent swaps cannot tear the ring, they can
//!    only lose the version race.
//! 4. **Anti-entropy.** After the swap phase, peers exchange
//!    [`Message::RingDigest`] frames (per-slot versions) with their
//!    ring neighbors; a receiver holding a newer version pushes the
//!    corresponding commit back. Rounds repeat until
//!    [`SYNC_QUIET_ROUNDS`] consecutive rounds repair nothing, so a
//!    commit dropped by a lossy link is re-delivered hop by hop before
//!    the period closes.
//!
//! **Reporting.** The per-period series are the shared ones
//! ([`record_period`]): the overlay is read from the lowest-id live
//! peer (the *witness*), diameters are evaluated on the oracle latency
//! view, and the reported ρ is the mean of the live peers' own
//! estimates — so `scenario compare` columns line up with the other
//! runners. Like every runner, frames to powered-off peers are
//! discarded by the world (counted as `net.dead_drops`), never
//! processed.
//!
//! Determinism: peers are iterated in ascending id everywhere, gossip
//! merges sort by sender, probe retries drain in sequence order — on
//! [`SimTransport`](crate::net::transport::SimTransport) a seeded run
//! is byte-identical at any thread count (there are no threads here at
//! all).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::Config;
use crate::coordinator::runner::{
    reject_non_exact_certify, AdaptiveRunner, RunOptions,
};
use crate::coordinator::service::{alive_overlay_graph, record_period};
use crate::coordinator::CoordinatorReport;
use crate::dgro::select::{
    decide, materialize, RingChoice, SelectConfig,
};
use crate::gossip::measure::GossipStats;
use crate::graph::diameter;
use crate::graph::ring::Ring;
use crate::latency::LatencyMatrix;
use crate::membership::events::{EventTrace, MembershipEvent};
use crate::membership::list::{MemberState, MembershipList};
use crate::metrics::Metrics;
use crate::net::runner::{
    frame_key, max_delay_ms, ObsHandles, PendingProbe, ProbeAccum,
    MAX_IDLE_SWEEPS, POLL_MS, PROBE_RETX,
};
use crate::net::transport::{Delivery, Transport};
use crate::net::wire::Message;
use crate::obs::trace::{span_id, trace_id, TraceCtx};
use crate::obs::Obs;
use crate::topology::kring::KRing;
use crate::topology::random_ring;
use crate::util::rng::Rng;

/// Upper bound on anti-entropy digest rounds per period (a backstop;
/// quiescence normally ends the loop much earlier).
const SYNC_ROUNDS_CAP: usize = 16;

/// Consecutive repair-free digest rounds before ring anti-entropy
/// declares the views converged for the period.
const SYNC_QUIET_ROUNDS: usize = 2;

/// `a` supersedes `b` under the swap version order: higher period
/// wins; within a period the lower proposer id wins. Boot rings carry
/// version `(0, 0)` and periods start at 1, so every commit supersedes
/// boot state.
fn ver_newer(a: (u32, u32), b: (u32, u32)) -> bool {
    a.0 > b.0 || (a.0 == b.0 && a.1 < b.1)
}

/// Shared per-period proposal priority: a deterministic hash of
/// `(seed, period, id)` every peer can compute for every other peer
/// from the deployment configuration alone. A peer proposes only when
/// it beats all its overlay neighbors, so proposers form an
/// independent set — without this, a period in which *every* peer
/// leaves the Keep band (the usual state right after boot) would
/// self-lock every grant and no swap could ever commit.
fn swap_prio(seed: u64, period: u32, id: u32) -> u64 {
    crate::obs::trace::derive(
        seed,
        "swap-prio",
        &[period as u64, id as u64],
    )
}

/// Walk `order` from `me` in both directions to the nearest members
/// `alive` contains (skipping `me` itself). Returns the deduplicated
/// neighbor pair — one entry when predecessor and successor coincide,
/// empty when the view holds no other alive member on this ring.
fn alive_ring_neighbors(
    order: &[u32],
    me: u32,
    alive: &HashSet<u32>,
) -> Vec<u32> {
    let n = order.len();
    let Some(pos) = order.iter().position(|&v| v == me) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for dir in [n - 1, 1usize] {
        let mut i = pos;
        for _ in 1..n {
            i = (i + dir) % n;
            let v = order[i];
            if v != me && alive.contains(&v) {
                out.push(v);
                break;
            }
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Circumference of a visit order under `w` — the ring-randomness
/// proxy the slot chooser shares with
/// [`swap_slot`](crate::coordinator::service::swap_slot) (random rings
/// are long, nearest-neighbour rings short).
fn order_len(order: &[u32], w: &LatencyMatrix) -> f32 {
    let n = order.len();
    let mut len = 0.0f32;
    for i in 0..n {
        len += w.get(order[i] as usize, order[(i + 1) % n] as usize);
    }
    len
}

/// An in-flight two-phase swap proposal on its proposer.
struct Proposal {
    slot: usize,
    seq: u32,
    order: Vec<u32>,
    acks: usize,
    quorum: usize,
}

/// One peer's entire protocol state: everything it knows, it learned
/// from its boot configuration or from frames on the transport.
struct Peer {
    id: u32,
    /// Physically powered on (the world's truth, not a view).
    up: bool,
    rng: Rng,
    /// This peer's own membership view (SWIM merge rule).
    membership: MembershipList,
    /// This peer's own copy of the K ring visit orders.
    rings: Vec<Vec<u32>>,
    /// Per-slot swap version `(period, proposer)`; boot is `(0, 0)`.
    ring_ver: Vec<(u32, u32)>,
    next_seq: u32,
    pending: HashMap<u32, PendingProbe>,
    probe: ProbeAccum,
    /// Push-sum accumulator: local, global, min, m, ml.
    acc: [f64; 5],
    /// Incoming pushes for the current gossip round, keyed by sender.
    gossip_in: Vec<(u32, [f64; 5])>,
    /// This period's own ρ estimate (valid only when `has_rho`).
    rho: f64,
    has_rho: bool,
    /// Whether this peer's single per-period swap grant is taken.
    granted: bool,
    prop: Option<Proposal>,
    /// Membership records that advanced this peer's view this period
    /// (its churn-guard signal).
    events_seen: u64,
}

impl Peer {
    fn fresh_seq(&mut self) -> u32 {
        let s = self.next_seq;
        self.next_seq = self.next_seq.wrapping_add(1);
        s
    }

    /// The peer's own view of its alive set.
    fn alive_view(&self) -> HashSet<u32> {
        self.membership.alive().collect()
    }

    /// Alive overlay neighbors per this peer's own rings and own
    /// membership view: the walked predecessor/successor on every
    /// ring, sorted and deduplicated.
    fn overlay_neighbors(&self) -> Vec<u32> {
        let alive = self.alive_view();
        let mut out = Vec::new();
        for ring in &self.rings {
            out.extend(alive_ring_neighbors(ring, self.id, &alive));
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Coordinator-free DGRO over a [`Transport`]. Construct with
/// [`DecentralizedRunner::new`], then drive through
/// [`AdaptiveRunner::run_with`] like every other runner.
pub struct DecentralizedRunner<T: Transport> {
    /// Shared runtime configuration (nodes, ε, gossip knobs,
    /// churn guard, adaptation period).
    pub cfg: Config,
    /// Oracle latency view: shapes the transport's per-link delays and
    /// evaluates reported diameters. Never consulted for ρ.
    pub w: LatencyMatrix,
    /// Oracle membership (fed by the trace) — reporting only; peers
    /// keep their own views.
    pub membership: MembershipList,
    /// Counters + per-period series (same names as the coordinators).
    pub metrics: Metrics,
    /// This run's observability surface.
    pub obs: Obs,
    /// Causal-trace sampling stride (same contract as
    /// [`NetCoordinator::trace_sample`](crate::net::NetCoordinator::trace_sample)).
    pub trace_sample: usize,
    hot: ObsHandles,
    dead_drops: Arc<AtomicU64>,
    peers: Vec<Peer>,
    transport: T,
    in_flight: usize,
    epoch: u32,
    seen: HashSet<u64>,
    max_w_ms: f64,
    /// Ring repairs applied since the counter was last reset (the
    /// anti-entropy quiescence signal).
    repairs: u64,
    trace: u64,
    span_period: u64,
    tctx: Option<TraceCtx>,
}

impl<T: Transport> DecentralizedRunner<T> {
    /// Boot `cfg.nodes` peers over `transport` with identical ring
    /// state (the deployment configuration), one RNG stream per peer.
    pub fn new(
        cfg: Config,
        w: LatencyMatrix,
        transport: T,
    ) -> Result<Self> {
        let mut transport = transport;
        cfg.validate()?;
        if w.n() != cfg.nodes {
            bail!(
                "latency matrix has {} nodes but cfg.nodes = {}",
                w.n(),
                cfg.nodes
            );
        }
        if transport.n() != cfg.nodes {
            bail!(
                "transport has {} endpoints but cfg.nodes = {}",
                transport.n(),
                cfg.nodes
            );
        }
        let k = cfg.effective_k();
        let mut rng = Rng::new(cfg.seed);
        let boot_rings: Vec<Vec<u32>> = (0..k)
            .map(|_| random_ring(cfg.nodes, &mut rng).order().to_vec())
            .collect();
        let peers = (0..cfg.nodes as u32)
            .map(|id| Peer {
                id,
                up: true,
                rng: rng.fork(0xDECE_0000 + id as u64),
                membership: MembershipList::full(cfg.nodes),
                rings: boot_rings.clone(),
                ring_ver: vec![(0, 0); k],
                next_seq: 0,
                pending: HashMap::new(),
                probe: ProbeAccum::default(),
                acc: [0.0; 5],
                gossip_in: Vec::new(),
                rho: 0.5,
                has_rho: false,
                granted: false,
                prop: None,
                events_seen: 0,
            })
            .collect();
        let obs = Obs::new();
        transport.attach_obs(&obs);
        let hot = ObsHandles::new(&obs.reg);
        let dead_drops = obs.reg.counter("net.dead_drops");
        Ok(DecentralizedRunner {
            membership: MembershipList::full(cfg.nodes),
            metrics: Metrics::new(),
            obs,
            hot,
            dead_drops,
            peers,
            transport,
            in_flight: 0,
            epoch: 0,
            seen: HashSet::new(),
            max_w_ms: max_delay_ms(&w),
            repairs: 0,
            trace_sample: 0,
            trace: 0,
            span_period: 0,
            tctx: None,
            w,
            cfg,
        })
    }

    /// The underlying transport's name ("sim" / "udp" / ...).
    pub fn transport_name(&self) -> &'static str {
        self.transport.name()
    }

    /// Total frames the transport carried so far.
    pub fn frames_sent(&self) -> u64 {
        self.transport.frames_sent()
    }

    /// Ids of the peers the world currently has powered on.
    pub fn up_nodes(&self) -> Vec<u32> {
        self.peers
            .iter()
            .filter(|p| p.up)
            .map(|p| p.id)
            .collect()
    }

    /// Per-peer membership snapshots (what each peer *believes*).
    pub fn node_views(&self) -> Vec<Vec<(u32, MemberState, u64)>> {
        self.peers.iter().map(|p| p.membership.snapshot()).collect()
    }

    /// Per-peer ring views (K visit orders each), for convergence and
    /// ring-strand tests.
    pub fn ring_views(&self) -> Vec<Vec<Vec<u32>>> {
        self.peers.iter().map(|p| p.rings.clone()).collect()
    }

    /// Per-peer per-slot swap versions.
    pub fn ring_versions(&self) -> Vec<Vec<(u32, u32)>> {
        self.peers.iter().map(|p| p.ring_ver.clone()).collect()
    }

    fn tracing(&self) -> bool {
        self.trace_sample > 0
    }

    /// Open a new collection phase (same epoch discipline as the net
    /// coordinator: stragglers from written-off phases are rejected by
    /// their stale epoch tag).
    fn begin_phase(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        self.seen.clear();
        self.in_flight = 0;
    }

    fn send(&mut self, src: u32, dst: u32, msg: &Message) -> Result<()> {
        self.transport
            .send(src, dst, &msg.encode_traced(self.epoch, self.tctx))?;
        self.in_flight += 1;
        Ok(())
    }

    /// The lowest-id live peer — whose state the reporting plane reads
    /// (falling back to peer 0's frozen state when nobody is up).
    fn witness(&self) -> usize {
        self.peers.iter().position(|p| p.up).unwrap_or(0)
    }

    /// The witness's rings as a validated [`KRing`] for oracle-side
    /// diameter evaluation.
    fn witness_krings(&self) -> Result<KRing> {
        let p = &self.peers[self.witness()];
        let rings = p
            .rings
            .iter()
            .map(|o| Ring::new(o.clone()))
            .collect::<Result<Vec<_>>>()?;
        Ok(KRing::new(rings))
    }

    /// Pump deliveries round-robin until every in-flight frame landed
    /// or the write-off policy fires — the same two policies as the
    /// net coordinator (idle cap on faithful transports, deadline on
    /// transports that declare loss).
    fn collect(&mut self) -> Result<u64> {
        let n = self.cfg.nodes as u32;
        let lossy = self.transport.loss_hint() > 0.0;
        let start_ms = self.transport.now_ms();
        let budget_ms = 2.0 * self.max_w_ms + 8.0 * POLL_MS;
        let mut idle = 0usize;
        while self.in_flight > 0 {
            let mut any = false;
            for node in 0..n {
                while let Some(d) = self.transport.recv(node, POLL_MS) {
                    any = true;
                    self.on_delivery(node, d)?;
                }
            }
            if any {
                idle = 0;
                continue;
            }
            idle += 1;
            if lossy {
                if self.transport.now_ms() - start_ms > budget_ms {
                    break;
                }
            } else if idle >= MAX_IDLE_SWEEPS {
                break;
            }
        }
        let lost = self.in_flight as u64;
        if lost > 0 {
            self.hot.frames_lost.fetch_add(lost, Ordering::Relaxed);
            self.in_flight = 0;
        }
        Ok(lost)
    }
}

impl<T: Transport> DecentralizedRunner<T> {
    /// Handle one delivered frame at `node`. Decode, check the frame
    /// epoch, filter duplicates, discard frames addressed to
    /// powered-off peers (`net.dead_drops` — the world drops them so a
    /// barrier never stalls on a dead receiver), then dispatch.
    fn on_delivery(&mut self, node: u32, d: Delivery) -> Result<()> {
        if d.src as usize >= self.cfg.nodes || d.src == node {
            self.hot.decode_errors.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        let (epoch, ctx, msg) = match Message::decode_traced(&d.frame)
        {
            Ok(x) => x,
            Err(_) => {
                self.hot.decode_errors.fetch_add(1, Ordering::Relaxed);
                return Ok(());
            }
        };
        if epoch != self.epoch {
            self.hot.stale_frames.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        let key = frame_key(d.src, node, &d.frame);
        if !self.seen.insert(key) {
            self.hot.dup_frames.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        self.in_flight = self.in_flight.saturating_sub(1);
        if !self.peers[node as usize].up {
            self.dead_drops.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        // A sampled receive: stitch this delivery under the sender's
        // span (phase-granular on this runner — frames carry the
        // originating phase span as parent).
        let mut deliver_span = 0u64;
        if let Some(c) = ctx {
            if self.obs.rec.is_enabled()
                && self.trace_sample > 0
                && node as usize % self.trace_sample == 0
            {
                deliver_span =
                    span_id(c.trace, "deliver", node as u64, key);
                self.obs.rec.record_traced(
                    "deliver",
                    node as u64,
                    d.at_ms,
                    0.0,
                    0.0,
                    c.trace,
                    deliver_span,
                    c.parent,
                );
            }
        }
        // Replies and forwards echo the incoming context, parented
        // under the delivery span when one was recorded.
        let reply_ctx = ctx.map(|c| TraceCtx {
            trace: c.trace,
            parent: if deliver_span != 0 { deliver_span } else { c.parent },
        });
        match msg {
            Message::Ping { seq } => {
                let hold_ms =
                    (self.transport.now_ms() - d.at_ms).max(0.0);
                let saved = self.tctx;
                self.tctx = reply_ctx;
                let sent = self.send(
                    node,
                    d.src,
                    &Message::Pong { seq, hold_ms },
                );
                self.tctx = saved;
                sent?;
            }
            Message::Pong { seq, hold_ms } => {
                let at_ms = d.at_ms;
                let peer = &mut self.peers[node as usize];
                if let Some(p) = peer.pending.remove(&seq) {
                    let one_way = ((at_ms - p.sent_at_ms - hold_ms)
                        / 2.0)
                        .max(0.0);
                    let truth = self
                        .w
                        .get(node as usize, p.target as usize)
                        as f64;
                    self.hot.rtt_err.observe((one_way - truth).abs());
                    if p.global {
                        peer.probe.global_sum += one_way;
                        peer.probe.global_cnt += 1;
                        if peer.probe.global_cnt == 1
                            || one_way < peer.probe.min
                        {
                            peer.probe.min = one_way;
                        }
                    } else {
                        peer.probe.local_sum += one_way;
                        peer.probe.local_cnt += 1;
                    }
                }
            }
            Message::GossipPush {
                local,
                global,
                min,
                m,
                ml,
            } => {
                self.peers[node as usize]
                    .gossip_in
                    .push((d.src, [local, global, min, m, ml]));
            }
            Message::MemberUpdate {
                node: subject,
                state,
                incarnation,
                time,
            } => {
                let peer = &mut self.peers[node as usize];
                let changed = peer
                    .membership
                    .apply(subject, state, incarnation, time);
                if changed {
                    peer.events_seen += 1;
                    // Re-forward along this peer's own neighbor view;
                    // the changed-guard quenches the flood.
                    let targets: Vec<u32> = self.peers[node as usize]
                        .overlay_neighbors()
                        .into_iter()
                        .filter(|&v| v != d.src)
                        .collect();
                    let fwd = Message::MemberUpdate {
                        node: subject,
                        state,
                        incarnation,
                        time,
                    };
                    let saved = self.tctx;
                    self.tctx = reply_ctx;
                    for dst in targets {
                        self.send(node, dst, &fwd)?;
                    }
                    self.tctx = saved;
                }
            }
            Message::SwapPropose { slot, seq, order } => {
                let peer = &mut self.peers[node as usize];
                let accept = (slot as usize) < peer.rings.len()
                    && order.len() == self.cfg.nodes
                    && !peer.granted;
                if accept {
                    peer.granted = true;
                }
                let saved = self.tctx;
                self.tctx = reply_ctx;
                let sent = self.send(
                    node,
                    d.src,
                    &Message::SwapAck { seq, accept },
                );
                self.tctx = saved;
                sent?;
            }
            Message::SwapAck { seq, accept } => {
                if let Some(p) =
                    self.peers[node as usize].prop.as_mut()
                {
                    if p.seq == seq && accept {
                        p.acks += 1;
                    }
                }
            }
            Message::SwapCommit {
                slot,
                period,
                proposer,
                order,
            } => {
                self.apply_commit(node, slot, period, proposer, order);
            }
            Message::RingDigest { versions } => {
                let peer = &self.peers[node as usize];
                if versions.len() != peer.ring_ver.len() {
                    return Ok(());
                }
                // Push back the commits the sender is missing.
                let mut pushes = Vec::new();
                for (s, (&mine, &theirs)) in peer
                    .ring_ver
                    .iter()
                    .zip(versions.iter())
                    .enumerate()
                {
                    if ver_newer(mine, theirs) {
                        pushes.push(Message::SwapCommit {
                            slot: s as u32,
                            period: mine.0,
                            proposer: mine.1,
                            order: peer.rings[s].clone(),
                        });
                    }
                }
                let saved = self.tctx;
                self.tctx = reply_ctx;
                for m in pushes {
                    self.send(node, d.src, &m)?;
                }
                self.tctx = saved;
            }
            // Centralized-protocol frames have no meaning here.
            _ => {}
        }
        Ok(())
    }

    /// Install a committed ring at `node` iff its `(period, proposer)`
    /// version supersedes what the peer holds.
    fn apply_commit(
        &mut self,
        node: u32,
        slot: u32,
        period: u32,
        proposer: u32,
        order: Vec<u32>,
    ) {
        let peer = &mut self.peers[node as usize];
        let s = slot as usize;
        if s >= peer.rings.len() || order.len() != peer.rings[s].len()
        {
            return;
        }
        if ver_newer((period, proposer), peer.ring_ver[s]) {
            peer.rings[s] = order;
            peer.ring_ver[s] = (period, proposer);
            self.repairs += 1;
        }
    }
}

impl<T: Transport> DecentralizedRunner<T> {
    /// Peer-local Algorithm-3 measurement: RTT probes (with the
    /// [`PROBE_RETX`] retransmission budget), then push-sum gossip —
    /// but each peer plans against its *own* membership view and reads
    /// out its *own* mass-weighted ρ into [`Peer::rho`].
    fn measure_local(&mut self) -> Result<()> {
        let n = self.cfg.nodes;
        let k = self.cfg.gossip_samples.max(1);
        let ups: Vec<u32> = self.up_nodes();
        for p in &mut self.peers {
            p.has_rho = false;
        }
        if ups.len() < 2 {
            return Ok(());
        }
        // Views are frozen for the whole measurement: precompute each
        // live peer's walked alive-neighbor list and alive view once.
        let neigh: Vec<Vec<u32>> = (0..n as u32)
            .map(|u| {
                if !self.peers[u as usize].up {
                    return Vec::new();
                }
                self.peers[u as usize].overlay_neighbors()
            })
            .collect();
        let views: Vec<HashSet<u32>> = (0..n)
            .map(|u| {
                if self.peers[u].up {
                    self.peers[u].alive_view()
                } else {
                    HashSet::new()
                }
            })
            .collect();

        // Phase 1 — RTT probes, planned from each peer's own RNG in a
        // fixed order (deterministic across transports).
        let mut plans: Vec<Vec<(u32, bool)>> = vec![Vec::new(); n];
        for &u in &ups {
            let peer = &mut self.peers[u as usize];
            peer.probe = ProbeAccum::default();
            peer.pending.clear();
            let mut plan: Vec<(u32, bool)> =
                Vec::with_capacity(2 * k);
            for _ in 0..k {
                if neigh[u as usize].is_empty() {
                    break;
                }
                let list = &neigh[u as usize];
                plan.push((list[peer.rng.index(list.len())], false));
            }
            for _ in 0..k {
                let tgt = loop {
                    let v = peer.rng.index(n) as u32;
                    if v != u {
                        break v;
                    }
                };
                if !views[u as usize].contains(&tgt) {
                    continue; // own view says it cannot answer
                }
                plan.push((tgt, true));
            }
            plans[u as usize] = plan;
        }
        for attempt in 0..=PROBE_RETX {
            if plans.iter().all(|p| p.is_empty()) {
                break;
            }
            if attempt > 0 {
                let outstanding: u64 =
                    plans.iter().map(|p| p.len() as u64).sum();
                self.hot
                    .probe_retx
                    .fetch_add(outstanding, Ordering::Relaxed);
            }
            self.begin_phase();
            for &u in &ups {
                let plan = std::mem::take(&mut plans[u as usize]);
                for (tgt, global) in plan {
                    let seq = self.peers[u as usize].fresh_seq();
                    let sent_at_ms = self.transport.now_ms();
                    self.peers[u as usize].pending.insert(
                        seq,
                        PendingProbe {
                            target: tgt,
                            sent_at_ms,
                            global,
                            span: 0,
                            parent: 0,
                            attempt: attempt as u32,
                        },
                    );
                    self.send(u, tgt, &Message::Ping { seq })?;
                }
            }
            self.collect()?;
            // Unanswered probes queue for the next round in sequence
            // order (deterministic for a deterministic fault pattern).
            for &u in &ups {
                if self.peers[u as usize].pending.is_empty() {
                    continue;
                }
                let mut retry: Vec<(u32, PendingProbe)> = self.peers
                    [u as usize]
                    .pending
                    .drain()
                    .collect();
                retry.sort_by_key(|&(seq, _)| seq);
                plans[u as usize] = retry
                    .into_iter()
                    .map(|(_, p)| (p.target, p.global))
                    .collect();
            }
        }

        // Seed push-sum accumulators (zero mass for sample kinds the
        // peer never measured, so lost probes cannot bias averages).
        for &u in &ups {
            let peer = &mut self.peers[u as usize];
            let p = &peer.probe;
            let has_local = p.local_cnt > 0;
            let has_global = p.global_cnt > 0;
            peer.acc = [
                if has_local {
                    p.local_sum / p.local_cnt as f64
                } else {
                    0.0
                },
                if has_global {
                    p.global_sum / p.global_cnt as f64
                } else {
                    0.0
                },
                if has_global { p.min } else { 0.0 },
                if has_global { 1.0 } else { 0.0 },
                if has_local { 1.0 } else { 0.0 },
            ];
        }

        // Phase 2 — push-sum rounds, barriered per epoch, merged in
        // ascending sender order. Lost pushes are never retransmitted:
        // the mass-weighted readout absorbs them.
        for _ in 0..self.cfg.gossip_rounds {
            self.begin_phase();
            for &u in &ups {
                let list = &neigh[u as usize];
                if list.is_empty() {
                    continue;
                }
                let peer = &mut self.peers[u as usize];
                let v = list[peer.rng.index(list.len())];
                let mut half = [0.0; 5];
                for (h, a) in
                    half.iter_mut().zip(peer.acc.iter_mut())
                {
                    *a /= 2.0;
                    *h = *a;
                }
                self.send(
                    u,
                    v,
                    &Message::GossipPush {
                        local: half[0],
                        global: half[1],
                        min: half[2],
                        m: half[3],
                        ml: half[4],
                    },
                )?;
            }
            self.collect()?;
            for &u in &ups {
                let peer = &mut self.peers[u as usize];
                let mut incoming =
                    std::mem::take(&mut peer.gossip_in);
                incoming.sort_by_key(|&(src, _)| src);
                for (_, vals) in incoming {
                    for (a, x) in
                        peer.acc.iter_mut().zip(vals.iter())
                    {
                        *a += x;
                    }
                }
            }
        }

        // Peer-local readout: each live peer computes its own ρ from
        // its own mass-weighted averages; zero-mass peers sit out.
        for &u in &ups {
            let peer = &mut self.peers[u as usize];
            let a = &peer.acc;
            if a[3] > 1e-9 && a[4] > 1e-9 {
                let stats = GossipStats {
                    local: a[0] / a[4],
                    global: a[1] / a[3],
                    min: a[2] / a[3],
                    messages: 0,
                };
                peer.rho = stats.rho();
                peer.has_rho = true;
            }
        }
        Ok(())
    }

    /// The two-phase swap agreement for one period: propose to the
    /// affected ring neighbors, collect grants, commit full grants
    /// under `(period, proposer)` versions.
    fn swap_phase(&mut self, period: u32) -> Result<()> {
        let n = self.cfg.nodes;
        let ups: Vec<u32> = self.up_nodes();
        // Decide per peer, plan proposals ascending.
        let mut proposers: Vec<u32> = Vec::new();
        for &u in &ups {
            let guard = self.cfg.churn_guard > 0
                && self.peers[u as usize].events_seen
                    > self.cfg.churn_guard;
            let peer = &mut self.peers[u as usize];
            if !peer.has_rho {
                continue;
            }
            let stats = GossipStats {
                local: peer.rho,
                global: 1.0,
                min: 0.0,
                messages: 0,
            };
            let choice = decide(
                &stats,
                SelectConfig {
                    epsilon: self.cfg.epsilon,
                },
            );
            if choice == RingChoice::Keep {
                continue;
            }
            if guard {
                self.obs.reg.incr("rings.guard_skips", 1);
                continue;
            }
            // Liveness gate: propose only when this peer's shared
            // priority hash beats all its overlay neighbors', so
            // responders are never proposers themselves (see
            // [`swap_prio`]).
            let my_prio = swap_prio(self.cfg.seed, period, u);
            let eligible = self.peers[u as usize]
                .overlay_neighbors()
                .iter()
                .all(|&v| my_prio < swap_prio(self.cfg.seed, period, v));
            if !eligible {
                continue;
            }
            // Materialize the candidate against the oracle view (the
            // same fidelity shortcut the net coordinator takes) and
            // pick the slot from this peer's own rings.
            let start = self.peers[u as usize].rng.index(n);
            let Some(ring) = materialize(
                choice,
                &self.w,
                start,
                &mut self.peers[u as usize].rng,
            ) else {
                continue;
            };
            let peer = &self.peers[u as usize];
            let lengths: Vec<f32> = peer
                .rings
                .iter()
                .map(|o| order_len(o, &self.w))
                .collect();
            let mut slot = 0usize;
            for (i, &len) in lengths.iter().enumerate() {
                let better = match choice {
                    RingChoice::Shortest => len > lengths[slot],
                    _ => len < lengths[slot],
                };
                if better {
                    slot = i;
                }
            }
            let alive = peer.alive_view();
            let targets =
                alive_ring_neighbors(&peer.rings[slot], u, &alive);
            let quorum = targets.len();
            let peer = &mut self.peers[u as usize];
            // Self-lock the proposer's own per-period grant so
            // concurrent neighbors cannot be granted by it.
            peer.granted = true;
            let seq = peer.fresh_seq();
            peer.prop = Some(Proposal {
                slot,
                seq,
                order: ring.order().to_vec(),
                acks: 0,
                quorum,
            });
            proposers.push(u);
        }
        if proposers.is_empty() {
            return Ok(());
        }

        // Phase 1: propose to the affected ring neighbors, barriered;
        // responders grant or refuse within the same phase.
        self.begin_phase();
        self.tctx = self.tracing().then_some(TraceCtx {
            trace: self.trace,
            parent: self.span_period,
        });
        for &u in &proposers {
            let peer = &self.peers[u as usize];
            let Some(prop) = peer.prop.as_ref() else { continue };
            let msg = Message::SwapPropose {
                slot: prop.slot as u32,
                seq: prop.seq,
                order: prop.order.clone(),
            };
            let alive = peer.alive_view();
            let targets = alive_ring_neighbors(
                &peer.rings[prop.slot],
                u,
                &alive,
            );
            for dst in targets {
                self.send(u, dst, &msg)?;
            }
        }
        self.tctx = None;
        self.collect()?;

        // Phase 2: fully granted proposers install and flood commits.
        let mut committed = false;
        self.begin_phase();
        self.tctx = self.tracing().then_some(TraceCtx {
            trace: self.trace,
            parent: self.span_period,
        });
        for &u in &proposers {
            let peer = &mut self.peers[u as usize];
            let Some(prop) = peer.prop.take() else { continue };
            if prop.acks < prop.quorum {
                continue;
            }
            peer.rings[prop.slot] = prop.order.clone();
            peer.ring_ver[prop.slot] = (period, u);
            self.hot.rings_swapped.fetch_add(1, Ordering::Relaxed);
            committed = true;
            let msg = Message::SwapCommit {
                slot: prop.slot as u32,
                period,
                proposer: u,
                order: prop.order,
            };
            let mut targets: Vec<u32> = self.peers[u as usize]
                .alive_view()
                .into_iter()
                .filter(|&v| v != u)
                .collect();
            targets.sort_unstable();
            for dst in targets {
                self.send(u, dst, &msg)?;
            }
        }
        self.tctx = None;
        if committed {
            self.collect()?;
        } else {
            // No commits flew; close the (empty) phase barrier.
            self.in_flight = 0;
        }
        Ok(())
    }

    /// Ring anti-entropy: digest rounds between ring neighbors until
    /// [`SYNC_QUIET_ROUNDS`] consecutive rounds repair nothing (cap
    /// [`SYNC_ROUNDS_CAP`]), so commits dropped by a lossy link are
    /// re-delivered hop by hop.
    fn sync_rings(&mut self) -> Result<()> {
        let ups: Vec<u32> = self.up_nodes();
        if ups.len() < 2 {
            return Ok(());
        }
        let mut quiet = 0usize;
        for _ in 0..SYNC_ROUNDS_CAP {
            self.repairs = 0;
            self.begin_phase();
            self.tctx = self.tracing().then_some(TraceCtx {
                trace: self.trace,
                parent: self.span_period,
            });
            for &u in &ups {
                let msg = Message::RingDigest {
                    versions: self.peers[u as usize].ring_ver.clone(),
                };
                let targets =
                    self.peers[u as usize].overlay_neighbors();
                for dst in targets {
                    self.send(u, dst, &msg)?;
                }
            }
            self.tctx = None;
            self.collect()?;
            if self.repairs == 0 {
                quiet += 1;
                if quiet >= SYNC_QUIET_ROUNDS {
                    break;
                }
            } else {
                quiet = 0;
            }
        }
        Ok(())
    }
}

impl<T: Transport> DecentralizedRunner<T> {
    /// Fold this period's oracle trace events into the world (power
    /// peers on/off) and return the per-origin [`Message::MemberUpdate`]
    /// records the protocol will flood, plus the peers that power down
    /// *after* announcing (graceful leaves).
    fn originate_events(
        &mut self,
        trace: &EventTrace,
        ev_idx: &mut usize,
        t: f64,
    ) -> (Vec<(u32, Message)>, Vec<usize>, u64) {
        let mut origins: Vec<(u32, Message)> = Vec::new();
        let mut leavers: Vec<usize> = Vec::new();
        let mut applied = 0u64;
        while *ev_idx < trace.events.len()
            && trace.events[*ev_idx].time() <= t
        {
            let ev = trace.events[*ev_idx];
            self.membership.apply_trace_event(&ev);
            *ev_idx += 1;
            applied += 1;
            let subject = ev.node() as usize;
            match ev {
                MembershipEvent::Join { time, node } => {
                    self.obs.reg.incr("membership.joins", 1);
                    // The subject announces itself: apply locally
                    // (bumping the incarnation — the refutation rule),
                    // power on, flood the resulting record.
                    self.peers[subject].up = true;
                    self.peers[subject]
                        .membership
                        .apply_trace_event(&ev);
                    self.peers[subject].events_seen += 1;
                    let inc = self.peers[subject]
                        .membership
                        .get(node)
                        .map(|m| m.incarnation)
                        .unwrap_or(0);
                    origins.push((
                        node,
                        Message::MemberUpdate {
                            node,
                            state: MemberState::Alive,
                            incarnation: inc,
                            time,
                        },
                    ));
                }
                MembershipEvent::Leave { time, node } => {
                    self.obs.reg.incr("membership.leaves", 1);
                    if self.peers[subject].up {
                        // Graceful: announce, then power down after
                        // the flood phases.
                        self.peers[subject]
                            .membership
                            .apply_trace_event(&ev);
                        self.peers[subject].events_seen += 1;
                        let inc = self.peers[subject]
                            .membership
                            .get(node)
                            .map(|m| m.incarnation)
                            .unwrap_or(0);
                        origins.push((
                            node,
                            Message::MemberUpdate {
                                node,
                                state: MemberState::Left,
                                incarnation: inc,
                                time,
                            },
                        ));
                        leavers.push(subject);
                    } else if let Some((det, inc)) =
                        self.detector_for(node, time, MemberState::Left)
                    {
                        origins.push((
                            det,
                            Message::MemberUpdate {
                                node,
                                state: MemberState::Left,
                                incarnation: inc,
                                time,
                            },
                        ));
                    }
                }
                MembershipEvent::Crash { time, node } => {
                    self.obs.reg.incr("membership.crashes", 1);
                    // The subject cannot announce: the lowest-id live
                    // peer plays failure detector (SWIM stand-in).
                    self.peers[subject].up = false;
                    if let Some((det, inc)) = self.detector_for(
                        node,
                        time,
                        MemberState::Faulty,
                    ) {
                        origins.push((
                            det,
                            Message::MemberUpdate {
                                node,
                                state: MemberState::Faulty,
                                incarnation: inc,
                                time,
                            },
                        ));
                    }
                }
            }
        }
        (origins, leavers, applied)
    }

    /// The lowest-id live peer other than `subject` applies the
    /// detection locally and becomes the record's origin; returns
    /// `(detector, incarnation)` or `None` when nobody is left to
    /// detect.
    fn detector_for(
        &mut self,
        subject: u32,
        time: f64,
        state: MemberState,
    ) -> Option<(u32, u64)> {
        let det = self
            .peers
            .iter()
            .position(|p| p.up && p.id != subject)? as u32;
        let peer = &mut self.peers[det as usize];
        let inc = peer
            .membership
            .get(subject)
            .map(|m| m.incarnation)
            .unwrap_or(0);
        if peer.membership.apply(subject, state, inc, time) {
            peer.events_seen += 1;
        }
        Some((det, inc))
    }
}

impl<T: Transport> AdaptiveRunner for DecentralizedRunner<T> {
    fn kind(&self) -> &'static str {
        "decentralized"
    }

    /// The coordinator-free event loop: per period, originate and
    /// flood membership news, run the peer-local measurement, run the
    /// two-phase swap agreement, anti-entropy the ring views, then
    /// record the shared per-period series from the witness peer.
    /// Latency updates reshape the transport; a non-exact
    /// [`RunOptions::certify`] override is rejected.
    fn run_with(
        &mut self,
        trace: &EventTrace,
        horizon: f64,
        mut opts: RunOptions<'_>,
    ) -> Result<CoordinatorReport> {
        reject_non_exact_certify(self.kind(), opts.certify)?;
        if let Some(g) = opts.churn_guard {
            self.cfg.churn_guard = g;
        }
        if opts.record {
            self.obs.rec.set_enabled(true);
        }
        if opts.trace_sample > 0 {
            self.trace_sample = opts.trace_sample;
        }
        let mut latency_at = opts.take_latency();
        let mut observer = opts.observer;
        let initial_diameter =
            diameter::diameter(&self.witness_krings()?.to_graph(&self.w));
        let mut timeline = Vec::new();
        let frames_start = self.transport.frames_sent();
        let initial_swaps =
            self.hot.rings_swapped.load(Ordering::Relaxed);
        let mut swaps0 = initial_swaps;
        let mut t = 0.0;
        let mut ev_idx = 0usize;
        let mut period = 0u32;
        while t < horizon {
            t += self.cfg.adapt_period_ms;
            period += 1;
            if self.tracing() {
                self.trace = trace_id(self.cfg.seed, period as usize);
                self.span_period =
                    span_id(self.trace, "period", period as u64, 0);
            }
            let period_wall0 = std::time::Instant::now();
            let p_span = self
                .obs
                .rec
                .start("period", period as u64, self.transport.now_ms())
                .traced(self.trace, self.span_period, 0);
            if let Some(w) = latency_at(t) {
                if w.n() != self.w.n() {
                    bail!(
                        "latency update has {} nodes, overlay has {}",
                        w.n(),
                        self.w.n()
                    );
                }
                self.transport.set_latency(&w)?;
                self.max_w_ms = max_delay_ms(&w);
                self.w = w;
                self.obs.reg.incr("latency.updates", 1);
            }
            // Per-period protocol state resets.
            for p in &mut self.peers {
                p.granted = false;
                p.prop = None;
                p.events_seen = 0;
            }
            // Membership: originate this period's events and flood
            // them; origins re-send for PROBE_RETX extra epochs (the
            // per-phase dup filter makes re-sends idempotent, the
            // changed-guard quenches the forwarding).
            let (origins, leavers, applied) =
                self.originate_events(trace, &mut ev_idx, t);
            if !origins.is_empty() {
                for _round in 0..=PROBE_RETX {
                    self.begin_phase();
                    self.tctx = self.tracing().then_some(TraceCtx {
                        trace: self.trace,
                        parent: self.span_period,
                    });
                    for (src, msg) in &origins {
                        if !self.peers[*src as usize].up {
                            continue;
                        }
                        let targets = self.peers[*src as usize]
                            .overlay_neighbors();
                        for dst in targets {
                            self.send(*src, dst, msg)?;
                        }
                    }
                    self.tctx = None;
                    self.collect()?;
                }
            }
            for l in leavers {
                self.peers[l].up = false;
            }

            // Measure (peer-local ρ), then the swap agreement and the
            // ring anti-entropy pass.
            let m_span = self
                .obs
                .rec
                .start("measure", period as u64, self.transport.now_ms())
                .traced(
                    self.trace,
                    span_id(self.trace, "measure", period as u64, 0),
                    self.span_period,
                );
            let frames0 = self.transport.frames_sent();
            self.tctx = self.tracing().then_some(TraceCtx {
                trace: self.trace,
                parent: self.span_period,
            });
            self.measure_local()?;
            self.tctx = None;
            m_span.finish(&self.obs.rec, self.transport.now_ms());
            self.obs.reg.incr(
                "gossip.messages",
                self.transport.frames_sent() - frames0,
            );
            self.swap_phase(period)?;
            self.sync_rings()?;

            // Report from the witness peer, evaluated on the oracle
            // view — the shared per-period series.
            let kr = self.witness_krings()?;
            let d = diameter::diameter(&kr.to_graph(&self.w));
            let alive_cnt =
                self.membership.count_state(MemberState::Alive);
            let alive_d = if alive_cnt == self.membership.len() {
                d
            } else {
                diameter::diameter(&alive_overlay_graph(
                    &kr,
                    &self.w,
                    &self.membership,
                ))
            };
            let mut rho_sum = 0.0;
            let mut rho_cnt = 0usize;
            for p in &self.peers {
                if p.up && p.has_rho {
                    rho_sum += p.rho;
                    rho_cnt += 1;
                }
            }
            let rho = if rho_cnt > 0 {
                rho_sum / rho_cnt as f64
            } else {
                0.5
            };
            let swaps_now =
                self.hot.rings_swapped.load(Ordering::Relaxed);
            record_period(
                &mut self.metrics,
                d,
                rho,
                alive_cnt,
                alive_d,
                swaps_now - swaps0,
                applied,
            );
            swaps0 = swaps_now;
            timeline.push((t, rho, d));
            if let Some(f) = observer.as_mut() {
                let ga =
                    alive_overlay_graph(&kr, &self.w, &self.membership);
                let mut alive: Vec<u32> =
                    self.membership.alive().collect();
                alive.sort_unstable();
                f(t, &ga, &self.w, &alive);
            }
            self.hot
                .period_wall
                .observe(period_wall0.elapsed().as_secs_f64() * 1e3);
            p_span.finish(&self.obs.rec, self.transport.now_ms());
        }
        self.obs.reg.incr(
            "net.frames_sent",
            self.transport.frames_sent() - frames_start,
        );
        crate::obs::sync_counters(&self.obs.reg, &mut self.metrics);
        Ok(CoordinatorReport {
            final_diameter: timeline
                .last()
                .map(|&(_, _, d)| d)
                .unwrap_or(initial_diameter),
            initial_diameter,
            swaps: (swaps0 - initial_swaps) as usize,
            alive: self.membership.count_state(MemberState::Alive),
            timeline,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::eval::{CertifyConfig, CertifyMode};
    use crate::latency::Model;
    use crate::net::transport::SimTransport;

    fn cfg(nodes: usize, seed: u64) -> Config {
        Config {
            nodes,
            seed,
            k: 2,
            model: "fabric".to_string(),
            gossip_rounds: 8,
            adapt_period_ms: 250.0,
            ..Config::default()
        }
    }

    fn world(n: usize, seed: u64) -> LatencyMatrix {
        Model::Fabric.sample(n, &mut Rng::new(seed))
    }

    fn runner(
        n: usize,
        seed: u64,
    ) -> DecentralizedRunner<SimTransport> {
        let w = world(n, seed);
        let t = SimTransport::new(w.clone());
        DecentralizedRunner::new(cfg(n, seed), w, t).unwrap()
    }

    #[test]
    fn converges_to_shared_valid_rings_on_sim() {
        let mut co = runner(12, 11);
        let rep = co
            .run_with(&EventTrace::default(), 1000.0, RunOptions::new())
            .unwrap();
        assert_eq!(rep.timeline.len(), 4);
        assert_eq!(rep.alive, 12);
        // After quiescence every up peer holds identical, valid rings.
        let views = co.ring_views();
        let first = &views[0];
        for v in &views {
            assert_eq!(v, first, "ring views diverged");
        }
        for order in first {
            Ring::new(order.clone()).unwrap().validate().unwrap();
        }
    }

    #[test]
    fn byte_deterministic_on_sim() {
        let run = || {
            let mut co = runner(10, 42);
            let rep = co
                .run_with(
                    &EventTrace::default(),
                    1250.0,
                    RunOptions::new(),
                )
                .unwrap();
            (rep.timeline, rep.swaps, co.ring_views())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn crash_is_detected_and_flooded() {
        let mut co = runner(10, 5);
        let trace = EventTrace {
            events: vec![MembershipEvent::Crash { time: 300.0, node: 3 }],
        };
        let rep = co
            .run_with(&trace, 1000.0, RunOptions::new())
            .unwrap();
        assert_eq!(rep.alive, 9);
        assert!(!co.peers[3].up);
        // Every surviving peer learned of the crash via the flood.
        for p in co.peers.iter().filter(|p| p.up) {
            assert_eq!(
                p.membership.get(3).map(|m| m.state),
                Some(MemberState::Faulty),
                "peer {} missed the crash of node 3",
                p.id
            );
        }
    }

    #[test]
    fn rejects_non_exact_certify() {
        let mut co = runner(8, 1);
        let sketch = CertifyConfig {
            mode: CertifyMode::Sketch,
            ..CertifyConfig::exact()
        };
        let err = co
            .run_with(
                &EventTrace::default(),
                500.0,
                RunOptions::new().certify(sketch),
            )
            .unwrap_err();
        assert!(err.to_string().contains("decentralized"));
    }
}
