//! FABRIC-like latency model (paper §VII-A1, §VII-A3).
//!
//! The paper uses measured one-way latencies between 17 FABRIC sites
//! (14 US + 1 Japan + 2 Europe); each site spawns 1..58 nodes and
//! `latency(u, v) = latency(site_i, site_j) + latency(u) + latency(v)`
//! with per-node latencies ~ N(5, 1). The FABRIC measurement feed is not
//! reachable offline, so inter-site latencies are synthesized from the
//! real FABRIC site locations with the fiber-propagation model in
//! `geo.rs` (DESIGN.md §3). Structure preserved: 17 clusters, ~ms-scale
//! intra-site vs tens-of-ms transcontinental links, one trans-Pacific and
//! two trans-Atlantic outliers.

use super::geo;
use super::LatencyMatrix;
use crate::util::rng::Rng;

/// The 17 FABRIC sites: name, (lat, lon). 14 US + Tokyo + Amsterdam +
/// Geneva (CERN), matching the paper's description.
pub const SITES: [(&str, (f64, f64)); 17] = [
    ("STAR", (41.8960, -87.6190)),   // Chicago StarLight
    ("WASH", (38.9072, -77.0369)),   // Washington DC
    ("DALL", (32.7767, -96.7970)),   // Dallas
    ("SALT", (40.7608, -111.8910)),  // Salt Lake City
    ("UTAH", (40.7649, -111.8421)),  // University of Utah
    ("MICH", (42.2808, -83.7430)),   // Ann Arbor
    ("MASS", (42.3601, -71.0589)),   // Boston
    ("TACC", (30.2849, -97.7341)),   // Austin TACC
    ("NCSA", (40.1106, -88.2073)),   // Urbana-Champaign
    ("MAX",  (39.0840, -77.1528)),   // College Park MAX
    ("GATECH", (33.7756, -84.3963)), // Atlanta
    ("CLEM", (34.6834, -82.8374)),   // Clemson
    ("UCSD", (32.8801, -117.2340)),  // San Diego
    ("FIU",  (25.7574, -80.3733)),   // Miami FIU
    ("TOKY", (35.6762, 139.6503)),   // Tokyo
    ("AMST", (52.3676, 4.9041)),     // Amsterdam
    ("CERN", (46.2330, 6.0557)),     // Geneva
];

/// Number of physical sites.
pub const N_SITES: usize = SITES.len();

/// Per-node processing jitter: N(5, 1) ms, truncated positive (paper's
/// "individual latencies latency(u) ... normal distribution with a mean
/// of 5 and a standard deviation of 1").
fn node_latency(rng: &mut Rng) -> f64 {
    rng.gaussian(5.0, 1.0).max(0.1)
}

/// Inter-site one-way latency matrix (ms), synthesized from geography.
pub fn site_matrix() -> Vec<f64> {
    let mut m = vec![0.0f64; N_SITES * N_SITES];
    for i in 0..N_SITES {
        for j in (i + 1)..N_SITES {
            let l = geo::propagation_ms(SITES[i].1, SITES[j].1)
                // Small constant per-hop overhead (router/queueing floor).
                + 0.5;
            m[i * N_SITES + j] = l;
            m[j * N_SITES + i] = l;
        }
    }
    m
}

/// Assign `n` nodes round-robin over the 17 sites (paper: "each site
/// generates a varying number of nodes ranging from 1 to 58, resulting in
/// total node counts from 17 to 986"). Returns site index per node.
pub fn assign_sites(n: usize) -> Vec<usize> {
    (0..n).map(|i| i % N_SITES).collect()
}

/// Sample an n-node FABRIC latency matrix:
/// latency(u, v) = site(i, j) + nodelat(u) + nodelat(v).
pub fn sample(n: usize, rng: &mut Rng) -> LatencyMatrix {
    let sites = assign_sites(n);
    let sm = site_matrix();
    let nodelat: Vec<f64> = (0..n).map(|_| node_latency(rng)).collect();
    LatencyMatrix::from_fn(n, |u, v| {
        let s = sm[sites[u] * N_SITES + sites[v]];
        (s + nodelat[u] + nodelat[v]) as f32
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seventeen_sites() {
        assert_eq!(N_SITES, 17);
    }

    #[test]
    fn site_matrix_symmetric_positive() {
        let sm = site_matrix();
        for i in 0..N_SITES {
            assert_eq!(sm[i * N_SITES + i], 0.0);
            for j in 0..N_SITES {
                assert!((sm[i * N_SITES + j] - sm[j * N_SITES + i]).abs() < 1e-9);
                if i != j {
                    assert!(sm[i * N_SITES + j] > 0.0);
                }
            }
        }
    }

    #[test]
    fn transpacific_dominates_domestic() {
        let sm = site_matrix();
        let star = 0; // Chicago
        let toky = 14; // Tokyo
        let wash = 1; // DC
        assert!(
            sm[star * N_SITES + toky] > 3.0 * sm[star * N_SITES + wash],
            "trans-Pacific should be much slower than Chicago-DC"
        );
    }

    #[test]
    fn sample_is_valid_and_clustered() {
        let mut rng = Rng::new(42);
        let n = 68; // 4 nodes per site
        let m = sample(n, &mut rng);
        m.validate().unwrap();
        // Same-site pairs (sites repeat every 17) should be much cheaper
        // than Chicago-Tokyo pairs.
        let same_site = m.get(0, 17); // both at site 0
        let cross = m.get(0, 14); // site 0 vs Tokyo
        assert!(
            same_site < cross / 3.0,
            "intra-site {same_site} vs trans-Pacific {cross}"
        );
    }

    #[test]
    fn assign_round_robin_counts_balanced() {
        let s = assign_sites(35); // 35 = 2*17 + 1
        let count0 = s.iter().filter(|&&x| x == 0).count();
        let count16 = s.iter().filter(|&&x| x == 16).count();
        assert_eq!(count0, 3);
        assert_eq!(count16, 2);
    }
}
