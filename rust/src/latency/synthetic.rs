//! Synthetic latency distributions (paper §VII-A1):
//! X ~ Uniform{1..10} and Y ~ N(5, 1), i.i.d. per unordered pair.

use super::LatencyMatrix;
use crate::util::rng::Rng;

/// Uniform integer latencies from {1, 2, ..., 10} (the paper's set).
pub fn uniform(n: usize, rng: &mut Rng) -> LatencyMatrix {
    let mut m = LatencyMatrix::zeros(n);
    for u in 0..n {
        for v in (u + 1)..n {
            m.set(u, v, rng.range_i64(1, 10) as f32);
        }
    }
    m
}

/// Gaussian latencies N(5, 1), truncated below at a small positive floor
/// (latencies must stay positive; P(X <= 0.1) under N(5,1) is ~1e-6 so the
/// truncation is statistically invisible but keeps §III's model valid).
pub fn gaussian(n: usize, rng: &mut Rng) -> LatencyMatrix {
    gaussian_with(n, rng, 5.0, 1.0)
}

/// Gaussian with explicit mean/std (used by FABRIC's intra-site jitter).
pub fn gaussian_with(
    n: usize,
    rng: &mut Rng,
    mean: f64,
    std: f64,
) -> LatencyMatrix {
    let mut m = LatencyMatrix::zeros(n);
    for u in 0..n {
        for v in (u + 1)..n {
            let x = rng.gaussian(mean, std).max(0.1);
            m.set(u, v, x as f32);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_range_and_validity() {
        let mut rng = Rng::new(1);
        let m = uniform(20, &mut rng);
        m.validate().unwrap();
        for u in 0..20 {
            for v in 0..20 {
                if u != v {
                    let x = m.get(u, v);
                    assert!((1.0..=10.0).contains(&x));
                    assert_eq!(x.fract(), 0.0, "integer latencies");
                }
            }
        }
    }

    #[test]
    fn uniform_covers_support() {
        let mut rng = Rng::new(2);
        let m = uniform(40, &mut rng);
        let mut seen = [false; 11];
        for u in 0..40 {
            for v in (u + 1)..40 {
                seen[m.get(u, v) as usize] = true;
            }
        }
        for x in 1..=10 {
            assert!(seen[x], "value {x} never sampled");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(3);
        let m = gaussian(60, &mut rng);
        m.validate().unwrap();
        let mean = m.mean_offdiag();
        assert!((mean - 5.0).abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn gaussian_strictly_positive() {
        let mut rng = Rng::new(4);
        // Aggressive params to stress the floor.
        let m = gaussian_with(30, &mut rng, 0.5, 2.0);
        m.validate().unwrap();
    }
}
