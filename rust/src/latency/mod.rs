//! Latency models (paper §VII-A "Network settings").
//!
//! Four distributions drive every experiment:
//!   * Uniform{1..10}  — synthetic (paper: X ~ Uniform(1, 10))
//!   * Gaussian(5, 1)  — synthetic (paper: Y ~ N(5, 1))
//!   * FABRIC          — 17 research sites (14 US + Japan + 2 EU),
//!                       inter-site latency from geography, intra-site
//!                       jitter N(5, 1) per node, exactly §VII-A3
//!   * Bitnode         — ~global node population over 7 regions
//!
//! The realistic datasets are *synthesized* from real site coordinates
//! because the original measurement feeds (FABRIC monitoring, iPlane) are
//! not available offline — see DESIGN.md §3 for the substitution argument.

pub mod bitnode;
pub mod fabric;
pub mod geo;
pub mod matrix;
pub mod synthetic;

pub use matrix::LatencyMatrix;

use crate::util::rng::Rng;

/// Which latency model to draw a matrix from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Model {
    /// I.i.d. uniform link latencies (paper SS-VII synthetic).
    Uniform,
    /// Gaussian link latencies, clipped positive.
    Gaussian,
    /// FABRIC-testbed-like clustered latencies.
    Fabric,
    /// Bitnodes-derived geographic latencies.
    Bitnode,
}

impl Model {
    /// Parse a CLI model name.
    pub fn parse(s: &str) -> Option<Model> {
        match s.to_ascii_lowercase().as_str() {
            "uniform" => Some(Model::Uniform),
            "gaussian" | "normal" => Some(Model::Gaussian),
            "fabric" => Some(Model::Fabric),
            "bitnode" => Some(Model::Bitnode),
            _ => None,
        }
    }

    /// Stable CLI/display name.
    pub fn name(&self) -> &'static str {
        match self {
            Model::Uniform => "uniform",
            Model::Gaussian => "gaussian",
            Model::Fabric => "fabric",
            Model::Bitnode => "bitnode",
        }
    }

    /// Sample an `n`-node latency matrix from this model.
    pub fn sample(&self, n: usize, rng: &mut Rng) -> LatencyMatrix {
        match self {
            Model::Uniform => synthetic::uniform(n, rng),
            Model::Gaussian => synthetic::gaussian(n, rng),
            Model::Fabric => fabric::sample(n, rng),
            Model::Bitnode => bitnode::sample(n, rng),
        }
    }

    /// Every model, in CLI order.
    pub const ALL: [Model; 4] =
        [Model::Uniform, Model::Gaussian, Model::Fabric, Model::Bitnode];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        for m in Model::ALL {
            assert_eq!(Model::parse(m.name()), Some(m));
        }
        assert_eq!(Model::parse("nope"), None);
    }

    #[test]
    fn all_models_produce_valid_matrices() {
        let mut rng = Rng::new(5);
        for m in Model::ALL {
            let w = m.sample(24, &mut rng);
            w.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name()));
        }
    }
}
