//! Dense symmetric latency matrix — the `W` of the paper's system model:
//! `delta(u, v)` is a constant non-negative per-pair message latency.

use anyhow::{bail, Result};

/// Row-major symmetric `n x n` matrix with zero diagonal, f32 entries.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyMatrix {
    n: usize,
    w: Vec<f32>,
}

impl LatencyMatrix {
    /// An all-zero n x n matrix.
    pub fn zeros(n: usize) -> LatencyMatrix {
        LatencyMatrix {
            n,
            w: vec![0.0; n * n],
        }
    }

    /// Build from a function over (u, v); symmetrized by construction
    /// (f is evaluated once per unordered pair with u < v).
    pub fn from_fn(n: usize, f: impl Fn(usize, usize) -> f32) -> LatencyMatrix {
        let mut m = LatencyMatrix::zeros(n);
        for u in 0..n {
            for v in (u + 1)..n {
                let w = f(u, v);
                m.w[u * n + v] = w;
                m.w[v * n + u] = w;
            }
        }
        m
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    /// Latency between `u` and `v` (0 on the diagonal).
    pub fn get(&self, u: usize, v: usize) -> f32 {
        self.w[u * self.n + v]
    }

    #[inline]
    /// Set the symmetric latency between `u` and `v`.
    pub fn set(&mut self, u: usize, v: usize, w: f32) {
        self.w[u * self.n + v] = w;
        self.w[v * self.n + u] = w;
    }

    /// Row `u`: latencies from `u` to every node.
    pub fn row(&self, u: usize) -> &[f32] {
        &self.w[u * self.n..(u + 1) * self.n]
    }

    /// Raw row-major data (fed to the PJRT runtime as the W literal).
    pub fn data(&self) -> &[f32] {
        &self.w
    }

    /// Mean over ALL entries incl. the zero diagonal — this is the exact
    /// normalizer convention the Q-net was trained with
    /// (python model.default_wscale: N * mean(W)).
    pub fn wscale(&self) -> f32 {
        if self.n == 0 {
            return 1e-8;
        }
        let mean =
            self.w.iter().map(|&x| x as f64).sum::<f64>() / (self.w.len() as f64);
        (self.n as f64 * mean + 1e-8) as f64 as f32
    }

    /// Mean off-diagonal latency.
    pub fn mean_offdiag(&self) -> f32 {
        if self.n < 2 {
            return 0.0;
        }
        let sum: f64 = self.w.iter().map(|&x| x as f64).sum();
        (sum / (self.n * (self.n - 1)) as f64) as f32
    }

    /// Minimum off-diagonal latency.
    pub fn min_offdiag(&self) -> f32 {
        let mut best = f32::INFINITY;
        for u in 0..self.n {
            for v in 0..self.n {
                if u != v {
                    best = best.min(self.get(u, v));
                }
            }
        }
        best
    }

    /// Check the §III invariants: square, symmetric, zero diagonal,
    /// non-negative finite entries, strictly positive off-diagonal.
    pub fn validate(&self) -> Result<()> {
        if self.w.len() != self.n * self.n {
            bail!("storage size mismatch");
        }
        for u in 0..self.n {
            if self.get(u, u) != 0.0 {
                bail!("nonzero diagonal at {u}");
            }
            for v in 0..self.n {
                let x = self.get(u, v);
                if !x.is_finite() || x < 0.0 {
                    bail!("invalid latency {x} at ({u},{v})");
                }
                if u != v && x <= 0.0 {
                    bail!("non-positive off-diagonal at ({u},{v})");
                }
                if (x - self.get(v, u)).abs() > 1e-6 {
                    bail!("asymmetric at ({u},{v})");
                }
            }
        }
        Ok(())
    }

    /// Copy into a zero-padded `npad x npad` buffer (bucket padding for
    /// the PJRT path; pad rows/cols stay zero by construction).
    pub fn padded_data(&self, npad: usize) -> Vec<f32> {
        assert!(npad >= self.n);
        let mut out = vec![0.0f32; npad * npad];
        for u in 0..self.n {
            out[u * npad..u * npad + self.n]
                .copy_from_slice(self.row(u));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_fn_symmetric() {
        let m = LatencyMatrix::from_fn(4, |u, v| (u + v) as f32);
        m.validate().unwrap();
        assert_eq!(m.get(1, 3), 4.0);
        assert_eq!(m.get(3, 1), 4.0);
        assert_eq!(m.get(2, 2), 0.0);
    }

    #[test]
    fn validate_catches_asymmetry() {
        let mut m = LatencyMatrix::from_fn(3, |_, _| 1.0);
        m.w[1] = 9.0; // (0,1) only
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_catches_zero_offdiag() {
        let m = LatencyMatrix::zeros(3);
        assert!(m.validate().is_err());
    }

    #[test]
    fn wscale_matches_python_convention() {
        // N=2, entries [[0, 3], [3, 0]]: mean = 6/4 = 1.5, scale = 3.0.
        let m = LatencyMatrix::from_fn(2, |_, _| 3.0);
        assert!((m.wscale() - 3.0).abs() < 1e-5);
    }

    #[test]
    fn offdiag_stats() {
        let m = LatencyMatrix::from_fn(3, |u, v| (u + v) as f32);
        // off-diag entries (unordered): 1, 2, 3 -> mean 2, min 1.
        assert!((m.mean_offdiag() - 2.0).abs() < 1e-6);
        assert_eq!(m.min_offdiag(), 1.0);
    }

    #[test]
    fn padding_zero_fills() {
        let m = LatencyMatrix::from_fn(2, |_, _| 2.0);
        let p = m.padded_data(4);
        assert_eq!(p.len(), 16);
        assert_eq!(p[0 * 4 + 1], 2.0);
        assert_eq!(p[1 * 4 + 0], 2.0);
        assert_eq!(p[2 * 4 + 2], 0.0);
        assert_eq!(p[0 * 4 + 3], 0.0);
    }
}
