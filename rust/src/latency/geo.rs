//! Geographic latency primitives shared by the FABRIC and Bitnode models.
//!
//! One-way network latency between sites is modeled as
//!   latency = distance / (2/3 c) * route_inflation + per-endpoint access
//! where 2/3 c is signal speed in fiber and route_inflation ~1.6 accounts
//! for non-great-circle routing (standard practice in network-geography
//! literature; see DESIGN.md §3 on why this substitution preserves the
//! paper-relevant structure: multi-modal clusters of close/far latencies).

/// Degrees -> radians.
fn rad(deg: f64) -> f64 {
    deg * std::f64::consts::PI / 180.0
}

/// Great-circle distance in kilometers between two (lat, lon) points.
pub fn haversine_km(a: (f64, f64), b: (f64, f64)) -> f64 {
    const R: f64 = 6371.0; // mean Earth radius, km
    let (lat1, lon1) = (rad(a.0), rad(a.1));
    let (lat2, lon2) = (rad(b.0), rad(b.1));
    let dlat = lat2 - lat1;
    let dlon = lon2 - lon1;
    let h = (dlat / 2.0).sin().powi(2)
        + lat1.cos() * lat2.cos() * (dlon / 2.0).sin().powi(2);
    2.0 * R * h.sqrt().asin()
}

/// Speed of light in fiber: ~200,000 km/s -> 0.2 km per microsecond.
const FIBER_KM_PER_MS: f64 = 200.0;

/// Typical route inflation over great-circle distance.
pub const ROUTE_INFLATION: f64 = 1.6;

/// One-way propagation latency in milliseconds between two coordinates.
pub fn propagation_ms(a: (f64, f64), b: (f64, f64)) -> f64 {
    haversine_km(a, b) / FIBER_KM_PER_MS * ROUTE_INFLATION
}

#[cfg(test)]
mod tests {
    use super::*;

    const CHICAGO: (f64, f64) = (41.88, -87.63);
    const NYC: (f64, f64) = (40.71, -74.01);
    const TOKYO: (f64, f64) = (35.68, 139.69);

    #[test]
    fn haversine_known_distances() {
        // Chicago <-> NYC is ~1145 km.
        let d = haversine_km(CHICAGO, NYC);
        assert!((d - 1145.0).abs() < 30.0, "got {d}");
        // Symmetry and identity.
        assert!((haversine_km(NYC, CHICAGO) - d).abs() < 1e-9);
        assert_eq!(haversine_km(NYC, NYC), 0.0);
    }

    #[test]
    fn propagation_scales_with_distance() {
        let near = propagation_ms(CHICAGO, NYC);
        let far = propagation_ms(CHICAGO, TOKYO);
        assert!(near > 5.0 && near < 15.0, "Chicago-NYC {near} ms");
        assert!(far > 60.0 && far < 120.0, "Chicago-Tokyo {far} ms");
        assert!(far > 4.0 * near);
    }
}
