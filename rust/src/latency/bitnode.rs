//! Bitnode-like latency model (paper §VII-A1).
//!
//! The paper samples 1000 of 9,408 Bitcoin nodes spread over seven
//! geographic regions (North America, South America, Europe, Asia,
//! Africa, China, Oceania) and derives pairwise latency from the iPlane
//! measurement dataset. Offline substitution (DESIGN.md §3): nodes are
//! sampled from region population weights matching the public Bitnodes
//! distribution, placed with intra-region geographic scatter around the
//! region centroid, and pairwise latency = fiber propagation + per-node
//! access latency. This reproduces the paper-relevant structure: a
//! heavy-tailed multi-modal latency distribution with tight intra-region
//! clusters and 100ms+ inter-continental links.

use super::geo;
use super::LatencyMatrix;
use crate::util::rng::Rng;

/// Region: name, centroid (lat, lon), geographic scatter (degrees),
/// sampling weight (approximate Bitnodes share).
pub struct Region {
    /// Region label (continent-scale cluster).
    pub name: &'static str,
    /// Cluster center in abstract latency-space coordinates.
    pub center: (f64, f64),
    /// Intra-region scatter (spread of node placements).
    pub scatter: f64,
    /// Sampling weight (share of nodes placed here).
    pub weight: f64,
}

/// The Bitnodes-derived region mix.
pub const REGIONS: [Region; 7] = [
    Region { name: "north_america", center: (39.5, -98.4), scatter: 8.0, weight: 0.30 },
    Region { name: "europe", center: (50.1, 9.2), scatter: 6.0, weight: 0.38 },
    Region { name: "asia", center: (28.6, 96.1), scatter: 9.0, weight: 0.12 },
    Region { name: "china", center: (34.7, 109.0), scatter: 5.0, weight: 0.08 },
    Region { name: "south_america", center: (-14.2, -55.5), scatter: 7.0, weight: 0.05 },
    Region { name: "oceania", center: (-31.0, 140.0), scatter: 5.0, weight: 0.04 },
    Region { name: "africa", center: (2.8, 21.0), scatter: 7.0, weight: 0.03 },
];

/// Per-node access-network latency (last-mile + peering), ms. Log-normal
/// flavored: most nodes a few ms, a tail of poorly connected ones.
fn access_ms(rng: &mut Rng) -> f64 {
    let z = rng.normal();
    (2.0 + (0.8 * z).exp()).min(50.0)
}

/// A sampled node placement.
pub struct Placement {
    /// Index into [`REGIONS`].
    pub region: usize,
    /// Sampled position in latency space.
    pub coords: (f64, f64),
    /// Last-mile access latency added to every link of this node.
    pub access: f64,
}

/// Sample `n` node placements according to region weights.
pub fn place_nodes(n: usize, rng: &mut Rng) -> Vec<Placement> {
    let total: f64 = REGIONS.iter().map(|r| r.weight).sum();
    (0..n)
        .map(|_| {
            let mut x = rng.f64() * total;
            let mut region = REGIONS.len() - 1;
            for (i, r) in REGIONS.iter().enumerate() {
                if x < r.weight {
                    region = i;
                    break;
                }
                x -= r.weight;
            }
            let r = &REGIONS[region];
            let lat = (r.center.0 + rng.normal() * r.scatter).clamp(-65.0, 70.0);
            let lon = r.center.1 + rng.normal() * r.scatter;
            Placement {
                region,
                coords: (lat, lon),
                access: access_ms(rng),
            }
        })
        .collect()
}

/// Sample an n-node Bitnode latency matrix.
pub fn sample(n: usize, rng: &mut Rng) -> LatencyMatrix {
    let nodes = place_nodes(n, rng);
    LatencyMatrix::from_fn(n, |u, v| {
        let prop = geo::propagation_ms(nodes[u].coords, nodes[v].coords);
        (prop + nodes[u].access + nodes[v].access).max(0.2) as f32
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_sum_to_one() {
        let total: f64 = REGIONS.iter().map(|r| r.weight).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn placement_respects_weights() {
        let mut rng = Rng::new(1);
        let nodes = place_nodes(4000, &mut rng);
        let na = nodes.iter().filter(|p| p.region == 0).count() as f64 / 4000.0;
        let eu = nodes.iter().filter(|p| p.region == 1).count() as f64 / 4000.0;
        assert!((na - 0.30).abs() < 0.04, "NA share {na}");
        assert!((eu - 0.38).abs() < 0.04, "EU share {eu}");
    }

    #[test]
    fn sample_valid_and_multimodal() {
        let mut rng = Rng::new(2);
        let m = sample(120, &mut rng);
        m.validate().unwrap();
        // The latency distribution must be multi-modal: some pairs far
        // below the mean (intra-region) and some far above
        // (inter-continental).
        let mean = m.mean_offdiag();
        let mut below = 0;
        let mut above = 0;
        for u in 0..120 {
            for v in (u + 1)..120 {
                let x = m.get(u, v);
                if x < 0.4 * mean {
                    below += 1;
                }
                if x > 1.8 * mean {
                    above += 1;
                }
            }
        }
        assert!(below > 50, "want intra-region cluster, got {below}");
        assert!(above > 50, "want intercontinental tail, got {above}");
    }

    #[test]
    fn access_latency_bounded() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let a = access_ms(&mut rng);
            assert!(a >= 2.0 && a <= 50.0);
        }
    }
}
