//! Algorithm 3 — Gossip-based Latency Measurement (paper §V).
//!
//! Each node u samples K of its overlay neighbors (L_local) and K random
//! nodes from the whole network (L_global, L_min = min of the global
//! samples), then the per-node triples are averaged across the network
//! by gossip rounds: every round a node pushes its accumulated triple to
//! a random neighbor; message counts normalize the sums. After T rounds
//! each node holds (L̄_local, L̄_global, L̄_min) estimates; we return the
//! network-wide view (and the exact averages for tests).

use crate::graph::Graph;
use crate::latency::LatencyMatrix;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
/// Knobs of Algorithm 3.
pub struct MeasureConfig {
    /// Samples per node (the paper's K).
    pub samples: usize,
    /// Gossip rounds before reading the averages (the paper's period T).
    pub rounds: usize,
}

impl Default for MeasureConfig {
    fn default() -> Self {
        MeasureConfig {
            samples: 4,
            rounds: 20,
        }
    }
}

/// Result of Algorithm 3.
#[derive(Clone, Copy, Debug)]
pub struct GossipStats {
    /// Network average of per-node mean latency to sampled *neighbors*.
    pub local: f64,
    /// Network average of per-node mean latency to random nodes.
    pub global: f64,
    /// Network average of per-node minimum sampled global latency.
    pub min: f64,
    /// Gossip messages exchanged (cost accounting).
    pub messages: usize,
}

impl GossipStats {
    /// The §V ratio ρ = (L̄_local − L̄_min) / (L̄_global − L̄_min),
    /// clamped to [0, 1]. ρ→0: neighbors are as close as the closest
    /// nodes (clustered); ρ→1: neighbors look like random picks
    /// (dispersed).
    pub fn rho(&self) -> f64 {
        let denom = self.global - self.min;
        if denom <= 1e-12 {
            return 0.5; // degenerate metric: treat as balanced
        }
        ((self.local - self.min) / denom).clamp(0.0, 1.0)
    }
}

/// Run Algorithm 3 over overlay `g` with physical latencies `w`.
pub fn measure(
    w: &LatencyMatrix,
    g: &Graph,
    cfg: MeasureConfig,
    rng: &mut Rng,
) -> GossipStats {
    let n = g.n();
    assert_eq!(w.n(), n);
    assert!(n >= 2);
    let k = cfg.samples.max(1);

    // Phase 1: per-node sampling (lines 4-10).
    let mut local = vec![0.0f64; n];
    let mut global = vec![0.0f64; n];
    let mut min = vec![0.0f64; n];
    let mut has_local = vec![true; n];
    for u in 0..n {
        let neigh = g.neighbors(u);
        if neigh.is_empty() {
            // Isolated node: it has no neighbor latency to sample, so it
            // contributes nothing to the local average (tracked by a
            // separate push-sum weight below — without it every isolated
            // node would drag L̄_local toward 0 and bias ρ low whenever
            // part of the membership is down).
            local[u] = 0.0;
            has_local[u] = false;
        } else {
            let mut acc = 0.0;
            for _ in 0..k {
                let (_, lw) = neigh[rng.index(neigh.len())];
                acc += lw as f64;
            }
            local[u] = acc / k as f64;
        }
        let mut acc = 0.0;
        let mut m = f64::INFINITY;
        for _ in 0..k {
            let v = loop {
                let v = rng.index(n);
                if v != u {
                    break v;
                }
            };
            let lw = w.get(u, v) as f64;
            acc += lw;
            m = m.min(lw);
        }
        global[u] = acc / k as f64;
        min[u] = m;
    }

    // Phase 2: gossip aggregation (lines 11-19). Push-based averaging:
    // each node repeatedly pushes its current (sum, count) accumulator
    // to a random neighbor; the receiver merges. After T rounds every
    // accumulator approximates the network average.
    #[derive(Clone, Copy)]
    struct Acc {
        local: f64,
        global: f64,
        min: f64,
        m: f64,  // node-count weight
        ml: f64, // weight of nodes that contributed a local sample
    }
    let mut acc: Vec<Acc> = (0..n)
        .map(|u| Acc {
            local: local[u],
            global: global[u],
            min: min[u],
            m: 1.0,
            ml: if has_local[u] { 1.0 } else { 0.0 },
        })
        .collect();
    let mut messages = 0usize;
    for _ in 0..cfg.rounds {
        for u in 0..n {
            let neigh = g.neighbors(u);
            if neigh.is_empty() {
                continue;
            }
            let (v, _) = neigh[rng.index(neigh.len())];
            let v = v as usize;
            // Push half of u's mass to v (push-sum style, keeps totals
            // conserved so the global average is exact in the limit).
            let half = Acc {
                local: acc[u].local / 2.0,
                global: acc[u].global / 2.0,
                min: acc[u].min / 2.0,
                m: acc[u].m / 2.0,
                ml: acc[u].ml / 2.0,
            };
            acc[u] = half;
            acc[v].local += half.local;
            acc[v].global += half.global;
            acc[v].min += half.min;
            acc[v].m += half.m;
            acc[v].ml += half.ml;
            messages += 1;
        }
    }

    // Read out: average the per-node ratio estimates (lines 20-24). The
    // local average uses its own weight (`ml`) so isolated nodes, which
    // contributed no local sample, do not dilute it; on graphs without
    // isolated nodes ml == m and the result is bit-identical.
    let mut l = 0.0;
    let mut cnt_l = 0usize;
    let mut gl = 0.0;
    let mut mn = 0.0;
    let mut cnt = 0usize;
    for a in &acc {
        if a.m > 1e-9 {
            gl += a.global / a.m;
            mn += a.min / a.m;
            cnt += 1;
        }
        if a.ml > 1e-9 {
            l += a.local / a.ml;
            cnt_l += 1;
        }
    }
    GossipStats {
        local: l / cnt_l.max(1) as f64,
        global: gl / cnt.max(1) as f64,
        min: mn / cnt.max(1) as f64,
        messages,
    }
}

/// Exact (non-gossip) versions of the three statistics, for tests and
/// for the centralized coordinator path.
pub fn exact_stats(w: &LatencyMatrix, g: &Graph) -> GossipStats {
    let n = g.n();
    let mut local = 0.0;
    let mut cnt_local = 0usize;
    for u in 0..n {
        for &(_, lw) in g.neighbors(u) {
            local += lw as f64;
            cnt_local += 1;
        }
    }
    let local = if cnt_local == 0 {
        0.0
    } else {
        local / cnt_local as f64
    };
    let global = w.mean_offdiag() as f64;
    // Expected per-node min over K=4 samples is approximated by the true
    // row minimum average (the asymptotic target as K grows).
    let mut min_sum = 0.0;
    for u in 0..n {
        let m = (0..n)
            .filter(|&v| v != u)
            .map(|v| w.get(u, v))
            .fold(f32::INFINITY, f32::min);
        min_sum += m as f64;
    }
    GossipStats {
        local,
        global,
        min: min_sum / n as f64,
        messages: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::{fabric, synthetic};
    use crate::topology::{random_ring, shortest_ring};

    #[test]
    fn gossip_estimates_converge_to_exact() {
        let mut rng = Rng::new(1);
        let w = synthetic::uniform(60, &mut rng);
        let ring = random_ring(60, &mut rng);
        let g = ring.to_graph(&w);
        let cfg = MeasureConfig {
            samples: 16,
            rounds: 60,
        };
        let est = measure(&w, &g, cfg, &mut rng);
        let exact = exact_stats(&w, &g);
        assert!(
            (est.global - exact.global).abs() / exact.global < 0.25,
            "global {} vs {}",
            est.global,
            exact.global
        );
        assert!(
            (est.local - exact.local).abs() / exact.local < 0.25,
            "local {} vs {}",
            est.local,
            exact.local
        );
        assert!(est.messages > 0);
    }

    #[test]
    fn rho_near_one_for_random_ring() {
        // Random ring neighbors are random picks: local ≈ global, ρ → 1.
        let mut rng = Rng::new(2);
        let w = fabric::sample(85, &mut rng);
        let g = random_ring(85, &mut rng).to_graph(&w);
        let stats = measure(&w, &g, MeasureConfig::default(), &mut rng);
        assert!(stats.rho() > 0.6, "rho {} should be high", stats.rho());
    }

    #[test]
    fn rho_near_zero_for_shortest_ring() {
        // NN-ring neighbors are nearly the closest nodes: ρ → 0.
        let mut rng = Rng::new(3);
        let w = fabric::sample(85, &mut rng);
        let g = shortest_ring(&w, 0).to_graph(&w);
        let stats = measure(&w, &g, MeasureConfig::default(), &mut rng);
        assert!(stats.rho() < 0.4, "rho {} should be low", stats.rho());
    }

    #[test]
    fn rho_orders_topologies() {
        // The statistic must rank shortest < hybrid < random even when
        // individual estimates are noisy.
        let mut rng = Rng::new(4);
        let w = fabric::sample(51, &mut rng);
        let g_short = shortest_ring(&w, 0).to_graph(&w);
        let g_rand = random_ring(51, &mut rng).to_graph(&w);
        let r_short =
            measure(&w, &g_short, MeasureConfig::default(), &mut rng).rho();
        let r_rand =
            measure(&w, &g_rand, MeasureConfig::default(), &mut rng).rho();
        assert!(r_short < r_rand, "{r_short} !< {r_rand}");
    }

    #[test]
    fn isolated_nodes_do_not_dilute_the_local_average() {
        // Half the membership is down: the local estimate must reflect
        // the live ring, not be dragged toward zero by isolated nodes
        // (the scenario engine measures alive sub-overlays like this).
        let mut rng = Rng::new(6);
        let w = synthetic::uniform(40, &mut rng);
        let mut g = crate::graph::Graph::empty(40);
        for i in 0..20usize {
            let j = (i + 1) % 20;
            g.add_edge(i, j, w.get(i, j));
        }
        let stats = measure(
            &w,
            &g,
            MeasureConfig {
                samples: 8,
                rounds: 40,
            },
            &mut rng,
        );
        // exact_stats averages over adjacency entries only, i.e. the
        // live ring — the gossiped value must track it, not half of it.
        let exact = exact_stats(&w, &g);
        assert!(
            (stats.local - exact.local).abs() / exact.local < 0.35,
            "local {} vs exact {}",
            stats.local,
            exact.local
        );
    }

    #[test]
    fn degenerate_uniform_metric_gives_balanced_rho() {
        let w = LatencyMatrix::from_fn(10, |_, _| 5.0);
        let mut rng = Rng::new(5);
        let g = random_ring(10, &mut rng).to_graph(&w);
        let stats = measure(&w, &g, MeasureConfig::default(), &mut rng);
        // local == global == min -> denominator ~ 0 -> 0.5 sentinel.
        assert!((stats.rho() - 0.5).abs() < 0.5);
    }

    use crate::latency::LatencyMatrix;
}
