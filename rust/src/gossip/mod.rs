//! Gossip substrate: Algorithm 3's decentralized latency measurement and
//! the round-based aggregation it relies on.

pub mod measure;

pub use measure::{measure, GossipStats, MeasureConfig};
