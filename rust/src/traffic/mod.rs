//! Service-traffic plane: simulated application requests routed *over*
//! the overlay the coordinator maintains.
//!
//! The paper optimizes overlay **diameter**, but what a member of the
//! integrated research infrastructure actually feels is end-to-end
//! request latency: a request enters at some node, greedily hops the
//! ring/chord/anchor edges toward its destination, queues for service
//! capacity, and either completes or times out and retries. Papillon
//! (PAPERS.md) makes the case sharply — a low-diameter ring that greedy
//! routing cannot exploit is a worse product — so this module measures
//! the *routable* quality of every topology the scenario engine knows:
//!
//! * [`route`] — greedy next-hop routing over the alive overlay: each
//!   node forwards to the live neighbor (ring successors + K-ring
//!   chords + shard anchors) closest to the destination in the latency
//!   metric, delivering directly when the destination itself is a
//!   neighbor. A visited-set guarantees termination within `n` hops.
//! * [`workload`] — a seeded open-loop generator: `rate` requests per
//!   sim-second (10^5–10^6 in scaled sim time), sources uniform over
//!   the alive list, destinations cycling round-robin pools.
//! * [`sim`] — per-node FIFO service capacity, session timeouts with
//!   bounded retries, and the [`sim::TrafficReport`]: p50/p99
//!   end-to-end latency, success rate, per-node load, and the
//!   Papillon-style greedy-routing **stretch** (greedy path latency ÷
//!   shortest-path latency) reported next to diameter.
//!
//! Everything is a pure function of `(overlay timeline, seed, config)`:
//! reports are byte-identical across repeated runs and across worker
//! thread counts (`rust/tests/traffic.rs` pins T ∈ {1,2,8}, including
//! under `LossyTransport`), and the routing invariants — termination,
//! never visiting a dead node, stretch ≥ 1 — are property-tested on
//! arbitrary connected overlays with shrinking
//! (`rust/tests/proptests.rs`).
//!
//! Entry points: [`ScenarioEngine::run_traffic`] drives a scenario and
//! feeds each period's alive overlay to a [`sim::TrafficSim`];
//! `dgro traffic run|compare` is the CLI face; `scenario::compare`
//! grows stretch/p99 columns when traffic is enabled.
//!
//! [`ScenarioEngine::run_traffic`]: crate::scenario::ScenarioEngine::run_traffic

use anyhow::{bail, Result};

use crate::graph::Graph;
use crate::latency::LatencyMatrix;

pub mod route;
pub mod sim;
pub mod workload;

pub use route::{greedy_route, RouteScratch, RouteSummary};
pub use sim::{RequestTrace, TrafficPeriod, TrafficReport, TrafficSim};
pub use workload::{DestPools, Request};

/// Per-period overlay observer threaded through the coordinator event
/// loops: `(sim_time_ms, alive_overlay, latency_matrix, sorted_alive)`.
/// The graph is the alive sub-overlay (faulty nodes do not relay) with
/// edges weighted by the *current* latency view; `sorted_alive` lists
/// the alive node ids ascending.
pub type OverlayObserver<'a> =
    &'a mut dyn FnMut(f64, &Graph, &LatencyMatrix, &[u32]);

/// Knobs of the traffic plane: workload intensity, per-node service
/// capacity, session timeout/retry policy, and stretch sampling.
/// `Default` models a moderately loaded fabric (2·10^5 req/s across
/// the cluster — the middle of the 10^5–10^6 design band).
#[derive(Clone, Copy, Debug)]
pub struct TrafficConfig {
    /// Open-loop arrival rate, requests per sim-second across the
    /// whole cluster (scaled sim time).
    pub rate: f64,
    /// Per-node service capacity, requests per sim-second (service
    /// time is its reciprocal; FIFO queue in front).
    pub capacity: f64,
    /// Session timeout, sim-ms: a request whose queue wait would
    /// exceed this aborts and retries on the next pool destination.
    pub timeout_ms: f64,
    /// Bounded retries per session (0 = fail on first timeout).
    pub retries: u32,
    /// Round-robin destination-pool size per source node.
    pub pool: usize,
    /// Sampled requests per period for the stretch metric (each sample
    /// costs one Dijkstra on the alive overlay).
    pub stretch_samples: usize,
    /// Extra seed mixed into the workload stream (the scenario seed is
    /// mixed in too, so the same scenario at two seeds differs).
    pub seed: u64,
    /// Per-request hop-trace sampling stride: 0 = no request traces;
    /// `s ≥ 1` records the full attempt history (queue wait, per-hop
    /// latencies, outcome) of every request whose id is a multiple of
    /// `s`, exported as `traces.jsonl`.
    pub trace_sample: usize,
}

impl Default for TrafficConfig {
    fn default() -> TrafficConfig {
        TrafficConfig {
            rate: 200_000.0,
            capacity: 8_000.0,
            timeout_ms: 40.0,
            retries: 2,
            pool: 4,
            stretch_samples: 8,
            seed: 0,
            trace_sample: 0,
        }
    }
}

impl TrafficConfig {
    /// Reject non-physical configurations with a CLI-grade message.
    pub fn validate(&self) -> Result<()> {
        if !(self.rate > 0.0) || !self.rate.is_finite() {
            bail!("--rate must be a positive req/s, got {}", self.rate);
        }
        if !(self.capacity > 0.0) || !self.capacity.is_finite() {
            bail!(
                "--capacity must be a positive req/s per node, got {}",
                self.capacity
            );
        }
        if !(self.timeout_ms > 0.0) || !self.timeout_ms.is_finite() {
            bail!(
                "--timeout-ms must be positive, got {}",
                self.timeout_ms
            );
        }
        if self.pool == 0 {
            bail!("--pool must be at least 1");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_validates() {
        TrafficConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_configs_are_rejected() {
        let mut c = TrafficConfig::default();
        c.rate = 0.0;
        assert!(c.validate().is_err());
        let mut c = TrafficConfig::default();
        c.capacity = -1.0;
        assert!(c.validate().is_err());
        let mut c = TrafficConfig::default();
        c.timeout_ms = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = TrafficConfig::default();
        c.pool = 0;
        assert!(c.validate().is_err());
    }
}
