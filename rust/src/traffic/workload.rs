//! Seeded open-loop workload generation.
//!
//! Each period the generator emits `rate × window` requests, evenly
//! spaced over the window (open loop: arrivals never wait for earlier
//! requests to finish — overload shows up as queueing and timeouts,
//! not as back-pressure on the generator). Sources are drawn uniformly
//! from the alive list with a dedicated RNG stream; destinations cycle
//! each source's **round-robin pool** — a deterministic spread of pool
//! slots over the alive list, so two requests from the same source hit
//! different services while the mapping stays a pure function of
//! `(source, counter, alive list)`. Determinism across thread counts
//! is trivial here: generation is serial and routing (the only
//! parallel stage) consumes requests in input order.

use crate::util::rng::Rng;

/// One simulated application request (or retry attempt).
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Generation time of the *original* attempt, sim-ms (end-to-end
    /// latency is measured from here, so retries pay for the timeouts
    /// that preceded them).
    pub t0: f64,
    /// Generation time of this attempt, sim-ms.
    pub t_gen: f64,
    /// Source node (alive at generation time).
    pub src: u32,
    /// Destination node (alive at generation time).
    pub dst: u32,
    /// Attempt index (0 = first try).
    pub attempt: u32,
}

/// Round-robin destination pools: each source cycles through `pool`
/// deterministic slots spread over the alive list. Counters persist
/// across periods so the rotation continues where it left off.
pub struct DestPools {
    counters: Vec<u64>,
    pool: usize,
}

impl DestPools {
    /// Pools for a universe of `n` source nodes, `pool` slots each.
    pub fn new(n: usize, pool: usize) -> DestPools {
        DestPools {
            counters: vec![0; n],
            pool: pool.max(1),
        }
    }

    /// Next destination for `src` given the current sorted alive list
    /// (requires `alive.len() >= 2`; never returns `src` itself).
    pub fn next(&mut self, src: u32, alive: &[u32]) -> u32 {
        let m = alive.len() as u64;
        debug_assert!(m >= 2, "need at least two alive nodes");
        let k = self.counters[src as usize];
        self.counters[src as usize] += 1;
        // Source-keyed base offset + stride per pool slot: pools of
        // different sources land on different services, pools of one
        // source spread across the alive list.
        let h = u64::from(src).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 11;
        let stride = (m / self.pool as u64).max(1);
        let slot = k % self.pool as u64;
        let mut idx = ((h + slot * stride) % m) as usize;
        if alive[idx] == src {
            idx = (idx + 1) % alive.len();
        }
        alive[idx]
    }
}

/// Generate the open-loop arrivals for one period window
/// `(t_prev, t]`: `rate` requests per sim-second, evenly spaced.
/// Returns an empty batch when fewer than two nodes are alive (no
/// valid destination exists).
pub fn generate(
    rate: f64,
    t_prev: f64,
    t: f64,
    alive: &[u32],
    pools: &mut DestPools,
    rng: &mut Rng,
) -> Vec<Request> {
    let window = (t - t_prev).max(0.0);
    let count = (rate * window / 1000.0).round() as usize;
    if alive.len() < 2 || count == 0 {
        return Vec::new();
    }
    let dt = window / (count as f64 + 1.0);
    let mut reqs = Vec::with_capacity(count);
    for i in 0..count {
        let src = alive[rng.index(alive.len())];
        let dst = pools.next(src, alive);
        let t_gen = t_prev + dt * (i as f64 + 1.0);
        reqs.push(Request {
            t0: t_gen,
            t_gen,
            src,
            dst,
            attempt: 0,
        });
    }
    reqs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pools_never_return_the_source_and_do_cycle() {
        let alive: Vec<u32> = (0..10).collect();
        let mut pools = DestPools::new(10, 3);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..9 {
            let d = pools.next(4, &alive);
            assert_ne!(d, 4);
            seen.insert(d);
        }
        // A pool of 3 slots cycles through (up to) 3 destinations.
        assert!(seen.len() <= 3 && seen.len() >= 2, "{seen:?}");
    }

    #[test]
    fn generation_is_deterministic_and_in_window() {
        let alive: Vec<u32> = (0..8).collect();
        let gen = |seed| {
            let mut rng = Rng::new(seed);
            let mut pools = DestPools::new(8, 4);
            generate(20_000.0, 250.0, 500.0, &alive, &mut pools, &mut rng)
        };
        let a = gen(7);
        let b = gen(7);
        assert_eq!(a.len(), 5_000); // 20k/s × 250 ms
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.src, y.src);
            assert_eq!(x.dst, y.dst);
            assert_eq!(x.t_gen.to_bits(), y.t_gen.to_bits());
            assert!(x.t_gen > 250.0 && x.t_gen < 500.0);
            assert_ne!(x.src, x.dst);
        }
    }

    #[test]
    fn degenerate_alive_list_generates_nothing() {
        let mut rng = Rng::new(1);
        let mut pools = DestPools::new(4, 2);
        let reqs =
            generate(1e5, 0.0, 250.0, &[2], &mut pools, &mut rng);
        assert!(reqs.is_empty());
    }
}
