//! Greedy next-hop routing over the alive overlay.
//!
//! The forwarding rule every node applies, with nothing but its live
//! neighbor set (ring successors + K-ring chords + shard anchors) and
//! the latency metric:
//!
//! 1. If the destination itself is a live neighbor, deliver over that
//!    edge (one hop, no estimate beats the real thing).
//! 2. Otherwise forward to the unvisited live neighbor `v` minimizing
//!    `w(v, dst)`, breaking ties toward the lower node id.
//! 3. If every live neighbor was already visited, the request is
//!    stuck: report a routing failure (the session layer retries on a
//!    different destination).
//!
//! The visited set makes two invariants structural, and the proptests
//! in `rust/tests/proptests.rs` pin them on arbitrary connected
//! overlays: every route terminates within `n` hops (each hop claims a
//! new node), and a route over the alive sub-overlay can never touch a
//! dead node (dead nodes have no edges there). Delivered routes
//! satisfy stretch ≥ 1 by definition — the greedy path is *a* path, so
//! its latency is bounded below by the shortest one.

use crate::graph::Graph;
use crate::latency::LatencyMatrix;

/// Reusable per-worker scratch for [`greedy_route`]: a visited mask
/// sized to the universe plus the list of touched cells, so repeated
/// routes reset O(path) state instead of O(n).
pub struct RouteScratch {
    visited: Vec<bool>,
    touched: Vec<u32>,
}

impl RouteScratch {
    /// Scratch for graphs of `n` nodes.
    pub fn new(n: usize) -> RouteScratch {
        RouteScratch {
            visited: vec![false; n],
            touched: Vec::new(),
        }
    }

    fn mark(&mut self, v: u32) {
        if !self.visited[v as usize] {
            self.visited[v as usize] = true;
            self.touched.push(v);
        }
    }

    fn clear(&mut self) {
        for &v in &self.touched {
            self.visited[v as usize] = false;
        }
        self.touched.clear();
    }
}

/// Outcome of one greedy route attempt.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RouteSummary {
    /// Whether the request reached its destination.
    pub delivered: bool,
    /// Overlay hops taken (0 when `src == dst`).
    pub hops: u32,
    /// Sum of traversed edge latencies, sim-ms.
    pub latency_ms: f64,
}

/// Route one request greedily from `src` toward `dst` over `g` (the
/// alive overlay), using `w` as the distance metric. `path`, when
/// given, receives the full node sequence including `src` (cleared
/// first) — the proptests use it to check the alive/edge invariants.
/// The scratch is reset on return, so one instance serves any number
/// of sequential routes.
pub fn greedy_route(
    g: &Graph,
    w: &LatencyMatrix,
    src: u32,
    dst: u32,
    scratch: &mut RouteScratch,
    mut path: Option<&mut Vec<u32>>,
) -> RouteSummary {
    if let Some(p) = path.as_deref_mut() {
        p.clear();
        p.push(src);
    }
    let mut out = RouteSummary {
        delivered: false,
        hops: 0,
        latency_ms: 0.0,
    };
    if src == dst {
        out.delivered = true;
        return out;
    }
    let mut cur = src;
    scratch.mark(src);
    loop {
        let mut direct: Option<f32> = None;
        // (metric to dst, node id, edge latency) of the best next hop.
        let mut best: Option<(f32, u32, f32)> = None;
        for &(v, wt) in g.neighbors(cur as usize) {
            if v == dst {
                direct = Some(wt);
                break;
            }
            if scratch.visited[v as usize] {
                continue;
            }
            let key = w.get(v as usize, dst as usize);
            let better = match best {
                None => true,
                Some((bk, bv, _)) => {
                    key < bk || (key == bk && v < bv)
                }
            };
            if better {
                best = Some((key, v, wt));
            }
        }
        if let Some(wt) = direct {
            out.hops += 1;
            out.latency_ms += f64::from(wt);
            out.delivered = true;
            if let Some(p) = path.as_deref_mut() {
                p.push(dst);
            }
            break;
        }
        match best {
            // Stuck: every live neighbor already visited (or none).
            None => break,
            Some((_, v, wt)) => {
                out.hops += 1;
                out.latency_ms += f64::from(wt);
                cur = v;
                scratch.mark(v);
                if let Some(p) = path.as_deref_mut() {
                    p.push(v);
                }
            }
        }
    }
    scratch.clear();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Uniform metric: w(u, v) = |u - v| (a line embeds exactly).
    fn line_metric(n: usize) -> LatencyMatrix {
        LatencyMatrix::from_fn(n, |u, v| {
            (u as f32 - v as f32).abs()
        })
    }

    #[test]
    fn direct_neighbor_delivers_in_one_hop() {
        let g = Graph::from_weighted_edges(3, &[(0, 1, 5.0), (1, 2, 1.0)]);
        let w = line_metric(3);
        let mut s = RouteScratch::new(3);
        let r = greedy_route(&g, &w, 0, 1, &mut s, None);
        assert!(r.delivered);
        assert_eq!(r.hops, 1);
        assert_eq!(r.latency_ms, 5.0);
    }

    #[test]
    fn line_routes_end_to_end_and_sums_latency() {
        let g = Graph::from_weighted_edges(
            4,
            &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 3.0)],
        );
        let w = line_metric(4);
        let mut s = RouteScratch::new(4);
        let mut path = Vec::new();
        let r = greedy_route(&g, &w, 0, 3, &mut s, Some(&mut path));
        assert!(r.delivered);
        assert_eq!(r.hops, 3);
        assert_eq!(r.latency_ms, 6.0);
        assert_eq!(path, vec![0, 1, 2, 3]);
    }

    #[test]
    fn unreachable_destination_fails_within_n_hops() {
        // 0-1 component, 2-3 component: 0 -> 3 must fail, not spin.
        let g = Graph::from_weighted_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
        let w = line_metric(4);
        let mut s = RouteScratch::new(4);
        let r = greedy_route(&g, &w, 0, 3, &mut s, None);
        assert!(!r.delivered);
        assert!(r.hops <= 4);
    }

    #[test]
    fn self_route_is_free() {
        let g = Graph::from_weighted_edges(2, &[(0, 1, 1.0)]);
        let w = line_metric(2);
        let mut s = RouteScratch::new(2);
        let r = greedy_route(&g, &w, 1, 1, &mut s, None);
        assert!(r.delivered);
        assert_eq!(r.hops, 0);
        assert_eq!(r.latency_ms, 0.0);
    }

    #[test]
    fn scratch_resets_between_routes() {
        let g = Graph::from_weighted_edges(
            4,
            &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)],
        );
        let w = line_metric(4);
        let mut s = RouteScratch::new(4);
        let a = greedy_route(&g, &w, 0, 3, &mut s, None);
        let b = greedy_route(&g, &w, 0, 3, &mut s, None);
        assert_eq!(a, b);
    }
}
