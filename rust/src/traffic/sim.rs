//! The traffic simulator: queueing, timeouts, retries, and the report.
//!
//! [`TrafficSim`] is a streaming consumer of per-period overlay
//! snapshots (fed by [`ScenarioEngine::run_traffic`] through an
//! [`OverlayObserver`]): each period it generates the open-loop
//! arrivals for the window, routes them greedily over the alive
//! overlay, then applies per-node FIFO service capacity in arrival
//! order. A request whose queue wait would exceed the session timeout
//! — or whose route got stuck — retries on the next round-robin pool
//! destination, up to the configured retry bound, paying one timeout
//! of latency per failed attempt.
//!
//! Determinism contract (pinned by `rust/tests/traffic.rs`): the
//! report is a pure function of `(overlay timeline, seed, config)`.
//! The only parallel stage is routing, which fans request chunks over
//! [`par::scoped_map`] and reassembles results in input order; the
//! queueing pass is serial over a fully ordered sequence
//! (arrival time, then request index), so worker thread count never
//! changes a byte of the output.
//!
//! [`ScenarioEngine::run_traffic`]: crate::scenario::ScenarioEngine::run_traffic
//! [`OverlayObserver`]: super::OverlayObserver

use std::fmt::Write as _;

use crate::graph::apsp;
use crate::graph::Graph;
use crate::latency::LatencyMatrix;
use crate::metrics::Table;
use crate::obs::trace::{derive, span_id};
use crate::obs::{Obs, TrafficSlo};
use crate::par;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

use super::route::{greedy_route, RouteScratch, RouteSummary};
use super::workload::{generate, DestPools, Request};
use super::TrafficConfig;

/// Per-period traffic aggregates (one row per adaptation period,
/// aligned with the scenario report's period rows).
#[derive(Clone, Copy, Debug)]
pub struct TrafficPeriod {
    /// Period end, sim-ms.
    pub t: f64,
    /// Requests generated in the window.
    pub offered: u64,
    /// Requests that completed service.
    pub delivered: u64,
    /// Attempts abandoned because the queue wait exceeded the timeout.
    pub timeouts: u64,
    /// Retry attempts issued.
    pub retries: u64,
    /// Attempts whose greedy route got stuck or hit a dead component.
    pub routing_failures: u64,
    /// Median end-to-end latency of delivered requests, sim-ms.
    pub p50_ms: f64,
    /// 99th-percentile end-to-end latency, sim-ms.
    pub p99_ms: f64,
    /// Mean greedy-routing stretch over the period's samples (0 when
    /// no sample was taken).
    pub mean_stretch: f64,
}

/// One sampled request attempt for `traces.jsonl`: the hop-level
/// story of request → queue wait → per-hop latency →
/// deliver/timeout/retry. Rows exist only for requests whose id is a
/// multiple of [`TrafficConfig::trace_sample`].
#[derive(Clone, Debug, PartialEq)]
pub struct RequestTrace {
    /// Request id — stable across the run, the sampling key.
    pub req: u64,
    /// Attempt index (0 = first try; retries chain under it).
    pub attempt: u32,
    /// Session start, sim-ms.
    pub t0: f64,
    /// This attempt's issue time, sim-ms.
    pub t_gen: f64,
    /// Source node.
    pub src: u32,
    /// Destination node of this attempt.
    pub dst: u32,
    /// `"delivered"`, `"timeout"` or `"routing-failure"`.
    pub outcome: &'static str,
    /// Queue wait at the destination, sim-ms (0 unless routed).
    pub queue_ms: f64,
    /// End-to-end session latency, sim-ms (0 unless delivered).
    pub e2e_ms: f64,
    /// Overlay hops the greedy route took.
    pub hops: u32,
    /// Per-hop edge latencies along the greedy path, sim-ms.
    pub hop_ms: Vec<f64>,
}

/// Full traffic report for one `(scenario, topology, seed)` run.
#[derive(Clone, Debug)]
pub struct TrafficReport {
    /// Scenario name.
    pub scenario: String,
    /// Topology name (CLI spelling).
    pub topology: String,
    /// Scenario seed.
    pub seed: u64,
    /// Per-period rows, aligned with the scenario report.
    pub periods: Vec<TrafficPeriod>,
    /// Total requests generated.
    pub offered: u64,
    /// Total requests that completed service.
    pub delivered: u64,
    /// Total timed-out attempts.
    pub timeouts: u64,
    /// Total retry attempts issued.
    pub retries: u64,
    /// Total routing failures.
    pub routing_failures: u64,
    /// Median end-to-end latency over every delivered request, sim-ms.
    pub p50_ms: f64,
    /// 99th-percentile end-to-end latency, sim-ms.
    pub p99_ms: f64,
    /// Mean greedy-routing stretch over every sample (≥ 1 whenever at
    /// least one sample was taken).
    pub mean_stretch: f64,
    /// Worst sampled stretch.
    pub max_stretch: f64,
    /// Requests serviced per node (the per-node load vector; also
    /// exported as the `traffic.node_load` counter-vec).
    pub node_load: Vec<u64>,
    /// Sampled per-request hop traces (empty unless
    /// [`TrafficConfig::trace_sample`] ≥ 1).
    pub traces: Vec<RequestTrace>,
}

impl TrafficReport {
    /// Delivered ÷ offered (1.0 for an empty run).
    pub fn success_rate(&self) -> f64 {
        if self.offered == 0 {
            1.0
        } else {
            self.delivered as f64 / self.offered as f64
        }
    }

    /// Peak-to-mean per-node load over nodes that serviced at least
    /// one request (1.0 = perfectly balanced; 0 for an empty run).
    pub fn load_imbalance(&self) -> f64 {
        let loaded: Vec<f64> = self
            .node_load
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| c as f64)
            .collect();
        if loaded.is_empty() {
            return 0.0;
        }
        let mean = loaded.iter().sum::<f64>() / loaded.len() as f64;
        let max = loaded.iter().cloned().fold(0.0f64, f64::max);
        max / mean
    }

    /// Per-period table (CSV-able artifact).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "traffic {} {} seed={}",
                self.scenario, self.topology, self.seed
            ),
            &[
                "t_ms",
                "offered",
                "delivered",
                "timeouts",
                "retries",
                "routing_failures",
                "p50_ms",
                "p99_ms",
                "mean_stretch",
            ],
        );
        for p in &self.periods {
            t.row(vec![
                p.t,
                p.offered as f64,
                p.delivered as f64,
                p.timeouts as f64,
                p.retries as f64,
                p.routing_failures as f64,
                p.p50_ms,
                p.p99_ms,
                p.mean_stretch,
            ]);
        }
        t
    }

    /// One-row totals table (CSV-able artifact).
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "traffic summary {} {} seed={}",
                self.scenario, self.topology, self.seed
            ),
            &[
                "offered",
                "delivered",
                "success_rate",
                "p50_ms",
                "p99_ms",
                "mean_stretch",
                "max_stretch",
                "load_imbalance",
                "timeouts",
                "retries",
                "routing_failures",
            ],
        );
        t.row(vec![
            self.offered as f64,
            self.delivered as f64,
            self.success_rate(),
            self.p50_ms,
            self.p99_ms,
            self.mean_stretch,
            self.max_stretch,
            self.load_imbalance(),
            self.timeouts as f64,
            self.retries as f64,
            self.routing_failures as f64,
        ]);
        t
    }

    /// Deterministic human-readable rendering — the byte-determinism
    /// pins compare this string across runs and thread counts.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "traffic {} topology={} seed={}",
            self.scenario, self.topology, self.seed
        );
        let _ = writeln!(
            out,
            "  offered {}  delivered {}  success {:.4}  \
             timeouts {}  retries {}  routing_failures {}",
            self.offered,
            self.delivered,
            self.success_rate(),
            self.timeouts,
            self.retries,
            self.routing_failures
        );
        let _ = writeln!(
            out,
            "  latency p50 {:.3} ms  p99 {:.3} ms  stretch mean {:.4} \
             max {:.4}  load max/mean {:.3}",
            self.p50_ms,
            self.p99_ms,
            self.mean_stretch,
            self.max_stretch,
            self.load_imbalance()
        );
        for p in &self.periods {
            let _ = writeln!(
                out,
                "  t={:8.1}  offered {:>8}  delivered {:>8}  \
                 p50 {:>9.3}  p99 {:>9.3}  stretch {:.4}  \
                 to {:>6}  rt {:>6}  rf {:>6}",
                p.t,
                p.offered,
                p.delivered,
                p.p50_ms,
                p.p99_ms,
                p.mean_stretch,
                p.timeouts,
                p.retries,
                p.routing_failures
            );
        }
        out
    }

    /// The SLO inputs the `health.json` digest consumes.
    pub fn slo(&self) -> TrafficSlo {
        TrafficSlo {
            p99_ms: self.p99_ms,
            success_rate: self.success_rate(),
        }
    }

    /// Sampled request traces as JSONL, sorted by (request, attempt).
    /// Trace/span ids derive from the scenario seed and the request id
    /// (see [`crate::obs::trace`]) — never from wall clocks — so the
    /// export is byte-deterministic at any thread count. Each retry
    /// attempt is parented under the prior attempt's span, and the
    /// rows carry `kind`/`id`/`t_ms`/`dur_ms` so
    /// [`parse_jsonl`](crate::obs::trace::parse_jsonl) +
    /// [`assemble`](crate::obs::trace::assemble) build per-request
    /// causal chains from this file directly.
    pub fn traces_jsonl(&self) -> String {
        let mut rows: Vec<&RequestTrace> = self.traces.iter().collect();
        rows.sort_by_key(|r| (r.req, r.attempt));
        let mut out = String::new();
        for r in rows {
            let trace = derive(self.seed, "traffic", &[r.req]);
            let span =
                span_id(trace, "attempt", r.attempt as u64, r.req);
            // Sim-time extent of this attempt: session latency for a
            // delivery, the abandoning queue wait otherwise.
            let dur = if r.outcome == "delivered" {
                (r.e2e_ms - (r.t_gen - r.t0)).max(0.0)
            } else {
                r.queue_ms
            };
            let mut fields = vec![
                ("attempt", Json::num(r.attempt as f64)),
                ("dst", Json::num(r.dst as f64)),
                ("dur_ms", Json::num(dur)),
                ("e2e_ms", Json::num(r.e2e_ms)),
                ("hop_ms", Json::f64s(&r.hop_ms)),
                ("hops", Json::num(r.hops as f64)),
                (
                    "kind",
                    Json::str(if r.attempt == 0 {
                        "request"
                    } else {
                        "retry"
                    }),
                ),
                ("id", Json::num(r.req as f64)),
                ("outcome", Json::str(r.outcome)),
                ("queue_ms", Json::num(r.queue_ms)),
                ("span", Json::str(&format!("{span:016x}"))),
                ("src", Json::num(r.src as f64)),
                ("t0", Json::num(r.t0)),
                ("t_ms", Json::num(r.t_gen)),
                ("trace", Json::str(&format!("{trace:016x}"))),
            ];
            if r.attempt > 0 {
                let parent = span_id(
                    trace,
                    "attempt",
                    (r.attempt - 1) as u64,
                    r.req,
                );
                fields.push((
                    "parent",
                    Json::str(&format!("{parent:016x}")),
                ));
            }
            out.push_str(&Json::obj(fields).to_string());
            out.push('\n');
        }
        out
    }

    /// Machine-readable totals (the CI artifact payload).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::str(&self.scenario)),
            ("topology", Json::str(&self.topology)),
            ("seed", Json::num(self.seed as f64)),
            ("offered", Json::num(self.offered as f64)),
            ("delivered", Json::num(self.delivered as f64)),
            ("success_rate", Json::num(self.success_rate())),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("mean_stretch", Json::num(self.mean_stretch)),
            ("max_stretch", Json::num(self.max_stretch)),
            ("load_imbalance", Json::num(self.load_imbalance())),
            ("timeouts", Json::num(self.timeouts as f64)),
            ("retries", Json::num(self.retries as f64)),
            (
                "routing_failures",
                Json::num(self.routing_failures as f64),
            ),
            ("periods", Json::num(self.periods.len() as f64)),
        ])
    }
}

/// Streaming traffic simulator: feed one period at a time via
/// [`TrafficSim::on_period`], then [`TrafficSim::finish`].
pub struct TrafficSim {
    cfg: TrafficConfig,
    threads: usize,
    rng: Rng,
    pools: DestPools,
    /// Earliest time each node's server is free again, sim-ms.
    next_free: Vec<f64>,
    node_load: Vec<u64>,
    latencies: Vec<f64>,
    stretch_sum: f64,
    stretch_count: u64,
    stretch_max: f64,
    periods: Vec<TrafficPeriod>,
    prev_t: f64,
    offered: u64,
    delivered: u64,
    timeouts: u64,
    retries: u64,
    routing_failures: u64,
    /// Next request id (monotone across periods — the sampling key).
    req_seq: u64,
    /// Accumulated sampled attempt rows.
    trace_rows: Vec<RequestTrace>,
    obs: Obs,
}

impl TrafficSim {
    /// A simulator over a universe of `n` nodes. `seed` is the
    /// scenario seed (mixed with [`TrafficConfig::seed`] into a
    /// dedicated workload stream); `threads` caps the routing fan-out.
    pub fn new(
        n: usize,
        seed: u64,
        cfg: TrafficConfig,
        threads: usize,
    ) -> TrafficSim {
        let obs = Obs::new();
        // Pre-register the per-node load vector so snapshots always
        // carry it, even for an all-idle run.
        obs.reg.counter_vec("traffic.node_load", n);
        TrafficSim {
            threads: threads.max(1),
            rng: Rng::new(seed ^ cfg.seed ^ 0x7AFF_1C5E_ED01),
            pools: DestPools::new(n, cfg.pool),
            next_free: vec![0.0; n],
            node_load: vec![0; n],
            latencies: Vec::new(),
            stretch_sum: 0.0,
            stretch_count: 0,
            stretch_max: 0.0,
            periods: Vec::new(),
            prev_t: 0.0,
            offered: 0,
            delivered: 0,
            timeouts: 0,
            retries: 0,
            routing_failures: 0,
            req_seq: 0,
            trace_rows: Vec::new(),
            obs,
            cfg,
        }
    }

    /// The observability surface (request-latency histogram, per-node
    /// load counter-vec, timeout/retry counters).
    pub fn obs(&self) -> &Obs {
        &self.obs
    }

    /// Simulate the window `(prev_t, t]` over this period's alive
    /// overlay. `alive` must be the sorted alive node ids; `g` the
    /// alive sub-overlay weighted by the current latency view `w`.
    pub fn on_period(
        &mut self,
        t: f64,
        g: &Graph,
        w: &LatencyMatrix,
        alive: &[u32],
    ) {
        let t_prev = self.prev_t;
        self.prev_t = t;
        let reqs = generate(
            self.cfg.rate,
            t_prev,
            t,
            alive,
            &mut self.pools,
            &mut self.rng,
        );
        let offered = reqs.len() as u64;
        // Request ids are assigned in generation order, monotone
        // across periods, so the trace-sampling predicate
        // `id % trace_sample == 0` picks the same sessions on every
        // run and at every thread count.
        let mut ids: Vec<u64> =
            (self.req_seq..self.req_seq + offered).collect();
        self.req_seq += offered;
        let stride = self.cfg.trace_sample as u64;
        self.offered += offered;
        self.obs.reg.incr("traffic.offered", offered);

        let service_ms = 1000.0 / self.cfg.capacity;
        let latency_hist =
            self.obs.reg.histogram("traffic.request_latency_ms");
        let load_vec =
            self.obs.reg.counter_vec("traffic.node_load", g.n());
        let mut period_lat: Vec<f64> = Vec::with_capacity(reqs.len());
        let mut stretches: Vec<f64> = Vec::new();
        let (mut p_deliv, mut p_to, mut p_rt, mut p_rf) =
            (0u64, 0u64, 0u64, 0u64);

        let mut trace_scratch = RouteScratch::new(g.n());
        let mut trace_path: Vec<u32> = Vec::new();
        let mut attempt = 0u32;
        let mut round = reqs;
        while !round.is_empty() {
            let outcomes = route_all(g, w, &round, self.threads);
            if attempt == 0 {
                self.sample_stretch(g, &round, &outcomes, &mut stretches);
            }
            // Serial queueing pass in deterministic arrival order.
            let mut order: Vec<usize> = (0..round.len()).collect();
            order.sort_by(|&a, &b| {
                let ta = round[a].t_gen + outcomes[a].latency_ms;
                let tb = round[b].t_gen + outcomes[b].latency_ms;
                ta.partial_cmp(&tb).unwrap().then(a.cmp(&b))
            });
            let mut retry: Vec<Request> = Vec::new();
            let mut retry_ids: Vec<u64> = Vec::new();
            for idx in order {
                let r = round[idx];
                let o = outcomes[idx];
                let traced = stride > 0 && ids[idx] % stride == 0;
                if !o.delivered {
                    p_rf += 1;
                    if traced {
                        self.push_trace(
                            g,
                            w,
                            ids[idx],
                            &r,
                            "routing-failure",
                            0.0,
                            0.0,
                            &mut trace_scratch,
                            &mut trace_path,
                        );
                    }
                    retry.push(r);
                    retry_ids.push(ids[idx]);
                    continue;
                }
                let dst = r.dst as usize;
                let arrival = r.t_gen + o.latency_ms;
                let wait = (self.next_free[dst] - arrival).max(0.0);
                if wait > self.cfg.timeout_ms {
                    p_to += 1;
                    if traced {
                        self.push_trace(
                            g,
                            w,
                            ids[idx],
                            &r,
                            "timeout",
                            wait,
                            0.0,
                            &mut trace_scratch,
                            &mut trace_path,
                        );
                    }
                    retry.push(r);
                    retry_ids.push(ids[idx]);
                    continue;
                }
                let done = arrival + wait + service_ms;
                self.next_free[dst] = done;
                self.node_load[dst] += 1;
                load_vec.incr(dst, 1);
                let e2e = done - r.t0;
                latency_hist.observe(e2e);
                period_lat.push(e2e);
                p_deliv += 1;
                if traced {
                    self.push_trace(
                        g,
                        w,
                        ids[idx],
                        &r,
                        "delivered",
                        wait,
                        e2e,
                        &mut trace_scratch,
                        &mut trace_path,
                    );
                }
            }
            if retry.is_empty() || attempt >= self.cfg.retries {
                break;
            }
            // Each abandoned attempt costs one session timeout before
            // the client re-issues against the next pool destination.
            attempt += 1;
            round = retry
                .into_iter()
                .map(|r| {
                    let t_gen = r.t_gen + self.cfg.timeout_ms;
                    Request {
                        t0: r.t0,
                        t_gen,
                        src: r.src,
                        dst: self.pools.next(r.src, alive),
                        attempt,
                    }
                })
                .collect();
            ids = retry_ids;
            p_rt += round.len() as u64;
        }

        self.delivered += p_deliv;
        self.timeouts += p_to;
        self.retries += p_rt;
        self.routing_failures += p_rf;
        self.obs.reg.incr("traffic.delivered", p_deliv);
        self.obs.reg.incr("traffic.timeouts", p_to);
        self.obs.reg.incr("traffic.retries", p_rt);
        self.obs.reg.incr("traffic.routing_failures", p_rf);

        let s = Summary::of(&period_lat);
        let mean_stretch = if stretches.is_empty() {
            0.0
        } else {
            stretches.iter().sum::<f64>() / stretches.len() as f64
        };
        for &x in &stretches {
            self.stretch_sum += x;
            self.stretch_count += 1;
            self.stretch_max = self.stretch_max.max(x);
        }
        self.latencies.extend_from_slice(&period_lat);
        self.periods.push(TrafficPeriod {
            t,
            offered,
            delivered: p_deliv,
            timeouts: p_to,
            retries: p_rt,
            routing_failures: p_rf,
            p50_ms: s.p50,
            p99_ms: s.p99,
            mean_stretch,
        });
    }

    /// Stride-sample first-attempt requests and measure greedy stretch
    /// against the shortest path on the alive overlay (one Dijkstra per
    /// distinct sampled source, cached within the period).
    fn sample_stretch(
        &mut self,
        g: &Graph,
        round: &[Request],
        outcomes: &[RouteSummary],
        stretches: &mut Vec<f64>,
    ) {
        let k = self.cfg.stretch_samples.max(1);
        let stride = (round.len() / k).max(1);
        let mut dist_cache: std::collections::BTreeMap<u32, Vec<f32>> =
            std::collections::BTreeMap::new();
        let mut i = 0;
        while i < round.len() {
            let r = round[i];
            let o = outcomes[i];
            i += stride;
            if !o.delivered || r.src == r.dst {
                continue;
            }
            let dist = dist_cache
                .entry(r.src)
                .or_insert_with(|| apsp::dijkstra(g, r.src as usize));
            let d = f64::from(dist[r.dst as usize]);
            if d.is_finite() && d > 0.0 {
                stretches.push(o.latency_ms / d);
            }
        }
    }

    /// Record one sampled attempt row. Routing is a pure function of
    /// `(g, w, src, dst)`, so re-running the route serially with path
    /// capture reproduces exactly the hops the batched (possibly
    /// parallel) pass took — the trace stays thread-invariant.
    #[allow(clippy::too_many_arguments)]
    fn push_trace(
        &mut self,
        g: &Graph,
        w: &LatencyMatrix,
        req: u64,
        r: &Request,
        outcome: &'static str,
        queue_ms: f64,
        e2e_ms: f64,
        scratch: &mut RouteScratch,
        path: &mut Vec<u32>,
    ) {
        let o = greedy_route(g, w, r.src, r.dst, scratch, Some(path));
        let hop_ms: Vec<f64> = path
            .windows(2)
            .map(|e| f64::from(w.get(e[0] as usize, e[1] as usize)))
            .collect();
        self.trace_rows.push(RequestTrace {
            req,
            attempt: r.attempt,
            t0: r.t0,
            t_gen: r.t_gen,
            src: r.src,
            dst: r.dst,
            outcome,
            queue_ms,
            e2e_ms,
            hops: o.hops,
            hop_ms,
        });
    }

    /// Close the run and produce the report (consumes the simulator).
    /// Returns the [`Obs`] alongside so callers can export snapshots.
    pub fn finish(
        self,
        scenario: &str,
        topology: &str,
        seed: u64,
    ) -> (TrafficReport, Obs) {
        let s = Summary::of(&self.latencies);
        let mean_stretch = if self.stretch_count == 0 {
            0.0
        } else {
            self.stretch_sum / self.stretch_count as f64
        };
        (
            TrafficReport {
                scenario: scenario.to_string(),
                topology: topology.to_string(),
                seed,
                periods: self.periods,
                offered: self.offered,
                delivered: self.delivered,
                timeouts: self.timeouts,
                retries: self.retries,
                routing_failures: self.routing_failures,
                p50_ms: s.p50,
                p99_ms: s.p99,
                mean_stretch,
                max_stretch: self.stretch_max,
                node_load: self.node_load,
                traces: self.trace_rows,
            },
            self.obs,
        )
    }
}

/// Route a batch: serial below the fan-out threshold, otherwise
/// chunked over the worker pool. Chunk boundaries never change a
/// result — every request routes independently and results come back
/// in input order — so thread count is invisible in the output.
fn route_all(
    g: &Graph,
    w: &LatencyMatrix,
    reqs: &[Request],
    threads: usize,
) -> Vec<RouteSummary> {
    let n = g.n();
    if threads <= 1 || reqs.len() < 512 {
        let mut scratch = RouteScratch::new(n);
        return reqs
            .iter()
            .map(|r| {
                greedy_route(g, w, r.src, r.dst, &mut scratch, None)
            })
            .collect();
    }
    let chunk = reqs.len().div_ceil(threads * 4).max(1);
    let slices: Vec<&[Request]> = reqs.chunks(chunk).collect();
    par::scoped_map(slices, threads, |_, slice| {
        let mut scratch = RouteScratch::new(n);
        slice
            .iter()
            .map(|r| {
                greedy_route(g, w, r.src, r.dst, &mut scratch, None)
            })
            .collect::<Vec<RouteSummary>>()
    })
    .into_iter()
    .flatten()
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{kring, paper_k};

    fn ring_world(n: usize, seed: u64) -> (Graph, LatencyMatrix, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let w = crate::latency::Model::Uniform.sample(n, &mut rng);
        let g = kring::random_krings(n, paper_k(n), &mut rng).to_graph(&w);
        (g, w, (0..n as u32).collect())
    }

    fn run_once(threads: usize) -> (TrafficReport, Obs) {
        let (g, w, alive) = ring_world(48, 11);
        let mut sim =
            TrafficSim::new(48, 5, TrafficConfig::default(), threads);
        for p in 1..=4 {
            sim.on_period(p as f64 * 250.0, &g, &w, &alive);
        }
        sim.finish("unit", "kring", 5)
    }

    #[test]
    fn simulator_delivers_and_reports() {
        let (rep, obs) = run_once(1);
        assert!(rep.offered > 0);
        assert!(rep.success_rate() > 0.9, "{}", rep.success_rate());
        assert!(rep.p99_ms >= rep.p50_ms);
        assert!(rep.mean_stretch >= 1.0);
        assert!(rep.max_stretch >= rep.mean_stretch);
        assert_eq!(
            rep.node_load.iter().sum::<u64>(),
            rep.delivered,
            "every delivered request is serviced exactly once"
        );
        assert_eq!(obs.reg.get("traffic.delivered"), rep.delivered);
        assert_eq!(
            obs.reg.counter_vec("traffic.node_load", 48).total(),
            rep.delivered
        );
        assert_eq!(rep.periods.len(), 4);
    }

    #[test]
    fn report_is_thread_invariant_and_repeatable() {
        let (a, _) = run_once(1);
        let (b, _) = run_once(2);
        let (c, _) = run_once(8);
        let (d, _) = run_once(1);
        assert_eq!(a.render(), b.render());
        assert_eq!(a.render(), c.render());
        assert_eq!(a.render(), d.render());
        assert_eq!(a.table().to_csv(), c.table().to_csv());
        assert_eq!(
            a.summary_table().to_csv(),
            c.summary_table().to_csv()
        );
        assert_eq!(a.to_json().to_string(), c.to_json().to_string());
    }

    #[test]
    fn saturation_times_out_and_retries() {
        let (g, w, alive) = ring_world(16, 3);
        let mut cfg = TrafficConfig::default();
        cfg.rate = 100_000.0;
        cfg.capacity = 50.0; // 20 ms service: instant saturation
        cfg.timeout_ms = 5.0;
        cfg.retries = 1;
        let mut sim = TrafficSim::new(16, 1, cfg, 1);
        sim.on_period(250.0, &g, &w, &alive);
        let (rep, _) = sim.finish("sat", "kring", 1);
        assert!(rep.timeouts > 0, "saturated run must time out");
        assert!(rep.retries > 0);
        assert!(rep.success_rate() < 1.0);
    }

    #[test]
    fn sampled_request_traces_chain_attempts_and_assemble() {
        let (g, w, alive) = ring_world(16, 3);
        let mut cfg = TrafficConfig::default();
        cfg.rate = 100_000.0;
        cfg.capacity = 50.0; // saturated: timeouts force retries
        cfg.timeout_ms = 5.0;
        cfg.retries = 1;
        cfg.trace_sample = 7;
        let mut sim = TrafficSim::new(16, 1, cfg, 1);
        sim.on_period(250.0, &g, &w, &alive);
        let (rep, _) = sim.finish("sat", "kring", 1);
        assert!(!rep.traces.is_empty(), "sampling must record rows");
        for r in &rep.traces {
            assert_eq!(r.req % 7, 0, "only sampled ids are traced");
            assert_eq!(
                r.hop_ms.len() as u32,
                r.hops,
                "one latency per hop"
            );
        }
        assert!(
            rep.traces.iter().any(|r| r.attempt > 0),
            "a saturated run must trace retry attempts"
        );
        // The JSONL rows assemble into per-request causal chains:
        // every retry resolves to its prior attempt, no orphans.
        let jsonl = rep.traces_jsonl();
        let spans = crate::obs::trace::parse_jsonl(&jsonl).unwrap();
        let forest = crate::obs::trace::assemble(&spans);
        assert!(!forest.traces.is_empty());
        for tr in &forest.traces {
            assert!(tr.orphans.is_empty(), "{}", tr.render_tree());
            assert_eq!(tr.roots.len(), 1, "one root attempt per request");
        }
        // Byte-determinism: an 8-thread repeat exports identically.
        let mut sim2 = TrafficSim::new(16, 1, cfg, 8);
        sim2.on_period(250.0, &g, &w, &alive);
        let (rep2, _) = sim2.finish("sat", "kring", 1);
        assert_eq!(jsonl, rep2.traces_jsonl());
    }

    #[test]
    fn trace_sampling_off_records_nothing_and_slo_matches() {
        let (rep, _) = run_once(1);
        assert!(rep.traces.is_empty(), "trace_sample = 0 is off");
        assert_eq!(rep.traces_jsonl(), "");
        let slo = rep.slo();
        assert_eq!(slo.p99_ms, rep.p99_ms);
        assert_eq!(slo.success_rate, rep.success_rate());
    }

    #[test]
    fn empty_overlay_is_all_failures() {
        // Two alive nodes, no edges: everything is a routing failure.
        let g = Graph::empty(4);
        let w = LatencyMatrix::from_fn(4, |u, v| {
            if u == v {
                0.0
            } else {
                1.0
            }
        });
        let mut cfg = TrafficConfig::default();
        cfg.rate = 4_000.0;
        cfg.retries = 0;
        let mut sim = TrafficSim::new(4, 9, cfg, 1);
        sim.on_period(250.0, &g, &w, &[0, 1]);
        let (rep, _) = sim.finish("dead", "none", 9);
        assert_eq!(rep.delivered, 0);
        assert!(rep.routing_failures > 0);
        assert_eq!(rep.success_rate(), 0.0);
    }
}
