//! `dgro` — the DGRO membership-coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!   build     construct one overlay and report diameter vs baselines
//!   serve     run the coordinator over a churn trace (adaptive loop)
//!   measure   Algorithm-3 gossip measurement + ρ for a topology
//!   scenario  deterministic churn + dynamic-latency workloads
//!   traffic   route simulated application requests over the overlay
//!   net       run the coordinator over a real transport (UDP loopback)
//!   obs       inspect --obs-out artifacts
//!             (dump | diff | top | trace | critical | health)
//!   figures   regenerate paper figures (CSV under reports/)
//!   config    print the default config JSON
//!
//! Examples (docs/CLI.md documents every flag):
//!   dgro build --nodes 120 --model fabric --scorer pjrt
//!   dgro serve --nodes 100 --model bitnode --horizon 5000
//!   dgro scenario list
//!   dgro scenario run --name flash-crowd --topology dgro --seed 7
//!   dgro scenario run --name churn-storm --topology sharded --shards 8
//!   dgro scenario run --name anchor-storm --topology sharded \
//!       --certify hybrid --landmarks 16 --oracle-every 4
//!   dgro scenario run --name anchor-storm --transport udp --seed 0
//!   dgro scenario run --name anchor-storm --transport tcp --loss-rate 0.05
//!   dgro scenario compare --shards 8 --out reports
//!   dgro scenario compare --certify hybrid --landmarks 16 --quick
//!   dgro scenario run --name flash-crowd --obs-out obs/a
//!   dgro traffic run --name steady-state --topology dgro --rate 200000
//!   dgro traffic compare --quick --seed 7 --out reports
//!   dgro net demo --nodes 16 --transport tcp
//!   dgro scenario run --name anchor-storm --transport sim \
//!       --obs-out obs/a --trace-sample 1
//!   dgro obs top obs/a --slowest 10
//!   dgro obs critical obs/a --period 2
//!   dgro obs health obs/a
//!   dgro figures --fig 21 --quick
//!   dgro figures --all

#![allow(clippy::field_reassign_with_default)] // config-mutation idiom

use std::path::{Path, PathBuf};

use anyhow::Result;

use dgro::bench_harness::{self, runner};
use dgro::cli::Command;
use dgro::config::Config;
use dgro::coordinator::{Coordinator, ScorerKind};
use dgro::dgro::construct::best_of_starts;
use dgro::gossip::measure::{measure, MeasureConfig};
use dgro::graph::diameter;
use dgro::latency::Model;
use dgro::membership::events::EventTrace;
use dgro::scenario;
use dgro::topology::{chord::Chord, paper_k, rapid::Rapid, random_ring, shortest_ring};
use dgro::util::rng::Rng;
use dgro::{log_error, log_info};

fn main() {
    dgro::util::logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            log_error!("{e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(sub) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "build" => cmd_build(rest),
        "serve" => cmd_serve(rest),
        "measure" => cmd_measure(rest),
        "scenario" => cmd_scenario(rest),
        "traffic" => cmd_traffic(rest),
        "net" => cmd_net(rest),
        "obs" => cmd_obs(rest),
        "figures" => cmd_figures(rest),
        "config" => {
            println!("{}", Config::default().to_json().to_string());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try: help)"),
    }
}

fn print_help() {
    println!(
        "dgro — Diameter-Guided Ring Optimization membership coordinator\n\
         \n\
         subcommands:\n\
         \x20 build     construct one overlay, report diameter vs baselines\n\
         \x20 serve     run the adaptive coordinator over a churn trace\n\
         \x20 measure   gossip latency measurement + rho for a topology\n\
         \x20 scenario  churn + dynamic-latency workloads (list|run|compare)\n\
         \x20 traffic   route simulated requests over the overlay (run|compare)\n\
         \x20 net       coordinator over a real transport (demo)\n\
         \x20 obs       inspect --obs-out artifacts \
         (dump|diff|top|trace|critical|health)\n\
         \x20 figures   regenerate paper figures (CSV under reports/)\n\
         \x20 config    print the default config JSON\n\
         \n\
         pass any unknown flag to a subcommand to see its usage."
    );
}

fn base_flags(cmd: Command) -> Command {
    cmd.flag("nodes", "100", "overlay size N")
        .flag("model", "uniform", "latency model: uniform|gaussian|fabric|bitnode")
        .flag("seed", "7", "rng seed")
        .flag("k", "0", "rings per overlay (0 = log2 N)")
}

/// `--log-level` shared by serve/scenario/net/figures: an explicit
/// level overrides the `DGRO_LOG` environment default for this
/// invocation; an empty value leaves the environment's choice alone.
fn log_level_flag(cmd: Command) -> Command {
    cmd.flag(
        "log-level",
        "",
        "override log verbosity: error|warn|info|debug|trace \
         (empty = honor DGRO_LOG)",
    )
}

fn apply_log_level(spec: &str) -> Result<()> {
    if spec.is_empty() {
        return Ok(());
    }
    let level = dgro::util::logging::Level::parse(spec).ok_or_else(|| {
        anyhow::anyhow!(
            "bad --log-level '{spec}' (error|warn|info|debug|trace)"
        )
    })?;
    dgro::util::logging::set_level(level);
    Ok(())
}

fn cmd_build(raw: &[String]) -> Result<()> {
    let cmd = base_flags(Command::new("build", "construct one overlay"))
        .flag("scorer", "native", "dgro scorer: pjrt|native|greedy")
        .flag("starts", "10", "construction restarts (keep best)")
        .flag("partitions", "1", "parallel partitions (Algorithm 4)");
    let a = cmd.parse(raw)?;
    let n = a.get_usize("nodes")?;
    let seed = a.get_u64("seed")?;
    let model = Model::parse(a.get("model"))
        .ok_or_else(|| anyhow::anyhow!("bad --model"))?;
    let k = match a.get_usize("k")? {
        0 => paper_k(n),
        k => k,
    };
    let mut rng = Rng::new(seed);
    let w = model.sample(n, &mut rng);

    // Baselines.
    let d_random = diameter::diameter(
        &dgro::topology::kring::random_krings(n, k, &mut rng).to_graph(&w),
    );
    let d_chord = diameter::diameter(&Chord::build(n, &mut rng).to_graph(&w));
    let d_rapid = diameter::diameter(&Rapid::build(n, &mut rng).to_graph(&w));
    let d_nn = diameter::diameter(
        &dgro::topology::kring::hybrid_krings(&w, k, 0, &mut rng)
            .to_graph(&w),
    );

    // DGRO.
    let mut cfg = Config::default();
    cfg.nodes = n;
    cfg.model = model.name().to_string();
    cfg.scorer = a.get("scorer").to_string();
    cfg.partitions = a.get_usize("partitions")?;
    let kind = ScorerKind::parse(&cfg.scorer)?;
    let t0 = std::time::Instant::now();
    let d_dgro = if cfg.partitions > 1 {
        // Parallel construction path (Algorithm 4 per ring).
        let mut rings = Vec::new();
        for _ in 0..k {
            let base = random_ring(n, &mut rng);
            let pc = dgro::dgro::parallel::ParallelConfig::new(cfg.partitions);
            let app = cfg.clone();
            rings.push(dgro::dgro::parallel::parallel_ring(
                &w,
                &base,
                pc,
                move |_| kind.make(&app),
            )?);
        }
        diameter::diameter(
            &dgro::topology::kring::KRing::new(rings).to_graph(&w),
        )
    } else {
        let mut scorer = kind.make(&cfg);
        let (_, _, d) = best_of_starts(
            scorer.as_mut(),
            &w,
            k,
            a.get_usize("starts")?,
            &mut rng,
        )?;
        d
    };
    let dt = t0.elapsed().as_secs_f64() * 1e3;

    println!("n={n} k={k} model={} scorer={}", model.name(), cfg.scorer);
    println!("random-kring   diameter: {d_random:.2}");
    println!("chord          diameter: {d_chord:.2}");
    println!("rapid          diameter: {d_rapid:.2}");
    println!("shortest-kring diameter: {d_nn:.2}");
    println!("dgro           diameter: {d_dgro:.2}  ({dt:.0} ms)");
    Ok(())
}

fn cmd_serve(raw: &[String]) -> Result<()> {
    let cmd = log_level_flag(base_flags(Command::new(
        "serve",
        "run the adaptive coordinator",
    )))
    .flag("horizon", "5000", "sim-time horizon (ms)")
        .flag("churn", "0.0005", "membership churn rate per node-ms")
        .flag("scorer", "greedy", "ring-rebuild scorer")
        .flag("epsilon", "0.25", "rho decision band half-width")
        .flag(
            "churn-guard",
            "0",
            "skip ring swaps in periods with more than this many \
             membership events (0 = off)",
        );
    let a = cmd.parse(raw)?;
    apply_log_level(a.get("log-level"))?;
    let mut cfg = Config::default();
    cfg.nodes = a.get_usize("nodes")?;
    cfg.model = a.get("model").to_string();
    cfg.seed = a.get_u64("seed")?;
    cfg.scorer = a.get("scorer").to_string();
    cfg.epsilon = a.get_f64("epsilon")?;
    cfg.churn_guard = a.get_u64("churn-guard")?;
    let horizon = a.get_f64("horizon")?;
    let churn = a.get_f64("churn")?;

    let mut co = Coordinator::new(cfg.clone())?;
    let mut rng = Rng::new(cfg.seed ^ 0xC0FFEE);
    let trace = EventTrace::churn(cfg.nodes, horizon, churn, &mut rng);
    log_info!(
        "serving n={} model={} horizon={horizon}ms events={}",
        cfg.nodes,
        cfg.model,
        trace.len()
    );
    let rep = co.run(&trace, horizon)?;
    println!(
        "initial diameter {:.2} -> final {:.2} ({} swaps, {} alive)",
        rep.initial_diameter, rep.final_diameter, rep.swaps, rep.alive
    );
    for (t, rho, d) in rep.timeline.iter().take(20) {
        println!("t={t:8.0}ms rho={rho:.3} diameter={d:.2}");
    }
    if rep.timeline.len() > 20 {
        println!("... ({} periods total)", rep.timeline.len());
    }
    print!("{}", co.metrics.report());
    Ok(())
}

fn cmd_measure(raw: &[String]) -> Result<()> {
    let cmd = base_flags(Command::new("measure", "gossip measurement"))
        .flag("topology", "random", "random|shortest|chord|rapid")
        .flag("samples", "4", "samples per node (Algorithm 3 K)")
        .flag("rounds", "20", "gossip rounds");
    let a = cmd.parse(raw)?;
    let n = a.get_usize("nodes")?;
    let model = Model::parse(a.get("model"))
        .ok_or_else(|| anyhow::anyhow!("bad --model"))?;
    let mut rng = Rng::new(a.get_u64("seed")?);
    let w = model.sample(n, &mut rng);
    let g = match a.get("topology") {
        "random" => random_ring(n, &mut rng).to_graph(&w),
        "shortest" => shortest_ring(&w, 0).to_graph(&w),
        "chord" => Chord::build(n, &mut rng).to_graph(&w),
        "rapid" => Rapid::build(n, &mut rng).to_graph(&w),
        other => anyhow::bail!("unknown --topology {other}"),
    };
    let stats = measure(
        &w,
        &g,
        MeasureConfig {
            samples: a.get_usize("samples")?,
            rounds: a.get_usize("rounds")?,
        },
        &mut rng,
    );
    println!(
        "L_local={:.3} L_global={:.3} L_min={:.3} rho={:.3} messages={}",
        stats.local,
        stats.global,
        stats.min,
        stats.rho(),
        stats.messages
    );
    let choice = dgro::dgro::select::decide(
        &stats,
        dgro::dgro::select::SelectConfig::default(),
    );
    println!("decision: {choice:?}");
    println!("overlay diameter: {:.2}", diameter::diameter(&g));
    Ok(())
}

fn cmd_scenario(raw: &[String]) -> Result<()> {
    let cmd = Command::new(
        "scenario",
        "churn + dynamic-latency workloads; actions: list | run | compare",
    )
    .flag("name", "flash-crowd", "catalog scenario (dgro scenario list)")
    .flag("spec", "", "path to a JSON ScenarioSpec (overrides --name)")
    .flag(
        "topology",
        "dgro",
        "dgro|decentralized|sharded|chord|rapid|perigee|random|\
         circulant",
    )
    .flag("seed", "7", "rng seed (same seed => byte-identical report)")
    .flag("period", "250", "adaptation/measurement period (sim-ms)")
    .flag(
        "certify",
        "exact",
        "diameter certification for sharded and static-baseline \
         evaluations, on run and compare alike: exact|hybrid|sketch \
         (docs/SCENARIOS.md, 'Scaling & certification'; the dgro \
         compare column always certifies exactly)",
    )
    .flag(
        "landmarks",
        "16",
        "sketch/hybrid: landmark sweep budget per diameter evaluation",
    )
    .flag(
        "oracle-every",
        "8",
        "hybrid: pin the certified interval against the exact oracle \
         every k-th evaluation",
    )
    .flag(
        "shards",
        "0",
        "partition count for the sharded coordinator: run --topology \
         sharded uses it (0 = engine default), compare > 1 appends a \
         'sharded' column to the panel",
    )
    .flag(
        "threads",
        "0",
        "worker threads for static-baseline evaluation and the compare \
         cross product (0 = all cores; the dgro coordinator path is \
         unaffected)",
    )
    .flag(
        "transport",
        "",
        "run the dgro topology over a message-level transport: \
         sim|udp|tcp (empty = in-process coordinator; see \
         docs/TRANSPORT.md)",
    )
    .flag(
        "time-scale",
        "0.05",
        "udp/tcp transports: real-ms of shaped delay per sim-ms",
    )
    .flag(
        "loss-rate",
        "0",
        "transport runs: seeded per-frame drop probability in [0, 1) \
         (deterministic for a fixed --seed)",
    )
    .flag(
        "dup-rate",
        "0",
        "transport runs: seeded per-frame duplication probability in \
         [0, 1)",
    )
    .flag(
        "reorder-rate",
        "0",
        "transport runs: seeded per-frame reorder probability in \
         [0, 1) (a hit frame swaps wire order with the next one)",
    )
    .flag(
        "churn-guard",
        "0",
        "skip ring swaps in periods with more than this many membership \
         events (0 = off; centralized dgro paths only)",
    )
    .flag(
        "trace-sample",
        "0",
        "causal-trace sampling stride (0 = tracing off; s >= 1 stamps \
         every frame with trace context and records deliver spans on \
         nodes with id % s == 0); on compare, traced cells export \
         per-topology traces-<scenario>-<topology>.jsonl under --out",
    )
    .flag("out", "", "also write CSV tables under this directory")
    .flag(
        "obs-out",
        "",
        "run: write snapshot.json, metrics.prom, timeline.jsonl, \
         traces.jsonl and health.json under this directory (enables \
         span recording)",
    )
    .flag(
        "log-level",
        "",
        "override log verbosity: error|warn|info|debug|trace \
         (empty = honor DGRO_LOG)",
    )
    .switch("quick", "compare against the trimmed baseline panel")
    .switch(
        "rebuild",
        "force the from-scratch per-period rebuild on static-baseline \
         runs (perf A/B baseline; no effect on the dgro path)",
    );
    let a = cmd.parse(raw)?;
    apply_log_level(a.get("log-level"))?;
    let action =
        a.positional.first().map(|s| s.as_str()).unwrap_or("list");
    let seed = a.get_u64("seed")?;
    let period = a.get_f64("period")?;
    if !(period > 0.0) {
        anyhow::bail!("--period must be > 0, got {period}");
    }
    let threads = match a.get_usize("threads")? {
        0 => dgro::graph::eval::EvalPool::default_threads(),
        t => t,
    };
    let shards = a.get_usize("shards")?;
    match action {
        "list" => {
            for s in scenario::catalog() {
                println!(
                    "{:<18} n={:<4} alive0={:<4} horizon={:<6} \
                     model={:<8} {}",
                    s.name,
                    s.nodes,
                    s.initial_alive,
                    s.horizon,
                    s.model,
                    s.about
                );
            }
            Ok(())
        }
        "run" => {
            let spec = if a.get("spec").is_empty() {
                scenario::find(a.get("name"))?
            } else {
                scenario::ScenarioSpec::load(a.get("spec"))?
            };
            let topology = scenario::Topology::parse(a.get("topology"))?;
            let mut engine = scenario::ScenarioEngine::new(spec, seed)?;
            engine.opts.period = period;
            engine.opts.threads = threads;
            engine.opts.incremental = !a.switch("rebuild");
            engine.opts.shards = shards;
            engine.opts.certify = parse_certify(&a)?;
            if !a.get("transport").is_empty() {
                engine.opts.transport =
                    Some(dgro::net::TransportKind::parse(a.get("transport"))?);
            }
            engine.opts.time_scale = a.get_f64("time-scale")?;
            engine.opts.loss_rate = a.get_f64("loss-rate")?;
            engine.opts.dup_rate = a.get_f64("dup-rate")?;
            engine.opts.reorder_rate = a.get_f64("reorder-rate")?;
            engine.opts.churn_guard = a.get_u64("churn-guard")?;
            engine.opts.trace_sample = a.get_usize("trace-sample")?;
            let obs_out = a.get("obs-out");
            engine.opts.obs_record = !obs_out.is_empty();
            let report = engine.run(topology)?;
            print!("{}", report.render());
            if !a.get("out").is_empty() {
                runner::emit(&[report.table()], a.get("out"))?;
            }
            if !obs_out.is_empty() {
                // Wall-clock fields are only meaningful when a real
                // transport ran; sim / in-process runs export the
                // byte-deterministic timeline.
                let sim_only = matches!(
                    engine.opts.transport,
                    None | Some(dgro::net::TransportKind::Sim)
                );
                if let Some(obs) = &report.obs {
                    obs.write_dir(Path::new(obs_out), sim_only)?;
                    log_info!("obs artifacts written to {obs_out}");
                }
            }
            Ok(())
        }
        "compare" => {
            if !a.get("transport").is_empty() {
                anyhow::bail!(
                    "--transport applies to 'scenario run' only; \
                     compare always uses the in-process coordinators"
                );
            }
            if a.get_f64("loss-rate")? != 0.0
                || a.get_f64("dup-rate")? != 0.0
                || a.get_f64("reorder-rate")? != 0.0
            {
                anyhow::bail!(
                    "--loss-rate/--dup-rate/--reorder-rate apply to \
                     transport-backed 'scenario run' only"
                );
            }
            if a.get_u64("churn-guard")? != 0 {
                anyhow::bail!(
                    "--churn-guard applies to 'scenario run' only; \
                     compare runs every topology unguarded"
                );
            }
            if !a.get("obs-out").is_empty() {
                anyhow::bail!(
                    "--obs-out applies to 'scenario run' only"
                );
            }
            let mut topologies: Vec<scenario::Topology> =
                if a.switch("quick") {
                    vec![
                        scenario::Topology::Dgro,
                        scenario::Topology::Chord,
                        scenario::Topology::Rapid,
                    ]
                } else {
                    scenario::Topology::ALL.to_vec()
                };
            if shards > 1 {
                // Sharded-vs-centralized under identical conditions:
                // the extra column shares every seed/trace/latency draw.
                topologies.push(scenario::Topology::DgroSharded);
            }
            // Non-exact modes apply PR 7's upper-envelope semantics to
            // the static/sharded columns; the dgro column stays exact.
            let rep = scenario::compare_opts(
                &scenario::catalog(),
                &topologies,
                seed,
                scenario::CompareOpts {
                    period,
                    threads,
                    shards,
                    certify: parse_certify(&a)?,
                    trace_sample: a.get_usize("trace-sample")?,
                    ..scenario::CompareOpts::default()
                },
            )?;
            print!("{}", rep.render());
            if a.get("out").is_empty() {
                for t in &rep.timelines {
                    println!("\n{}", t.to_markdown());
                }
                if !rep.trace_exports.is_empty() {
                    println!(
                        "\n{} traced cells (pass --out DIR to export \
                         per-topology traces-*.jsonl)",
                        rep.trace_exports.len()
                    );
                }
            } else {
                let mut tables = vec![rep.summary.clone()];
                tables.extend(rep.timelines.iter().cloned());
                runner::emit(&tables, a.get("out"))?;
                let dir = Path::new(a.get("out"));
                for (scenario, topo, jsonl) in &rep.trace_exports {
                    let path = dir
                        .join(format!("traces-{scenario}-{topo}.jsonl"));
                    std::fs::write(&path, jsonl)?;
                }
                if !rep.trace_exports.is_empty() {
                    log_info!(
                        "{} per-topology trace timelines written to {}",
                        rep.trace_exports.len(),
                        a.get("out")
                    );
                }
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown scenario action '{other}' (list | run | compare)\n\n{}",
            cmd.usage()
        ),
    }
}

/// Shared `--certify/--landmarks/--oracle-every` parsing for the
/// scenario and traffic subcommands.
fn parse_certify(
    a: &dgro::cli::Args,
) -> Result<dgro::graph::eval::CertifyConfig> {
    let cname = a.get("certify");
    let mode =
        dgro::graph::eval::CertifyMode::parse(cname).ok_or_else(|| {
            anyhow::anyhow!(
                "--certify must be exact|hybrid|sketch, got '{cname}'"
            )
        })?;
    Ok(dgro::graph::eval::CertifyConfig {
        mode,
        budget: a.get_usize("landmarks")?,
        oracle_every: a.get_usize("oracle-every")?,
    })
}

/// Traffic-plane knobs shared by `traffic run` and `traffic compare`.
fn parse_traffic_cfg(
    a: &dgro::cli::Args,
) -> Result<dgro::traffic::TrafficConfig> {
    Ok(dgro::traffic::TrafficConfig {
        rate: a.get_f64("rate")?,
        capacity: a.get_f64("capacity")?,
        timeout_ms: a.get_f64("timeout-ms")?,
        retries: a.get_u64("retries")? as u32,
        pool: a.get_usize("pool")?,
        stretch_samples: a.get_usize("stretch-samples")?,
        seed: a.get_u64("traffic-seed")?,
        trace_sample: a.get_usize("trace-sample")?,
    })
}

fn cmd_traffic(raw: &[String]) -> Result<()> {
    let cmd = Command::new(
        "traffic",
        "route simulated application requests over the evolving \
         overlay; actions: run | compare (docs/TRAFFIC.md)",
    )
    .flag("name", "flash-crowd", "catalog scenario (dgro scenario list)")
    .flag("spec", "", "path to a JSON ScenarioSpec (overrides --name)")
    .flag(
        "topology",
        "dgro",
        "run: dgro|decentralized|sharded|chord|rapid|perigee|random|\
         circulant",
    )
    .flag("seed", "7", "rng seed (same seed => byte-identical report)")
    .flag("period", "250", "adaptation/measurement period (sim-ms)")
    .flag(
        "rate",
        "200000",
        "offered load, requests per sim-second across the cluster",
    )
    .flag(
        "capacity",
        "8000",
        "per-node service capacity, requests per sim-second",
    )
    .flag(
        "timeout-ms",
        "40",
        "session timeout before a retry (sim-ms)",
    )
    .flag(
        "retries",
        "2",
        "bounded retries per session (0 = fail on first timeout)",
    )
    .flag("pool", "4", "round-robin destination-pool size per source")
    .flag(
        "stretch-samples",
        "8",
        "stretch samples per period (each costs one Dijkstra)",
    )
    .flag("traffic-seed", "0", "extra seed for the workload stream")
    .flag(
        "trace-sample",
        "0",
        "request-trace sampling stride (0 = off; s >= 1 records the \
         full attempt history of every request with id % s == 0, \
         exported as traces.jsonl under --obs-out); transport-backed \
         runs also stamp frames with causal trace context",
    )
    .flag(
        "certify",
        "exact",
        "compare: per-topology diameter certification exact|hybrid|\
         sketch (the dgro column always certifies exactly)",
    )
    .flag(
        "landmarks",
        "16",
        "sketch/hybrid: landmark sweep budget per diameter evaluation",
    )
    .flag(
        "oracle-every",
        "8",
        "hybrid: pin the certified interval against the exact oracle \
         every k-th evaluation",
    )
    .flag(
        "shards",
        "0",
        "partition count for the sharded coordinator: run --topology \
         sharded uses it (0 = engine default), compare > 1 appends a \
         'sharded' column to the panel",
    )
    .flag(
        "threads",
        "0",
        "worker threads for routing fan-out, static-baseline \
         evaluation and the compare cross product (0 = all cores)",
    )
    .flag(
        "transport",
        "",
        "run: drive the dgro topology over a message-level transport: \
         sim|udp|tcp (empty = in-process coordinator)",
    )
    .flag(
        "time-scale",
        "0.05",
        "udp/tcp transports: real-ms of shaped delay per sim-ms",
    )
    .flag(
        "loss-rate",
        "0",
        "transport runs: seeded per-frame drop probability in [0, 1)",
    )
    .flag(
        "dup-rate",
        "0",
        "transport runs: seeded per-frame duplication probability in \
         [0, 1)",
    )
    .flag(
        "reorder-rate",
        "0",
        "transport runs: seeded per-frame reorder probability in [0, 1)",
    )
    .flag("out", "", "also write CSV tables under this directory")
    .flag(
        "obs-out",
        "",
        "run: write the traffic obs surface (request-latency \
         histogram, per-node load vector, timeout/retry counters) \
         under this directory",
    )
    .flag(
        "log-level",
        "",
        "override log verbosity: error|warn|info|debug|trace \
         (empty = honor DGRO_LOG)",
    )
    .switch("quick", "compare against the trimmed baseline panel");
    let a = cmd.parse(raw)?;
    apply_log_level(a.get("log-level"))?;
    let action =
        a.positional.first().map(|s| s.as_str()).unwrap_or("run");
    let seed = a.get_u64("seed")?;
    let period = a.get_f64("period")?;
    if !(period > 0.0) {
        anyhow::bail!("--period must be > 0, got {period}");
    }
    let threads = match a.get_usize("threads")? {
        0 => dgro::graph::eval::EvalPool::default_threads(),
        t => t,
    };
    let shards = a.get_usize("shards")?;
    let tcfg = parse_traffic_cfg(&a)?;
    match action {
        "run" => {
            let spec = if a.get("spec").is_empty() {
                scenario::find(a.get("name"))?
            } else {
                scenario::ScenarioSpec::load(a.get("spec"))?
            };
            let topology = scenario::Topology::parse(a.get("topology"))?;
            let mut engine = scenario::ScenarioEngine::new(spec, seed)?;
            engine.opts.period = period;
            engine.opts.threads = threads;
            engine.opts.shards = shards;
            engine.opts.certify = parse_certify(&a)?;
            if !a.get("transport").is_empty() {
                engine.opts.transport = Some(dgro::net::TransportKind::parse(
                    a.get("transport"),
                )?);
            }
            engine.opts.time_scale = a.get_f64("time-scale")?;
            engine.opts.loss_rate = a.get_f64("loss-rate")?;
            engine.opts.dup_rate = a.get_f64("dup-rate")?;
            engine.opts.reorder_rate = a.get_f64("reorder-rate")?;
            engine.opts.trace_sample = tcfg.trace_sample;
            let (report, traffic, obs) =
                engine.run_traffic(topology, tcfg)?;
            print!("{}", report.render());
            println!();
            print!("{}", traffic.render());
            if !a.get("out").is_empty() {
                runner::emit(
                    &[
                        report.table(),
                        traffic.table(),
                        traffic.summary_table(),
                    ],
                    a.get("out"),
                )?;
            }
            let obs_out = a.get("obs-out");
            if !obs_out.is_empty() {
                let sim_only = matches!(
                    engine.opts.transport,
                    None | Some(dgro::net::TransportKind::Sim)
                );
                let dir = Path::new(obs_out);
                obs.write_dir(dir, sim_only)?;
                // The traffic plane owns richer versions of two
                // artifacts: sampled per-request hop traces and an
                // SLO-aware health digest (p99 / success-rate checks
                // next to the fabric counters).
                std::fs::write(
                    dir.join("traces.jsonl"),
                    traffic.traces_jsonl(),
                )?;
                std::fs::write(
                    dir.join("health.json"),
                    dgro::obs::health_json(
                        &obs.reg.to_json(),
                        Some(&traffic.slo()),
                    )
                    .to_string(),
                )?;
                log_info!("traffic obs artifacts written to {obs_out}");
            }
            Ok(())
        }
        "compare" => {
            if !a.get("transport").is_empty() {
                anyhow::bail!(
                    "--transport applies to 'traffic run' only; \
                     compare always uses the in-process coordinators"
                );
            }
            if a.get_f64("loss-rate")? != 0.0
                || a.get_f64("dup-rate")? != 0.0
                || a.get_f64("reorder-rate")? != 0.0
            {
                anyhow::bail!(
                    "--loss-rate/--dup-rate/--reorder-rate apply to \
                     transport-backed 'traffic run' only"
                );
            }
            let mut topologies: Vec<scenario::Topology> =
                if a.switch("quick") {
                    vec![
                        scenario::Topology::Dgro,
                        scenario::Topology::Chord,
                        scenario::Topology::Rapid,
                    ]
                } else {
                    scenario::Topology::ALL.to_vec()
                };
            if shards > 1 {
                topologies.push(scenario::Topology::DgroSharded);
            }
            let rep = scenario::compare_opts(
                &scenario::catalog(),
                &topologies,
                seed,
                scenario::CompareOpts {
                    period,
                    threads,
                    shards,
                    certify: parse_certify(&a)?,
                    traffic: Some(tcfg),
                },
            )?;
            print!("{}", rep.render());
            if a.get("out").is_empty() {
                for t in &rep.traffic_tables {
                    println!("\n{}", t.to_markdown());
                }
            } else {
                let mut tables = vec![rep.summary.clone()];
                if let Some(ts) = &rep.traffic_summary {
                    tables.push(ts.clone());
                }
                tables.extend(rep.timelines.iter().cloned());
                tables.extend(rep.traffic_tables.iter().cloned());
                runner::emit(&tables, a.get("out"))?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown traffic action '{other}' (run | compare)\n\n{}",
            cmd.usage()
        ),
    }
}

fn cmd_net(raw: &[String]) -> Result<()> {
    let cmd = log_level_flag(base_flags(Command::new(
        "net",
        "run the coordinator over a real transport; actions: demo",
    )))
    .flag(
        "obs-out",
        "",
        "write snapshot.json, metrics.prom, timeline.jsonl, \
         traces.jsonl and health.json under this directory (enables \
         span recording)",
    )
    .flag(
        "trace-sample",
        "0",
        "causal-trace sampling stride (0 = tracing off; s >= 1 stamps \
         every frame and records deliver spans on nodes with \
         id % s == 0)",
    )
    .flag("transport", "udp", "message transport: sim|udp|tcp")
    .flag("horizon", "1000", "sim-time horizon (ms)")
    .flag("period", "250", "adaptation/measurement period (sim-ms)")
    .flag("churn", "0.001", "membership churn rate per node-ms")
    .flag(
        "time-scale",
        "0.05",
        "udp/tcp: real-ms of shaped delay per sim-ms",
    )
    .flag(
        "loss-rate",
        "0",
        "seeded per-frame drop probability in [0, 1)",
    )
    .flag(
        "dup-rate",
        "0",
        "seeded per-frame duplication probability in [0, 1)",
    )
    .flag(
        "reorder-rate",
        "0",
        "seeded per-frame reorder probability in [0, 1)",
    )
    .flag(
        "churn-guard",
        "0",
        "skip ring swaps in periods with more than this many membership \
         events (0 = off)",
    );
    let a = cmd.parse(raw)?;
    apply_log_level(a.get("log-level"))?;
    let action =
        a.positional.first().map(|s| s.as_str()).unwrap_or("demo");
    if action != "demo" {
        anyhow::bail!(
            "unknown net action '{action}' (demo)\n\n{}",
            cmd.usage()
        );
    }
    let mut cfg = Config::default();
    cfg.nodes = a.get_usize("nodes")?;
    cfg.model = a.get("model").to_string();
    cfg.seed = a.get_u64("seed")?;
    cfg.k = a.get_usize("k")?;
    cfg.scorer = "greedy".to_string();
    cfg.adapt_period_ms = a.get_f64("period")?;
    if !(cfg.adapt_period_ms > 0.0) {
        anyhow::bail!("--period must be > 0");
    }
    cfg.churn_guard = a.get_u64("churn-guard")?;
    let horizon = a.get_f64("horizon")?;
    let churn = a.get_f64("churn")?;
    let kind = dgro::net::TransportKind::parse(a.get("transport"))?;
    let model = Model::parse(&cfg.model)
        .ok_or_else(|| anyhow::anyhow!("bad --model"))?;
    let mut rng = Rng::new(cfg.seed);
    let w = model.sample(cfg.nodes, &mut rng);
    let mut trng = Rng::new(cfg.seed ^ 0xC0FFEE);
    let trace = EventTrace::churn(cfg.nodes, horizon, churn, &mut trng);
    log_info!(
        "net demo: transport={} n={} model={} horizon={horizon}ms \
         events={}",
        kind.name(),
        cfg.nodes,
        cfg.model,
        trace.len()
    );
    let scale = a.get_f64("time-scale")?;
    let base: Box<dyn dgro::net::Transport> = match kind {
        dgro::net::TransportKind::Sim => {
            Box::new(dgro::net::SimTransport::new(w.clone()))
        }
        dgro::net::TransportKind::Udp => {
            Box::new(dgro::net::UdpTransport::bind(w.clone(), scale)?)
        }
        dgro::net::TransportKind::Tcp => {
            Box::new(dgro::net::TcpTransport::bind(w.clone(), scale)?)
        }
    };
    let loss = a.get_f64("loss-rate")?;
    let dup = a.get_f64("dup-rate")?;
    let reorder = a.get_f64("reorder-rate")?;
    for (name, rate) in
        [("loss", loss), ("dup", dup), ("reorder", reorder)]
    {
        if !(0.0..1.0).contains(&rate) {
            anyhow::bail!("--{name}-rate must be in [0, 1), got {rate}");
        }
    }
    let fault = dgro::net::LossyConfig {
        drop_rate: loss,
        dup_rate: dup,
        reorder_rate: reorder,
        seed: cfg.seed,
    };
    let obs_out = a.get("obs-out");
    let trace_sample = a.get_usize("trace-sample")?;
    let sim_only = kind == dgro::net::TransportKind::Sim;
    if fault.active() {
        net_demo_run(
            cfg,
            w,
            dgro::net::LossyTransport::new(base, fault),
            &trace,
            horizon,
            obs_out,
            trace_sample,
            sim_only,
        )
    } else {
        net_demo_run(
            cfg,
            w,
            base,
            &trace,
            horizon,
            obs_out,
            trace_sample,
            sim_only,
        )
    }
}

#[allow(clippy::too_many_arguments)]
fn net_demo_run<T: dgro::net::Transport>(
    cfg: Config,
    w: dgro::latency::LatencyMatrix,
    transport: T,
    trace: &EventTrace,
    horizon: f64,
    obs_out: &str,
    trace_sample: usize,
    sim_only: bool,
) -> Result<()> {
    let t0 = std::time::Instant::now();
    let mut co = dgro::net::NetCoordinator::new(cfg, w, transport)?;
    if !obs_out.is_empty() {
        co.obs.rec.set_enabled(true);
    }
    co.trace_sample = trace_sample;
    let show = co.cfg.nodes.min(3);
    for node in 0..show {
        println!("node {node} @ {}", co.addr(node as u32));
    }
    if co.cfg.nodes > 3 {
        println!("... ({} nodes total)", co.cfg.nodes);
    }
    let rep = co.run(trace, horizon)?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "initial diameter {:.2} -> final {:.2} ({} swaps, {} alive)",
        rep.initial_diameter, rep.final_diameter, rep.swaps, rep.alive
    );
    for (t, rho, d) in rep.timeline.iter().take(20) {
        println!("t={t:8.0}ms rho={rho:.3} diameter={d:.2}");
    }
    if rep.timeline.len() > 20 {
        println!("... ({} periods total)", rep.timeline.len());
    }
    let frames = co.frames_sent();
    let rtt_err = co.obs.reg.histogram("net.rtt_abs_error_ms").mean();
    println!(
        "transport={} frames={frames} ({:.0} frames/s wall) \
         probe_rtt_abs_error={rtt_err:.3}ms lost={} stale={} retx={}",
        co.transport_name(),
        frames as f64 / wall.max(1e-9),
        co.metrics.counter("net.frames_lost"),
        co.metrics.counter("net.stale_frames"),
        co.metrics.counter("net.probe_retx")
    );
    print!("{}", co.metrics.report());
    if !obs_out.is_empty() {
        co.obs.write_dir(Path::new(obs_out), sim_only)?;
        log_info!("obs artifacts written to {obs_out}");
    }
    Ok(())
}

/// Accept either an artifact directory (as given to `--obs-out`) or a
/// direct file path; directories resolve to the named file inside.
fn obs_path(arg: &str, file: &str) -> PathBuf {
    let p = PathBuf::from(arg);
    if p.is_dir() {
        p.join(file)
    } else {
        p
    }
}

fn cmd_obs(raw: &[String]) -> Result<()> {
    let cmd = Command::new(
        "obs",
        "inspect --obs-out artifacts; actions: dump <dir> | \
         diff <a> <b> | top <dir> | trace <dir> | critical <dir> | \
         health <dir>",
    )
    .flag("slowest", "10", "top: how many spans to list")
    .flag(
        "period",
        "",
        "trace|critical: only the trace of this adaptation period \
         (empty = every trace in the timeline)",
    );
    let a = cmd.parse(raw)?;
    let action = a.positional.first().map(|s| s.as_str());
    let arg = |i: usize, what: &str| -> Result<&str> {
        a.positional
            .get(i)
            .map(|s| s.as_str())
            .ok_or_else(|| {
                anyhow::anyhow!("obs {}: missing {what}\n\n{}",
                    action.unwrap_or(""), cmd.usage())
            })
    };
    match action {
        Some("dump") => {
            let p = obs_path(arg(1, "snapshot path")?, "snapshot.json");
            print!("{}", dgro::obs::dump_snapshot(&p)?);
            Ok(())
        }
        Some("diff") => {
            let pa = obs_path(arg(1, "first snapshot")?, "snapshot.json");
            let pb = obs_path(arg(2, "second snapshot")?, "snapshot.json");
            print!("{}", dgro::obs::diff_snapshots(&pa, &pb)?);
            Ok(())
        }
        Some("top") => {
            let root = arg(1, "timeline path")?;
            let p = obs_path(root, "timeline.jsonl");
            let n = a.get_usize("slowest")?;
            print!("{}", dgro::obs::top_slowest(&p, n)?);
            // Estimator health rides along whenever the sibling
            // snapshot recorded sketch/hybrid evaluations.
            let snap = obs_path(root, "snapshot.json");
            let snap = if snap == p {
                p.parent()
                    .map(|d| d.join("snapshot.json"))
                    .unwrap_or(snap)
            } else {
                snap
            };
            print!("{}", dgro::obs::estimator_summary(&snap)?);
            Ok(())
        }
        Some("trace") => {
            let forest = obs_forest(arg(1, "timeline path")?)?;
            for t in obs_select(&forest, a.get("period"))? {
                print!("{}", t.render_tree());
            }
            Ok(())
        }
        Some("critical") => {
            // One line per causal trace: the sim-time critical path
            // (longest root-to-leaf chain) and its length — the answer
            // to "what did this period's latency consist of".
            let forest = obs_forest(arg(1, "timeline path")?)?;
            for t in obs_select(&forest, a.get("period"))? {
                let (chain, ms) = t.critical_chain();
                let period = t
                    .period()
                    .map(|p| p.to_string())
                    .unwrap_or_else(|| "-".into());
                println!(
                    "period {period} critical_ms {ms:.3}  {chain}"
                );
            }
            Ok(())
        }
        Some("health") => {
            let p = obs_path(arg(1, "health path")?, "health.json");
            let text = std::fs::read_to_string(&p).map_err(|e| {
                anyhow::anyhow!("reading {}: {e}", p.display())
            })?;
            let health = dgro::util::json::parse(&text)?;
            print!("{}", dgro::obs::health::render(&health));
            Ok(())
        }
        other => anyhow::bail!(
            "unknown obs action '{}' (dump | diff | top | trace | \
             critical | health)\n\n{}",
            other.unwrap_or(""),
            cmd.usage()
        ),
    }
}

/// Load a `timeline.jsonl` (directory or direct path) and assemble its
/// traced spans into the causal forest.
fn obs_forest(root: &str) -> Result<dgro::obs::Forest> {
    let p = obs_path(root, "timeline.jsonl");
    let text = std::fs::read_to_string(&p).map_err(|e| {
        anyhow::anyhow!("reading {}: {e}", p.display())
    })?;
    let spans = dgro::obs::trace::parse_jsonl(&text)?;
    Ok(dgro::obs::trace::assemble(&spans))
}

/// Apply the `--period` filter to an assembled forest; erroring out
/// (rather than printing nothing) when the selection is empty keeps
/// smoke scripts honest about missing traces.
fn obs_select<'f>(
    forest: &'f dgro::obs::Forest,
    period: &str,
) -> Result<Vec<&'f dgro::obs::trace::Trace>> {
    let picked: Vec<&dgro::obs::trace::Trace> = if period.is_empty() {
        forest.traces.iter().collect()
    } else {
        let p: u64 = period.parse().map_err(|_| {
            anyhow::anyhow!("--period must be an integer, got '{period}'")
        })?;
        forest.by_period(p).into_iter().collect()
    };
    if picked.is_empty() {
        anyhow::bail!(
            "no traced spans matched (was the run made with \
             --trace-sample >= 1?)"
        );
    }
    Ok(picked)
}

fn cmd_figures(raw: &[String]) -> Result<()> {
    let cmd = log_level_flag(Command::new(
        "figures",
        "regenerate paper figures",
    ))
    .flag("fig", "0", "figure number (0 with --all)")
        .flag("out", "reports", "output directory for CSVs")
        .flag("threads", "0", "evaluation worker threads (0 = all cores)")
        .switch("all", "run every figure")
        .switch("quick", "trimmed sizes/runs (CI mode)")
        .switch("full", "paper-scale budgets (fig 10 GA: 1e5 evals)");
    let a = cmd.parse(raw)?;
    apply_log_level(a.get("log-level"))?;
    let opts = bench_harness::FigureOpts {
        quick: a.switch("quick"),
        full: a.switch("full"),
        threads: a.get_usize("threads")?,
    };
    let out = a.get("out");
    let figs: Vec<usize> = if a.switch("all") {
        bench_harness::ALL_FIGURES.to_vec()
    } else {
        vec![a.get_usize("fig")?]
    };
    for fig in figs {
        log_info!(
            "regenerating figure {fig} (quick={} full={} threads={})",
            opts.quick,
            opts.full,
            opts.resolve_threads()
        );
        let tables = bench_harness::run_figure_opts(fig, opts)?;
        runner::emit(&tables, out)?;
    }
    Ok(())
}
