//! Overlay-topology builders: the two heuristic rings DGRO selects
//! between, the three state-of-the-art baselines the paper compares
//! against (Chord, RAPID, Perigee), the genetic-algorithm search
//! benchmark, and K-ring composition.

pub mod chord;
pub mod circulant;
pub mod genetic;
pub mod kring;
pub mod perigee;
pub mod rapid;

use crate::graph::ring::Ring;
use crate::graph::Graph;
use crate::latency::LatencyMatrix;
use crate::util::rng::Rng;

/// A uniformly random ring — what consistent hashing induces (the paper's
/// "random ring"; Chord/RAPID's logical rings are latency-oblivious).
pub fn random_ring(n: usize, rng: &mut Rng) -> Ring {
    Ring::new(rng.permutation(n)).expect("permutation is a valid ring")
}

/// The nearest-neighbour ("shortest") ring: from `start`, repeatedly hop
/// to the closest unvisited node (paper §V: "the shortest ring is
/// constructed by sequentially selecting the nearest available
/// neighbor"). O(N^2).
pub fn shortest_ring(w: &LatencyMatrix, start: usize) -> Ring {
    let n = w.n();
    assert!(start < n);
    let mut order = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut cur = start;
    visited[cur] = true;
    order.push(cur as u32);
    for _ in 1..n {
        let mut best = usize::MAX;
        let mut best_w = f32::INFINITY;
        let row = w.row(cur);
        for (v, &lat) in row.iter().enumerate() {
            if !visited[v] && lat < best_w {
                best = v;
                best_w = lat;
            }
        }
        debug_assert!(best != usize::MAX);
        visited[best] = true;
        order.push(best as u32);
        cur = best;
    }
    Ring::new(order).expect("nearest-neighbour order is a valid ring")
}

/// Degree budget used across the paper: each node keeps log2(N) outgoing
/// connections (§III-A), i.e. a K-ring overlay with K = max(1, log2 N).
pub fn paper_k(n: usize) -> usize {
    ((n as f64).log2().floor() as usize).max(1)
}

/// The standard connectivity-threshold radius for [`random_geometric`]:
/// `sqrt(c · ln n / n)` with c = 1.5/π, comfortably above the sharp
/// threshold `ln n / (π n)` so seeded instances are connected with
/// overwhelming probability at the scale-tier sizes.
pub fn geometric_radius(n: usize) -> f32 {
    let n = n.max(2) as f64;
    (1.5 * n.ln() / (std::f64::consts::PI * n)).sqrt() as f32
}

/// A random-geometric graph: `n` seeded points in the unit square,
/// every pair within `radius` linked with its Euclidean distance as
/// the edge weight. Built with grid bucketing (cell = radius, 3×3
/// neighborhood scan), so construction is O(n + m) and never touches
/// an n×n matrix — the scale tier's irregular counterpart to the
/// structured [`circulant::Circulant`] family.
pub fn random_geometric(n: usize, radius: f32, rng: &mut Rng) -> Graph {
    let pts: Vec<(f32, f32)> =
        (0..n).map(|_| (rng.f64() as f32, rng.f64() as f32)).collect();
    let mut g = Graph::empty(n);
    if n == 0 || radius <= 0.0 {
        return g;
    }
    // Finer than ~sqrt(n) cells buys nothing and risks a huge bin
    // table when the radius is tiny; clamping down keeps cell width
    // >= radius, which the 3x3 scan's correctness relies on.
    let max_cells = ((n as f64).sqrt().ceil() as usize).max(1);
    let cells = ((1.0 / radius).floor() as usize).clamp(1, max_cells);
    let cell_of =
        |p: f32| -> usize { ((p * cells as f32) as usize).min(cells - 1) };
    let mut bins: Vec<Vec<u32>> = vec![Vec::new(); cells * cells];
    for (i, &(x, y)) in pts.iter().enumerate() {
        bins[cell_of(y) * cells + cell_of(x)].push(i as u32);
    }
    let r2 = radius * radius;
    for (i, &(x, y)) in pts.iter().enumerate() {
        let (cx, cy) = (cell_of(x), cell_of(y));
        for gy in cy.saturating_sub(1)..=(cy + 1).min(cells - 1) {
            for gx in cx.saturating_sub(1)..=(cx + 1).min(cells - 1) {
                for &j in &bins[gy * cells + gx] {
                    // Each unordered pair once, in deterministic order.
                    if (j as usize) <= i {
                        continue;
                    }
                    let (px, py) = pts[j as usize];
                    let (dx, dy) = (px - x, py - y);
                    let d2 = dx * dx + dy * dy;
                    if d2 <= r2 {
                        g.add_edge(i, j as usize, d2.sqrt());
                    }
                }
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::synthetic;

    #[test]
    fn random_ring_is_valid() {
        let mut rng = Rng::new(3);
        for n in [3usize, 10, 57] {
            let r = random_ring(n, &mut rng);
            r.validate().unwrap();
            assert_eq!(r.n(), n);
        }
    }

    #[test]
    fn shortest_ring_valid_and_greedy_first_hop() {
        let mut rng = Rng::new(4);
        let w = synthetic::uniform(20, &mut rng);
        let r = shortest_ring(&w, 5);
        r.validate().unwrap();
        assert_eq!(r.order()[0], 5);
        // First hop is the globally nearest neighbor of the start node.
        let first = r.order()[1] as usize;
        let row = w.row(5);
        let min = (0..20)
            .filter(|&v| v != 5)
            .map(|v| row[v])
            .fold(f32::INFINITY, f32::min);
        assert_eq!(row[first], min);
    }

    #[test]
    fn shortest_ring_line_metric() {
        // Nodes on a line: NN-ring from 0 visits them in order.
        let w = LatencyMatrix::from_fn(6, |u, v| {
            (u as f32 - v as f32).abs()
        });
        let r = shortest_ring(&w, 0);
        assert_eq!(r.order(), &[0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn paper_k_values() {
        assert_eq!(paper_k(2), 1);
        assert_eq!(paper_k(50), 5);
        assert_eq!(paper_k(64), 6);
        assert_eq!(paper_k(1000), 9);
    }

    #[test]
    fn random_geometric_matches_brute_force() {
        // Grid bucketing must produce exactly the all-pairs edge set.
        for seed in [1u64, 2, 3] {
            let n = 120;
            let r = geometric_radius(n);
            let g = random_geometric(n, r, &mut Rng::new(seed));
            // Rebuild the same points (same seed draws) and compare.
            let mut rng = Rng::new(seed);
            let pts: Vec<(f32, f32)> = (0..n)
                .map(|_| (rng.f64() as f32, rng.f64() as f32))
                .collect();
            let mut brute = 0usize;
            for i in 0..n {
                for j in (i + 1)..n {
                    let (dx, dy) =
                        (pts[j].0 - pts[i].0, pts[j].1 - pts[i].1);
                    if dx * dx + dy * dy <= r * r {
                        brute += 1;
                        let hit = g
                            .neighbors(i)
                            .iter()
                            .any(|&(v, _)| v as usize == j);
                        assert!(hit, "missing edge ({i}, {j})");
                    }
                }
            }
            assert_eq!(g.m(), brute, "seed={seed}");
        }
    }

    #[test]
    fn random_geometric_is_deterministic_and_mostly_connected() {
        let n = 400;
        let r = geometric_radius(n);
        let a = random_geometric(n, r, &mut Rng::new(9));
        let b = random_geometric(n, r, &mut Rng::new(9));
        assert_eq!(a.m(), b.m());
        // The threshold radius keeps the bulk of the nodes in one
        // component (full connectivity is asymptotic, not certain).
        let labels = crate::graph::components::components(&a);
        let giant = crate::graph::components::largest(&labels);
        assert!(giant.len() >= (n * 9) / 10, "giant = {}", giant.len());
        // Degenerate inputs.
        assert_eq!(random_geometric(0, r, &mut Rng::new(1)).n(), 0);
        assert_eq!(random_geometric(5, 0.0, &mut Rng::new(1)).m(), 0);
    }
}
