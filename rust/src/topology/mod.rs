//! Overlay-topology builders: the two heuristic rings DGRO selects
//! between, the three state-of-the-art baselines the paper compares
//! against (Chord, RAPID, Perigee), the genetic-algorithm search
//! benchmark, and K-ring composition.

pub mod chord;
pub mod genetic;
pub mod kring;
pub mod perigee;
pub mod rapid;

use crate::graph::ring::Ring;
use crate::latency::LatencyMatrix;
use crate::util::rng::Rng;

/// A uniformly random ring — what consistent hashing induces (the paper's
/// "random ring"; Chord/RAPID's logical rings are latency-oblivious).
pub fn random_ring(n: usize, rng: &mut Rng) -> Ring {
    Ring::new(rng.permutation(n)).expect("permutation is a valid ring")
}

/// The nearest-neighbour ("shortest") ring: from `start`, repeatedly hop
/// to the closest unvisited node (paper §V: "the shortest ring is
/// constructed by sequentially selecting the nearest available
/// neighbor"). O(N^2).
pub fn shortest_ring(w: &LatencyMatrix, start: usize) -> Ring {
    let n = w.n();
    assert!(start < n);
    let mut order = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut cur = start;
    visited[cur] = true;
    order.push(cur as u32);
    for _ in 1..n {
        let mut best = usize::MAX;
        let mut best_w = f32::INFINITY;
        let row = w.row(cur);
        for (v, &lat) in row.iter().enumerate() {
            if !visited[v] && lat < best_w {
                best = v;
                best_w = lat;
            }
        }
        debug_assert!(best != usize::MAX);
        visited[best] = true;
        order.push(best as u32);
        cur = best;
    }
    Ring::new(order).expect("nearest-neighbour order is a valid ring")
}

/// Degree budget used across the paper: each node keeps log2(N) outgoing
/// connections (§III-A), i.e. a K-ring overlay with K = max(1, log2 N).
pub fn paper_k(n: usize) -> usize {
    ((n as f64).log2().floor() as usize).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::synthetic;

    #[test]
    fn random_ring_is_valid() {
        let mut rng = Rng::new(3);
        for n in [3usize, 10, 57] {
            let r = random_ring(n, &mut rng);
            r.validate().unwrap();
            assert_eq!(r.n(), n);
        }
    }

    #[test]
    fn shortest_ring_valid_and_greedy_first_hop() {
        let mut rng = Rng::new(4);
        let w = synthetic::uniform(20, &mut rng);
        let r = shortest_ring(&w, 5);
        r.validate().unwrap();
        assert_eq!(r.order()[0], 5);
        // First hop is the globally nearest neighbor of the start node.
        let first = r.order()[1] as usize;
        let row = w.row(5);
        let min = (0..20)
            .filter(|&v| v != 5)
            .map(|v| row[v])
            .fold(f32::INFINITY, f32::min);
        assert_eq!(row[first], min);
    }

    #[test]
    fn shortest_ring_line_metric() {
        // Nodes on a line: NN-ring from 0 visits them in order.
        let w = LatencyMatrix::from_fn(6, |u, v| {
            (u as f32 - v as f32).abs()
        });
        let r = shortest_ring(&w, 0);
        assert_eq!(r.order(), &[0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn paper_k_values() {
        assert_eq!(paper_k(2), 1);
        assert_eq!(paper_k(50), 5);
        assert_eq!(paper_k(64), 6);
        assert_eq!(paper_k(1000), 9);
    }
}
