//! Genetic-algorithm diameter search — the paper's brute-force benchmark
//! (§VII-A2: "to establish a benchmark for the lowest possible network
//! diameter, we utilized a genetic algorithm. For each graph instance,
//! the genetic algorithm will search 100,000 topologies").
//!
//! An individual is a K-ring (K permutations). Fitness = −diameter of the
//! induced overlay. Selection is tournament; crossover is order crossover
//! (OX1) per ring; mutation swaps two positions. The budget is counted in
//! *evaluated topologies* so "GA-100000" in the figures means exactly
//! what the paper ran.
//!
//! Fitness evaluation is batched: each offspring generation is bred
//! serially (so the RNG stream — and therefore the whole run — is
//! deterministic for a given seed regardless of `threads`) and then
//! scored as one [`EvalPool::diameter_batch`] across the pool. That is
//! what makes the paper's 1e5-evaluation budget tractable. Note this is
//! a deliberate scheme change from the original one-child-at-a-time
//! steady-state loop: a generation is bred against the population
//! snapshot before any of its children merge, so best-diameter
//! trajectories differ from pre-batching runs at the same seed. The
//! budget accounting (evaluated topologies) is unchanged.

use crate::graph::eval::EvalPool;
use crate::graph::Graph;
use crate::graph::ring::Ring;
use crate::latency::LatencyMatrix;
use crate::util::rng::Rng;

use super::kring::KRing;

#[derive(Clone, Copy, Debug)]
/// Knobs of the GA baseline (paper SS-VII-B3).
pub struct GaConfig {
    /// Total topology evaluations (the paper's 1e5; scale down for CI).
    pub budget: usize,
    /// Individuals per generation.
    pub population: usize,
    /// Tournament size for parent selection.
    pub tournament: usize,
    /// Per-gene mutation probability.
    pub mutation_rate: f64,
    /// Worker threads for fitness evaluation (1 = serial). Thread count
    /// never changes the result, only the wall clock.
    pub threads: usize,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            budget: 2_000,
            population: 40,
            tournament: 4,
            mutation_rate: 0.3,
            threads: 1,
        }
    }
}

/// Result of a GA run.
pub struct GaResult {
    /// Best K-ring found.
    pub best: KRing,
    /// Its overlay diameter.
    pub best_diameter: f32,
    /// Topology evaluations spent (the comparison budget axis).
    pub evaluations: usize,
}

/// Score a batch of individuals (diameter of each induced overlay) on
/// the pool. One graph per task; values match serial evaluation exactly.
fn evaluate_batch(
    pool: &EvalPool,
    w: &LatencyMatrix,
    inds: &[KRing],
) -> Vec<f32> {
    let graphs: Vec<Graph> =
        inds.iter().map(|ind| ind.to_graph(w)).collect();
    pool.diameter_batch(&graphs)
}

fn random_individual(n: usize, k: usize, rng: &mut Rng) -> KRing {
    KRing::new(
        (0..k)
            .map(|_| Ring::new(rng.permutation(n)).unwrap())
            .collect(),
    )
}

/// Order crossover (OX1): copy a random slice from parent A, fill the
/// rest in parent-B order. Preserves permutation validity.
fn ox1(a: &[u32], b: &[u32], rng: &mut Rng) -> Vec<u32> {
    let n = a.len();
    let mut i = rng.index(n);
    let mut j = rng.index(n);
    if i > j {
        std::mem::swap(&mut i, &mut j);
    }
    let mut child = vec![u32::MAX; n];
    let mut used = vec![false; n];
    for pos in i..=j {
        child[pos] = a[pos];
        used[a[pos] as usize] = true;
    }
    let mut fill = (j + 1) % n;
    for step in 0..n {
        let v = b[(j + 1 + step) % n];
        if !used[v as usize] {
            child[fill] = v;
            used[v as usize] = true;
            fill = (fill + 1) % n;
        }
    }
    debug_assert!(child.iter().all(|&x| x != u32::MAX));
    child
}

fn mutate(order: &mut [u32], rng: &mut Rng) {
    let n = order.len();
    let i = rng.index(n);
    let j = rng.index(n);
    order.swap(i, j);
}

fn tournament_pick<'a>(
    pop: &'a [(KRing, f32)],
    t: usize,
    rng: &mut Rng,
) -> &'a KRing {
    let mut best: Option<&(KRing, f32)> = None;
    for _ in 0..t {
        let cand = &pop[rng.index(pop.len())];
        if best.map_or(true, |b| cand.1 < b.1) {
            best = Some(cand);
        }
    }
    &best.unwrap().0
}

/// Run the GA; `k` rings per individual.
pub fn search(
    w: &LatencyMatrix,
    k: usize,
    cfg: GaConfig,
    rng: &mut Rng,
) -> GaResult {
    let n = w.n();
    let pool = EvalPool::new(cfg.threads);
    let pop_size = cfg.population.max(4);
    let mut evals = 0usize;

    // Seed population, scored as one parallel batch.
    let seed_inds: Vec<KRing> = (0..pop_size.min(cfg.budget.max(1)))
        .map(|_| random_individual(n, k, rng))
        .collect();
    let seed_fits = evaluate_batch(&pool, w, &seed_inds);
    evals += seed_inds.len();
    let mut pop: Vec<(KRing, f32)> =
        seed_inds.into_iter().zip(seed_fits).collect();

    let mut best = pop
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .cloned()
        .unwrap();

    while evals < cfg.budget {
        // One offspring generation: bred serially against the current
        // population snapshot, scored as a parallel batch, then merged
        // steady-state (each child replaces the then-worst individual).
        let gen_size = pop_size.min(cfg.budget - evals);
        let children: Vec<KRing> = (0..gen_size)
            .map(|_| {
                let pa = tournament_pick(&pop, cfg.tournament, rng).clone();
                let pb = tournament_pick(&pop, cfg.tournament, rng).clone();
                let rings: Vec<Ring> = (0..k)
                    .map(|r| {
                        let mut child = ox1(
                            pa.rings[r].order(),
                            pb.rings[r].order(),
                            rng,
                        );
                        if rng.chance(cfg.mutation_rate) {
                            mutate(&mut child, rng);
                        }
                        Ring::new(child).expect("OX1 preserves permutations")
                    })
                    .collect();
                KRing::new(rings)
            })
            .collect();
        let fits = evaluate_batch(&pool, w, &children);
        evals += children.len();
        for (child, fit) in children.into_iter().zip(fits) {
            if fit < best.1 {
                best = (child.clone(), fit);
            }
            let worst = pop
                .iter()
                .enumerate()
                .max_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if fit < pop[worst].1 {
                pop[worst] = (child, fit);
            }
        }
    }

    GaResult {
        best: best.0,
        best_diameter: best.1,
        evaluations: evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::diameter;
    use crate::latency::synthetic;
    use crate::topology::kring::random_krings;

    #[test]
    fn ga_result_is_identical_across_thread_counts() {
        // Breeding is serial and fitness is deterministic per graph, so
        // the whole run — not just the final value — must not depend on
        // the evaluation thread count.
        let run_with = |threads: usize| {
            let mut rng = Rng::new(77);
            let w = synthetic::uniform(20, &mut rng);
            let cfg = GaConfig {
                budget: 200,
                threads,
                ..Default::default()
            };
            search(&w, 2, cfg, &mut rng)
        };
        let serial = run_with(1);
        for threads in [2, 8] {
            let par = run_with(threads);
            assert_eq!(par.evaluations, serial.evaluations);
            assert_eq!(par.best_diameter, serial.best_diameter);
            assert_eq!(
                par.best.rings.len(),
                serial.best.rings.len()
            );
            for (a, b) in par.best.rings.iter().zip(&serial.best.rings) {
                assert_eq!(a.order(), b.order(), "threads={threads}");
            }
        }
    }

    #[test]
    fn ox1_produces_valid_permutation() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let a = rng.permutation(12);
            let b = rng.permutation(12);
            let c = ox1(&a, &b, &mut rng);
            let mut s = c.clone();
            s.sort_unstable();
            assert_eq!(s, (0..12).collect::<Vec<u32>>());
        }
    }

    #[test]
    fn ga_beats_random_on_average() {
        let mut rng = Rng::new(2);
        let w = synthetic::uniform(24, &mut rng);
        let k = 2;
        let res = search(&w, k, GaConfig::default(), &mut rng);
        assert_eq!(res.evaluations, GaConfig::default().budget);
        // Compare with the mean of random K-rings.
        let mut rand_sum = 0.0;
        for _ in 0..20 {
            let ind = random_krings(24, k, &mut rng);
            rand_sum += diameter::diameter(&ind.to_graph(&w));
        }
        let rand_mean = rand_sum / 20.0;
        assert!(
            res.best_diameter < rand_mean,
            "GA {} vs random mean {rand_mean}",
            res.best_diameter
        );
        res.best.rings.iter().for_each(|r| r.validate().unwrap());
    }

    #[test]
    fn ga_respects_budget_exactly() {
        let mut rng = Rng::new(3);
        let w = synthetic::uniform(12, &mut rng);
        let cfg = GaConfig {
            budget: 123,
            ..Default::default()
        };
        let res = search(&w, 2, cfg, &mut rng);
        assert_eq!(res.evaluations, 123);
    }

    #[test]
    fn tiny_budget_still_returns_best_seen() {
        let mut rng = Rng::new(4);
        let w = synthetic::uniform(10, &mut rng);
        let cfg = GaConfig {
            budget: 5,
            population: 10,
            ..Default::default()
        };
        let res = search(&w, 1, cfg, &mut rng);
        assert!(res.best_diameter > 0.0);
        assert_eq!(res.evaluations, 5);
    }
}
