//! RAPID K-ring overlay (Suresh et al., USENIX ATC'18) — baseline #2
//! (paper §V-A2).
//!
//! RAPID's expander topology is K rings induced by K independent
//! consistent hash functions; monitoring edges follow the rings. The
//! hashes ignore latency, so all K rings are physically random — DGRO's
//! repair (Fig 6) swaps `m` of them for shortest rings.

use crate::graph::Graph;
use crate::latency::LatencyMatrix;
use crate::util::rng::Rng;

use super::kring::{KRing, random_krings};
use super::shortest_ring;

/// A RAPID overlay is exactly a K-ring; this wrapper carries the K
/// convention (K = log2 N by default) and the DGRO swap operation.
#[derive(Clone, Debug)]
pub struct Rapid {
    /// The K random rings RAPID composes.
    pub krings: KRing,
}

impl Rapid {
    /// Build with the paper's K = log2(N) rings.
    pub fn build(n: usize, rng: &mut Rng) -> Rapid {
        let k = super::paper_k(n);
        Rapid {
            krings: random_krings(n, k, rng),
        }
    }

    /// Build with explicit K.
    pub fn build_k(n: usize, k: usize, rng: &mut Rng) -> Rapid {
        Rapid {
            krings: random_krings(n, k, rng),
        }
    }

    /// The induced overlay graph.
    pub fn to_graph(&self, w: &LatencyMatrix) -> Graph {
        self.krings.to_graph(w)
    }

    /// DGRO repair (Fig 6): replace `m` of the K random rings with
    /// shortest rings (distinct deterministic start nodes).
    pub fn with_shortest_rings(&self, w: &LatencyMatrix, m: usize) -> Rapid {
        let k = self.krings.k();
        assert!(m <= k);
        let n = self.krings.n();
        let mut out = self.clone();
        for i in 0..m {
            let start = (i * n) / m.max(1) % n;
            out.krings.replace(i, shortest_ring(w, start));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{components, diameter};
    use crate::latency::synthetic;

    #[test]
    fn rapid_uses_log_n_rings() {
        let mut rng = Rng::new(1);
        let r = Rapid::build(64, &mut rng);
        assert_eq!(r.krings.k(), 6);
    }

    #[test]
    fn rapid_connected_and_degree_bounded() {
        let mut rng = Rng::new(2);
        let w = synthetic::uniform(50, &mut rng);
        let r = Rapid::build(50, &mut rng);
        let g = r.to_graph(&w);
        assert!(components::is_connected(&g));
        assert!(g.max_degree() <= 2 * r.krings.k());
    }

    #[test]
    fn swapping_reduces_diameter_on_clustered_latency() {
        // On a strongly clustered metric (FABRIC-like), one shortest ring
        // should not hurt and typically helps the diameter.
        let mut rng = Rng::new(3);
        let w = crate::latency::fabric::sample(68, &mut rng);
        let r = Rapid::build(68, &mut rng);
        let swapped = r.with_shortest_rings(&w, 1);
        let d0 = diameter::diameter(&r.to_graph(&w));
        let d1 = diameter::diameter(&swapped.to_graph(&w));
        assert!(d1 <= d0 * 1.15, "swap should not blow up: {d0} -> {d1}");
    }

    #[test]
    fn swap_all_rings() {
        let mut rng = Rng::new(4);
        let w = synthetic::uniform(20, &mut rng);
        let r = Rapid::build_k(20, 3, &mut rng);
        let all = r.with_shortest_rings(&w, 3);
        assert_eq!(all.krings.k(), 3);
        assert!(components::is_connected(&all.to_graph(&w)));
    }
}
