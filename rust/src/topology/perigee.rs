//! Perigee neighbor selection (Mao et al., PODC'20) — baseline #3
//! (paper §V-A3).
//!
//! Perigee adapts each node's neighbor set from *observed broadcast
//! timestamps*: rounds of random-source broadcasts are simulated over the
//! current overlay; each node scores its incoming neighbors by how early
//! they delivered, keeps the best, drops the worst, and explores random
//! replacements. It is nearest-neighbor-flavored and gives no
//! connectivity guarantee — the paper therefore always pairs it with a
//! ring (random or shortest; Fig 7/11/15 show the random ring is the
//! right companion, which DGRO's ρ statistic discovers automatically).

use crate::graph::Graph;
use crate::latency::LatencyMatrix;
use crate::util::rng::Rng;

/// Tunables for the Perigee simulation.
#[derive(Clone, Copy, Debug)]
pub struct PerigeeConfig {
    /// Outgoing-neighbor budget per node (paper: log N).
    pub degree: usize,
    /// Adaptation rounds.
    pub rounds: usize,
    /// Broadcasts scored per round.
    pub broadcasts_per_round: usize,
    /// Fraction of the neighbor set replaced each round (the paper's
    /// "subset replacement"; 1/degree ≈ one neighbor per round).
    pub churn: f64,
}

impl Default for PerigeeConfig {
    fn default() -> Self {
        PerigeeConfig {
            degree: 0, // 0 = auto (log2 N)
            rounds: 10,
            broadcasts_per_round: 8,
            churn: 0.34,
        }
    }
}

/// Run Perigee and return each node's chosen neighbor set as a graph.
pub fn build(w: &LatencyMatrix, cfg: PerigeeConfig, rng: &mut Rng) -> Graph {
    let n = w.n();
    let degree = if cfg.degree == 0 {
        super::paper_k(n).max(2)
    } else {
        cfg.degree
    };

    // Outgoing neighbor lists, start random.
    let mut neighbors: Vec<Vec<u32>> = (0..n)
        .map(|u| {
            let mut set = Vec::with_capacity(degree);
            while set.len() < degree.min(n - 1) {
                let v = rng.index(n) as u32;
                if v as usize != u && !set.contains(&v) {
                    set.push(v);
                }
            }
            set
        })
        .collect();

    let mut arrival = vec![0.0f64; n];
    let mut score = vec![0.0f64; n]; // per-neighbor accumulation buffer

    for _ in 0..cfg.rounds {
        // Score accumulator: per node, per current neighbor, total
        // delivery delay over this round's broadcasts.
        let mut delay_sum: Vec<Vec<f64>> = neighbors
            .iter()
            .map(|ns| vec![0.0; ns.len()])
            .collect();

        for _ in 0..cfg.broadcasts_per_round {
            let src = rng.index(n);
            simulate_broadcast(w, &neighbors, src, &mut arrival);
            // Each node credits each incoming/outgoing neighbor with the
            // neighbor's arrival time + link latency (when the message
            // would have arrived *via that neighbor*).
            for u in 0..n {
                for (slot, &v) in neighbors[u].iter().enumerate() {
                    let via =
                        arrival[v as usize] + w.get(v as usize, u) as f64;
                    delay_sum[u][slot] += via;
                }
            }
        }

        // Adapt: drop the worst `churn` fraction, explore random
        // replacements.
        let drop_count =
            ((degree as f64 * cfg.churn).round() as usize).clamp(1, degree);
        for u in 0..n {
            // Rank slots by accumulated delay (ascending = best first).
            let mut slots: Vec<usize> = (0..neighbors[u].len()).collect();
            for (i, &s) in delay_sum[u].iter().enumerate() {
                score[i] = s;
            }
            slots.sort_by(|&a, &b| {
                delay_sum[u][a]
                    .partial_cmp(&delay_sum[u][b])
                    .unwrap()
            });
            let keep = neighbors[u].len().saturating_sub(drop_count);
            let kept: Vec<u32> =
                slots[..keep].iter().map(|&s| neighbors[u][s]).collect();
            let mut next = kept;
            while next.len() < degree.min(n - 1) {
                let v = rng.index(n) as u32;
                if v as usize != u && !next.contains(&v) {
                    next.push(v);
                }
            }
            neighbors[u] = next;
        }
    }

    let mut g = Graph::empty(n);
    for (u, ns) in neighbors.iter().enumerate() {
        for &v in ns {
            g.add_edge(u, v as usize, w.get(u, v as usize));
        }
    }
    g
}

/// Weighted-BFS (Dijkstra over the *directed-as-undirected* neighbor
/// sets) computing per-node first arrival of a broadcast from `src`.
fn simulate_broadcast(
    w: &LatencyMatrix,
    neighbors: &[Vec<u32>],
    src: usize,
    arrival: &mut [f64],
) {
    let n = neighbors.len();
    arrival.fill(f64::INFINITY);
    arrival[src] = 0.0;
    // Collect undirected adjacency on the fly via a heap walk.
    let mut heap = std::collections::BinaryHeap::new();
    heap.push(std::cmp::Reverse((ordf(0.0), src)));
    // Incoming lists: node u relays to outgoing neighbors AND the nodes
    // that chose u (TCP links are bidirectional, §III-A).
    let mut incoming: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (u, ns) in neighbors.iter().enumerate() {
        for &v in ns {
            incoming[v as usize].push(u as u32);
        }
    }
    while let Some(std::cmp::Reverse((t, u))) = heap.pop() {
        let t = f64::from_bits(t);
        if t > arrival[u] {
            continue;
        }
        let relay = |v: usize,
                     heap: &mut std::collections::BinaryHeap<
            std::cmp::Reverse<(u64, usize)>,
        >,
                     arrival: &mut [f64]| {
            let alt = t + w.get(u, v) as f64;
            if alt < arrival[v] {
                arrival[v] = alt;
                heap.push(std::cmp::Reverse((ordf(alt), v)));
            }
        };
        for &v in &neighbors[u] {
            relay(v as usize, &mut heap, arrival);
        }
        for &v in &incoming[u] {
            relay(v as usize, &mut heap, arrival);
        }
    }
}

/// Order-preserving f64 -> u64 (non-negative floats only).
#[inline]
fn ordf(x: f64) -> u64 {
    debug_assert!(x >= 0.0);
    x.to_bits()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::diameter;
    use crate::latency::{fabric, synthetic};

    #[test]
    fn perigee_respects_degree_budget() {
        let mut rng = Rng::new(1);
        let w = synthetic::uniform(30, &mut rng);
        let g = build(&w, PerigeeConfig::default(), &mut rng);
        // Outgoing budget log2(30)=4; undirected degree can exceed it
        // (incoming links) but must stay well below N.
        assert!(g.max_degree() <= 30 - 1);
        assert!(g.m() >= 30); // at least one out-edge per node
    }

    #[test]
    fn perigee_prefers_close_neighbors() {
        // On a clustered metric, adaptation should pull the average kept
        // link latency below the global average.
        let mut rng = Rng::new(2);
        let w = fabric::sample(51, &mut rng);
        let g = build(&w, PerigeeConfig::default(), &mut rng);
        let mean_kept: f64 = g
            .edges()
            .iter()
            .map(|&(_, _, lw)| lw as f64)
            .sum::<f64>()
            / g.m() as f64;
        let mean_all = w.mean_offdiag() as f64;
        assert!(
            mean_kept < mean_all * 0.9,
            "kept {mean_kept:.2} vs global {mean_all:.2}"
        );
    }

    #[test]
    fn broadcast_arrival_times_are_shortest_paths() {
        let mut rng = Rng::new(3);
        let w = synthetic::uniform(12, &mut rng);
        let neighbors: Vec<Vec<u32>> = (0..12)
            .map(|u| vec![((u + 1) % 12) as u32])
            .collect();
        let mut arrival = vec![0.0; 12];
        simulate_broadcast(&w, &neighbors, 0, &mut arrival);
        // The induced undirected graph is the ring 0-1-...-11-0; check
        // against Dijkstra on that ring.
        let mut g = Graph::empty(12);
        for u in 0..12 {
            g.add_edge(u, (u + 1) % 12, w.get(u, (u + 1) % 12));
        }
        let d = crate::graph::apsp::dijkstra(&g, 0);
        for v in 0..12 {
            assert!(
                (arrival[v] - d[v] as f64).abs() < 1e-4,
                "node {v}: {} vs {}",
                arrival[v],
                d[v]
            );
        }
    }

    #[test]
    fn perigee_alone_can_disconnect_adding_ring_fixes() {
        // The reason the paper pairs Perigee with a ring: pure
        // nearest-neighbor selection may fragment. Pairing with a random
        // ring must always restore connectivity.
        let mut rng = Rng::new(4);
        let w = fabric::sample(34, &mut rng);
        let g = build(&w, PerigeeConfig::default(), &mut rng);
        let ring = crate::topology::random_ring(34, &mut rng);
        let combined = g.union(&ring.to_graph(&w));
        assert!(crate::graph::components::is_connected(&combined));
        assert!(diameter::diameter(&combined) > 0.0);
    }
}
