//! K-ring composition: the overlay graph induced by K rings (paper §III:
//! each node keeps log(N) outgoing connections; RAPID's expander is K
//! rings from K hash functions).

use crate::graph::ring::Ring;
use crate::graph::Graph;
use crate::latency::LatencyMatrix;
use crate::util::rng::Rng;

use super::{random_ring, shortest_ring};

/// A K-ring overlay: the union of K rings over the same node set.
#[derive(Clone, Debug)]
pub struct KRing {
    /// The K rings (same node set).
    pub rings: Vec<Ring>,
}

impl KRing {
    /// Compose rings into an overlay (panics if sizes differ).
    pub fn new(rings: Vec<Ring>) -> KRing {
        assert!(!rings.is_empty());
        let n = rings[0].n();
        assert!(rings.iter().all(|r| r.n() == n), "ring sizes differ");
        KRing { rings }
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.rings[0].n()
    }

    /// Number of rings.
    pub fn k(&self) -> usize {
        self.rings.len()
    }

    /// Induced overlay graph (edge union, min weight on duplicates).
    pub fn to_graph(&self, w: &LatencyMatrix) -> Graph {
        let mut g = Graph::empty(self.n());
        for ring in &self.rings {
            for (u, v) in ring.edges() {
                g.add_edge(u as usize, v as usize, w.get(u as usize, v as usize));
            }
        }
        g
    }

    /// Replace ring `idx` with a new one.
    pub fn replace(&mut self, idx: usize, ring: Ring) {
        assert_eq!(ring.n(), self.n());
        self.rings[idx] = ring;
    }
}

/// K independent random rings (consistent-hash K-ring, RAPID-style).
pub fn random_krings(n: usize, k: usize, rng: &mut Rng) -> KRing {
    KRing::new((0..k).map(|_| random_ring(n, rng)).collect())
}

/// Hybrid: `m` random rings + `k - m` shortest rings started from
/// distinct nodes (the paper's Fig 12/16 ablation axis).
pub fn hybrid_krings(
    w: &LatencyMatrix,
    k: usize,
    m_random: usize,
    rng: &mut Rng,
) -> KRing {
    assert!(m_random <= k);
    let n = w.n();
    let mut rings = Vec::with_capacity(k);
    for _ in 0..m_random {
        rings.push(random_ring(n, rng));
    }
    for i in 0..(k - m_random) {
        // Distinct deterministic starts spread over the node set so the
        // shortest rings are not identical copies.
        let start = (i * n) / (k - m_random).max(1) % n;
        rings.push(shortest_ring(w, start));
    }
    KRing::new(rings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components;
    use crate::latency::synthetic;

    #[test]
    fn kring_degree_bound() {
        let mut rng = Rng::new(1);
        let w = synthetic::uniform(30, &mut rng);
        let kr = random_krings(30, 4, &mut rng);
        let g = kr.to_graph(&w);
        // Each ring adds exactly 2 to a node's degree, minus collisions.
        assert!(g.max_degree() <= 8);
        assert!(components::is_connected(&g));
    }

    #[test]
    fn hybrid_mix_counts() {
        let mut rng = Rng::new(2);
        let w = synthetic::uniform(24, &mut rng);
        let kr = hybrid_krings(&w, 4, 1, &mut rng);
        assert_eq!(kr.k(), 4);
        kr.rings.iter().for_each(|r| r.validate().unwrap());
        // All-shortest edge case.
        let kr0 = hybrid_krings(&w, 3, 0, &mut rng);
        assert_eq!(kr0.k(), 3);
        // All-random edge case.
        let kr3 = hybrid_krings(&w, 3, 3, &mut rng);
        assert_eq!(kr3.k(), 3);
    }

    #[test]
    fn replace_swaps_ring() {
        let mut rng = Rng::new(3);
        let w = synthetic::uniform(12, &mut rng);
        let mut kr = random_krings(12, 2, &mut rng);
        let s = shortest_ring(&w, 0);
        kr.replace(1, s.clone());
        assert_eq!(kr.rings[1], s);
    }

    #[test]
    fn union_graph_connected_even_with_one_ring() {
        let mut rng = Rng::new(4);
        let w = synthetic::uniform(10, &mut rng);
        let kr = KRing::new(vec![shortest_ring(&w, 0)]);
        assert!(components::is_connected(&kr.to_graph(&w)));
    }
}
