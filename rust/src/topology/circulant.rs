//! Circulant overlays C_n(S) — the closed-form low-diameter family
//! (Huang et al., arXiv:2201.01342) used as the scale tier's
//! known-diameter reference and synthetic workload.
//!
//! A circulant graph connects node `u` to `(u ± s) mod n` for every
//! generator `s ∈ S`. Its structure is vertex-transitive, so the
//! unit-weight (hop) diameter is a pure function of `(n, S)` and is
//! computable in O(n·|S|) by a BFS over the residues — no Dijkstra
//! over an n×n latency matrix required. That gives the scenario
//! engine's `Topology::Circulant` baseline and the hotpath bench's
//! 10^4–10^5-node tier an exact ground truth to pin the
//! [`crate::graph::eval::EvalPool::diameter_est`] interval against:
//!
//!   * `C_n({1})` is the n-cycle with diameter `⌊n/2⌋`
//!     ([`Circulant::cycle_hop_diameter`], closed form).
//!   * `C_n({1, 2, 4, …, 2^k})` ([`Circulant::power_two`]) reaches any
//!     residue greedily in at most ~2·log2(n) hops — the same degree
//!     budget as the paper's K-ring overlays ([`super::paper_k`]).

use crate::graph::Graph;
use crate::latency::LatencyMatrix;

/// A circulant graph C_n(S): node `u` links to `(u ± s) mod n`, s ∈ S.
#[derive(Clone, Debug)]
pub struct Circulant {
    n: usize,
    gens: Vec<u32>,
}

impl Circulant {
    /// Build C_n(S). Generators are deduplicated, reduced to the
    /// canonical range `1..=n/2`, and sorted; out-of-range or zero
    /// generators are dropped. Panics if `n < 3` or no generator
    /// survives (the overlay would be edgeless).
    pub fn new(n: usize, gens: &[u32]) -> Circulant {
        assert!(n >= 3, "circulant needs n >= 3, got {n}");
        let mut keep: Vec<u32> = gens
            .iter()
            .map(|&s| {
                let s = (s as usize) % n;
                // ±s and ±(n−s) induce the same chord set.
                s.min(n - s) as u32
            })
            .filter(|&s| s > 0)
            .collect();
        keep.sort_unstable();
        keep.dedup();
        assert!(
            !keep.is_empty(),
            "circulant C_{n}(S) needs at least one nonzero generator"
        );
        Circulant { n, gens: keep }
    }

    /// The power-of-two circulant C_n({1, 2, 4, …}) with generators up
    /// to n/2 — per-node degree ~2·log2(n), hop diameter O(log n).
    /// This is the scale tier's standard low-diameter construction.
    pub fn power_two(n: usize) -> Circulant {
        let mut gens = Vec::new();
        let mut s = 1u64;
        while s as usize <= n / 2 {
            gens.push(s as u32);
            s *= 2;
        }
        if gens.is_empty() {
            gens.push(1);
        }
        Circulant::new(n, &gens)
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The canonical generator set (sorted, in `1..=n/2`).
    pub fn generators(&self) -> &[u32] {
        &self.gens
    }

    /// The chord list: every `(u, (u + s) mod n)` with `u < target`
    /// normalization, deduplicated by construction (generators are
    /// canonical). `s = n/2` chords are emitted once.
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let n = self.n;
        let mut out = Vec::new();
        for &s in &self.gens {
            let s = s as usize;
            // For s = n/2 (n even), u and (u + s) pair up exactly once.
            let span = if 2 * s == n { n / 2 } else { n };
            for u in 0..span {
                let v = (u + s) % n;
                out.push((u.min(v) as u32, u.max(v) as u32));
            }
        }
        out
    }

    /// The overlay with physical latency weights.
    pub fn to_graph(&self, w: &LatencyMatrix) -> Graph {
        self.graph_with(|u, v| w.get(u as usize, v as usize))
    }

    /// The overlay with a synthetic per-edge weight function — how the
    /// scale tier builds 10^5-node graphs without materializing an n²
    /// latency matrix.
    pub fn graph_with(
        &self,
        mut weight: impl FnMut(u32, u32) -> f32,
    ) -> Graph {
        let mut g = Graph::empty(self.n);
        for (u, v) in self.edges() {
            g.add_edge(u as usize, v as usize, weight(u, v));
        }
        g
    }

    /// The unit-weight overlay (every chord costs 1), whose diameter
    /// equals [`Circulant::hop_diameter`].
    pub fn unit_graph(&self) -> Graph {
        self.graph_with(|_, _| 1.0)
    }

    /// Exact hop diameter from the circulant structure: BFS over the
    /// residues 0..n stepping ±s per generator. O(n·|S|) — the
    /// closed-form-grade ground truth the scale tier certifies
    /// estimator intervals against (vertex-transitivity makes the
    /// eccentricity of residue 0 the diameter).
    pub fn hop_diameter(&self) -> usize {
        let n = self.n;
        let mut dist = vec![usize::MAX; n];
        let mut frontier = vec![0usize];
        dist[0] = 0;
        let mut hops = 0;
        let mut far = 0;
        while !frontier.is_empty() {
            hops += 1;
            let mut next = Vec::new();
            for &u in &frontier {
                for &s in &self.gens {
                    let s = s as usize;
                    for v in [(u + s) % n, (u + n - s) % n] {
                        if dist[v] == usize::MAX {
                            dist[v] = hops;
                            far = hops;
                            next.push(v);
                        }
                    }
                }
            }
            frontier = next;
        }
        far
    }

    /// Closed form for the plain cycle C_n({1}): `⌊n/2⌋`.
    pub fn cycle_hop_diameter(n: usize) -> usize {
        n / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{components, diameter};
    use crate::latency::Model;
    use crate::util::rng::Rng;

    #[test]
    fn cycle_matches_closed_form() {
        for n in [3usize, 4, 9, 16, 101] {
            let c = Circulant::new(n, &[1]);
            assert_eq!(c.hop_diameter(), Circulant::cycle_hop_diameter(n));
            // The unit-weight graph diameter agrees with the formula.
            let d = diameter::diameter(&c.unit_graph());
            assert_eq!(d as usize, n / 2, "n={n}");
        }
    }

    #[test]
    fn hop_diameter_matches_graph_diameter() {
        for (n, gens) in [
            (12usize, vec![1u32, 3]),
            (30, vec![2, 7]),
            (64, vec![1, 8, 31]),
        ] {
            let c = Circulant::new(n, &gens);
            let g = c.unit_graph();
            if components::is_connected(&g) {
                let d = diameter::diameter(&g) as usize;
                assert_eq!(c.hop_diameter(), d, "C_{n}({gens:?})");
            }
        }
    }

    #[test]
    fn power_two_is_logarithmic_and_connected() {
        for n in [8usize, 64, 100, 1000] {
            let c = Circulant::power_two(n);
            let g = c.unit_graph();
            assert!(components::is_connected(&g));
            let bound = 2 * (n as f64).log2().ceil() as usize + 1;
            assert!(
                c.hop_diameter() <= bound,
                "n={n}: {} > {bound}",
                c.hop_diameter()
            );
            // Degree budget ~2 per generator.
            assert!(g.max_degree() <= 2 * c.generators().len());
        }
    }

    #[test]
    fn generators_canonicalized() {
        // 9 ≡ −3 (mod 12), duplicates and zeros drop out.
        let c = Circulant::new(12, &[3, 9, 0, 3, 15]);
        assert_eq!(c.generators(), &[3]);
        // s = n/2 emits each chord once.
        let half = Circulant::new(8, &[4]);
        assert_eq!(half.edges().len(), 4);
        let g = half.unit_graph();
        assert_eq!(g.m(), 4);
    }

    #[test]
    fn latency_weighted_graph_uses_matrix() {
        let mut rng = Rng::new(5);
        let w = Model::Uniform.sample(16, &mut rng);
        let g = Circulant::power_two(16).to_graph(&w);
        assert!(components::is_connected(&g));
        for u in 0..16 {
            for &(v, wt) in g.neighbors(u) {
                assert_eq!(wt, w.get(u, v as usize));
            }
        }
    }
}
